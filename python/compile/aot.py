"""AOT compile path: lower every L2 entry point to HLO text + manifest.

Run once by ``make artifacts``; Python never executes on the request path.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md
and DESIGN.md).

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.models import tiny_diffusion, tiny_llama, tiny_whisper


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for the loader)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def all_entry_points():
    eps = []
    eps.extend(tiny_llama.entry_points())
    eps.extend(tiny_diffusion.entry_points())
    eps.extend(tiny_whisper.entry_points())
    return eps


def render_manifest_line(name, filename, shapes, n_outputs):
    specs = ";".join("f32:" + "x".join(str(d) for d in shape) for shape in shapes)
    return f"{name}|{filename}|{specs}|{n_outputs}"


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = ["# built by python/compile/aot.py — do not edit"]
    for name, fn, shapes in all_entry_points():
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        # Count outputs by evaluating the abstract signature.
        out = jax.eval_shape(fn, *specs)
        n_outputs = len(out) if isinstance(out, (tuple, list)) else 1
        filename = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, filename)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(render_manifest_line(name, filename, shapes, n_outputs))
        print(f"  {name}: {len(text)} chars, {n_outputs} outputs -> {filename}")

    manifest_path = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest_path, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest_path} ({len(manifest_lines) - 1} artifacts)")


if __name__ == "__main__":
    main()
