"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the L1 kernels are pinned against in
``python/tests/test_kernels.py`` (hypothesis sweeps + assert_allclose).
Kept deliberately boring: direct textbook implementations, no tiling.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, scale=None):
    """Single-head scaled dot-product attention.

    q: [Sq, d], k: [Sk, d], v: [Sk, d] -> [Sq, d]
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    logits = (q @ k.T) * scale
    weights = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return weights @ v


def mha_ref(q, k, v):
    """Multi-head attention over [H, S, d] tensors."""
    return jnp.stack([attention_ref(q[h], k[h], v[h]) for h in range(q.shape[0])])


def rmsnorm_ref(x, weight, eps=1e-6):
    """Root-mean-square layer norm. x: [S, D], weight: [D]."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * weight
