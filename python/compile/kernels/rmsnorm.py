"""L1 Pallas kernel: fused RMSNorm.

§5.1's "bounded intermediate results" advice applied to normalization: one
VMEM-resident pass fuses the mean-square reduction, rsqrt, and scale so no
intermediate ever round-trips to HBM (contrast with the unfused jnp version,
which materializes ``x*x`` and the broadcasted rsqrt).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 16


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...]  # [block_rows, D] in VMEM
    w = w_ref[...]  # [D]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * w


def rmsnorm(x, weight, *, eps=1e-6, block_rows=DEFAULT_BLOCK_ROWS):
    """Fused RMSNorm: x [S, D], weight [D] -> [S, D].

    S must be a multiple of block_rows.
    """
    seq, d = x.shape
    block_rows = min(block_rows, seq)
    assert seq % block_rows == 0, f"seq={seq} not a multiple of {block_rows}"
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(seq // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq, d), x.dtype),
        interpret=True,
    )(x, weight)
