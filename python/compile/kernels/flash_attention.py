"""L1 Pallas kernel: tiled flash attention.

The paper's §4.1/§5.1 finding is that ImageGen's *generic* attention kernel
needs >150 registers/thread (all the logits and softmax intermediates live
in registers), capping SM occupancy at 1 block/SM. The TPU re-expression of
that insight (DESIGN.md §3) is this kernel: Q is tiled into VMEM blocks via
``BlockSpec`` (VMEM plays the scratchpad role of CUDA shared memory), K/V
tiles are streamed through an **online-softmax accumulator**, so the working
set is O(block) regardless of sequence length and the contractions hit the
MXU with lane-aligned shapes.

Runs with ``interpret=True`` — the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU performance is estimated from the block shapes in
DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. On a real TPU these would be (128, 128) to match the
# MXU systolic array; the tiny models use 16 to exercise multi-tile grids
# at small sequence lengths.
DEFAULT_BLOCK_Q = 16
DEFAULT_BLOCK_K = 16


def _flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, scale):
    """One grid step: one Q tile against all K/V tiles (online softmax)."""
    q = q_ref[...]  # [block_q, d] — staged into VMEM by BlockSpec
    seq_k, d = k_ref.shape
    num_k_blocks = seq_k // block_k

    def body(i, carry):
        acc, row_max, row_sum = carry
        k_tile = k_ref[pl.dslice(i * block_k, block_k), :]  # stream K tile
        v_tile = v_ref[pl.dslice(i * block_k, block_k), :]  # stream V tile
        logits = (q @ k_tile.T) * scale  # [block_q, block_k] on the MXU
        tile_max = jnp.max(logits, axis=-1)
        new_max = jnp.maximum(row_max, tile_max)
        # Rescale the running accumulator to the new max (online softmax).
        correction = jnp.exp(row_max - new_max)
        p = jnp.exp(logits - new_max[:, None])
        new_sum = row_sum * correction + p.sum(axis=-1)
        new_acc = acc * correction[:, None] + p @ v_tile
        return new_acc, new_max, new_sum

    block_q = q.shape[0]
    init = (
        jnp.zeros((block_q, d), dtype=q.dtype),
        jnp.full((block_q,), -jnp.inf, dtype=q.dtype),
        jnp.zeros((block_q,), dtype=q.dtype),
    )
    acc, _, row_sum = jax.lax.fori_loop(0, num_k_blocks, body, init)
    o_ref[...] = acc / row_sum[:, None]


def flash_attention(q, k, v, *, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Single-head attention: q [Sq, d], k/v [Sk, d] -> [Sq, d].

    Sq must be a multiple of block_q and Sk of block_k (the tiny models are
    sized accordingly; the test suite sweeps the valid lattice).
    """
    seq_q, d = q.shape
    seq_k = k.shape[0]
    # Shrink tiles for short sequences (decode steps have seq_q == 1).
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    assert seq_q % block_q == 0, f"seq_q={seq_q} not a multiple of {block_q}"
    assert seq_k % block_k == 0, f"seq_k={seq_k} not a multiple of {block_k}"
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_flash_attention_kernel, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(seq_q // block_q,),
        in_specs=[
            # Q: one tile per grid step, staged into VMEM.
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            # K/V: full arrays visible; the kernel streams tiles itself.
            pl.BlockSpec((seq_k, d), lambda i: (0, 0)),
            pl.BlockSpec((seq_k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq_q, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)


def mha(q, k, v, **kw):
    """Multi-head wrapper: [H, S, d] tensors, vmapped over heads."""
    return jax.vmap(lambda qh, kh, vh: flash_attention(qh, kh, vh, **kw))(q, k, v)


def vmem_bytes(block_q, block_k, d, dtype_bytes=4):
    """Estimated VMEM working set of one grid step (perf model, DESIGN §8):
    Q tile + K tile + V tile + accumulator + softmax state."""
    q_tile = block_q * d
    kv_tiles = 2 * block_k * d
    acc = block_q * d
    softmax_state = 2 * block_q
    logits = block_q * block_k
    return (q_tile + kv_tiles + acc + softmax_state + logits) * dtype_bytes
