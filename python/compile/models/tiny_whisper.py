"""L2 model: tiny-whisper — the LiveCaptions encoder-decoder analogue.

* ``encode(mel)`` — the parallel audio encoder: mel features are projected
  and passed through transformer blocks (the paper's high-SMOCC phase).
* ``decode_step(y, enc)`` — one autoregressive decoder step with cross-
  attention to the encoder output (the low-SMOCC tiny-kernel phase).
"""

import numpy as np
import jax.numpy as jnp

from compile.models.common import TransformerBlock, dense_params

D_MODEL = 64
N_HEADS = 4
D_FF = 128
MEL_BINS = 80
AUDIO_FRAMES = 96   # ~2 s segment after feature extraction
ENC_TOKENS = 48     # 2x temporal downsampling
VOCAB = 256


class TinyWhisper:
    def __init__(self, seed=2):
        rng = np.random.RandomState(seed)
        self.in_proj = dense_params(rng, MEL_BINS, D_MODEL)
        self.enc_blocks = [TransformerBlock(rng, D_MODEL, N_HEADS, D_FF) for _ in range(2)]
        self.dec_self = TransformerBlock(rng, D_MODEL, N_HEADS, D_FF)
        self.dec_cross = TransformerBlock(rng, D_MODEL, N_HEADS, D_FF)
        self.unembed = dense_params(rng, D_MODEL, VOCAB)

    def encode(self, mel):
        """mel: [AUDIO_FRAMES, MEL_BINS] -> (enc [ENC_TOKENS, D_MODEL],)."""
        x = mel @ self.in_proj  # [AUDIO_FRAMES, D]
        # 2x temporal downsample (strided conv stand-in).
        x = x.reshape(ENC_TOKENS, 2, D_MODEL).mean(axis=1)
        for b in self.enc_blocks:
            x = b(x)
        return (x,)

    def decode_step(self, y, enc):
        """One decoder token step.

        y: [1, D_MODEL] current token embedding; enc: [ENC_TOKENS, D_MODEL].
        Returns (logits [1, VOCAB],).
        """
        h = self.dec_self(y, kv=(y, y))
        h = self.dec_cross(h, kv=(enc, enc))
        return (h @ self.unembed,)


def entry_points():
    model = TinyWhisper(seed=2)
    return [
        ("tiny_whisper_encode", model.encode, [(AUDIO_FRAMES, MEL_BINS)]),
        ("tiny_whisper_decode", model.decode_step, [(1, D_MODEL), (ENC_TOKENS, D_MODEL)]),
    ]
