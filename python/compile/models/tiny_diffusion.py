"""L2 model: tiny-diffusion — the ImageGen denoise-step analogue.

One denoising step of an attention-based latent diffusion transformer
(SD-3-style MMDiT, simplified): latent patch tokens pass through transformer
blocks with a timestep conditioning signal; the output is the predicted
noise for that step. The L3 ImageGen app invokes this once per simulated
denoise step when artifacts are loaded.
"""

import numpy as np
import jax.numpy as jnp

from compile.models.common import TransformerBlock, dense_params

D_MODEL = 64
N_HEADS = 4
D_FF = 128
N_BLOCKS = 2
LATENT_TOKENS = 64  # 8x8 patch grid


class TinyDiffusion:
    def __init__(self, seed=1):
        rng = np.random.RandomState(seed)
        self.blocks = [TransformerBlock(rng, D_MODEL, N_HEADS, D_FF) for _ in range(N_BLOCKS)]
        self.t_proj = dense_params(rng, 1, D_MODEL)
        self.out_proj = dense_params(rng, D_MODEL, D_MODEL)

    def step(self, latents, t):
        """latents: [LATENT_TOKENS, D_MODEL]; t: [1, 1] timestep in [0, 1].

        Returns (eps_prediction [LATENT_TOKENS, D_MODEL],).
        """
        # AdaLN-style conditioning, radically simplified: add the projected
        # timestep embedding to every token.
        cond = jnp.tanh(t @ self.t_proj)  # [1, D]
        x = latents + cond
        for b in self.blocks:
            x = b(x)
        return (x @ self.out_proj,)


def entry_points():
    model = TinyDiffusion(seed=1)
    return [
        (
            "tiny_diffusion_step",
            model.step,
            [(LATENT_TOKENS, D_MODEL), (1, 1)],
        ),
    ]
