"""L2 model: tiny-llama — the Chatbot/DeepResearch backbone analogue.

A small decoder-only transformer with two entry points:

* ``prefill(x)``  — embed a [S] prompt (already embedded as [S, D] f32) and
  produce logits for every position.
* ``decode(x, ctx)`` — one decode step: the current token embedding [1, D]
  attends over the cached context [T, D].

Sizes are deliberately tiny (D=64, 2 blocks) so AOT compilation and the
per-request PJRT executions stay cheap; the *footprint* of the production
model lives in the L3 kernel-trace profiles, not here.
"""

import numpy as np
import jax.numpy as jnp

from compile.models.common import TransformerBlock, dense_params

D_MODEL = 64
N_HEADS = 4
D_FF = 128
N_BLOCKS = 2
VOCAB = 256
PREFILL_SEQ = 32
CONTEXT = 32


class TinyLlama:
    def __init__(self, seed=0):
        rng = np.random.RandomState(seed)
        self.blocks = [TransformerBlock(rng, D_MODEL, N_HEADS, D_FF) for _ in range(N_BLOCKS)]
        self.unembed = dense_params(rng, D_MODEL, VOCAB)
        self.final_norm = jnp.ones((D_MODEL,), jnp.float32)

    def prefill(self, x):
        """x: [PREFILL_SEQ, D_MODEL] -> logits [PREFILL_SEQ, VOCAB]."""
        for b in self.blocks:
            x = b(x)
        from compile.kernels.rmsnorm import rmsnorm

        x = rmsnorm(x, self.final_norm)
        return (x @ self.unembed,)

    def decode(self, x, ctx):
        """One decode step.

        x: [1, D_MODEL] current-token embedding; ctx: [CONTEXT, D_MODEL]
        cached context. Returns (logits [1, VOCAB], updated ctx).
        """
        h = x
        for b in self.blocks:
            h = b(h, kv=(ctx, ctx))
        from compile.kernels.rmsnorm import rmsnorm

        h = rmsnorm(h, self.final_norm)
        logits = h @ self.unembed
        # Roll the context window and append the new hidden state —
        # the KV-cache update the Rust side sees as an output buffer.
        new_ctx = jnp.concatenate([ctx[1:], h], axis=0)
        return (logits, new_ctx)


def entry_points():
    """(name, fn, input_shapes) triples for aot.py."""
    model = TinyLlama(seed=0)
    return [
        (
            "tiny_llama_prefill",
            model.prefill,
            [(PREFILL_SEQ, D_MODEL)],
        ),
        (
            "tiny_llama_decode",
            model.decode,
            [(1, D_MODEL), (CONTEXT, D_MODEL)],
        ),
    ]
