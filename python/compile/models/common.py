"""Shared pieces for the tiny L2 models.

Weights are deterministic numpy constants (seeded per model) baked into the
jitted functions — there is no training here; the models exist so every
simulated request exercises a *real* lowered computation through PJRT, with
the L1 Pallas kernels inlined into the same HLO.
"""

import numpy as np
import jax.numpy as jnp

from compile.kernels.flash_attention import mha
from compile.kernels.rmsnorm import rmsnorm


def dense_params(rng, d_in, d_out):
    """Xavier-ish initialization as an f32 constant."""
    scale = np.sqrt(2.0 / (d_in + d_out))
    return jnp.asarray(rng.randn(d_in, d_out) * scale, jnp.float32)


class TransformerBlock:
    """Pre-norm transformer block over [S, D] using the Pallas kernels."""

    def __init__(self, rng, d_model, n_heads, d_ff):
        assert d_model % n_heads == 0
        self.d_model = d_model
        self.n_heads = n_heads
        self.d_head = d_model // n_heads
        self.wq = dense_params(rng, d_model, d_model)
        self.wk = dense_params(rng, d_model, d_model)
        self.wv = dense_params(rng, d_model, d_model)
        self.wo = dense_params(rng, d_model, d_model)
        self.w1 = dense_params(rng, d_model, d_ff)
        self.w2 = dense_params(rng, d_ff, d_model)
        self.norm1 = jnp.ones((d_model,), jnp.float32)
        self.norm2 = jnp.ones((d_model,), jnp.float32)

    def _split(self, x):
        s = x.shape[0]
        return x.reshape(s, self.n_heads, self.d_head).transpose(1, 0, 2)

    def _merge(self, x):
        h, s, d = x.shape
        return x.transpose(1, 0, 2).reshape(s, h * d)

    def __call__(self, x, kv=None):
        """x: [S, D]; kv: optional ([Sk, D], [Sk, D]) for cross/cached attn."""
        h = rmsnorm(x, self.norm1)
        q = self._split(h @ self.wq)
        if kv is None:
            k = self._split(h @ self.wk)
            v = self._split(h @ self.wv)
        else:
            k_src, v_src = kv
            k = self._split(k_src @ self.wk)
            v = self._split(v_src @ self.wv)
        attn = self._merge(mha(q, k, v))
        x = x + attn @ self.wo
        h2 = rmsnorm(x, self.norm2)
        x = x + jnp.tanh(h2 @ self.w1) @ self.w2
        return x
