"""L2 model sanity: shapes, determinism, finiteness, and the causal wiring
each app's executor relies on."""

import numpy as np
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile.models import tiny_diffusion, tiny_llama, tiny_whisper


def rand(seed, *shape):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape).astype(np.float32))


class TestTinyLlama:
    def test_prefill_shapes(self):
        m = tiny_llama.TinyLlama(seed=0)
        x = rand(0, tiny_llama.PREFILL_SEQ, tiny_llama.D_MODEL)
        (logits,) = m.prefill(x)
        assert logits.shape == (tiny_llama.PREFILL_SEQ, tiny_llama.VOCAB)
        assert bool(jnp.isfinite(logits).all())

    def test_decode_shapes_and_ctx_roll(self):
        m = tiny_llama.TinyLlama(seed=0)
        x = rand(1, 1, tiny_llama.D_MODEL)
        ctx = rand(2, tiny_llama.CONTEXT, tiny_llama.D_MODEL)
        logits, new_ctx = m.decode(x, ctx)
        assert logits.shape == (1, tiny_llama.VOCAB)
        assert new_ctx.shape == ctx.shape
        # The rolled context keeps rows 1..T-1.
        assert_allclose(np.asarray(new_ctx[:-1]), np.asarray(ctx[1:]), rtol=1e-6)

    def test_deterministic_weights(self):
        a = tiny_llama.TinyLlama(seed=0)
        b = tiny_llama.TinyLlama(seed=0)
        x = rand(3, tiny_llama.PREFILL_SEQ, tiny_llama.D_MODEL)
        assert_allclose(np.asarray(a.prefill(x)[0]), np.asarray(b.prefill(x)[0]))

    def test_context_affects_decode(self):
        m = tiny_llama.TinyLlama(seed=0)
        x = rand(4, 1, tiny_llama.D_MODEL)
        ctx1 = rand(5, tiny_llama.CONTEXT, tiny_llama.D_MODEL)
        ctx2 = rand(6, tiny_llama.CONTEXT, tiny_llama.D_MODEL)
        l1, _ = m.decode(x, ctx1)
        l2, _ = m.decode(x, ctx2)
        assert float(jnp.abs(l1 - l2).max()) > 1e-4


class TestTinyDiffusion:
    def test_step_shapes(self):
        m = tiny_diffusion.TinyDiffusion(seed=1)
        lat = rand(0, tiny_diffusion.LATENT_TOKENS, tiny_diffusion.D_MODEL)
        t = jnp.asarray([[0.5]], jnp.float32)
        (eps,) = m.step(lat, t)
        assert eps.shape == lat.shape
        assert bool(jnp.isfinite(eps).all())

    def test_timestep_conditions_output(self):
        m = tiny_diffusion.TinyDiffusion(seed=1)
        lat = rand(1, tiny_diffusion.LATENT_TOKENS, tiny_diffusion.D_MODEL)
        e0 = m.step(lat, jnp.asarray([[0.0]], jnp.float32))[0]
        e1 = m.step(lat, jnp.asarray([[1.0]], jnp.float32))[0]
        assert float(jnp.abs(e0 - e1).max()) > 1e-4

    def test_jit_compiles(self):
        m = tiny_diffusion.TinyDiffusion(seed=1)
        f = jax.jit(m.step)
        lat = rand(2, tiny_diffusion.LATENT_TOKENS, tiny_diffusion.D_MODEL)
        out = f(lat, jnp.asarray([[0.3]], jnp.float32))[0]
        assert bool(jnp.isfinite(out).all())


class TestTinyWhisper:
    def test_encode_shapes(self):
        m = tiny_whisper.TinyWhisper(seed=2)
        mel = rand(0, tiny_whisper.AUDIO_FRAMES, tiny_whisper.MEL_BINS)
        (enc,) = m.encode(mel)
        assert enc.shape == (tiny_whisper.ENC_TOKENS, tiny_whisper.D_MODEL)

    def test_decode_step_shapes(self):
        m = tiny_whisper.TinyWhisper(seed=2)
        mel = rand(1, tiny_whisper.AUDIO_FRAMES, tiny_whisper.MEL_BINS)
        (enc,) = m.encode(mel)
        y = rand(2, 1, tiny_whisper.D_MODEL)
        (logits,) = m.decode_step(y, enc)
        assert logits.shape == (1, tiny_whisper.VOCAB)
        assert bool(jnp.isfinite(logits).all())

    def test_audio_affects_transcript(self):
        m = tiny_whisper.TinyWhisper(seed=2)
        y = rand(3, 1, tiny_whisper.D_MODEL)
        enc1 = m.encode(rand(4, tiny_whisper.AUDIO_FRAMES, tiny_whisper.MEL_BINS))[0]
        enc2 = m.encode(rand(5, tiny_whisper.AUDIO_FRAMES, tiny_whisper.MEL_BINS))[0]
        l1 = m.decode_step(y, enc1)[0]
        l2 = m.decode_step(y, enc2)[0]
        assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_all_entry_points_declare_valid_shapes():
    for mod in (tiny_llama, tiny_diffusion, tiny_whisper):
        for name, fn, shapes in mod.entry_points():
            specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
            out = jax.eval_shape(fn, *specs)
            assert isinstance(out, tuple) and len(out) >= 1, name
