"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is the core
correctness signal for the compile path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels.flash_attention import flash_attention, mha, vmem_bytes
from compile.kernels.ref import attention_ref, mha_ref, rmsnorm_ref
from compile.kernels.rmsnorm import rmsnorm

# Valid lattice: multiples of the tile sizes plus the degenerate seq_q=1
# decode shape.
SEQ_Q = st.sampled_from([1, 16, 32, 48, 64])
SEQ_K = st.sampled_from([16, 32, 48, 64, 96])
HEAD_DIM = st.sampled_from([8, 16, 32, 64])


def rand(rng, *shape):
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


@settings(max_examples=40, deadline=None)
@given(seq_q=SEQ_Q, seq_k=SEQ_K, d=HEAD_DIM, seed=st.integers(0, 2**16))
def test_flash_attention_matches_ref(seq_q, seq_k, d, seed):
    rng = np.random.RandomState(seed)
    q, k, v = rand(rng, seq_q, d), rand(rng, seq_k, d), rand(rng, seq_k, d)
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    assert out.shape == (seq_q, d)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    heads=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([16, 32]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
def test_mha_matches_ref(heads, seq, d, seed):
    rng = np.random.RandomState(seed)
    q, k, v = (rand(rng, heads, seq, d) for _ in range(3))
    assert_allclose(np.asarray(mha(q, k, v)), np.asarray(mha_ref(q, k, v)), rtol=2e-5, atol=2e-5)


@settings(max_examples=40, deadline=None)
@given(
    seq=st.sampled_from([1, 8, 16, 32, 64]),
    d=st.sampled_from([8, 32, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_rmsnorm_matches_ref(seq, d, seed):
    rng = np.random.RandomState(seed)
    x = rand(rng, seq, d)
    w = rand(rng, d)
    assert_allclose(
        np.asarray(rmsnorm(x, w)), np.asarray(rmsnorm_ref(x, w)), rtol=2e-5, atol=2e-5
    )


def test_attention_rows_are_convex_combinations():
    # Softmax weights sum to 1: with constant V the output is that constant.
    rng = np.random.RandomState(0)
    q, k = rand(rng, 32, 16), rand(rng, 48, 16)
    v = jnp.ones((48, 16), jnp.float32) * 3.5
    out = flash_attention(q, k, v)
    assert_allclose(np.asarray(out), np.full((32, 16), 3.5, np.float32), rtol=1e-5)


def test_attention_is_permutation_invariant_in_kv():
    # Attention is a set operation over K/V rows.
    rng = np.random.RandomState(1)
    q, k, v = rand(rng, 16, 16), rand(rng, 32, 16), rand(rng, 32, 16)
    perm = rng.permutation(32)
    out1 = flash_attention(q, k, v)
    out2 = flash_attention(q, k[perm], v[perm])
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-4, atol=1e-5)


def test_rmsnorm_scale_invariance():
    # rmsnorm(c*x) == rmsnorm(x) for c > 0 (up to eps).
    rng = np.random.RandomState(2)
    x = rand(rng, 16, 64)
    w = jnp.ones((64,), jnp.float32)
    assert_allclose(
        np.asarray(rmsnorm(7.0 * x, w)), np.asarray(rmsnorm(x, w)), rtol=1e-4, atol=1e-5
    )


def test_rmsnorm_unit_rms():
    rng = np.random.RandomState(3)
    x = rand(rng, 32, 128)
    w = jnp.ones((128,), jnp.float32)
    out = np.asarray(rmsnorm(x, w))
    rms = np.sqrt((out**2).mean(axis=-1))
    assert_allclose(rms, np.ones(32), rtol=1e-3)


def test_invalid_shape_rejected():
    rng = np.random.RandomState(4)
    q = rand(rng, 24, 16)  # 24 not a multiple of block_q=16
    k = rand(rng, 32, 16)
    with pytest.raises(AssertionError):
        flash_attention(q, k, k)


def test_vmem_footprint_fits_tpu_budget():
    # DESIGN.md §8: production tiles (128, 128, d=128) must fit VMEM (16 MB)
    # with generous headroom for double-buffering.
    bytes_needed = vmem_bytes(128, 128, 128)
    assert bytes_needed < 2 * 1024 * 1024, bytes_needed
