"""AOT path: HLO-text lowering and the manifest contract with Rust."""

import os

import jax
import jax.numpy as jnp

from compile import aot


def test_to_hlo_text_produces_parsable_module():
    def fn(x):
        return (x @ x.T + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # return_tuple=True: the root is a tuple.
    assert "tuple(" in text.replace(" ", "")


def test_manifest_line_format():
    line = aot.render_manifest_line("m", "m.hlo.txt", [(1, 64), (32, 64)], 2)
    assert line == "m|m.hlo.txt|f32:1x64;f32:32x64|2"


def test_all_entry_points_lower(tmp_path):
    # Full AOT build into a temp dir; verifies every model lowers and the
    # manifest references existing files with consistent shapes.
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    entries = [l for l in manifest if l and not l.startswith("#")]
    assert len(entries) == len(aot.all_entry_points())
    for line in entries:
        name, filename, specs, n_out = line.split("|")
        path = tmp_path / filename
        assert path.is_file(), filename
        assert int(n_out) >= 1
        text = path.read_text()
        assert "HloModule" in text
        for spec in specs.split(";"):
            dtype, dims = spec.split(":")
            assert dtype == "f32"
            assert all(int(d) > 0 for d in dims.split("x"))


def test_entry_point_names_match_rust_executor():
    # The Rust executor's real-compute hook references these artifact names;
    # renaming one silently disables numerics validation.
    names = {name for name, _, _ in aot.all_entry_points()}
    for required in (
        "tiny_llama_prefill",
        "tiny_llama_decode",
        "tiny_diffusion_step",
        "tiny_whisper_encode",
        "tiny_whisper_decode",
    ):
        assert required in names
