#!/usr/bin/env python3
"""Perf gate: compare a fresh microbench run against the committed baseline.

Usage:
    python3 python/perf_gate.py BASELINE.json FRESH.json [--threshold 0.20]

Both files are `microbench` outputs (``consumerbench_bench: 1``). Each entry
is matched by name; the direction of "worse" follows the unit:

* ``s`` (wall-clock) — higher is worse;
* everything else (``events/s``, ``jobs/s``, ``bytes/s``, ``batches/s``,
  ``traces/s``, ``x``) — lower is worse.

The gate fails (exit 1) when any comparable entry regressed by more than the
threshold. It *skips* — exit 0 with a visible notice, never a silent pass —
when the comparison would be meaningless:

* the baseline file is missing (toolchain never produced one);
* the baseline is the unmeasured schema placeholder;
* baseline and fresh runs used different microbench modes (fast-mode
  numbers are not comparable to full-mode numbers);
* an individual entry is null on either side or absent from one file.

GitHub Actions renders ``::notice::``/``::error::`` lines in the job UI, so
the skip is visible in CI instead of masquerading as a green gate.
"""

import argparse
import json
import sys


def notice(msg: str) -> None:
    print(f"::notice::perf-gate: {msg}")


def error(msg: str) -> None:
    print(f"::error::perf-gate: {msg}")


def load(path: str):
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        return None, f"{path} not found"
    except json.JSONDecodeError as e:
        return None, f"{path} is not valid JSON ({e})"
    if doc.get("consumerbench_bench") != 1:
        return None, f"{path} is not a microbench report (consumerbench_bench != 1)"
    return doc, None


def entries_by_name(doc) -> dict:
    return {e["name"]: e for e in doc.get("entries", []) if "name" in e}


def lower_is_better(unit: str) -> bool:
    return unit == "s"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH.json")
    ap.add_argument("fresh", help="freshly measured microbench output")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="max tolerated fractional regression (default 0.20 = 20%%)",
    )
    args = ap.parse_args()

    baseline, err = load(args.baseline)
    if baseline is None:
        notice(f"skipping ({err}); commit a measured baseline to arm the gate")
        return 0
    fresh, err = load(args.fresh)
    if fresh is None:
        # A missing *fresh* run means the bench step itself broke — that is
        # a failure, not a skip (the baseline exists and expects a compare).
        error(f"fresh run unusable ({err})")
        return 1

    if baseline.get("mode") == "unmeasured":
        notice(
            "skipping (baseline is the unmeasured schema placeholder); "
            "run `cargo bench --bench microbench` and commit BENCH.json to arm the gate"
        )
        return 0
    if baseline.get("mode") != fresh.get("mode"):
        notice(
            f"skipping (baseline mode `{baseline.get('mode')}` != fresh mode "
            f"`{fresh.get('mode')}`; numbers are not comparable across modes)"
        )
        return 0

    base = entries_by_name(baseline)
    new = entries_by_name(fresh)
    regressions = []
    compared = 0
    for name, b in base.items():
        f = new.get(name)
        if f is None:
            notice(f"entry `{name}` absent from fresh run; skipped")
            continue
        bv, fv = b.get("value"), f.get("value")
        if bv is None or fv is None:
            notice(f"entry `{name}` is null ({'baseline' if bv is None else 'fresh'}); skipped")
            continue
        if bv <= 0:
            notice(f"entry `{name}` baseline is non-positive ({bv}); skipped")
            continue
        compared += 1
        unit = b.get("unit", "")
        if lower_is_better(unit):
            change = (fv - bv) / bv  # positive = slower = worse
        else:
            change = (bv - fv) / bv  # positive = lower throughput = worse
        if change > args.threshold:
            regressions.append((name, bv, fv, unit, change))

    if not compared:
        notice("skipping (no comparable entries between baseline and fresh run)")
        return 0
    if regressions:
        for name, bv, fv, unit, change in regressions:
            error(
                f"`{name}` regressed {change * 100.0:.1f}% "
                f"(baseline {bv:g} {unit} -> fresh {fv:g} {unit}, "
                f"threshold {args.threshold * 100.0:.0f}%)"
            )
        return 1
    print(
        f"perf-gate: OK — {compared} entries within "
        f"{args.threshold * 100.0:.0f}% of baseline"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
