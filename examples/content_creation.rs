//! The paper's §4.3 real-world user workflow: digital content creation.
//!
//! Brainstorming (Chatbot via a shared llama.cpp server with CPU KV cache),
//! analysis of existing content (DeepResearch on the same server), script
//! preparation (Chatbot), cover image (ImageGen), and captions
//! (LiveCaptions) — wired as the Fig. 23 DAG. Runs the workflow under
//! greedy allocation and static GPU partitioning and reports the Fig. 7
//! comparison.
//!
//! ```sh
//! cargo run --release --example content_creation
//! ```

use consumerbench::coordinator::{generate, run_config_text};

fn config(strategy: &str) -> String {
    format!(
        "\
Brainstorm (chatbot):
  num_requests: 10
  device: gpu
  server: shared_llama
  slo: [1s, 0.25s]

Analysis (deepresearch):
  num_requests: 1
  device: gpu
  server: shared_llama

Preparing Outline (chatbot):
  num_requests: 10
  device: gpu
  slo: [1s, 0.25s]

Creating Cover Art (imagegen):
  num_requests: 5
  device: gpu
  slo: 1s

Generating Captions (livecaptions):
  num_requests: 30
  device: gpu
  slo: 2s

servers:
  shared_llama:
    model: Llama-3.2-3B
    context_window: 131072
    kv_placement: cpu

workflows:
  analysis:
    uses: Analysis (deepresearch)
    background: true
  brainstorm:
    uses: Brainstorm (chatbot)
  outline:
    uses: Preparing Outline (chatbot)
    depend_on: [\"brainstorm\", \"analysis\"]
  cover_art:
    uses: Creating Cover Art (imagegen)
    depend_on: [\"outline\"]
  generate_captions:
    uses: Generating Captions (livecaptions)
    depend_on: [\"outline\"]

strategy: {strategy}
seed: 42
"
    )
}

fn main() -> anyhow::Result<()> {
    let mut makespans = Vec::new();
    for strategy in ["greedy", "partition"] {
        println!("================ strategy: {strategy} ================");
        let result = run_config_text(&config(strategy), Some("artifacts"))?;
        let report = generate(&result);
        println!("{}", report.text);
        makespans.push((strategy, result.makespan));
    }
    let (g, p) = (makespans[0].1, makespans[1].1);
    println!("--- Fig. 7 headline ---");
    println!("greedy end-to-end:      {g:.1} s");
    println!("partitioned end-to-end: {p:.1} s");
    println!(
        "greedy is {:.0}% shorter (paper: ~45% — partitioning slows \
         DeepResearch, delaying every downstream task)",
        (1.0 - g / p) * 100.0
    );
    Ok(())
}
