//! The paper's §4.2 concurrent-execution study (Fig. 5): Chatbot, ImageGen,
//! and LiveCaptions run simultaneously on one consumer GPU under greedy
//! allocation vs. static MPS-style partitioning, demonstrating the
//! starvation / under-utilization trade-off.
//!
//! ```sh
//! cargo run --release --example concurrent_contention
//! ```

use consumerbench::coordinator::{run_config_text, NodeResult};

fn config(strategy: &str) -> String {
    format!(
        "\
Chat (chatbot):
  num_requests: 8
  device: gpu
  slo: [1s, 0.25s]
Image (imagegen):
  num_requests: 6
  device: gpu
  slo: 1s
Captions (livecaptions):
  num_requests: 40
  device: gpu
  slo: 2s
strategy: {strategy}
seed: 42
"
    )
}

fn describe(node: &NodeResult) {
    println!(
        "  {:<24} mean-norm {:>6.2}  SLO attainment {}",
        node.id,
        node.mean_normalized(),
        consumerbench::apps::attainment_pct(node.attainment())
    );
}

fn main() -> anyhow::Result<()> {
    for strategy in ["greedy", "partition"] {
        println!("=== {strategy} ===");
        let result = run_config_text(&config(strategy), Some("artifacts"))?;
        for node in &result.nodes {
            describe(node);
        }
        // The Fig. 5b decode-stall analysis: time LiveCaptions spent queued
        // behind other applications' kernels.
        let lc = result.node("Captions (livecaptions)").unwrap();
        let mean_lat: f64 = lc.metrics.iter().map(|m| m.latency).sum::<f64>()
            / lc.metrics.len().max(1) as f64;
        println!("  LiveCaptions mean segment latency: {mean_lat:.2} s\n");
    }
    println!("paper shape: greedy starves LiveCaptions (~12x e2e, misses nearly");
    println!("all SLOs) while ImageGen is unaffected; partitioning protects");
    println!("LiveCaptions but pushes ImageGen past its step SLO.");
    Ok(())
}
