//! The paper's §4.2.1 static model sharing study (Fig. 6): Chatbot and
//! DeepResearch share one Llama-3.2-3B through a llama.cpp-style inference
//! server. Comparing the default GPU KV cache against the `--no-kv-offload`
//! CPU placement shows why static server configuration cannot serve both
//! applications' needs.
//!
//! ```sh
//! cargo run --release --example model_sharing
//! ```

use consumerbench::coordinator::run_config_text;

fn config(kv: &str, ctx: usize) -> String {
    format!(
        "\
Chat (chatbot):
  num_requests: 10
  device: gpu
  server: llama
  slo: [1s, 0.25s]
Research (deepresearch):
  num_requests: 1
  device: gpu
  server: llama
servers:
  llama:
    model: Llama-3.2-3B
    context_window: {ctx}
    kv_placement: {kv}
strategy: greedy
seed: 42
"
    )
}

fn main() -> anyhow::Result<()> {
    // Config A: KV on GPU. The 128K window would not fit (14 GiB KV), so
    // DeepResearch is limited to a 16K context (quality loss, per paper).
    // Config B: KV in CPU DRAM (--no-kv-offload), full 128K window.
    let scenarios = [("Chatbot (KV on GPU, 4K ctx)", "gpu", 4096usize),
                     ("Chatbot-KVCache-CPU (128K ctx)", "cpu", 131_072)];
    for (label, kv, ctx) in scenarios {
        let result = run_config_text(&config(kv, ctx), Some("artifacts"))?;
        let chat = result.node("Chat (chatbot)").unwrap();
        let ttfts: Vec<f64> = chat
            .metrics
            .iter()
            .filter_map(|m| m.components.iter().find(|(n, _)| *n == "ttft").map(|(_, v)| *v))
            .collect();
        let tpots: Vec<f64> = chat
            .metrics
            .iter()
            .filter_map(|m| m.components.iter().find(|(n, _)| *n == "tpot").map(|(_, v)| *v))
            .collect();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        println!("=== {label} ===");
        println!(
            "  chat SLO attainment: {}   mean TTFT {:.2}s   mean TPOT {:.3}s",
            consumerbench::apps::attainment_pct(chat.attainment()),
            mean(&ttfts),
            mean(&tpots),
        );
        let dr = result.node("Research (deepresearch)").unwrap();
        println!(
            "  research task time:  {:.1}s   workflow makespan {:.1}s\n",
            dr.metrics.first().map(|m| m.latency).unwrap_or(0.0),
            result.makespan
        );
    }
    println!("paper shape: the CPU-KV configuration misses the chat SLO for");
    println!("~40% of requests with high variance — attention runs on the CPU");
    println!("and DeepResearch's long-context prefills stall chat iterations.");
    Ok(())
}
