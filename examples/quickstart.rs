//! Quickstart: define a two-application workload in YAML, run it on the
//! simulated RTX 6000 testbed, and print the benchmark report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use consumerbench::coordinator::{generate, run_config_text};

const CONFIG: &str = "\
# A latency-sensitive chatbot and an image generator sharing the GPU.
Chat (chatbot):
  model: Llama-3.2-3B
  num_requests: 5
  device: gpu
  slo: [1s, 0.25s]

Cover Art (imagegen):
  model: SD-3.5-Medium-Turbo
  num_requests: 3
  device: gpu
  slo: 1s

strategy: greedy
seed: 42
";

fn main() -> anyhow::Result<()> {
    // Use the AOT artifacts when they exist (`make artifacts`); otherwise
    // run simulation-only.
    let result = run_config_text(CONFIG, Some("artifacts"))?;
    let report = generate(&result);
    println!("{}", report.text);

    for node in &result.nodes {
        println!(
            "{}: {} requests, SLO attainment {}, mean normalized latency {:.2}",
            node.id,
            node.metrics.len(),
            consumerbench::apps::attainment_pct(node.attainment()).trim(),
            node.mean_normalized()
        );
    }
    println!("\nPJRT real-compute validations: {}", result.pjrt_calls);
    Ok(())
}
