//! The GenAI applications of Table 1.
//!
//! Each application implements the paper's three-function integration API
//! (§3.3): `setup()` loads the model (VRAM allocation + load time),
//! `execute()` issues one request, `cleanup()` releases resources. Here
//! those functions produce [`JobSpec`]s for the simulated testbed; the
//! numerics behind each request run through the real PJRT runtime when
//! artifacts are available (see `runtime`).

pub mod chatbot;
pub mod deepresearch;
pub mod imagegen;
pub mod livecaptions;
pub mod models;

pub use chatbot::Chatbot;
pub use deepresearch::DeepResearch;
pub use imagegen::ImageGen;
pub use livecaptions::LiveCaptions;

use crate::gpusim::engine::{ClientId, JobResult, JobSpec};
use crate::gpusim::kernel::Device;

/// Placement + identity context handed to the app by the orchestrator.
#[derive(Debug, Clone, Copy)]
pub struct AppContext {
    pub client: ClientId,
    pub device: Device,
}

/// Service-level objective per application class (Table 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Slo {
    /// Chatbot: time-to-first-token and time-per-output-token bounds.
    Chat { ttft: f64, tpot: f64 },
    /// ImageGen: per-denoising-step bound.
    StepTime(f64),
    /// LiveCaptions: per-segment bound.
    SegmentTime(f64),
    /// Background applications (DeepResearch).
    None,
}

impl Slo {
    pub fn describe(&self) -> String {
        match self {
            Slo::Chat { ttft, tpot } => format!("TTFT:{ttft}s, TPOT: {tpot}s"),
            Slo::StepTime(s) => format!("Step Time: {s}s"),
            Slo::SegmentTime(s) => format!("Per-Segment Time: {s}s"),
            Slo::None => "N/A".to_string(),
        }
    }
}

/// How an application's requests arrive (virtual time).
///
/// The first two variants are the paper's closed-loop user and the
/// LiveCaptions audio cadence. `Poisson` and `Trace` are open-loop *client*
/// models: arrivals are independent of completions, so a slow backend
/// accumulates a queue — the heavy-traffic regime the scenario matrix
/// sweeps (see `crate::scenario`).
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Next request is sent `think` seconds after the previous completes.
    ClosedLoop { think: f64 },
    /// Request `i` arrives at `start + i × period` regardless of completion
    /// (the LiveCaptions 2-second audio cadence).
    OpenLoop { period: f64 },
    /// Open-loop Poisson process: exponential inter-arrival gaps with mean
    /// `1/rate` seconds, drawn deterministically from `seed`.
    Poisson { rate: f64, seed: u64 },
    /// Open-loop trace replay: request `i` arrives at `start + offsets[i]`.
    /// When more requests than offsets are needed, the trace wraps around,
    /// shifted by its span per lap (the standard replay-client behaviour).
    Trace { offsets: Vec<f64> },
}

impl Arrival {
    /// Materialize the arrival times of `n` requests starting at `start`.
    ///
    /// Returns `None` for the closed loop (arrival times depend on
    /// completions, which only the executor knows). Open-loop schedules are
    /// pure functions of `(self, n, start)`, which is what makes scenario
    /// runs replayable byte-for-byte.
    pub fn schedule(&self, n: usize, start: f64) -> Option<Vec<f64>> {
        match self {
            Arrival::ClosedLoop { .. } => None,
            Arrival::OpenLoop { period } => {
                Some((0..n).map(|i| start + i as f64 * period).collect())
            }
            Arrival::Poisson { rate, seed } => {
                let mut rng = crate::util::rng::Rng::new(*seed);
                let mut t = start;
                Some(
                    (0..n)
                        .map(|_| {
                            t += rng.exponential(*rate);
                            t
                        })
                        .collect(),
                )
            }
            Arrival::Trace { offsets } => {
                if offsets.is_empty() {
                    return Some(vec![start; n]);
                }
                let span = offsets.last().copied().unwrap_or(0.0).max(0.0);
                Some(
                    (0..n)
                        .map(|i| {
                            let lap = (i / offsets.len()) as f64;
                            start + offsets[i % offsets.len()] + lap * span
                        })
                        .collect(),
                )
            }
        }
    }

    /// Whether arrivals are independent of request completions.
    pub fn is_open_loop(&self) -> bool {
        !matches!(self, Arrival::ClosedLoop { .. })
    }
}

/// Per-request evaluation against the SLO.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub label: String,
    pub latency: f64,
    /// Latency (or the binding component) normalized to the SLO; the Fig. 3
    /// y-axis. 0 for SLO-less apps.
    pub normalized: f64,
    pub slo_met: bool,
    /// Named components, e.g. [("ttft", 0.8), ("tpot", 0.01)].
    pub components: Vec<(&'static str, f64)>,
}

/// The application integration API (paper §3.3).
pub trait Application {
    fn name(&self) -> &'static str;
    fn model_name(&self) -> &'static str;
    fn dataset_name(&self) -> &'static str;
    fn slo(&self) -> Slo;
    fn arrival(&self) -> Arrival;
    fn num_requests(&self) -> usize;

    /// Job that loads the model onto the context device.
    fn setup_job(&self, ctx: &AppContext) -> JobSpec;

    /// Job for request `idx` (0-based, < num_requests).
    fn request_job(&self, ctx: &AppContext, idx: usize) -> JobSpec;

    /// Job that unloads the model.
    fn cleanup_job(&self, ctx: &AppContext) -> JobSpec;

    /// Evaluate a finished request against the SLO.
    fn evaluate(&self, result: &JobResult) -> RequestMetrics;

    /// Downcasting hook (the executor needs concrete request shapes for
    /// server-backed nodes).
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Aggregate SLO attainment over request metrics — the Fig. 3b/5a metric.
///
/// `None` when no requests completed (e.g. the node's setup OOM'd): such a
/// node has no attainment, and report layers render `n/a` instead of the
/// perfect 100% the old `1.0` default implied.
pub fn slo_attainment(metrics: &[RequestMetrics]) -> Option<f64> {
    if metrics.is_empty() {
        return None;
    }
    Some(metrics.iter().filter(|m| m.slo_met).count() as f64 / metrics.len() as f64)
}

/// Display-layer counterpart of [`slo_attainment`]: render an optional
/// attainment as a fixed-width percentage, `n/a` when no requests completed
/// — never a fabricated score in either direction.
pub fn attainment_pct(attainment: Option<f64>) -> String {
    match attainment {
        Some(a) => format!("{:>5.1}%", a * 100.0),
        None => "  n/a ".to_string(),
    }
}

/// Mean normalized latency — the Fig. 3a/5a metric.
pub fn mean_normalized(metrics: &[RequestMetrics]) -> f64 {
    if metrics.is_empty() {
        return 0.0;
    }
    metrics.iter().map(|m| m.normalized).sum::<f64>() / metrics.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slo_description_matches_table1() {
        assert_eq!(
            Slo::Chat { ttft: 1.0, tpot: 0.25 }.describe(),
            "TTFT:1s, TPOT: 0.25s"
        );
        assert_eq!(Slo::StepTime(1.0).describe(), "Step Time: 1s");
        assert_eq!(Slo::SegmentTime(2.0).describe(), "Per-Segment Time: 2s");
        assert_eq!(Slo::None.describe(), "N/A");
    }

    #[test]
    fn attainment_counts_met() {
        let m = |ok: bool| RequestMetrics {
            label: "r".into(),
            latency: 1.0,
            normalized: 1.0,
            slo_met: ok,
            components: vec![],
        };
        let ms = vec![m(true), m(true), m(false), m(true)];
        assert!((slo_attainment(&ms).unwrap() - 0.75).abs() < 1e-12);
        // Regression: empty metrics are `None`, never a perfect score.
        assert_eq!(slo_attainment(&[]), None);
    }

    #[test]
    fn attainment_pct_renders_na_for_empty() {
        assert_eq!(attainment_pct(Some(1.0)), "100.0%");
        assert_eq!(attainment_pct(Some(0.953)), " 95.3%");
        assert_eq!(attainment_pct(None), "  n/a ");
    }

    #[test]
    fn poisson_schedule_is_deterministic_and_increasing() {
        let a = Arrival::Poisson { rate: 2.0, seed: 7 };
        let s1 = a.schedule(50, 1.0).unwrap();
        let s2 = a.schedule(50, 1.0).unwrap();
        assert_eq!(s1, s2);
        assert!(s1.windows(2).all(|w| w[1] > w[0]), "arrivals must increase");
        assert!(s1[0] > 1.0);
        let other = Arrival::Poisson { rate: 2.0, seed: 8 };
        assert_ne!(s1, other.schedule(50, 1.0).unwrap());
        // Mean inter-arrival ≈ 1/rate over many samples.
        let mean_gap = (s1.last().unwrap() - s1[0]) / (s1.len() - 1) as f64;
        assert!((mean_gap - 0.5).abs() < 0.2, "mean gap {mean_gap}");
    }

    #[test]
    fn trace_schedule_wraps_with_span() {
        let a = Arrival::Trace { offsets: vec![0.0, 0.1, 1.0] };
        let s = a.schedule(5, 10.0).unwrap();
        assert_eq!(s, vec![10.0, 10.1, 11.0, 11.0, 11.1]);
        let empty = Arrival::Trace { offsets: vec![] };
        assert_eq!(empty.schedule(2, 3.0).unwrap(), vec![3.0, 3.0]);
    }

    #[test]
    fn open_loop_classification() {
        assert!(!Arrival::ClosedLoop { think: 1.0 }.is_open_loop());
        assert!(Arrival::OpenLoop { period: 2.0 }.is_open_loop());
        assert!(Arrival::Poisson { rate: 1.0, seed: 0 }.is_open_loop());
        assert!(Arrival::Trace { offsets: vec![0.0] }.is_open_loop());
        assert_eq!(
            Arrival::ClosedLoop { think: 1.0 }.schedule(3, 0.0),
            None
        );
    }

    #[test]
    fn mean_normalized_averages() {
        let m = |n: f64| RequestMetrics {
            label: "r".into(),
            latency: n,
            normalized: n,
            slo_met: true,
            components: vec![],
        };
        assert!((mean_normalized(&[m(0.5), m(1.5)]) - 1.0).abs() < 1e-12);
    }
}
