//! LiveCaptions: real-time audio transcription (whisper-online, §3.3).
//!
//! The frontend sends a 2-second audio segment every 2 seconds (open-loop);
//! the SLO is that each segment transcribes within 2 s. Execution is one
//! encoder pass (healthy occupancy) followed by autoregressive decoding of
//! a handful of tokens, each a burst of tiny, register/smem-hungry kernels —
//! the profile that makes LiveCaptions the starvation victim of §4.2.
//!
//! A seeded ~2% of segments fail language identification and re-encode
//! (paper footnote 2 — the 3-in-150 SLO violations of Fig. 3).

use crate::apps::models::{whisper_large_v3_turbo, WhisperProfile};
use crate::apps::{AppContext, Application, Arrival, RequestMetrics, Slo};
use crate::datasets::earnings21::{AudioSegment, Earnings21};
use crate::gpusim::engine::{JobResult, JobSpec, MemOp, Phase};
use crate::gpusim::kernel::Device;

/// Host-side audio chunking/feature-extraction per segment.
const CHUNK_OVERHEAD: f64 = 0.02;

/// The LiveCaptions application.
pub struct LiveCaptions {
    model: WhisperProfile,
    segments: Vec<AudioSegment>,
    slo_segment: f64,
}

impl LiveCaptions {
    /// Latency-sensitive configuration: 2 s segments, 2 s SLO.
    pub fn new(seed: u64, num_segments: usize) -> Self {
        let mut gen = Earnings21::new(seed);
        LiveCaptions {
            segments: gen.stream(num_segments),
            model: whisper_large_v3_turbo(),
            slo_segment: 2.0,
        }
    }

    /// Apple Silicon configuration (Appendix C): 4 s SLO, longer chunks.
    pub fn apple_config(seed: u64, num_segments: usize) -> Self {
        let mut gen = Earnings21::new(seed).with_segment_seconds(4.0);
        LiveCaptions {
            segments: gen.stream(num_segments),
            model: whisper_large_v3_turbo(),
            slo_segment: 4.0,
        }
    }

    /// Transcribe through a different kernel implementation.
    pub fn with_backend(mut self, backend: crate::gpusim::backend::KernelBackend) -> Self {
        self.model = self.model.with_backend(backend);
        self
    }

    pub fn model(&self) -> &WhisperProfile {
        &self.model
    }

    pub fn segments(&self) -> &[AudioSegment] {
        &self.segments
    }

    pub fn segment_period(&self) -> f64 {
        self.segments.first().map(|s| s.duration).unwrap_or(2.0)
    }
}

impl Application for LiveCaptions {
    fn name(&self) -> &'static str {
        "LiveCaptions"
    }

    fn model_name(&self) -> &'static str {
        self.model.name
    }

    fn dataset_name(&self) -> &'static str {
        "Earnings-21"
    }

    fn slo(&self) -> Slo {
        Slo::SegmentTime(self.slo_segment)
    }

    fn arrival(&self) -> Arrival {
        Arrival::OpenLoop {
            period: self.segment_period(),
        }
    }

    fn num_requests(&self) -> usize {
        self.segments.len()
    }

    fn setup_job(&self, ctx: &AppContext) -> JobSpec {
        let mut phase = Phase::host("setup.load", self.model.load_seconds());
        if ctx.device == Device::Gpu {
            phase = phase.with_mem_ops(vec![MemOp::Alloc {
                label: "weights".into(),
                bytes: self.model.weights_bytes,
            }]);
        }
        JobSpec {
            client: ctx.client,
            label: "livecaptions.setup".into(),
            phases: vec![phase],
        }
    }

    fn request_job(&self, ctx: &AppContext, idx: usize) -> JobSpec {
        let seg = &self.segments[idx];
        // Language-ID failure → the segment is encoded again (footnote 2).
        let encode_passes = if seg.reencode { 2 } else { 1 };
        let mut phases = Vec::new();
        // A failed language ID stalls the pipeline until the segment is
        // re-submitted with the next audio chunk (paper footnote 2) — this
        // is what breaks the 2 s budget even on an exclusive GPU.
        let reencode_delay = if seg.reencode { self.segment_period() } else { 0.0 };
        match ctx.device {
            Device::Gpu => {
                for (i, _) in (0..encode_passes).enumerate() {
                    let host = CHUNK_OVERHEAD + if i > 0 { reencode_delay } else { 0.0 };
                    phases.push(Phase::gpu("encode", host, self.model.encode_kernels()));
                }
                for t in 0..seg.transcript_tokens {
                    let host = if t == 0 { 0.005 } else { 0.001 };
                    phases.push(Phase::gpu("decode", host, self.model.decode_token_kernels()));
                }
            }
            Device::Cpu => {
                for (i, _) in (0..encode_passes).enumerate() {
                    let host = CHUNK_OVERHEAD + if i > 0 { reencode_delay } else { 0.0 };
                    phases.push(Phase::cpu("encode", host, self.model.encode_cpu()));
                }
                for _ in 0..seg.transcript_tokens {
                    phases.push(Phase::cpu("decode", 0.001, self.model.decode_token_cpu()));
                }
            }
        }
        JobSpec {
            client: ctx.client,
            label: format!("livecaptions.seg{}", seg.id),
            phases,
        }
    }

    fn cleanup_job(&self, ctx: &AppContext) -> JobSpec {
        JobSpec {
            client: ctx.client,
            label: "livecaptions.cleanup".into(),
            phases: vec![Phase::host("cleanup", 0.05).with_mem_ops(vec![MemOp::FreeAll])],
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn evaluate(&self, result: &JobResult) -> RequestMetrics {
        let latency = result.latency();
        let normalized = latency / self.slo_segment;
        // Decode-phase time, for the Fig. 5b stall analysis.
        let decode_time: f64 = result
            .phases
            .iter()
            .filter(|p| p.tag == "decode")
            .map(|p| p.end - p.start)
            .sum();
        RequestMetrics {
            label: result.label.clone(),
            latency,
            normalized,
            slo_met: normalized <= 1.0,
            components: vec![("segment_time", latency), ("decode_time", decode_time)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::engine::Engine;
    use crate::gpusim::policy::Policy;
    use crate::gpusim::profiles::Testbed;

    fn run_segments(device: Device, n: usize, seed: u64) -> Vec<RequestMetrics> {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let client = e.register_client("livecaptions");
        let ctx = AppContext { client, device };
        let app = LiveCaptions::new(seed, n);
        e.submit(app.setup_job(&ctx), 0.0);
        e.run_all();
        let base = e.now();
        for i in 0..n {
            // Open-loop: segment i arrives at base + 2i.
            e.submit(app.request_job(&ctx, i), base + i as f64 * 2.0);
        }
        e.run_all();
        e.take_completed()
            .iter()
            .filter(|r| r.label.starts_with("livecaptions.seg"))
            .map(|r| app.evaluate(r))
            .collect()
    }

    #[test]
    fn gpu_exclusive_nearly_all_meet_slo() {
        // Fig. 3: on the GPU, ~147/150 segments meet the 2 s SLO (the
        // misses are the re-encoded segments — and even those usually fit
        // within 2 s when exclusive).
        let metrics = run_segments(Device::Gpu, 50, 42);
        let attainment = crate::apps::slo_attainment(&metrics).expect("segments ran");
        assert!(attainment > 0.9, "attainment {attainment}");
        // Latencies far below SLO when exclusive.
        let mean = crate::apps::mean_normalized(&metrics);
        assert!(mean < 0.3, "mean normalized {mean}");
    }

    #[test]
    fn cpu_exclusive_misses_slo() {
        let metrics = run_segments(Device::Cpu, 5, 42);
        let mean = crate::apps::mean_normalized(&metrics);
        assert!(mean > 1.0, "CPU should blow the 2 s budget: {mean}");
    }

    #[test]
    fn reencoded_segments_are_slower() {
        let app = LiveCaptions::new(42, 500);
        let has_reencode = app.segments().iter().any(|s| s.reencode);
        assert!(has_reencode, "seed should produce re-encode events");
        let ctx = AppContext {
            client: crate::gpusim::engine::ClientId(0),
            device: Device::Gpu,
        };
        let normal_idx = app.segments().iter().position(|s| !s.reencode).unwrap();
        let re_idx = app.segments().iter().position(|s| s.reencode).unwrap();
        let n_enc = |idx: usize| {
            app.request_job(&ctx, idx)
                .phases
                .iter()
                .filter(|p| p.tag == "encode")
                .count()
        };
        assert_eq!(n_enc(normal_idx), 1);
        assert_eq!(n_enc(re_idx), 2);
    }

    #[test]
    fn apple_config_relaxes_slo() {
        let app = LiveCaptions::apple_config(1, 10);
        assert_eq!(app.slo(), Slo::SegmentTime(4.0));
        assert_eq!(app.segment_period(), 4.0);
    }
}
