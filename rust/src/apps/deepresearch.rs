//! DeepResearch: agentic multi-step research (smolagents open-deep-research
//! over llama.cpp via LiteLLM, §3.3).
//!
//! A background application without an SLO. Each request is a full agent
//! task: several iterations of (tool use → long-context prefill → reasoning
//! decode), with context growing every hop — the workload that motivates the
//! 16 GB KV cache configuration of §4.2.1.

use crate::apps::models::{llama_3_2_3b, LlamaProfile};
use crate::apps::{AppContext, Application, Arrival, RequestMetrics, Slo};
use crate::datasets::hotpotqa::{HotpotQa, ResearchTask};
use crate::gpusim::engine::{JobResult, JobSpec, MemOp, Phase};
use crate::gpusim::kernel::Device;

/// Context cap when run standalone with a dedicated KV cache. The paper's
/// shared-server configuration uses the full 128K window (see `server`).
const STANDALONE_CONTEXT: usize = 32_768;

/// The DeepResearch application.
pub struct DeepResearch {
    model: LlamaProfile,
    tasks: Vec<ResearchTask>,
}

impl DeepResearch {
    pub fn new(seed: u64, num_tasks: usize) -> Self {
        let mut gen = HotpotQa::new(seed, STANDALONE_CONTEXT);
        DeepResearch {
            tasks: gen.batch(num_tasks),
            model: llama_3_2_3b(),
        }
    }

    /// Run the agent loop through a different kernel implementation.
    pub fn with_backend(mut self, backend: crate::gpusim::backend::KernelBackend) -> Self {
        self.model = self.model.with_backend(backend);
        self
    }

    pub fn model(&self) -> &LlamaProfile {
        &self.model
    }

    pub fn tasks(&self) -> &[ResearchTask] {
        &self.tasks
    }
}

impl Application for DeepResearch {
    fn name(&self) -> &'static str {
        "DeepResearch"
    }

    fn model_name(&self) -> &'static str {
        self.model.name
    }

    fn dataset_name(&self) -> &'static str {
        "HotpotQA"
    }

    fn slo(&self) -> Slo {
        Slo::None
    }

    fn arrival(&self) -> Arrival {
        Arrival::ClosedLoop { think: 1.0 }
    }

    fn num_requests(&self) -> usize {
        self.tasks.len()
    }

    fn setup_job(&self, ctx: &AppContext) -> JobSpec {
        let mut phase = Phase::host("setup.load", self.model.load_seconds());
        if ctx.device == Device::Gpu {
            phase = phase.with_mem_ops(vec![
                MemOp::Alloc {
                    label: "weights".into(),
                    bytes: self.model.weights_bytes,
                },
                MemOp::Alloc {
                    label: "kv-cache".into(),
                    bytes: self.model.kv_cache_bytes(STANDALONE_CONTEXT),
                },
            ]);
        }
        JobSpec {
            client: ctx.client,
            label: "deepresearch.setup".into(),
            phases: vec![phase],
        }
    }

    fn request_job(&self, ctx: &AppContext, idx: usize) -> JobSpec {
        let task = &self.tasks[idx];
        let mut phases = Vec::new();
        for it in &task.iterations {
            match ctx.device {
                Device::Gpu => {
                    phases.push(Phase::gpu(
                        "research.prefill",
                        it.tool_time,
                        self.model.prefill_kernels(it.context_tokens),
                    ));
                    // Reasoning decode is coarse-grained here: agent steps
                    // decode hundreds of tokens; we batch them 16 per phase
                    // to bound event count while keeping stream semantics.
                    let chunks = it.decode_tokens.div_ceil(16);
                    for c in 0..chunks {
                        let ctx_len = it.context_tokens + c * 16;
                        let mut kernels = Vec::new();
                        for _ in 0..16.min(it.decode_tokens - c * 16) {
                            kernels.extend(self.model.decode_kernels(ctx_len));
                        }
                        phases.push(Phase::gpu("research.decode", 0.002, kernels));
                    }
                }
                Device::Cpu => {
                    phases.push(Phase::cpu(
                        "research.prefill",
                        it.tool_time,
                        self.model.prefill_cpu(it.context_tokens),
                    ));
                    let mut work = self.model.decode_cpu(it.context_tokens);
                    work.flops *= it.decode_tokens as f64;
                    work.bytes *= it.decode_tokens as f64;
                    phases.push(Phase::cpu("research.decode", 0.002, work));
                }
            }
        }
        JobSpec {
            client: ctx.client,
            label: format!("deepresearch.task{}", task.id),
            phases,
        }
    }

    fn cleanup_job(&self, ctx: &AppContext) -> JobSpec {
        JobSpec {
            client: ctx.client,
            label: "deepresearch.cleanup".into(),
            phases: vec![Phase::host("cleanup", 0.05).with_mem_ops(vec![MemOp::FreeAll])],
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn evaluate(&self, result: &JobResult) -> RequestMetrics {
        RequestMetrics {
            label: result.label.clone(),
            latency: result.latency(),
            normalized: 0.0,
            slo_met: true,
            components: vec![("e2e", result.latency())],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::engine::Engine;
    use crate::gpusim::policy::Policy;
    use crate::gpusim::profiles::Testbed;

    #[test]
    fn task_is_long_running_on_gpu() {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let client = e.register_client("deepresearch");
        let ctx = AppContext { client, device: Device::Gpu };
        let app = DeepResearch::new(3, 1);
        e.submit(app.setup_job(&ctx), 0.0);
        e.run_all();
        e.submit(app.request_job(&ctx, 0), e.now());
        e.run_all();
        let done = e.take_completed();
        let r = done.iter().find(|r| r.label.starts_with("deepresearch.task")).unwrap();
        // A research task runs tens of seconds (background), far longer
        // than any single chat request.
        assert!(r.latency() > 10.0, "latency {}", r.latency());
        let m = app.evaluate(r);
        assert!(m.slo_met); // no SLO → always met
        assert_eq!(m.normalized, 0.0);
    }

    #[test]
    fn iterations_produce_prefill_decode_pairs() {
        let app = DeepResearch::new(3, 1);
        let ctx = AppContext {
            client: crate::gpusim::engine::ClientId(0),
            device: Device::Gpu,
        };
        let job = app.request_job(&ctx, 0);
        let n_prefill = job.phases.iter().filter(|p| p.tag == "research.prefill").count();
        let n_decode = job.phases.iter().filter(|p| p.tag == "research.decode").count();
        assert_eq!(n_prefill, app.tasks()[0].iterations.len());
        assert!(n_decode >= n_prefill);
    }

    #[test]
    fn background_app_has_no_slo() {
        let app = DeepResearch::new(1, 1);
        assert_eq!(app.slo(), Slo::None);
        assert_eq!(app.slo().describe(), "N/A");
    }
}
