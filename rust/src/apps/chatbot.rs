//! Chatbot: text-to-text chat/Q&A over a llama.cpp backend (§3.3).
//!
//! SLOs follow human reading speed: TTFT 1 s, TPOT 0.25 s. Requests are
//! LMSYS-shaped; execution is one prefill phase followed by one decode
//! phase per output token (llama.cpp samples on the host between tokens,
//! so each token is a separate stream enqueue — unlike ImageGen's bulk
//! launch-ahead, which is exactly why Chatbot interacts differently with
//! the greedy scheduler in §4.2).

use crate::apps::models::{llama_3_2_3b, LlamaProfile};
use crate::apps::{AppContext, Application, Arrival, RequestMetrics, Slo};
use crate::datasets::lmsys::{ChatRequest, LmsysChat};
use crate::gpusim::engine::{JobResult, JobSpec, MemOp, Phase};
use crate::gpusim::kernel::Device;

/// Host-side sampling time between decoded tokens.
const SAMPLE_OVERHEAD: f64 = 0.0005;

/// The Chatbot application.
pub struct Chatbot {
    model: LlamaProfile,
    requests: Vec<ChatRequest>,
    slo_ttft: f64,
    slo_tpot: f64,
    think: f64,
}

impl Chatbot {
    /// Default configuration: Llama-3.2-3B, TTFT 1 s / TPOT 0.25 s.
    pub fn new(seed: u64, num_requests: usize) -> Self {
        Chatbot::with_model(seed, num_requests, llama_3_2_3b())
    }

    /// Variant with a different backbone (Appendix B.4 uses Llama-3.1-8B).
    pub fn with_model(seed: u64, num_requests: usize, model: LlamaProfile) -> Self {
        let mut gen = LmsysChat::new(seed, 4096);
        Chatbot {
            requests: gen.batch(num_requests),
            model,
            slo_ttft: 1.0,
            slo_tpot: 0.25,
            // Closed-loop user: reads the answer, types the next prompt.
            think: 5.0,
        }
    }

    /// Serve the same requests through a different kernel implementation
    /// (the §6 tuned-vs-generic ablation).
    pub fn with_backend(mut self, backend: crate::gpusim::backend::KernelBackend) -> Self {
        self.model = self.model.with_backend(backend);
        self
    }

    pub fn model(&self) -> &LlamaProfile {
        &self.model
    }

    pub fn requests(&self) -> &[ChatRequest] {
        &self.requests
    }

    fn gpu_request_job(&self, ctx: &AppContext, r: &ChatRequest) -> JobSpec {
        let mut phases = Vec::with_capacity(1 + r.output_tokens);
        phases.push(Phase::gpu("prefill", 0.002, self.model.prefill_kernels(r.prompt_tokens)));
        for t in 0..r.output_tokens {
            let context = r.prompt_tokens + t;
            phases.push(Phase::gpu("decode", SAMPLE_OVERHEAD, self.model.decode_kernels(context)));
        }
        JobSpec {
            client: ctx.client,
            label: format!("chatbot.req{}", r.id),
            phases,
        }
    }

    fn cpu_request_job(&self, ctx: &AppContext, r: &ChatRequest) -> JobSpec {
        let mut phases = Vec::with_capacity(1 + r.output_tokens);
        phases.push(Phase::cpu("prefill", 0.002, self.model.prefill_cpu(r.prompt_tokens)));
        for t in 0..r.output_tokens {
            let context = r.prompt_tokens + t;
            phases.push(Phase::cpu("decode", SAMPLE_OVERHEAD, self.model.decode_cpu(context)));
        }
        JobSpec {
            client: ctx.client,
            label: format!("chatbot.req{}", r.id),
            phases,
        }
    }
}

impl Application for Chatbot {
    fn name(&self) -> &'static str {
        "Chatbot"
    }

    fn model_name(&self) -> &'static str {
        self.model.name
    }

    fn dataset_name(&self) -> &'static str {
        "LMSYS-Chat-1M"
    }

    fn slo(&self) -> Slo {
        Slo::Chat {
            ttft: self.slo_ttft,
            tpot: self.slo_tpot,
        }
    }

    fn arrival(&self) -> Arrival {
        Arrival::ClosedLoop { think: self.think }
    }

    fn num_requests(&self) -> usize {
        self.requests.len()
    }

    fn setup_job(&self, ctx: &AppContext) -> JobSpec {
        let mut phase = Phase::host("setup.load", self.model.load_seconds());
        if ctx.device == Device::Gpu {
            // Weights + a working KV cache for the 4K serving context.
            phase = phase.with_mem_ops(vec![
                MemOp::Alloc {
                    label: "weights".into(),
                    bytes: self.model.weights_bytes,
                },
                MemOp::Alloc {
                    label: "kv-cache".into(),
                    bytes: self.model.kv_cache_bytes(4096),
                },
            ]);
        }
        JobSpec {
            client: ctx.client,
            label: "chatbot.setup".into(),
            phases: vec![phase],
        }
    }

    fn request_job(&self, ctx: &AppContext, idx: usize) -> JobSpec {
        let r = &self.requests[idx];
        match ctx.device {
            Device::Gpu => self.gpu_request_job(ctx, r),
            Device::Cpu => self.cpu_request_job(ctx, r),
        }
    }

    fn cleanup_job(&self, ctx: &AppContext) -> JobSpec {
        JobSpec {
            client: ctx.client,
            label: "chatbot.cleanup".into(),
            phases: vec![Phase::host("cleanup", 0.05).with_mem_ops(vec![MemOp::FreeAll])],
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn evaluate(&self, result: &JobResult) -> RequestMetrics {
        let ttft = result
            .phases
            .iter()
            .find(|p| p.tag == "prefill")
            .map(|p| p.end - result.submit)
            .unwrap_or(f64::INFINITY);
        let decode: Vec<f64> = result
            .phases
            .iter()
            .filter(|p| p.tag == "decode")
            .map(|p| p.end - p.start)
            .collect();
        let tpot = if decode.is_empty() {
            0.0
        } else {
            decode.iter().sum::<f64>() / decode.len() as f64
        };
        let normalized = (ttft / self.slo_ttft).max(tpot / self.slo_tpot);
        RequestMetrics {
            label: result.label.clone(),
            latency: result.latency(),
            normalized,
            slo_met: normalized <= 1.0,
            components: vec![("ttft", ttft), ("tpot", tpot)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::engine::Engine;
    use crate::gpusim::policy::Policy;
    use crate::gpusim::profiles::Testbed;

    fn run_exclusive(device: Device) -> Vec<RequestMetrics> {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let client = e.register_client("chatbot");
        let ctx = AppContext { client, device };
        let app = Chatbot::new(1, 4);
        e.submit(app.setup_job(&ctx), 0.0);
        e.run_all();
        let mut t = e.now();
        for i in 0..app.num_requests() {
            e.submit(app.request_job(&ctx, i), t);
            e.run_all();
            t = e.now() + 0.1;
        }
        e.take_completed()
            .iter()
            .filter(|r| r.label.starts_with("chatbot.req"))
            .map(|r| app.evaluate(r))
            .collect()
    }

    #[test]
    fn gpu_exclusive_meets_slo() {
        let metrics = run_exclusive(Device::Gpu);
        assert_eq!(metrics.len(), 4);
        for m in &metrics {
            assert!(m.slo_met, "{} normalized {}", m.label, m.normalized);
            assert!(m.normalized < 0.5, "should be comfortably within SLO");
        }
    }

    #[test]
    fn cpu_exclusive_narrowly_misses() {
        // Fig. 3: on the CPU, Chatbot's normalized latency hovers around the
        // SLO boundary (TTFT-bound).
        let metrics = run_exclusive(Device::Cpu);
        let mean = crate::apps::mean_normalized(&metrics);
        assert!(mean > 0.5 && mean < 6.0, "mean normalized {mean}");
        // At least one request should be near/over the boundary.
        assert!(metrics.iter().any(|m| m.normalized > 0.8), "none near the SLO");
    }

    #[test]
    fn setup_allocates_weights_and_kv() {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let client = e.register_client("chatbot");
        let ctx = AppContext { client, device: Device::Gpu };
        let app = Chatbot::new(1, 1);
        e.submit(app.setup_job(&ctx), 0.0);
        e.run_all();
        assert!(e.vram().used() >= app.model().weights_bytes);
        e.submit(app.cleanup_job(&ctx), e.now());
        e.run_all();
        assert_eq!(e.vram().used(), 0);
    }

    #[test]
    fn evaluate_reports_components() {
        let metrics = run_exclusive(Device::Gpu);
        let m = &metrics[0];
        let names: Vec<&str> = m.components.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["ttft", "tpot"]);
        let ttft = m.components[0].1;
        assert!(ttft > 0.0 && ttft < 1.0);
    }
}
