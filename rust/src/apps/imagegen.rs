//! ImageGen: text-to-image via a stable-diffusion-webui-style backend (§3.3).
//!
//! SLO: 1 second per denoising step. A request is prompt-encode → N denoise
//! steps → VAE decode. Each denoise step bulk-enqueues its ~60 kernels
//! (PyTorch's launch-ahead stream) — the behaviour that lets ImageGen
//! monopolize the GPU under greedy allocation (§4.2) while its own
//! register-hungry attention kernels keep SMOCC low (§4.1).

use crate::apps::models::{sd35_medium_turbo, DiffusionProfile};

use crate::apps::{AppContext, Application, Arrival, RequestMetrics, Slo};
use crate::datasets::coco::{CocoCaptions, ImagePrompt};
use crate::gpusim::engine::{JobResult, JobSpec, MemOp, Phase};
use crate::gpusim::kernel::Device;

/// The ImageGen application.
pub struct ImageGen {
    model: DiffusionProfile,
    prompts: Vec<ImagePrompt>,
    slo_step: f64,
    think: f64,
}

impl ImageGen {
    pub fn new(seed: u64, num_requests: usize) -> Self {
        // stable-diffusion-webui's default sampler schedule (the paper's
        // per-step SLO implies a multi-tens-of-steps request).
        ImageGen::with_steps(seed, num_requests, 24)
    }

    pub fn with_steps(seed: u64, num_requests: usize, steps: usize) -> Self {
        let mut gen = CocoCaptions::new(seed, steps);
        ImageGen {
            prompts: gen.batch(num_requests),
            model: sd35_medium_turbo(),
            slo_step: 1.0,
            // Batched generation: the next request is queued as soon as the
            // previous image lands (webui queue behaviour).
            think: 0.1,
        }
    }

    /// Apple Silicon configuration (Appendix C): SD-v1-4 on the MPS
    /// backend — the NVIDIA-optimized SD-3.5 variant performs poorly on
    /// unified memory.
    pub fn apple_config(seed: u64, num_requests: usize) -> Self {
        let mut app = ImageGen::with_steps(seed, num_requests, 24);
        app.model = crate::apps::models::sd_v1_4();
        app
    }

    /// Render through a different kernel implementation.
    pub fn with_backend(mut self, backend: crate::gpusim::backend::KernelBackend) -> Self {
        self.model = self.model.with_backend(backend);
        self
    }

    pub fn model(&self) -> &DiffusionProfile {
        &self.model
    }

    pub fn prompts(&self) -> &[ImagePrompt] {
        &self.prompts
    }
}

impl Application for ImageGen {
    fn name(&self) -> &'static str {
        "ImageGen"
    }

    fn model_name(&self) -> &'static str {
        self.model.name
    }

    fn dataset_name(&self) -> &'static str {
        "COCO Captions"
    }

    fn slo(&self) -> Slo {
        Slo::StepTime(self.slo_step)
    }

    fn arrival(&self) -> Arrival {
        Arrival::ClosedLoop { think: self.think }
    }

    fn num_requests(&self) -> usize {
        self.prompts.len()
    }

    fn setup_job(&self, ctx: &AppContext) -> JobSpec {
        let mut phase = Phase::host("setup.load", self.model.load_seconds());
        if ctx.device == Device::Gpu {
            phase = phase.with_mem_ops(vec![
                MemOp::Alloc {
                    label: "weights".into(),
                    bytes: self.model.weights_bytes,
                },
                MemOp::Alloc {
                    label: "activations".into(),
                    bytes: self.model.activation_bytes,
                },
            ]);
        }
        JobSpec {
            client: ctx.client,
            label: "imagegen.setup".into(),
            phases: vec![phase],
        }
    }

    fn request_job(&self, ctx: &AppContext, idx: usize) -> JobSpec {
        let p = &self.prompts[idx];
        let mut phases = Vec::with_capacity(p.steps + 2);
        match ctx.device {
            Device::Gpu => {
                phases.push(Phase::gpu("encode", 0.01, self.model.preamble_kernels()));
                for _ in 0..p.steps {
                    phases.push(Phase::gpu(
                        "denoise",
                        self.model.step_host_overhead,
                        self.model.denoise_step_kernels(),
                    ));
                }
                phases.push(Phase::gpu("vae", 0.01, self.model.vae_kernels()));
            }
            Device::Cpu => {
                for _ in 0..p.steps {
                    phases.push(Phase::cpu(
                        "denoise",
                        self.model.step_host_overhead,
                        self.model.denoise_step_cpu(),
                    ));
                }
            }
        }
        JobSpec {
            client: ctx.client,
            label: format!("imagegen.req{}", p.id),
            phases,
        }
    }

    fn cleanup_job(&self, ctx: &AppContext) -> JobSpec {
        JobSpec {
            client: ctx.client,
            label: "imagegen.cleanup".into(),
            phases: vec![Phase::host("cleanup", 0.1).with_mem_ops(vec![MemOp::FreeAll])],
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn evaluate(&self, result: &JobResult) -> RequestMetrics {
        let steps: Vec<f64> = result
            .phases
            .iter()
            .filter(|p| p.tag == "denoise")
            .map(|p| p.end - p.start)
            .collect();
        let mean_step = if steps.is_empty() {
            f64::INFINITY
        } else {
            steps.iter().sum::<f64>() / steps.len() as f64
        };
        let normalized = mean_step / self.slo_step;
        RequestMetrics {
            label: result.label.clone(),
            latency: result.latency(),
            normalized,
            slo_met: normalized <= 1.0,
            components: vec![("step_time", mean_step)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::engine::Engine;
    use crate::gpusim::policy::Policy;
    use crate::gpusim::profiles::Testbed;

    fn run_one(device: Device) -> RequestMetrics {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let client = e.register_client("imagegen");
        let ctx = AppContext { client, device };
        let app = ImageGen::new(2, 1);
        e.submit(app.setup_job(&ctx), 0.0);
        e.run_all();
        e.submit(app.request_job(&ctx, 0), e.now());
        e.run_all();
        let done = e.take_completed();
        let r = done.iter().find(|r| r.label.starts_with("imagegen.req")).unwrap();
        app.evaluate(r)
    }

    #[test]
    fn gpu_exclusive_meets_step_slo() {
        let m = run_one(Device::Gpu);
        assert!(m.slo_met, "normalized {}", m.normalized);
        assert!(m.normalized > 0.2 && m.normalized < 1.0, "step should be a large fraction of the SLO: {}", m.normalized);
    }

    #[test]
    fn cpu_exclusive_massively_misses() {
        // Fig. 3: ImageGen on CPU is tens of times over its SLO.
        let m = run_one(Device::Cpu);
        assert!(!m.slo_met);
        assert!(m.normalized > 10.0, "normalized {}", m.normalized);
    }

    #[test]
    fn request_has_expected_phase_structure() {
        let app = ImageGen::new(2, 1);
        let ctx = AppContext {
            client: crate::gpusim::engine::ClientId(0),
            device: Device::Gpu,
        };
        let job = app.request_job(&ctx, 0);
        let tags: Vec<&str> = job.phases.iter().map(|p| p.tag).collect();
        assert_eq!(tags[0], "encode");
        assert_eq!(*tags.last().unwrap(), "vae");
        assert_eq!(tags.iter().filter(|t| **t == "denoise").count(), 24);
    }

    #[test]
    fn setup_is_the_biggest_vram_consumer() {
        // Fig. 8: ImageGen requires the most GPU memory of the three apps.
        let app = ImageGen::new(2, 1);
        let total = app.model().weights_bytes + app.model().activation_bytes;
        let chat = crate::apps::Chatbot::new(1, 1);
        let chat_total = chat.model().weights_bytes + chat.model().kv_cache_bytes(4096);
        assert!(total > chat_total);
    }
}
