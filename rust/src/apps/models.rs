//! Model execution profiles: per-request kernel traces and CPU work models.
//!
//! Each GenAI model in Table 1 is characterized by (a) its memory footprint
//! and (b) the *kernel footprint trace* its backend launches per unit of
//! work (token, denoise step, audio segment). The footprints — grid sizes,
//! registers/thread, shared memory — encode the paper's §4.1 analysis:
//!
//! * **Llama-3.2-3B via llama.cpp**: kernels tuned to the GPU architecture →
//!   high SMOCC; decode is memory-bandwidth-bound (reads all weights per
//!   token).
//! * **SD-3.5-Medium-Turbo via PyTorch**: generic attention kernels need
//!   >150 registers/thread → ≤1 block/SM → low SMOCC.
//! * **Whisper-Large-V3-Turbo**: encoder = large matmuls with healthy
//!   occupancy; decoder = hundreds of tiny kernels with high register and
//!   shared-memory pressure → very low SMOCC and launch-bound latency.
//!
//! CPU variants model llama.cpp/PyTorch CPU backends with empirically-shaped
//! inefficiency factors (quantized GEMV without AVX-friendly layout, no
//! operator fusion), documented per model.

use crate::gpusim::engine::CpuWork;
use crate::gpusim::kernel::KernelDesc;
use crate::gpusim::vram::{gib, mib};

// ---------------------------------------------------------------------
// Llama (Chatbot / DeepResearch backbone)
// ---------------------------------------------------------------------

/// A llama.cpp-served decoder-only LLM.
#[derive(Debug, Clone)]
pub struct LlamaProfile {
    pub name: &'static str,
    pub layers: usize,
    pub params: f64,
    /// Quantized weight bytes resident in device memory.
    pub weights_bytes: u64,
    /// KV-cache bytes per token of context.
    pub kv_bytes_per_token: u64,
    /// Max context window supported by the model.
    pub max_context: usize,
    /// CPU backend inefficiency: effective FLOPs multiplier.
    pub cpu_flops_factor: f64,
    /// CPU backend inefficiency: effective bytes multiplier.
    pub cpu_bytes_factor: f64,
}

/// Llama-3.2-3B, Q4_K_M quantization (the paper's default Chatbot /
/// DeepResearch model).
pub fn llama_3_2_3b() -> LlamaProfile {
    LlamaProfile {
        name: "Llama-3.2-3B",
        layers: 28,
        params: 3.2e9,
        weights_bytes: 2 * gib(1),
        // 28 layers × 2 (K,V) × 8 kv-heads × 128 dim × 2 B (f16)
        kv_bytes_per_token: 28 * 2 * 8 * 128 * 2,
        max_context: 131_072,
        cpu_flops_factor: 4.0,
        cpu_bytes_factor: 3.0,
    }
}

/// Llama-3.1-8B fp16 (Appendix B.4's larger model: 16 GB of weights, does
/// not fit alongside the other applications).
pub fn llama_3_1_8b() -> LlamaProfile {
    LlamaProfile {
        name: "Llama-3.1-8B",
        layers: 32,
        params: 8e9,
        weights_bytes: 16 * gib(1),
        kv_bytes_per_token: 32 * 2 * 8 * 128 * 2,
        max_context: 131_072,
        cpu_flops_factor: 4.0,
        cpu_bytes_factor: 1.5, // fp16 weights stream better than Q4 dequant
    }
}

/// Number of kernels llama.cpp launches per decoded token (fused per-layer
/// pipeline: qkv, rope+attn, o-proj, 2×norm, ffn — ~1 fused launch each plus
/// head/embedding).
const LLAMA_KERNELS_PER_TOKEN: usize = 30;

impl LlamaProfile {
    /// Prefill `tokens` of prompt on the GPU: one large fused kernel per
    /// layer, compute-bound, llama.cpp-tuned occupancy.
    pub fn prefill_kernels(&self, tokens: usize) -> Vec<KernelDesc> {
        let flops_total = 2.0 * self.params * tokens as f64;
        let per_layer = flops_total / self.layers as f64;
        let bytes_per_layer = self.weights_bytes as f64 / self.layers as f64;
        (0..self.layers)
            .map(|_| {
                KernelDesc::new(
                    "prefill.layer",
                    2048.min(tokens * 8).max(72),
                    256,
                    64,
                    16 * 1024,
                    per_layer,
                    bytes_per_layer,
                )
            })
            .collect()
    }

    /// Decode one token on the GPU at the given context length. Memory-bound:
    /// every kernel streams its slice of the weights plus the KV cache.
    pub fn decode_kernels(&self, context: usize) -> Vec<KernelDesc> {
        let n = LLAMA_KERNELS_PER_TOKEN;
        let weight_bytes = self.weights_bytes as f64 / n as f64;
        let kv_bytes = (self.kv_bytes_per_token * context as u64) as f64 / n as f64;
        let flops = 2.0 * self.params / n as f64;
        (0..n)
            .map(|_| {
                // 288 blocks at 3 blocks/SM spans all 72 SMs (SMACT 100%)
                // at 24/32 resident warps (SMOCC 75%) — llama.cpp's tuned
                // launch shape on Turing.
                KernelDesc::new("decode.layer", 288, 256, 80, 8 * 1024, flops, weight_bytes + kv_bytes)
            })
            .collect()
    }

    /// Decode-token kernels *excluding* attention — used when the KV cache
    /// lives in CPU DRAM (`--no-kv-offload`): llama.cpp then runs attention
    /// on the CPU (§4.2.1).
    pub fn decode_kernels_no_attn(&self) -> Vec<KernelDesc> {
        // Attention is ~8 of the 30 launches; the rest are weight matmuls.
        let n = LLAMA_KERNELS_PER_TOKEN - 8;
        let weight_bytes = self.weights_bytes as f64 / LLAMA_KERNELS_PER_TOKEN as f64;
        let flops = 2.0 * self.params / LLAMA_KERNELS_PER_TOKEN as f64;
        (0..n)
            .map(|_| KernelDesc::new("decode.matmul", 256, 256, 64, 8 * 1024, flops, weight_bytes))
            .collect()
    }

    /// CPU-side attention over the KV cache for one token (KV-cache-on-CPU
    /// mode). Bandwidth-bound over the context's K/V.
    pub fn attention_cpu(&self, context: usize) -> CpuWork {
        let kv_bytes = (self.kv_bytes_per_token * context as u64) as f64;
        CpuWork {
            flops: 4.0 * context as f64 * 4096.0, // qk^T + pv per layer-aggregate
            // f32 up-conversion + strided K/V walks: the CPU attention path
            // moves ~3x the nominal KV bytes through DRAM.
            bytes: kv_bytes * self.cpu_bytes_factor,
            threads: 6,
        }
    }

    /// Full prefill on the CPU backend.
    pub fn prefill_cpu(&self, tokens: usize) -> CpuWork {
        CpuWork {
            flops: 2.0 * self.params * tokens as f64 * self.cpu_flops_factor,
            bytes: self.weights_bytes as f64 * self.cpu_bytes_factor,
            threads: 24,
        }
    }

    /// Decode one token on the CPU backend.
    pub fn decode_cpu(&self, context: usize) -> CpuWork {
        let kv_bytes = (self.kv_bytes_per_token * context as u64) as f64;
        CpuWork {
            flops: 2.0 * self.params * self.cpu_flops_factor,
            bytes: (self.weights_bytes as f64 + kv_bytes) * self.cpu_bytes_factor,
            threads: 24,
        }
    }

    /// KV-cache bytes for a context window.
    pub fn kv_cache_bytes(&self, context: usize) -> u64 {
        self.kv_bytes_per_token * context as u64
    }

    /// Model load time from disk (NVMe + PCIe, ~2 GB/s effective).
    pub fn load_seconds(&self) -> f64 {
        self.weights_bytes as f64 / 2e9
    }
}

// ---------------------------------------------------------------------
// Stable Diffusion (ImageGen)
// ---------------------------------------------------------------------

/// A diffusion model served by stable-diffusion-webui (PyTorch backend).
#[derive(Debug, Clone)]
pub struct DiffusionProfile {
    pub name: &'static str,
    pub weights_bytes: u64,
    pub activation_bytes: u64,
    /// Attention kernels per denoise step (the >150-register hogs).
    pub attn_kernels_per_step: usize,
    /// Other (matmul/conv/norm) kernels per step.
    pub other_kernels_per_step: usize,
    /// FLOPs per attention kernel at 512×512.
    pub attn_flops: f64,
    /// FLOPs per non-attention kernel.
    pub other_flops: f64,
    /// Host-side overhead per step (webui scheduler + sampler).
    pub step_host_overhead: f64,
    pub cpu_flops_factor: f64,
}

/// SD-3.5-Medium-Turbo (2.5 B params, fp16, few-step turbo sampling).
pub fn sd35_medium_turbo() -> DiffusionProfile {
    DiffusionProfile {
        name: "SD-3.5-Medium-Turbo",
        weights_bytes: 5 * gib(1),
        activation_bytes: 3 * gib(1),
        attn_kernels_per_step: 48,
        other_kernels_per_step: 72,
        attn_flops: 5.0e10,
        other_flops: 3.0e10,
        // PyTorch launch-ahead keeps the stream fed between steps; only the
        // sampler's host math separates them.
        step_host_overhead: 0.005,
        // PyTorch CPU diffusion runs fp32 without fused attention: measured
        // step times are ~30x the GPU SLO on server-class CPUs (Fig. 3).
        cpu_flops_factor: 10.0,
    }
}

/// SD-v1-4 (860 M params) — the paper's Apple Silicon ImageGen model
/// (Appendix C): ~3x less compute per step than SD-3.5-Medium, better suited
/// to the unified-memory GPU.
pub fn sd_v1_4() -> DiffusionProfile {
    DiffusionProfile {
        name: "SD-v1-4",
        weights_bytes: 2 * gib(1),
        activation_bytes: gib(1),
        attn_kernels_per_step: 48,
        other_kernels_per_step: 72,
        attn_flops: 1.6e10,
        other_flops: 1.0e10,
        step_host_overhead: 0.005,
        cpu_flops_factor: 10.0,
    }
}

impl DiffusionProfile {
    /// One denoise step on the GPU. The attention kernels reproduce §4.1:
    /// 168 registers/thread → 1 block/SM → SMOCC ≈ 0.25.
    pub fn denoise_step_kernels(&self) -> Vec<KernelDesc> {
        let mut v = Vec::with_capacity(self.attn_kernels_per_step + self.other_kernels_per_step);
        for i in 0..(self.attn_kernels_per_step + self.other_kernels_per_step) {
            // Interleave attention and other kernels as a transformer block
            // sequence would.
            if i % 5 < 2 {
                v.push(KernelDesc::new(
                    "denoise.attn",
                    2048,
                    256,
                    168, // the paper's register-pressure pathology
                    16 * 1024,
                    self.attn_flops,
                    64.0 * 1024.0 * 1024.0,
                ));
            } else {
                v.push(KernelDesc::new(
                    "denoise.matmul",
                    2048,
                    256,
                    96,
                    8 * 1024,
                    self.other_flops,
                    128.0 * 1024.0 * 1024.0,
                ));
            }
        }
        v
    }

    /// Prompt encoding + VAE decode bracketing a request.
    pub fn preamble_kernels(&self) -> Vec<KernelDesc> {
        (0..8)
            .map(|_| KernelDesc::new("clip.encode", 512, 256, 64, 8 * 1024, 2e10, 32e6))
            .collect()
    }

    pub fn vae_kernels(&self) -> Vec<KernelDesc> {
        (0..12)
            .map(|_| KernelDesc::new("vae.decode", 4096, 256, 96, 8 * 1024, 4e10, 256e6))
            .collect()
    }

    /// One denoise step on the CPU backend (PyTorch CPU): heavily
    /// compute-bound, ~30–60× the GPU step.
    pub fn denoise_step_cpu(&self) -> CpuWork {
        let flops = self.attn_kernels_per_step as f64 * self.attn_flops
            + self.other_kernels_per_step as f64 * self.other_flops;
        CpuWork {
            flops: flops * self.cpu_flops_factor,
            bytes: self.weights_bytes as f64,
            threads: 24,
        }
    }

    pub fn load_seconds(&self) -> f64 {
        self.weights_bytes as f64 / 2e9
    }
}

// ---------------------------------------------------------------------
// Whisper (LiveCaptions)
// ---------------------------------------------------------------------

/// An encoder-decoder speech model (whisper-online backend).
#[derive(Debug, Clone)]
pub struct WhisperProfile {
    pub name: &'static str,
    pub weights_bytes: u64,
    pub encoder_kernels: usize,
    pub encoder_flops_per_kernel: f64,
    /// Tiny kernels per decoded token (the §4.1 low-SMOCC pathology).
    pub decoder_kernels_per_token: usize,
    pub decoder_flops_per_kernel: f64,
    pub cpu_flops_factor: f64,
}

/// Whisper-Large-V3-Turbo (809 M params, 4 decoder layers).
pub fn whisper_large_v3_turbo() -> WhisperProfile {
    WhisperProfile {
        name: "Whisper-Large-V3-Turbo",
        weights_bytes: 1_600 * mib(1),
        encoder_kernels: 16,
        encoder_flops_per_kernel: 4e10,
        decoder_kernels_per_token: 40,
        decoder_flops_per_kernel: 5e7,
        cpu_flops_factor: 6.0, // PyTorch CPU whisper-large: RTF > 1
    }
}

impl WhisperProfile {
    /// Encode one audio segment: large parallel matmuls, healthy occupancy.
    pub fn encode_kernels(&self) -> Vec<KernelDesc> {
        (0..self.encoder_kernels)
            .map(|_| {
                KernelDesc::new(
                    "encode.matmul",
                    1500,
                    256,
                    64,
                    32 * 1024,
                    self.encoder_flops_per_kernel,
                    48e6,
                )
            })
            .collect()
    }

    /// Decode one transcript token: many tiny kernels with ~200 registers
    /// and heavy shared memory → 1 block/SM, 2 warps → SMOCC ≈ 0.06, and
    /// the grid still spans the device (SMACT stays high, Fig. 4c).
    pub fn decode_token_kernels(&self) -> Vec<KernelDesc> {
        (0..self.decoder_kernels_per_token)
            .map(|_| {
                KernelDesc::new(
                    "decode.small",
                    72,
                    64,
                    200,
                    40 * 1024,
                    self.decoder_flops_per_kernel,
                    3e6,
                )
            })
            .collect()
    }

    /// Encode a segment on the CPU backend.
    pub fn encode_cpu(&self) -> CpuWork {
        CpuWork {
            flops: self.encoder_kernels as f64
                * self.encoder_flops_per_kernel
                * self.cpu_flops_factor,
            bytes: self.weights_bytes as f64,
            threads: 24,
        }
    }

    /// Decode one token on the CPU backend.
    pub fn decode_token_cpu(&self) -> CpuWork {
        CpuWork {
            flops: self.decoder_kernels_per_token as f64
                * self.decoder_flops_per_kernel
                * self.cpu_flops_factor
                * 5.0, // tiny-op dispatch overhead dominates on CPU
            bytes: 0.3e9,
            threads: 8,
        }
    }

    pub fn load_seconds(&self) -> f64 {
        self.weights_bytes as f64 / 2e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::kernel::{duration, occupancy};
    use crate::gpusim::profiles::rtx6000;

    #[test]
    fn llama_decode_token_is_fast_and_memory_bound() {
        let gpu = rtx6000();
        let m = llama_3_2_3b();
        let kernels = m.decode_kernels(512);
        assert_eq!(kernels.len(), 30);
        let total: f64 = kernels.iter().map(|k| duration(k, &gpu, gpu.num_sms).unwrap()).sum();
        // llama.cpp decodes a 3B-Q4 token in single-digit milliseconds.
        assert!(total > 1e-3 && total < 0.02, "token time {total}");
        // High SMOCC — llama.cpp's tuned kernels (Fig. 4a): 3 blocks/SM at
        // 24/32 warps.
        let occ = occupancy(&kernels[0], &gpu).unwrap();
        assert!(occ.occupancy >= 0.7, "occ {}", occ.occupancy);
    }

    #[test]
    fn llama_prefill_scales_with_tokens() {
        let gpu = rtx6000();
        let m = llama_3_2_3b();
        let t = |n: usize| -> f64 {
            m.prefill_kernels(n)
                .iter()
                .map(|k| duration(k, &gpu, gpu.num_sms).unwrap())
                .sum()
        };
        let short = t(64);
        let long = t(512);
        assert!(long > short * 4.0, "short={short} long={long}");
        // TTFT well under the 1 s SLO on GPU.
        assert!(long < 0.5, "prefill(512) = {long}");
    }

    #[test]
    fn sd_attention_kernels_have_low_occupancy() {
        let gpu = rtx6000();
        let m = sd35_medium_turbo();
        let kernels = m.denoise_step_kernels();
        let attn = kernels.iter().find(|k| k.tag == "denoise.attn").unwrap();
        let occ = occupancy(attn, &gpu).unwrap();
        assert!(occ.occupancy <= 0.3, "SD attention occ {}", occ.occupancy);
        // Step time within the 1 s SLO when exclusive.
        let step: f64 = kernels.iter().map(|k| duration(k, &gpu, gpu.num_sms).unwrap()).sum();
        assert!(step > 0.1 && step < 0.9, "step {step}");
    }

    #[test]
    fn whisper_decoder_tiny_kernels() {
        let gpu = rtx6000();
        let m = whisper_large_v3_turbo();
        let dec = m.decode_token_kernels();
        let occ = occupancy(&dec[0], &gpu).unwrap();
        assert!(occ.occupancy < 0.1, "whisper decode occ {}", occ.occupancy);
        let tok: f64 = dec.iter().map(|k| duration(k, &gpu, gpu.num_sms).unwrap()).sum();
        assert!(tok < 3e-3, "token {tok}");
        // Encoder healthy occupancy, Fig. 4c.
        let enc = m.encode_kernels();
        let eocc = occupancy(&enc[0], &gpu).unwrap();
        assert!(eocc.occupancy >= 0.4, "encoder occ {}", eocc.occupancy);
    }

    #[test]
    fn whisper_segment_exclusive_meets_slo() {
        let gpu = rtx6000();
        let m = whisper_large_v3_turbo();
        let enc: f64 = m.encode_kernels().iter().map(|k| duration(k, &gpu, gpu.num_sms).unwrap()).sum();
        let dec: f64 = (0..12)
            .flat_map(|_| m.decode_token_kernels())
            .map(|k| duration(&k, &gpu, gpu.num_sms).unwrap())
            .sum();
        let seg = enc + dec;
        assert!(seg < 0.5, "segment {seg} must be far below the 2 s SLO");
    }

    #[test]
    fn kv_cache_sizing_matches_paper() {
        // §4.2.1: a 128K-token window needs a ~16 GB KV cache... for the
        // llama.cpp f16 configuration of Llama-3.2-3B.
        let m = llama_3_2_3b();
        let bytes = m.kv_cache_bytes(131_072);
        let gb = bytes as f64 / (1 << 30) as f64;
        assert!((gb - 7.0).abs() < 2.0 || gb > 6.0, "kv cache {gb} GiB");
    }

    #[test]
    fn llama8b_does_not_fit_with_others() {
        // B.4: 16 GB of weights + SD (8 GB) exceeds the RTX 6000's 24 GB.
        let total = llama_3_1_8b().weights_bytes
            + sd35_medium_turbo().weights_bytes
            + sd35_medium_turbo().activation_bytes
            + whisper_large_v3_turbo().weights_bytes;
        assert!(total > 24 * gib(1));
    }

    #[test]
    fn cpu_models_much_slower() {
        let m = llama_3_2_3b();
        let cpu_work = m.decode_cpu(512);
        // Effective bytes per CPU token: several GB → tens of ms at DRAM bw.
        assert!(cpu_work.bytes > 5e9);
        let sd = sd35_medium_turbo().denoise_step_cpu();
        assert!(sd.flops > 1e13); // ~10s-scale on the Xeon
    }
}
