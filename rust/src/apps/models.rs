//! Model execution profiles: per-request kernel traces and CPU work models.
//!
//! Each GenAI model in Table 1 is characterized by (a) its memory footprint
//! and FLOP/byte magnitudes (owned here) and (b) the *kernel footprint
//! trace* its backend launches per unit of work (token, denoise step, audio
//! segment) — the grid sizes, registers/thread, shared memory, and launch
//! counts, which are owned by the pluggable
//! [`KernelBackend`](crate::gpusim::backend::KernelBackend) launch-shape
//! tables. The default `TunedNative` backend reproduces the paper's §4.1
//! measurements:
//!
//! * **Llama-3.2-3B via llama.cpp**: kernels tuned to the GPU architecture →
//!   high SMOCC; decode is memory-bandwidth-bound (reads all weights per
//!   token).
//! * **SD-3.5-Medium-Turbo via PyTorch**: generic attention kernels need
//!   >150 registers/thread → ≤1 block/SM → low SMOCC.
//! * **Whisper-Large-V3-Turbo**: encoder = large matmuls with healthy
//!   occupancy; decoder = hundreds of tiny kernels with high register and
//!   shared-memory pressure → very low SMOCC and launch-bound latency.
//!
//! Selecting `GenericTorch` or `FusedCustom` re-cuts the same logical work
//! into that implementation's launch shapes (the §6 tuned-vs-generic
//! ablation). CPU variants model llama.cpp/PyTorch CPU backends with
//! empirically-shaped inefficiency factors, scaled by the backend's CPU
//! multipliers.

use crate::gpusim::backend::KernelBackend;
use crate::gpusim::engine::CpuWork;
use crate::gpusim::kernel::KernelDesc;
use crate::gpusim::vram::{gib, mib};

// ---------------------------------------------------------------------
// Llama (Chatbot / DeepResearch backbone)
// ---------------------------------------------------------------------

/// A llama.cpp-served decoder-only LLM.
#[derive(Debug, Clone)]
pub struct LlamaProfile {
    pub name: &'static str,
    pub layers: usize,
    pub params: f64,
    /// Quantized weight bytes resident in device memory.
    pub weights_bytes: u64,
    /// KV-cache bytes per token of context.
    pub kv_bytes_per_token: u64,
    /// Max context window supported by the model.
    pub max_context: usize,
    /// CPU backend inefficiency: effective FLOPs multiplier.
    pub cpu_flops_factor: f64,
    /// CPU backend inefficiency: effective bytes multiplier.
    pub cpu_bytes_factor: f64,
    /// Which kernel implementation cuts this model's work into launches.
    pub backend: KernelBackend,
}

/// Llama-3.2-3B, Q4_K_M quantization (the paper's default Chatbot /
/// DeepResearch model).
pub fn llama_3_2_3b() -> LlamaProfile {
    LlamaProfile {
        name: "Llama-3.2-3B",
        layers: 28,
        params: 3.2e9,
        weights_bytes: 2 * gib(1),
        // 28 layers × 2 (K,V) × 8 kv-heads × 128 dim × 2 B (f16)
        kv_bytes_per_token: 28 * 2 * 8 * 128 * 2,
        max_context: 131_072,
        cpu_flops_factor: 4.0,
        cpu_bytes_factor: 3.0,
        backend: KernelBackend::TunedNative,
    }
}

/// Llama-3.1-8B fp16 (Appendix B.4's larger model: 16 GB of weights, does
/// not fit alongside the other applications).
pub fn llama_3_1_8b() -> LlamaProfile {
    LlamaProfile {
        name: "Llama-3.1-8B",
        layers: 32,
        params: 8e9,
        weights_bytes: 16 * gib(1),
        kv_bytes_per_token: 32 * 2 * 8 * 128 * 2,
        max_context: 131_072,
        cpu_flops_factor: 4.0,
        cpu_bytes_factor: 1.5, // fp16 weights stream better than Q4 dequant
        backend: KernelBackend::TunedNative,
    }
}

impl LlamaProfile {
    /// Re-cut this model's work with a different kernel implementation.
    pub fn with_backend(mut self, backend: KernelBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Kernel launches per decoded token under the selected backend — the
    /// single source of truth shared with the inference server's batched
    /// iterations (formerly the hardcoded `LLAMA_KERNELS_PER_TOKEN`).
    pub fn decode_launches(&self) -> usize {
        self.backend.llama().decode_launches()
    }

    /// Prefill `tokens` of prompt on the GPU: compute-bound layer kernels
    /// at the backend's launch shapes (llama.cpp fuses one launch per
    /// layer; eager backends split attention out at its 168-register
    /// footprint).
    pub fn prefill_kernels(&self, tokens: usize) -> Vec<KernelDesc> {
        let t = self.backend.llama();
        let blocks = 2048.min(tokens * 8).max(72);
        let per_layer = 2.0 * self.params * tokens as f64 / self.layers as f64;
        let bytes_per_layer = self.weights_bytes as f64 / self.layers as f64;
        let mut v = Vec::with_capacity(self.layers * (1 + t.prefill_attn.is_some() as usize));
        for _ in 0..self.layers {
            match &t.prefill_attn {
                None => v.push(t.prefill_matmul.kernel_with_blocks(blocks, per_layer, bytes_per_layer)),
                Some(attn) => {
                    let frac = t.attn_flops_frac;
                    v.push(t.prefill_matmul.kernel_with_blocks(
                        blocks,
                        per_layer * (1.0 - frac),
                        bytes_per_layer,
                    ));
                    v.push(attn.kernel_with_blocks(
                        blocks,
                        per_layer * frac,
                        bytes_per_layer * 0.25,
                    ));
                }
            }
        }
        v
    }

    /// Decode one token on the GPU at the given context length.
    /// Memory-bound: the matmul launches stream the full weights between
    /// them, the attention launches stream the context's KV (times the
    /// backend's intermediate-materialization factor).
    pub fn decode_kernels(&self, context: usize) -> Vec<KernelDesc> {
        let t = self.backend.llama();
        let total_flops = 2.0 * self.params;
        let kv_bytes = (self.kv_bytes_per_token * context as u64) as f64 * t.attn_bytes_factor;
        let mut v = self.decode_kernels_no_attn();
        let n_a = t.decode_attn_launches;
        for _ in 0..n_a {
            v.push(t.decode_attn.kernel(
                total_flops * t.attn_flops_frac / n_a as f64,
                kv_bytes / n_a as f64,
            ));
        }
        v
    }

    /// Decode-token kernels *excluding* attention — used when the KV cache
    /// lives in CPU DRAM (`--no-kv-offload`): the runtime then computes
    /// attention on the CPU (§4.2.1). This is literally the matmul prefix
    /// of [`Self::decode_kernels`], so the two variants share one launch
    /// table and cannot drift apart.
    pub fn decode_kernels_no_attn(&self) -> Vec<KernelDesc> {
        let t = self.backend.llama();
        let n_m = t.decode_matmul_launches;
        let total_flops = 2.0 * self.params;
        let weight_bytes = self.weights_bytes as f64;
        (0..n_m)
            .map(|_| {
                t.decode_matmul.kernel(
                    total_flops * (1.0 - t.attn_flops_frac) / n_m as f64,
                    weight_bytes / n_m as f64,
                )
            })
            .collect()
    }

    /// CPU-side attention over the KV cache for one token (KV-cache-on-CPU
    /// mode). Bandwidth-bound over the context's K/V.
    pub fn attention_cpu(&self, context: usize) -> CpuWork {
        let t = self.backend.llama();
        let kv_bytes = (self.kv_bytes_per_token * context as u64) as f64;
        CpuWork {
            flops: 4.0 * context as f64 * 4096.0 * t.cpu_flops_mult, // qk^T + pv per layer-aggregate
            // f32 up-conversion + strided K/V walks: the CPU attention path
            // moves ~3x the nominal KV bytes through DRAM.
            bytes: kv_bytes * self.cpu_bytes_factor * t.cpu_bytes_mult,
            threads: 6,
        }
    }

    /// Full prefill on the CPU backend.
    pub fn prefill_cpu(&self, tokens: usize) -> CpuWork {
        let t = self.backend.llama();
        CpuWork {
            flops: 2.0 * self.params * tokens as f64 * self.cpu_flops_factor * t.cpu_flops_mult,
            bytes: self.weights_bytes as f64 * self.cpu_bytes_factor * t.cpu_bytes_mult,
            threads: 24,
        }
    }

    /// Decode one token on the CPU backend.
    pub fn decode_cpu(&self, context: usize) -> CpuWork {
        let t = self.backend.llama();
        let kv_bytes = (self.kv_bytes_per_token * context as u64) as f64;
        CpuWork {
            flops: 2.0 * self.params * self.cpu_flops_factor * t.cpu_flops_mult,
            bytes: (self.weights_bytes as f64 + kv_bytes)
                * self.cpu_bytes_factor
                * t.cpu_bytes_mult,
            threads: 24,
        }
    }

    /// KV-cache bytes for a context window.
    pub fn kv_cache_bytes(&self, context: usize) -> u64 {
        self.kv_bytes_per_token * context as u64
    }

    /// Model load time from disk (NVMe + PCIe, ~2 GB/s effective).
    pub fn load_seconds(&self) -> f64 {
        self.weights_bytes as f64 / 2e9
    }
}

// ---------------------------------------------------------------------
// Stable Diffusion (ImageGen)
// ---------------------------------------------------------------------

/// A diffusion model served by stable-diffusion-webui (PyTorch backend).
#[derive(Debug, Clone)]
pub struct DiffusionProfile {
    pub name: &'static str,
    pub weights_bytes: u64,
    pub activation_bytes: u64,
    /// Attention kernels per denoise step (the >150-register hogs).
    pub attn_kernels_per_step: usize,
    /// Other (matmul/conv/norm) kernels per step.
    pub other_kernels_per_step: usize,
    /// FLOPs per attention kernel at 512×512.
    pub attn_flops: f64,
    /// FLOPs per non-attention kernel.
    pub other_flops: f64,
    /// Host-side overhead per step (webui scheduler + sampler).
    pub step_host_overhead: f64,
    pub cpu_flops_factor: f64,
    /// Which kernel implementation cuts this model's work into launches.
    pub backend: KernelBackend,
}

/// SD-3.5-Medium-Turbo (2.5 B params, fp16, few-step turbo sampling).
pub fn sd35_medium_turbo() -> DiffusionProfile {
    DiffusionProfile {
        name: "SD-3.5-Medium-Turbo",
        weights_bytes: 5 * gib(1),
        activation_bytes: 3 * gib(1),
        attn_kernels_per_step: 48,
        other_kernels_per_step: 72,
        attn_flops: 5.0e10,
        other_flops: 3.0e10,
        // PyTorch launch-ahead keeps the stream fed between steps; only the
        // sampler's host math separates them.
        step_host_overhead: 0.005,
        // PyTorch CPU diffusion runs fp32 without fused attention: measured
        // step times are ~30x the GPU SLO on server-class CPUs (Fig. 3).
        cpu_flops_factor: 10.0,
        backend: KernelBackend::TunedNative,
    }
}

/// SD-v1-4 (860 M params) — the paper's Apple Silicon ImageGen model
/// (Appendix C): ~3x less compute per step than SD-3.5-Medium, better suited
/// to the unified-memory GPU.
pub fn sd_v1_4() -> DiffusionProfile {
    DiffusionProfile {
        name: "SD-v1-4",
        weights_bytes: 2 * gib(1),
        activation_bytes: gib(1),
        attn_kernels_per_step: 48,
        other_kernels_per_step: 72,
        attn_flops: 1.6e10,
        other_flops: 1.0e10,
        step_host_overhead: 0.005,
        cpu_flops_factor: 10.0,
        backend: KernelBackend::TunedNative,
    }
}

impl DiffusionProfile {
    /// Re-cut this model's work with a different kernel implementation.
    pub fn with_backend(mut self, backend: KernelBackend) -> Self {
        self.backend = backend;
        self
    }

    /// One denoise step on the GPU at the backend's launch shapes. The
    /// default (webui/PyTorch) attention reproduces §4.1: 168
    /// registers/thread → 1 block/SM → SMOCC ≈ 0.25; the eager backend
    /// additionally splits each attention op into three launches with
    /// materialized intermediates; the fused backend runs it
    /// flash-attention-style at healthy occupancy.
    pub fn denoise_step_kernels(&self) -> Vec<KernelDesc> {
        let t = self.backend.diffusion();
        let ops = self.attn_kernels_per_step + self.other_kernels_per_step;
        let mut v =
            Vec::with_capacity(self.attn_kernels_per_step * t.attn_split + self.other_kernels_per_step);
        for i in 0..ops {
            // Interleave attention and other kernels as a transformer block
            // sequence would.
            if i % 5 < 2 {
                for _ in 0..t.attn_split {
                    v.push(t.attn.kernel(
                        self.attn_flops / t.attn_split as f64,
                        t.attn_bytes_per_op / t.attn_split as f64,
                    ));
                }
            } else {
                v.push(t.other.kernel(self.other_flops, t.other_bytes_per_op));
            }
        }
        v
    }

    /// Prompt encoding + VAE decode bracketing a request (geometry is
    /// single-sourced in the backend table, identical across backends).
    pub fn preamble_kernels(&self) -> Vec<KernelDesc> {
        let t = self.backend.diffusion();
        (0..t.clip_launches)
            .map(|_| t.clip.kernel(t.clip_flops, t.clip_bytes))
            .collect()
    }

    pub fn vae_kernels(&self) -> Vec<KernelDesc> {
        let t = self.backend.diffusion();
        (0..t.vae_launches)
            .map(|_| t.vae.kernel(t.vae_flops, t.vae_bytes))
            .collect()
    }

    /// One denoise step on the CPU backend (PyTorch CPU): heavily
    /// compute-bound, ~30–60× the GPU step.
    pub fn denoise_step_cpu(&self) -> CpuWork {
        let t = self.backend.diffusion();
        let flops = self.attn_kernels_per_step as f64 * self.attn_flops
            + self.other_kernels_per_step as f64 * self.other_flops;
        CpuWork {
            flops: flops * self.cpu_flops_factor * t.cpu_flops_mult,
            bytes: self.weights_bytes as f64,
            threads: 24,
        }
    }

    pub fn load_seconds(&self) -> f64 {
        self.weights_bytes as f64 / 2e9
    }
}

// ---------------------------------------------------------------------
// Whisper (LiveCaptions)
// ---------------------------------------------------------------------

/// An encoder-decoder speech model (whisper-online backend).
///
/// The `encoder_kernels` / `decoder_kernels_per_token` fields are the
/// *logical* op counts (the tuned reference used to budget FLOPs/bytes);
/// the backend table decides how many launches those ops become.
#[derive(Debug, Clone)]
pub struct WhisperProfile {
    pub name: &'static str,
    pub weights_bytes: u64,
    pub encoder_kernels: usize,
    pub encoder_flops_per_kernel: f64,
    /// Per-encoder-op DRAM traffic (activations + weight slices).
    pub encoder_bytes_per_kernel: f64,
    /// Tiny kernels per decoded token (the §4.1 low-SMOCC pathology).
    pub decoder_kernels_per_token: usize,
    pub decoder_flops_per_kernel: f64,
    pub decoder_bytes_per_kernel: f64,
    pub cpu_flops_factor: f64,
    /// Which kernel implementation cuts this model's work into launches.
    pub backend: KernelBackend,
}

/// Whisper-Large-V3-Turbo (809 M params, 4 decoder layers).
pub fn whisper_large_v3_turbo() -> WhisperProfile {
    WhisperProfile {
        name: "Whisper-Large-V3-Turbo",
        weights_bytes: 1_600 * mib(1),
        encoder_kernels: 16,
        encoder_flops_per_kernel: 4e10,
        encoder_bytes_per_kernel: 48e6,
        decoder_kernels_per_token: 40,
        decoder_flops_per_kernel: 5e7,
        decoder_bytes_per_kernel: 3e6,
        cpu_flops_factor: 6.0, // PyTorch CPU whisper-large: RTF > 1
        backend: KernelBackend::TunedNative,
    }
}

impl WhisperProfile {
    /// Re-cut this model's work with a different kernel implementation.
    pub fn with_backend(mut self, backend: KernelBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Encode one audio segment: large parallel matmuls, healthy occupancy.
    /// The logical FLOP/byte budget is spread over the backend's launch
    /// count.
    pub fn encode_kernels(&self) -> Vec<KernelDesc> {
        let t = self.backend.whisper();
        let total_flops = self.encoder_kernels as f64 * self.encoder_flops_per_kernel;
        let total_bytes = self.encoder_kernels as f64 * self.encoder_bytes_per_kernel;
        let n = t.encode_launches;
        (0..n)
            .map(|_| t.encode.kernel(total_flops / n as f64, total_bytes / n as f64))
            .collect()
    }

    /// Decode one transcript token. Under the tuned backend: many tiny
    /// kernels with ~200 registers and heavy shared memory → 1 block/SM,
    /// 2 warps → SMOCC ≈ 0.06, and the grid still spans the device (SMACT
    /// stays high, Fig. 4c). Eager execution doubles the launch count;
    /// the fused backend collapses the burst to a quarter of it.
    pub fn decode_token_kernels(&self) -> Vec<KernelDesc> {
        let t = self.backend.whisper();
        let total_flops = self.decoder_kernels_per_token as f64 * self.decoder_flops_per_kernel;
        let total_bytes = self.decoder_kernels_per_token as f64 * self.decoder_bytes_per_kernel;
        let n = t.decode_launches;
        (0..n)
            .map(|_| t.decode.kernel(total_flops / n as f64, total_bytes / n as f64))
            .collect()
    }

    /// Encode a segment on the CPU backend.
    pub fn encode_cpu(&self) -> CpuWork {
        let t = self.backend.whisper();
        CpuWork {
            flops: self.encoder_kernels as f64
                * self.encoder_flops_per_kernel
                * self.cpu_flops_factor
                * t.cpu_flops_mult,
            bytes: self.weights_bytes as f64,
            threads: 24,
        }
    }

    /// Decode one token on the CPU backend.
    pub fn decode_token_cpu(&self) -> CpuWork {
        let t = self.backend.whisper();
        CpuWork {
            flops: self.decoder_kernels_per_token as f64
                * self.decoder_flops_per_kernel
                * self.cpu_flops_factor
                * t.cpu_flops_mult
                * 5.0, // tiny-op dispatch overhead dominates on CPU
            bytes: 0.3e9,
            threads: 8,
        }
    }

    pub fn load_seconds(&self) -> f64 {
        self.weights_bytes as f64 / 2e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::kernel::{duration, occupancy};
    use crate::gpusim::profiles::rtx6000;

    #[test]
    fn llama_decode_token_is_fast_and_memory_bound() {
        let gpu = rtx6000();
        let m = llama_3_2_3b();
        let kernels = m.decode_kernels(512);
        assert_eq!(kernels.len(), 30);
        let total: f64 = kernels.iter().map(|k| duration(k, &gpu, gpu.num_sms).unwrap()).sum();
        // llama.cpp decodes a 3B-Q4 token in single-digit milliseconds.
        assert!(total > 1e-3 && total < 0.02, "token time {total}");
        // High SMOCC — llama.cpp's tuned kernels (Fig. 4a): 3 blocks/SM at
        // 24/32 warps.
        let occ = occupancy(&kernels[0], &gpu).unwrap();
        assert!(occ.occupancy >= 0.7, "occ {}", occ.occupancy);
    }

    #[test]
    fn llama_prefill_scales_with_tokens() {
        let gpu = rtx6000();
        let m = llama_3_2_3b();
        let t = |n: usize| -> f64 {
            m.prefill_kernels(n)
                .iter()
                .map(|k| duration(k, &gpu, gpu.num_sms).unwrap())
                .sum()
        };
        let short = t(64);
        let long = t(512);
        assert!(long > short * 4.0, "short={short} long={long}");
        // TTFT well under the 1 s SLO on GPU.
        assert!(long < 0.5, "prefill(512) = {long}");
    }

    #[test]
    fn sd_attention_kernels_have_low_occupancy() {
        let gpu = rtx6000();
        let m = sd35_medium_turbo();
        let kernels = m.denoise_step_kernels();
        let attn = kernels.iter().find(|k| k.tag == "denoise.attn").unwrap();
        let occ = occupancy(attn, &gpu).unwrap();
        assert!(occ.occupancy <= 0.3, "SD attention occ {}", occ.occupancy);
        // Step time within the 1 s SLO when exclusive.
        let step: f64 = kernels.iter().map(|k| duration(k, &gpu, gpu.num_sms).unwrap()).sum();
        assert!(step > 0.1 && step < 0.9, "step {step}");
    }

    #[test]
    fn whisper_decoder_tiny_kernels() {
        let gpu = rtx6000();
        let m = whisper_large_v3_turbo();
        let dec = m.decode_token_kernels();
        let occ = occupancy(&dec[0], &gpu).unwrap();
        assert!(occ.occupancy < 0.1, "whisper decode occ {}", occ.occupancy);
        let tok: f64 = dec.iter().map(|k| duration(k, &gpu, gpu.num_sms).unwrap()).sum();
        assert!(tok < 3e-3, "token {tok}");
        // Encoder healthy occupancy, Fig. 4c.
        let enc = m.encode_kernels();
        let eocc = occupancy(&enc[0], &gpu).unwrap();
        assert!(eocc.occupancy >= 0.4, "encoder occ {}", eocc.occupancy);
    }

    #[test]
    fn whisper_segment_exclusive_meets_slo() {
        let gpu = rtx6000();
        let m = whisper_large_v3_turbo();
        let enc: f64 = m.encode_kernels().iter().map(|k| duration(k, &gpu, gpu.num_sms).unwrap()).sum();
        let dec: f64 = (0..12)
            .flat_map(|_| m.decode_token_kernels())
            .map(|k| duration(&k, &gpu, gpu.num_sms).unwrap())
            .sum();
        let seg = enc + dec;
        assert!(seg < 0.5, "segment {seg} must be far below the 2 s SLO");
    }

    #[test]
    fn kv_cache_sizing_matches_paper() {
        // §4.2.1: a 128K-token window needs a ~16 GB KV cache... for the
        // llama.cpp f16 configuration of Llama-3.2-3B.
        let m = llama_3_2_3b();
        let bytes = m.kv_cache_bytes(131_072);
        let gb = bytes as f64 / (1 << 30) as f64;
        assert!((gb - 7.0).abs() < 2.0 || gb > 6.0, "kv cache {gb} GiB");
    }

    #[test]
    fn llama8b_does_not_fit_with_others() {
        // B.4: 16 GB of weights + SD (8 GB) exceeds the RTX 6000's 24 GB.
        let total = llama_3_1_8b().weights_bytes
            + sd35_medium_turbo().weights_bytes
            + sd35_medium_turbo().activation_bytes
            + whisper_large_v3_turbo().weights_bytes;
        assert!(total > 24 * gib(1));
    }

    #[test]
    fn cpu_models_much_slower() {
        let m = llama_3_2_3b();
        let cpu_work = m.decode_cpu(512);
        // Effective bytes per CPU token: several GB → tens of ms at DRAM bw.
        assert!(cpu_work.bytes > 5e9);
        let sd = sd35_medium_turbo().denoise_step_cpu();
        assert!(sd.flops > 1e13); // ~10s-scale on the Xeon
    }

    #[test]
    fn backend_recuts_launch_counts_but_preserves_work() {
        use crate::gpusim::backend::KernelBackend;
        let total = |ks: &[crate::gpusim::kernel::KernelDesc]| -> (f64, f64) {
            (ks.iter().map(|k| k.flops).sum(), ks.iter().map(|k| k.bytes).sum())
        };
        let tuned = llama_3_2_3b();
        let (tf, _) = total(&tuned.decode_kernels(512));
        for b in KernelBackend::ALL {
            let m = llama_3_2_3b().with_backend(b);
            let ks = m.decode_kernels(512);
            assert_eq!(ks.len(), m.decode_launches(), "{b}");
            let (f, _) = total(&ks);
            // Same logical FLOPs per token regardless of how they're cut.
            assert!((f - tf).abs() / tf < 1e-9, "{b}: flops {f} vs {tf}");
        }
        assert_eq!(tuned.decode_launches(), 30);
        assert_eq!(llama_3_2_3b().with_backend(KernelBackend::GenericTorch).decode_launches(), 120);
        // Whisper and diffusion recut too.
        let w = whisper_large_v3_turbo().with_backend(KernelBackend::GenericTorch);
        assert_eq!(w.decode_token_kernels().len(), 80);
        assert_eq!(w.encode_kernels().len(), 32);
        let sd = sd35_medium_turbo().with_backend(KernelBackend::GenericTorch);
        assert_eq!(sd.denoise_step_kernels().len(), 48 * 3 + 72);
        let fused = sd35_medium_turbo().with_backend(KernelBackend::FusedCustom);
        assert_eq!(fused.denoise_step_kernels().len(), 48 + 72);
    }

    #[test]
    fn no_attn_variant_is_the_matmul_prefix_of_decode() {
        use crate::gpusim::backend::KernelBackend;
        // The §4.2.1 `--no-kv-offload` variant must share the decode
        // table's matmul launches exactly — the shape-drift the backend
        // tables were introduced to prevent.
        for b in KernelBackend::ALL {
            let m = llama_3_2_3b().with_backend(b);
            let full = m.decode_kernels(2048);
            let no_attn = m.decode_kernels_no_attn();
            assert_eq!(&full[..no_attn.len()], &no_attn[..], "{b}");
            // The remainder is exactly the attention launches, which carry
            // the KV traffic (scaled by the backend's intermediates factor).
            let t = b.llama();
            assert_eq!(full.len() - no_attn.len(), t.decode_attn_launches, "{b}");
            let kv: f64 = full[no_attn.len()..].iter().map(|k| k.bytes).sum();
            let expected = (m.kv_bytes_per_token * 2048) as f64 * t.attn_bytes_factor;
            assert!((kv - expected).abs() / expected < 1e-9, "{b}: {kv} vs {expected}");
        }
    }

    #[test]
    fn cpu_multipliers_scale_with_backend() {
        use crate::gpusim::backend::KernelBackend;
        let tuned = llama_3_2_3b().decode_cpu(512);
        let generic = llama_3_2_3b()
            .with_backend(KernelBackend::GenericTorch)
            .decode_cpu(512);
        let fused = llama_3_2_3b()
            .with_backend(KernelBackend::FusedCustom)
            .decode_cpu(512);
        assert!(generic.flops > tuned.flops && generic.bytes > tuned.bytes);
        assert!(fused.flops < tuned.flops && fused.bytes < tuned.bytes);
    }
}
