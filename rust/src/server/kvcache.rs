//! KV-cache manager for the shared inference server.
//!
//! llama.cpp provisions one contiguous KV region at startup, sized by the
//! configured context window, and carves per-sequence cells out of it. The
//! paper's §4.2.1 finding is about the *placement* of this region: on the
//! GPU it competes with model weights for the 24 GB of VRAM; with
//! `--no-kv-offload` it lives in CPU DRAM and drags every attention op onto
//! the CPU. This manager implements the cell accounting for both placements.

use std::collections::BTreeMap;

/// Where the KV region lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPlacement {
    Gpu,
    Cpu,
}

impl std::fmt::Display for KvPlacement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvPlacement::Gpu => write!(f, "gpu"),
            KvPlacement::Cpu => write!(f, "cpu"),
        }
    }
}

/// Error when the KV region cannot host a sequence.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum KvError {
    #[error("kv cache full: requested {requested} tokens, {free} of {capacity} free")]
    Full {
        requested: usize,
        free: usize,
        capacity: usize,
    },
    #[error("unknown kv sequence {0}")]
    UnknownSeq(u64),
}

/// Token-cell accounting over the provisioned KV region.
#[derive(Debug, Clone)]
pub struct KvCacheManager {
    placement: KvPlacement,
    bytes_per_token: u64,
    capacity_tokens: usize,
    used_tokens: usize,
    seqs: BTreeMap<u64, usize>,
    peak_tokens: usize,
}

impl KvCacheManager {
    pub fn new(placement: KvPlacement, bytes_per_token: u64, capacity_tokens: usize) -> Self {
        KvCacheManager {
            placement,
            bytes_per_token,
            capacity_tokens,
            used_tokens: 0,
            seqs: BTreeMap::new(),
            peak_tokens: 0,
        }
    }

    pub fn placement(&self) -> KvPlacement {
        self.placement
    }

    pub fn capacity_tokens(&self) -> usize {
        self.capacity_tokens
    }

    pub fn used_tokens(&self) -> usize {
        self.used_tokens
    }

    pub fn free_tokens(&self) -> usize {
        self.capacity_tokens - self.used_tokens
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_tokens as u64 * self.bytes_per_token
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_tokens as u64 * self.bytes_per_token
    }

    pub fn peak_tokens(&self) -> usize {
        self.peak_tokens
    }

    /// Register a new sequence with an initial prompt length.
    pub fn alloc_seq(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        if tokens > self.free_tokens() {
            return Err(KvError::Full {
                requested: tokens,
                free: self.free_tokens(),
                capacity: self.capacity_tokens,
            });
        }
        assert!(!self.seqs.contains_key(&seq), "duplicate kv sequence {seq}");
        self.seqs.insert(seq, tokens);
        self.used_tokens += tokens;
        self.peak_tokens = self.peak_tokens.max(self.used_tokens);
        Ok(())
    }

    /// Grow a sequence by `tokens` (decode appends).
    pub fn extend_seq(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        if !self.seqs.contains_key(&seq) {
            return Err(KvError::UnknownSeq(seq));
        }
        if tokens > self.free_tokens() {
            return Err(KvError::Full {
                requested: tokens,
                free: self.free_tokens(),
                capacity: self.capacity_tokens,
            });
        }
        *self.seqs.get_mut(&seq).unwrap() += tokens;
        self.used_tokens += tokens;
        self.peak_tokens = self.peak_tokens.max(self.used_tokens);
        Ok(())
    }

    /// Release a finished sequence's cells.
    pub fn free_seq(&mut self, seq: u64) -> Result<usize, KvError> {
        let tokens = self.seqs.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        self.used_tokens -= tokens;
        Ok(tokens)
    }

    pub fn seq_tokens(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).copied()
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvCacheManager {
        // Llama-3.2-3B-ish: ~112 KiB/token, 16K-token window.
        KvCacheManager::new(KvPlacement::Gpu, 114_688, 16_384)
    }

    #[test]
    fn alloc_extend_free_balances() {
        let mut m = mgr();
        m.alloc_seq(1, 100).unwrap();
        m.alloc_seq(2, 200).unwrap();
        assert_eq!(m.used_tokens(), 300);
        m.extend_seq(1, 50).unwrap();
        assert_eq!(m.seq_tokens(1), Some(150));
        assert_eq!(m.free_seq(1).unwrap(), 150);
        assert_eq!(m.free_seq(2).unwrap(), 200);
        assert_eq!(m.used_tokens(), 0);
        assert_eq!(m.peak_tokens(), 350);
    }

    #[test]
    fn full_cache_rejects() {
        let mut m = KvCacheManager::new(KvPlacement::Gpu, 100, 1000);
        m.alloc_seq(1, 900).unwrap();
        let err = m.alloc_seq(2, 200).unwrap_err();
        assert!(matches!(err, KvError::Full { requested: 200, free: 100, .. }));
        m.extend_seq(1, 100).unwrap();
        assert!(m.extend_seq(1, 1).is_err());
    }

    #[test]
    fn unknown_seq_errors() {
        let mut m = mgr();
        assert!(matches!(m.extend_seq(9, 1), Err(KvError::UnknownSeq(9))));
        assert!(matches!(m.free_seq(9), Err(KvError::UnknownSeq(9))));
    }

    #[test]
    fn bytes_accounting() {
        let m = KvCacheManager::new(KvPlacement::Cpu, 114_688, 131_072);
        // The paper's 128K-context configuration ≈ 14 GiB.
        let gib = m.capacity_bytes() as f64 / (1u64 << 30) as f64;
        assert!(gib > 13.0 && gib < 16.5, "capacity {gib} GiB");
        assert_eq!(m.placement(), KvPlacement::Cpu);
    }

    #[test]
    #[should_panic(expected = "duplicate kv sequence")]
    fn duplicate_seq_panics() {
        let mut m = mgr();
        m.alloc_seq(1, 10).unwrap();
        let _ = m.alloc_seq(1, 10);
    }
}
