//! llama.cpp-style shared inference server (substrate for §4.2.1).
//!
//! On end-user devices, multiple applications with the same modality share a
//! single foundation model through a local inference server. This module
//! rebuilds the relevant llama.cpp server behaviour:
//!
//! * **Slots**: up to `n_slots` requests are active concurrently.
//! * **Unified batching**: each server iteration builds one batch combining
//!   one decode token for every decoding slot plus a chunk (≤ `batch_size`
//!   tokens) of one pending prefill — llama.cpp's continuous batching.
//! * **Static configuration**: the KV cache is sized for `context_window`
//!   at startup and placed on the GPU, or in CPU DRAM when
//!   `kv_placement = Cpu` (the `--no-kv-offload` flag). CPU placement moves
//!   every attention operation to the CPU — the paper's Chatbot-KVCache-CPU
//!   configuration whose interference DeepResearch's long contexts turn
//!   into ~40% chat SLO misses.
//!
//! The server is an actor over the simulated testbed: the coordinator calls
//! [`InferenceServer::pump`] whenever virtual time advances; the server
//! submits one iteration job at a time to the engine under its own client.

pub mod kvcache;

pub use kvcache::{KvCacheManager, KvPlacement};

use std::collections::VecDeque;

use crate::apps::models::LlamaProfile;
use crate::gpusim::engine::{ClientId, Engine, JobId, JobResult, JobSpec, MemOp, Phase};

/// Server configuration (static for the server's lifetime — the paper's
/// §4.2.1 point is precisely that this is a poor fit for mixed workloads).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub model: LlamaProfile,
    /// Tokens of context the KV cache is provisioned for.
    pub context_window: usize,
    pub kv_placement: KvPlacement,
    /// Concurrent sequence slots.
    pub n_slots: usize,
    /// Max tokens per unified batch (prefill chunking granularity).
    pub batch_size: usize,
}

impl ServerConfig {
    /// The paper's DeepResearch-friendly configuration: 128K context,
    /// 16 GB-class KV cache kept in CPU DRAM to save VRAM.
    pub fn kv_cpu(model: LlamaProfile) -> ServerConfig {
        ServerConfig {
            model,
            context_window: 131_072,
            kv_placement: KvPlacement::Cpu,
            n_slots: 4,
            batch_size: 512,
        }
    }

    /// The paper's Chatbot-friendly configuration: modest context window,
    /// KV on the GPU (DeepResearch quality degrades — not modeled here).
    pub fn kv_gpu(model: LlamaProfile) -> ServerConfig {
        ServerConfig {
            model,
            context_window: 16_384,
            kv_placement: KvPlacement::Gpu,
            n_slots: 4,
            batch_size: 512,
        }
    }
}

/// A request enqueued by an application.
#[derive(Debug, Clone)]
pub struct ServerRequest {
    pub id: u64,
    /// Originating application name (for per-app reporting).
    pub app: &'static str,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
}

/// A finished request with serving timestamps.
#[derive(Debug, Clone)]
pub struct ServerResponse {
    pub id: u64,
    pub app: &'static str,
    pub submit: f64,
    /// Completion of the first output token.
    pub first_token: f64,
    pub end: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
}

impl ServerResponse {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.submit
    }

    pub fn tpot(&self) -> f64 {
        if self.output_tokens <= 1 {
            0.0
        } else {
            (self.end - self.first_token) / (self.output_tokens - 1) as f64
        }
    }
}

#[derive(Debug)]
struct Slot {
    request: ServerRequest,
    submit: f64,
    prefilled: usize,
    decoded: usize,
    first_token: Option<f64>,
}

/// The shared inference server actor.
pub struct InferenceServer {
    cfg: ServerConfig,
    client: ClientId,
    queue: VecDeque<(ServerRequest, f64)>,
    slots: Vec<Option<Slot>>,
    inflight: Option<JobId>,
    responses: Vec<ServerResponse>,
    started: bool,
    iteration_count: u64,
    /// Slot-advances committed when the in-flight iteration completes.
    pending_advance: Option<PendingAdvance>,
}

impl InferenceServer {
    pub fn new(cfg: ServerConfig, client: ClientId) -> Self {
        let n = cfg.n_slots;
        InferenceServer {
            cfg,
            client,
            queue: VecDeque::new(),
            slots: (0..n).map(|_| None).collect(),
            inflight: None,
            responses: Vec::new(),
            started: false,
            iteration_count: 0,
            pending_advance: None,
        }
    }

    pub fn client(&self) -> ClientId {
        self.client
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    pub fn iterations(&self) -> u64 {
        self.iteration_count
    }

    /// Submit the server startup job (weight load + KV allocation). Must be
    /// pumped like any other state change.
    pub fn start(&mut self, engine: &mut Engine, at: f64) -> JobId {
        assert!(!self.started, "server already started");
        self.started = true;
        let mut mem_ops = vec![MemOp::Alloc {
            label: "weights".into(),
            bytes: self.cfg.model.weights_bytes,
        }];
        if self.cfg.kv_placement == KvPlacement::Gpu {
            mem_ops.push(MemOp::Alloc {
                label: "kv-cache".into(),
                bytes: self.cfg.model.kv_cache_bytes(self.cfg.context_window),
            });
        }
        let spec = JobSpec {
            client: self.client,
            label: "server.start".into(),
            phases: vec![Phase::host("server.load", self.cfg.model.load_seconds())
                .with_mem_ops(mem_ops)],
        };
        engine.submit(spec, at)
    }

    /// Enqueue an application request at virtual time `now`.
    ///
    /// Prompts longer than the provisioned context window are truncated to
    /// fit — llama.cpp's behaviour, and the §4.2.1 quality cost of a
    /// Chatbot-friendly (small-window) static configuration for
    /// DeepResearch.
    pub fn enqueue(&mut self, mut request: ServerRequest, now: f64) {
        let budget = self
            .cfg
            .context_window
            .saturating_sub(request.output_tokens)
            .max(16);
        request.prompt_tokens = request.prompt_tokens.min(budget);
        self.queue.push_back((request, now));
    }

    /// Notify the server that one of its jobs completed. Returns true if the
    /// result belonged to this server.
    pub fn on_job_done(&mut self, result: &JobResult) -> bool {
        if Some(result.id) != self.inflight {
            return false;
        }
        self.inflight = None;
        self.finish_iteration(result.end);
        true
    }

    /// Drive the server: admit queued requests and launch the next iteration
    /// if idle. Call whenever virtual time advances or jobs complete.
    pub fn pump(&mut self, engine: &mut Engine, now: f64) {
        if !self.started || self.inflight.is_some() {
            return;
        }
        self.admit(now);
        if let Some(spec) = self.build_iteration() {
            let id = engine.submit(spec, now);
            self.inflight = Some(id);
            self.iteration_count += 1;
        }
    }

    /// True when no queued work, no active slots, and nothing in flight.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.inflight.is_none() && self.slots.iter().all(|s| s.is_none())
    }

    /// Drain finished responses.
    pub fn take_responses(&mut self) -> Vec<ServerResponse> {
        std::mem::take(&mut self.responses)
    }

    fn admit(&mut self, now: f64) {
        for slot in self.slots.iter_mut() {
            if slot.is_none() {
                if let Some((request, submit)) = self.queue.pop_front() {
                    let _ = now;
                    *slot = Some(Slot {
                        request,
                        submit,
                        prefilled: 0,
                        decoded: 0,
                        first_token: None,
                    });
                } else {
                    break;
                }
            }
        }
    }

    /// Build the next unified batch: one decode token per decoding slot plus
    /// prefill chunks from every slot still prefilling, filling the token
    /// budget round-robin (llama.cpp's unified batch — a long prefill must
    /// not monopolize the server).
    fn build_iteration(&mut self) -> Option<JobSpec> {
        let mut decode_ctx: Vec<usize> = Vec::new();
        let mut prefill_chunks: Vec<(usize, usize)> = Vec::new(); // (slot, tokens)
        let mut budget = self.cfg.batch_size;

        for (_i, slot) in self.slots.iter().enumerate() {
            let Some(s) = slot else { continue };
            if s.prefilled >= s.request.prompt_tokens
                && s.decoded < s.request.output_tokens
                && budget > 0
            {
                decode_ctx.push(s.request.prompt_tokens + s.decoded);
                budget -= 1;
            }
        }
        for (i, slot) in self.slots.iter().enumerate() {
            let Some(s) = slot else { continue };
            if s.prefilled < s.request.prompt_tokens && budget > 0 {
                let remaining = s.request.prompt_tokens - s.prefilled;
                let chunk = remaining.min(budget);
                prefill_chunks.push((i, chunk));
                budget -= chunk;
            }
        }

        if decode_ctx.is_empty() && prefill_chunks.is_empty() {
            return None;
        }

        let mut phases = Vec::new();
        let m = &self.cfg.model;
        // Decode part: batched — weights are read once for the whole batch,
        // per-sequence KV is read per slot.
        if !decode_ctx.is_empty() {
            let batch = decode_ctx.len();
            match self.cfg.kv_placement {
                KvPlacement::Gpu => {
                    // Batched decode kernels: scale flops by batch, weights
                    // traffic shared, KV traffic summed.
                    let mut kernels = m.decode_kernels(avg(&decode_ctx));
                    for k in &mut kernels {
                        k.flops *= batch as f64;
                        // KV bytes scale with batch; approximate by adding
                        // the extra sequences' KV on top of shared weights.
                        k.bytes += (batch as f64 - 1.0)
                            * (m.kv_bytes_per_token * avg(&decode_ctx) as u64) as f64
                            / kernels_per_token() as f64;
                    }
                    phases.push(Phase::gpu("server.decode", 0.0005, kernels));
                }
                KvPlacement::Cpu => {
                    // Matmuls stay on the GPU; attention walks the CPU-
                    // resident KV for every sequence (--no-kv-offload).
                    let mut kernels = m.decode_kernels_no_attn();
                    for k in &mut kernels {
                        k.flops *= batch as f64;
                    }
                    phases.push(Phase::gpu("server.decode.matmul", 0.0005, kernels));
                    let attn = m.attention_cpu(decode_ctx.iter().sum());
                    // Per-layer GPU→CPU→GPU round trips (28 syncs/token).
                    phases.push(Phase::cpu("server.decode.attn", 0.02, attn));
                }
            }
        }
        // Prefill chunks: each prefilling slot's next tokens.
        for &(slot_idx, chunk) in &prefill_chunks {
            let s = self.slots[slot_idx].as_ref().unwrap();
            let ctx_so_far = s.prefilled + chunk;
            match self.cfg.kv_placement {
                KvPlacement::Gpu => {
                    phases.push(Phase::gpu("server.prefill", 0.001, m.prefill_kernels(chunk)));
                }
                KvPlacement::Cpu => {
                    // Projection matmuls on GPU; attention over the growing
                    // CPU-resident context, quadratic-ish in chunk × ctx,
                    // with per-layer GPU→CPU round trips.
                    phases.push(Phase::gpu(
                        "server.prefill.matmul",
                        0.001,
                        m.prefill_kernels(chunk),
                    ));
                    let mut attn = m.attention_cpu(ctx_so_far);
                    attn.bytes *= (chunk as f64 / 48.0).max(1.0);
                    attn.flops *= chunk as f64;
                    phases.push(Phase::cpu("server.prefill.attn", 0.05, attn));
                }
            }
        }

        // Record what this iteration advances so `finish_iteration` can
        // commit it.
        self.pending_advance = Some(PendingAdvance {
            decode_slots: self
                .slots
                .iter()
                .enumerate()
                .filter(|(_, s)| {
                    s.as_ref().is_some_and(|s| {
                        s.prefilled >= s.request.prompt_tokens
                            && s.decoded < s.request.output_tokens
                    })
                })
                .map(|(i, _)| i)
                .take(decode_ctx.len())
                .collect(),
            prefill: prefill_chunks,
        });

        Some(JobSpec {
            client: self.client,
            label: format!("server.iter{}", self.iteration_count),
            phases,
        })
    }

    fn finish_iteration(&mut self, now: f64) {
        let Some(adv) = self.pending_advance.take() else {
            return;
        };
        for &i in &adv.decode_slots {
            if let Some(s) = self.slots[i].as_mut() {
                s.decoded += 1;
                if s.first_token.is_none() {
                    s.first_token = Some(now);
                }
            }
        }
        for (i, chunk) in adv.prefill {
            if let Some(s) = self.slots[i].as_mut() {
                s.prefilled += chunk;
            }
        }
        // Retire finished slots.
        for slot in self.slots.iter_mut() {
            let done = slot
                .as_ref()
                .is_some_and(|s| s.decoded >= s.request.output_tokens);
            if done {
                let s = slot.take().unwrap();
                self.responses.push(ServerResponse {
                    id: s.request.id,
                    app: s.request.app,
                    submit: s.submit,
                    first_token: s.first_token.unwrap_or(now),
                    end: now,
                    prompt_tokens: s.request.prompt_tokens,
                    output_tokens: s.request.output_tokens,
                });
            }
        }
    }
}

/// Bookkeeping for the iteration in flight.
#[derive(Debug)]
struct PendingAdvance {
    decode_slots: Vec<usize>,
    prefill: Vec<(usize, usize)>,
}

fn avg(v: &[usize]) -> usize {
    if v.is_empty() {
        0
    } else {
        v.iter().sum::<usize>() / v.len()
    }
}

fn kernels_per_token() -> usize {
    30
}

/// VRAM bytes the server needs at startup under its configuration.
pub fn server_vram_bytes(cfg: &ServerConfig) -> u64 {
    let kv = if cfg.kv_placement == KvPlacement::Gpu {
        cfg.model.kv_cache_bytes(cfg.context_window)
    } else {
        0
    };
    cfg.model.weights_bytes + kv
}

/// Drive an engine + server pair until the server is idle (helper for tests
/// and benches).
pub fn run_server_to_idle(engine: &mut Engine, server: &mut InferenceServer) {
    loop {
        server.pump(engine, engine.now());
        let Some(t) = engine.next_event_time() else {
            break;
        };
        engine.run_until(t);
        for r in engine.take_completed() {
            server.on_job_done(&r);
        }
        if server.idle() && engine.next_event_time().is_none() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::models::llama_3_2_3b;
    use crate::gpusim::policy::Policy;
    use crate::gpusim::profiles::Testbed;

    fn setup(cfg: ServerConfig) -> (Engine, InferenceServer) {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let c = e.register_client("llama-server");
        let mut s = InferenceServer::new(cfg, c);
        s.start(&mut e, 0.0);
        e.run_all();
        e.take_completed();
        (e, s)
    }

    #[test]
    fn serves_a_single_request() {
        let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
        s.enqueue(
            ServerRequest {
                id: 0,
                app: "Chatbot",
                prompt_tokens: 64,
                output_tokens: 32,
            },
            e.now(),
        );
        run_server_to_idle(&mut e, &mut s);
        let rs = s.take_responses();
        assert_eq!(rs.len(), 1);
        let r = &rs[0];
        assert_eq!(r.output_tokens, 32);
        assert!(r.ttft() > 0.0);
        assert!(r.tpot() > 0.0);
        assert!(r.end > r.first_token);
    }

    #[test]
    fn kv_gpu_meets_chat_slo_when_alone() {
        let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
        for i in 0..4 {
            s.enqueue(
                ServerRequest {
                    id: i,
                    app: "Chatbot",
                    prompt_tokens: 64,
                    output_tokens: 64,
                },
                e.now(),
            );
        }
        run_server_to_idle(&mut e, &mut s);
        for r in s.take_responses() {
            assert!(r.ttft() < 1.0, "ttft {}", r.ttft());
            assert!(r.tpot() < 0.25, "tpot {}", r.tpot());
        }
    }

    #[test]
    fn kv_cpu_shifts_work_to_cpu() {
        let (mut e, mut s) = setup(ServerConfig::kv_cpu(llama_3_2_3b()));
        s.enqueue(
            ServerRequest {
                id: 0,
                app: "Chatbot",
                prompt_tokens: 128,
                output_tokens: 32,
            },
            e.now(),
        );
        run_server_to_idle(&mut e, &mut s);
        // With --no-kv-offload, no KV cache sits in VRAM …
        assert_eq!(e.vram().used(), s.config().model.weights_bytes);
        // … and the CPU sees real utilization during decoding (Fig. 6).
        assert!(e.trace().iter().any(|t| t.cpu_util > 0.2));
    }

    #[test]
    fn kv_gpu_reserves_vram_for_context_window() {
        let cfg = ServerConfig::kv_gpu(llama_3_2_3b());
        let expected = server_vram_bytes(&cfg);
        let (e, _s) = setup(cfg);
        assert_eq!(e.vram().used(), expected);
    }

    #[test]
    fn large_kv_on_gpu_would_not_fit_with_other_apps() {
        // §4.2.1: 128K-context KV on the GPU (~14 GiB) + weights + ImageGen
        // exceeds 24 GB — the reason the paper moves it to the CPU.
        let mut cfg = ServerConfig::kv_cpu(llama_3_2_3b());
        cfg.kv_placement = KvPlacement::Gpu;
        let server_bytes = server_vram_bytes(&cfg);
        let imagegen = crate::apps::models::sd35_medium_turbo();
        let total = server_bytes + imagegen.weights_bytes + imagegen.activation_bytes;
        // Lands exactly at the 24 GiB capacity with zero headroom for
        // activations/workspace — i.e. it does not fit in practice.
        assert!(total >= 24 * (1u64 << 30), "total {total}");
    }

    #[test]
    fn batching_overlaps_requests() {
        // Two concurrent requests should finish in much less than 2x the
        // single-request time (decode iterations are batched).
        let solo = {
            let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
            s.enqueue(
                ServerRequest { id: 0, app: "Chatbot", prompt_tokens: 64, output_tokens: 64 },
                e.now(),
            );
            let t0 = e.now();
            run_server_to_idle(&mut e, &mut s);
            e.now() - t0
        };
        let duo = {
            let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
            for i in 0..2 {
                s.enqueue(
                    ServerRequest { id: i, app: "Chatbot", prompt_tokens: 64, output_tokens: 64 },
                    e.now(),
                );
            }
            let t0 = e.now();
            run_server_to_idle(&mut e, &mut s);
            e.now() - t0
        };
        assert!(duo < solo * 1.7, "duo {duo} vs solo {solo}");
    }

    #[test]
    fn queue_beyond_slots_is_served_eventually() {
        let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
        for i in 0..10 {
            s.enqueue(
                ServerRequest { id: i, app: "Chatbot", prompt_tokens: 32, output_tokens: 16 },
                e.now(),
            );
        }
        run_server_to_idle(&mut e, &mut s);
        assert_eq!(s.take_responses().len(), 10);
        assert!(s.idle());
    }
}
