//! llama.cpp-style shared inference server (substrate for §4.2.1).
//!
//! On end-user devices, multiple applications with the same modality share a
//! single foundation model through a local inference server. This module
//! rebuilds the relevant llama.cpp server behaviour:
//!
//! * **Slots**: up to `n_slots` requests are active concurrently.
//! * **Unified batching**: each server iteration builds one batch combining
//!   one decode token for every decoding slot plus a chunk (≤ `batch_size`
//!   tokens) of one pending prefill — llama.cpp's continuous batching.
//! * **Configuration in two halves**: an immutable [`ServerProfile`] (which
//!   model, how much context the KV region is provisioned for) and a
//!   mutable [`ServerTuning`] (`kv_placement`, `n_slots`, `batch_size`).
//!   The paper's §4.2.1 finding is that freezing the tuning for the
//!   server's lifetime is a poor fit for mixed workloads: the
//!   Chatbot-KVCache-CPU configuration (`--no-kv-offload`) moves every
//!   attention operation to the CPU, and DeepResearch's long contexts turn
//!   that into ~40% chat SLO misses.
//! * **Runtime reconfiguration**: [`InferenceServer::reconfigure`] applies
//!   a new tuning between iterations — the in-flight unified batch drains
//!   first, occupied slots are never dropped, and a KV placement change
//!   runs as an engine job whose DMA transfer cost and VRAM `MemOp`s show
//!   up in the monitor trace like any other work. This is the substrate
//!   the adaptive controller (`coordinator::controller`) acts on.
//!
//! The server is an actor over the simulated testbed: the coordinator calls
//! [`InferenceServer::pump`] whenever virtual time advances; the server
//! submits one iteration job at a time to the engine under its own client.

pub mod kvcache;

pub use kvcache::{KvCacheManager, KvPlacement};

use std::collections::VecDeque;

use crate::apps::models::LlamaProfile;
use crate::gpusim::engine::{ClientId, Engine, JobId, JobResult, JobSpec, MemOp, Phase};

/// The immutable half of the server configuration: what the server *is*.
/// Changing either field means a different model deployment, not a runtime
/// adjustment — the KV region is provisioned for `context_window` once.
/// The model's [`KernelBackend`](crate::gpusim::backend::KernelBackend)
/// rides along: it governs every batched iteration's launch shapes and the
/// fixed cost of a KV-placement reconfiguration (YAML `backend:` on the
/// server definition).
#[derive(Debug, Clone)]
pub struct ServerProfile {
    pub model: LlamaProfile,
    /// Tokens of context the KV cache is provisioned for.
    pub context_window: usize,
}

/// The mutable half: the serving knobs a runtime controller may change
/// while requests are in flight (llama.cpp restart flags, made live).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerTuning {
    pub kv_placement: KvPlacement,
    /// Concurrent sequence slots.
    pub n_slots: usize,
    /// Max tokens per unified batch (prefill chunking granularity).
    pub batch_size: usize,
}

/// Full server configuration: immutable profile + current tuning.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub profile: ServerProfile,
    pub tuning: ServerTuning,
}

impl ServerConfig {
    /// The paper's DeepResearch-friendly configuration: 128K context,
    /// 16 GB-class KV cache kept in CPU DRAM to save VRAM.
    pub fn kv_cpu(model: LlamaProfile) -> ServerConfig {
        ServerConfig {
            profile: ServerProfile {
                model,
                context_window: 131_072,
            },
            tuning: ServerTuning {
                kv_placement: KvPlacement::Cpu,
                n_slots: 4,
                batch_size: 512,
            },
        }
    }

    /// The paper's Chatbot-friendly configuration: modest context window,
    /// KV on the GPU (DeepResearch quality degrades — not modeled here).
    pub fn kv_gpu(model: LlamaProfile) -> ServerConfig {
        ServerConfig {
            profile: ServerProfile {
                model,
                context_window: 16_384,
            },
            tuning: ServerTuning {
                kv_placement: KvPlacement::Gpu,
                n_slots: 4,
                batch_size: 512,
            },
        }
    }
}

/// A request enqueued by an application.
#[derive(Debug, Clone)]
pub struct ServerRequest {
    pub id: u64,
    /// Originating application name (for per-app reporting).
    pub app: &'static str,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
}

/// A finished request with serving timestamps.
#[derive(Debug, Clone)]
pub struct ServerResponse {
    pub id: u64,
    pub app: &'static str,
    pub submit: f64,
    /// Completion of the first output token.
    pub first_token: f64,
    pub end: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
}

impl ServerResponse {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.submit
    }

    pub fn tpot(&self) -> f64 {
        if self.output_tokens <= 1 {
            0.0
        } else {
            (self.end - self.first_token) / (self.output_tokens - 1) as f64
        }
    }
}

#[derive(Debug)]
struct Slot {
    request: ServerRequest,
    submit: f64,
    prefilled: usize,
    decoded: usize,
    first_token: Option<f64>,
}

/// What the server's single in-flight engine job is doing.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Inflight {
    /// A unified-batch iteration.
    Iteration(JobId),
    /// A KV migration transfer; the placement flips to the carried target
    /// only when the job completes without error (GPU OOM rolls back).
    Migration(JobId, KvPlacement),
}

/// Effective PCIe-class DMA bandwidth used to cost KV migrations (bytes/s).
const KV_DMA_BW: f64 = 24e9;

/// Fixed per-migration latency (driver synchronization + region setup).
const KV_DMA_LATENCY: f64 = 1e-3;

/// The shared inference server actor.
pub struct InferenceServer {
    cfg: ServerConfig,
    client: ClientId,
    queue: VecDeque<(ServerRequest, f64)>,
    slots: Vec<Option<Slot>>,
    inflight: Option<Inflight>,
    responses: Vec<ServerResponse>,
    started: bool,
    iteration_count: u64,
    /// Slot-advances committed when the in-flight iteration completes.
    pending_advance: Option<PendingAdvance>,
    /// Tuning waiting for the in-flight iteration to drain.
    pending_tuning: Option<ServerTuning>,
    reconfigurations: u64,
    /// Migrations rolled back because the target placement did not fit.
    failed_migrations: u64,
    /// Crash + restart cycles applied (chaos `server_crash`).
    crashes: u64,
    /// Migration jobs orphaned by a crash: their completion must not flip
    /// the restarted generation's placement, and an orphaned onload's KV
    /// allocation is released when it lands.
    stale_migrations: Vec<(JobId, KvPlacement)>,
    /// An orphaned onload landed: its KV region awaits release on the next
    /// pump (which holds the `&mut Engine` needed to submit the free).
    stale_onload_reap: bool,
    /// Effective PCIe DMA bandwidth scale in (0, 1] (chaos `pcie_degrade`).
    dma_bw_scale: f64,
}

impl InferenceServer {
    pub fn new(cfg: ServerConfig, client: ClientId) -> Self {
        let n = cfg.tuning.n_slots;
        InferenceServer {
            cfg,
            client,
            queue: VecDeque::new(),
            slots: (0..n).map(|_| None).collect(),
            inflight: None,
            responses: Vec::new(),
            started: false,
            iteration_count: 0,
            pending_advance: None,
            pending_tuning: None,
            reconfigurations: 0,
            failed_migrations: 0,
            crashes: 0,
            stale_migrations: Vec::new(),
            stale_onload_reap: false,
            dma_bw_scale: 1.0,
        }
    }

    pub fn client(&self) -> ClientId {
        self.client
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// The current tuning (post any applied reconfigurations).
    pub fn tuning(&self) -> ServerTuning {
        self.cfg.tuning
    }

    pub fn iterations(&self) -> u64 {
        self.iteration_count
    }

    /// Runtime reconfigurations that actually landed: slot/batch changes
    /// count when applied, placement changes only once the migration
    /// transfer completes (a rolled-back migration is not counted).
    pub fn reconfigurations(&self) -> u64 {
        self.reconfigurations
    }

    /// KV migrations that were rolled back (target placement OOM).
    pub fn failed_migrations(&self) -> u64 {
        self.failed_migrations
    }

    /// Crash + restart cycles this server went through.
    pub fn crashes(&self) -> u64 {
        self.crashes
    }

    /// Whether the startup job has run (and no crash is pending restart).
    pub fn is_started(&self) -> bool {
        self.started
    }

    /// Scale the effective KV-migration DMA bandwidth (chaos
    /// `pcie_degrade`); 1.0 restores full PCIe speed. Applies to
    /// migrations submitted from now on.
    pub fn set_dma_bw_scale(&mut self, scale: f64) {
        assert!(
            scale > 0.0 && scale <= 1.0,
            "dma bandwidth scale must be in (0, 1]: {scale}"
        );
        self.dma_bw_scale = scale;
    }

    /// Whether a requested reconfiguration has not fully landed yet (still
    /// draining the in-flight batch or migrating the KV region).
    pub fn reconfig_pending(&self) -> bool {
        self.pending_tuning.is_some() || matches!(self.inflight, Some(Inflight::Migration(..)))
    }

    /// Queued requests not yet admitted to a slot.
    pub fn queued_requests(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently occupying slots.
    pub fn active_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Submit the server startup job (weight load + KV allocation). Must be
    /// pumped like any other state change.
    pub fn start(&mut self, engine: &mut Engine, at: f64) -> JobId {
        assert!(!self.started, "server already started");
        self.started = true;
        let m = &self.cfg.profile.model;
        let mut mem_ops = vec![MemOp::Alloc {
            label: "weights".into(),
            bytes: m.weights_bytes,
        }];
        if self.cfg.tuning.kv_placement == KvPlacement::Gpu {
            mem_ops.push(MemOp::Alloc {
                label: "kv-cache".into(),
                bytes: m.kv_cache_bytes(self.cfg.profile.context_window),
            });
        }
        let spec = JobSpec {
            client: self.client,
            label: "server.start".into(),
            phases: vec![Phase::host("server.load", m.load_seconds()).with_mem_ops(mem_ops)],
        };
        engine.submit(spec, at)
    }

    /// Enqueue an application request at virtual time `now`.
    ///
    /// Prompts longer than the provisioned context window are truncated to
    /// fit — llama.cpp's behaviour, and the §4.2.1 quality cost of a
    /// Chatbot-friendly (small-window) static configuration for
    /// DeepResearch.
    pub fn enqueue(&mut self, mut request: ServerRequest, now: f64) {
        // The output budget is clamped to the window too: a request asking
        // for more completion tokens than the KV region is provisioned for
        // must not decode past it (previously only the prompt was clamped,
        // so such a request overran the window from the decode side).
        request.output_tokens = request.output_tokens.min(self.cfg.profile.context_window);
        let budget = self
            .cfg
            .profile
            .context_window
            .saturating_sub(request.output_tokens)
            .max(16);
        request.prompt_tokens = request.prompt_tokens.min(budget);
        self.queue.push_back((request, now));
    }

    /// Notify the server that one of its jobs completed. Returns true if the
    /// result belonged to this server.
    pub fn on_job_done(&mut self, result: &JobResult) -> bool {
        // Jobs orphaned by a crash belong to a dead server generation: they
        // must not advance the restarted server's state. An orphaned
        // *onload* that lands successfully has just allocated a KV region
        // for that dead generation — release it. (In practice it always
        // lands before the restarted generation's own KV allocation: the
        // migration's DMA is milliseconds while the restart's weight load
        // is seconds, so the labelled free can only hit the orphan.)
        if let Some(pos) = self
            .stale_migrations
            .iter()
            .position(|(id, _)| *id == result.id)
        {
            let (_, target) = self.stale_migrations.swap_remove(pos);
            if target == KvPlacement::Gpu && result.error.is_none() {
                self.stale_onload_reap = true;
            }
            return true;
        }
        match self.inflight {
            Some(Inflight::Iteration(id)) if id == result.id => {
                self.inflight = None;
                self.finish_iteration(result.end);
                true
            }
            Some(Inflight::Migration(id, target)) if id == result.id => {
                self.inflight = None;
                if result.error.is_none() {
                    self.cfg.tuning.kv_placement = target;
                    // The placement change only counts once it has landed.
                    self.reconfigurations += 1;
                } else {
                    // The target region did not fit (GPU OOM): the KV cache
                    // stays where it was; the rest of the tuning keeps.
                    self.failed_migrations += 1;
                }
                true
            }
            _ => false,
        }
    }

    /// Request a runtime reconfiguration. The change lands between
    /// iterations: the in-flight unified batch drains first, occupied
    /// slots keep their prefill/decode progress (a shrink below the
    /// occupancy retires surplus slots lazily), and a KV placement change
    /// runs as an engine job with a realistic DMA transfer cost before
    /// iterations resume. Calling again before the previous request
    /// applied replaces it (last writer wins).
    pub fn reconfigure(&mut self, engine: &mut Engine, now: f64, tuning: ServerTuning) {
        assert!(tuning.n_slots > 0, "n_slots must be >= 1");
        assert!(tuning.batch_size > 0, "batch_size must be >= 1");
        if !self.started {
            // Nothing allocated yet: the new tuning simply becomes the
            // startup configuration.
            self.cfg.tuning = tuning;
            self.slots = (0..tuning.n_slots).map(|_| None).collect();
            return;
        }
        self.pending_tuning = Some(tuning);
        self.try_apply_tuning(engine, now);
    }

    /// Apply a pending tuning once nothing is in flight.
    fn try_apply_tuning(&mut self, engine: &mut Engine, now: f64) {
        if self.inflight.is_some() {
            return;
        }
        let Some(t) = self.pending_tuning.take() else {
            return;
        };
        let old = self.cfg.tuning;
        if t == old {
            return;
        }
        // Slot resize: occupied slots are never dropped — compact them to
        // the front; on a shrink below the occupancy the vector stays long
        // enough and contracts as slots retire (see `admit`).
        let occupied: Vec<Slot> = self.slots.drain(..).flatten().collect();
        let len = t.n_slots.max(occupied.len());
        self.slots = occupied.into_iter().map(Some).collect();
        self.slots.resize_with(len, || None);
        // Non-placement knobs apply immediately; the placement flips when
        // the migration transfer completes (`on_job_done`). Each knob group
        // is counted when it actually lands — a rolled-back migration
        // (target OOM) never inflates the reconfiguration count.
        self.cfg.tuning = ServerTuning {
            kv_placement: old.kv_placement,
            ..t
        };
        if t.n_slots != old.n_slots || t.batch_size != old.batch_size {
            self.reconfigurations += 1;
        }
        if t.kv_placement != old.kv_placement {
            let id = self.submit_migration(engine, now, t.kv_placement);
            self.inflight = Some(Inflight::Migration(id, t.kv_placement));
        }
    }

    /// Submit the KV migration transfer: the region is (de)allocated via
    /// `MemOp`s and the live cells cross the PCIe bus at DMA speed, so the
    /// reconfiguration is itself visible in the monitor trace. The server's
    /// kernel backend governs the fixed cost: the generic framework tears
    /// down and rebuilds allocator state around a placement change
    /// (`kv_migration_latency_mult`), where the tuned runtime remaps in
    /// place.
    fn submit_migration(&mut self, engine: &mut Engine, now: f64, target: KvPlacement) -> JobId {
        let m = &self.cfg.profile.model;
        let region = m.kv_cache_bytes(self.cfg.profile.context_window);
        let live_tokens: usize = self
            .slots
            .iter()
            .flatten()
            .map(|s| s.prefilled + s.decoded)
            .sum();
        let moved = (m.kv_bytes_per_token * live_tokens as u64).min(region);
        let dma = KV_DMA_LATENCY * m.backend.kv_migration_latency_mult()
            + moved as f64 / (KV_DMA_BW * self.dma_bw_scale);
        let (tag, ops) = match target {
            KvPlacement::Gpu => (
                "server.kv_onload",
                vec![MemOp::Alloc {
                    label: "kv-cache".into(),
                    bytes: region,
                }],
            ),
            KvPlacement::Cpu => (
                "server.kv_offload",
                vec![MemOp::Free {
                    label: "kv-cache".into(),
                }],
            ),
        };
        let spec = JobSpec {
            client: self.client,
            label: format!("server.migrate.{target}"),
            phases: vec![Phase::host(tag, dma).with_mem_ops(ops)],
        };
        engine.submit(spec, now)
    }

    /// Drive the server: apply any pending reconfiguration, admit queued
    /// requests, and launch the next iteration if idle. Call whenever
    /// virtual time advances or jobs complete.
    pub fn pump(&mut self, engine: &mut Engine, now: f64) {
        if !self.started {
            return;
        }
        if self.stale_onload_reap {
            // Release the KV region an orphaned (pre-crash) onload just
            // allocated. Submitted here because only pump holds the engine;
            // it lands long before the restarted generation's own KV
            // allocation (weight reload is seconds, this is immediate).
            self.stale_onload_reap = false;
            engine.submit(
                JobSpec {
                    client: self.client,
                    label: "server.reap".into(),
                    phases: vec![Phase::host("server.reap", 0.0).with_mem_ops(vec![MemOp::Free {
                        label: "kv-cache".into(),
                    }])],
                },
                now,
            );
        }
        self.try_apply_tuning(engine, now);
        if self.inflight.is_some() {
            return;
        }
        self.admit();
        if let Some(spec) = self.build_iteration() {
            let id = engine.submit(spec, now);
            self.inflight = Some(Inflight::Iteration(id));
            self.iteration_count += 1;
        }
    }

    /// Crash the server mid-batch and restart it (chaos `server_crash`).
    /// The in-flight unified batch is dropped — its engine job becomes an
    /// orphan whose completion is ignored — occupied slots' requests go
    /// back to the *front* of the queue in slot order with their original
    /// submit timestamps (all prefill/decode progress is lost while latency
    /// keeps accruing), every VRAM region the server held is freed, and
    /// `start()` runs again so the weights reload under the current tuning.
    /// Returns the restart job, or `None` if the server never started.
    pub fn crash(&mut self, engine: &mut Engine, at: f64) -> Option<JobId> {
        if !self.started {
            return None;
        }
        match self.inflight.take() {
            Some(Inflight::Migration(id, target)) => {
                self.stale_migrations.push((id, target));
            }
            // An orphaned iteration has no mem ops; its completion is
            // simply not ours anymore (`on_job_done` returns false).
            Some(Inflight::Iteration(_)) | None => {}
        }
        self.pending_advance = None;
        self.pending_tuning = None;
        let occupied: Vec<Slot> = self.slots.iter_mut().filter_map(|s| s.take()).collect();
        for s in occupied.into_iter().rev() {
            self.queue.push_front((s.request, s.submit));
        }
        self.slots = (0..self.cfg.tuning.n_slots).map(|_| None).collect();
        self.crashes += 1;
        // The release is submitted before the restart so it is processed
        // first at the same timestamp (lower engine sequence number).
        engine.submit(
            JobSpec {
                client: self.client,
                label: "server.crash".into(),
                phases: vec![Phase::host("server.crash", 0.0).with_mem_ops(vec![MemOp::FreeAll])],
            },
            at,
        );
        self.started = false;
        Some(self.start(engine, at))
    }

    /// True when no queued work, no active slots, nothing in flight, and no
    /// reconfiguration waiting to land.
    pub fn idle(&self) -> bool {
        self.queue.is_empty()
            && self.inflight.is_none()
            && self.pending_tuning.is_none()
            && self.slots.iter().all(|s| s.is_none())
    }

    /// Drain finished responses.
    pub fn take_responses(&mut self) -> Vec<ServerResponse> {
        std::mem::take(&mut self.responses)
    }

    fn admit(&mut self) {
        let cap = self.cfg.tuning.n_slots;
        // A shrink leaves the vector longer than the cap until the surplus
        // occupied slots retire; contract over trailing empties first.
        while self.slots.len() > cap && matches!(self.slots.last(), Some(None)) {
            self.slots.pop();
        }
        if self.slots.len() < cap {
            self.slots.resize_with(cap, || None);
        }
        let mut occupied = self.active_slots();
        for slot in self.slots.iter_mut() {
            if occupied >= cap {
                break;
            }
            if slot.is_none() {
                let Some((request, submit)) = self.queue.pop_front() else {
                    break;
                };
                *slot = Some(Slot {
                    request,
                    submit,
                    prefilled: 0,
                    decoded: 0,
                    first_token: None,
                });
                occupied += 1;
            }
        }
    }

    /// Plan the next unified batch without mutating any state: one decode
    /// token per decoding slot plus prefill chunks from every slot still
    /// prefilling, filling the token budget round-robin (llama.cpp's
    /// unified batch — a long prefill must not monopolize the server).
    ///
    /// This is the verification surface for the batching-invariant property
    /// tests: immediately after an iteration launches, the plan equals the
    /// in-flight batch (slot state only advances when the iteration
    /// completes).
    pub fn plan_batch(&self) -> Option<BatchPlan> {
        let mut decode_slots: Vec<usize> = Vec::new();
        let mut prefill: Vec<(usize, usize)> = Vec::new(); // (slot, tokens)
        let mut budget = self.cfg.tuning.batch_size;

        for (i, slot) in self.slots.iter().enumerate() {
            let Some(s) = slot else { continue };
            if s.prefilled >= s.request.prompt_tokens
                && s.decoded < s.request.output_tokens
                && budget > 0
            {
                decode_slots.push(i);
                budget -= 1;
            }
        }
        for (i, slot) in self.slots.iter().enumerate() {
            let Some(s) = slot else { continue };
            if s.prefilled < s.request.prompt_tokens && budget > 0 {
                let remaining = s.request.prompt_tokens - s.prefilled;
                let chunk = remaining.min(budget);
                prefill.push((i, chunk));
                budget -= chunk;
            }
        }
        if decode_slots.is_empty() && prefill.is_empty() {
            None
        } else {
            Some(BatchPlan {
                decode_slots,
                prefill,
            })
        }
    }

    /// Lower the planned batch into an engine job.
    fn build_iteration(&mut self) -> Option<JobSpec> {
        let plan = self.plan_batch()?;
        let decode_ctx: Vec<usize> = plan
            .decode_slots
            .iter()
            .map(|&i| {
                let s = self.slots[i].as_ref().unwrap();
                s.request.prompt_tokens + s.decoded
            })
            .collect();

        let mut phases = Vec::new();
        let m = &self.cfg.profile.model;
        // Decode part: batched — weights are read once for the whole batch,
        // per-sequence KV is read per slot.
        if !decode_ctx.is_empty() {
            let batch = decode_ctx.len();
            match self.cfg.tuning.kv_placement {
                KvPlacement::Gpu => {
                    // Batched decode kernels: scale flops by batch, weights
                    // traffic shared, KV traffic summed.
                    let mut kernels = m.decode_kernels(avg(&decode_ctx));
                    let launches = m.decode_launches() as f64;
                    // The extra sequences' KV moves at the same per-token
                    // cost the backend charges the first one (materialized
                    // attention intermediates included), spread over the
                    // batch's launches.
                    let extra_kv_per_kernel = (batch as f64 - 1.0)
                        * (m.kv_bytes_per_token * avg(&decode_ctx) as u64) as f64
                        * m.backend.llama().attn_bytes_factor
                        / launches;
                    for k in &mut kernels {
                        k.flops *= batch as f64;
                        k.bytes += extra_kv_per_kernel;
                    }
                    phases.push(Phase::gpu("server.decode", 0.0005, kernels));
                }
                KvPlacement::Cpu => {
                    // Matmuls stay on the GPU; attention walks the CPU-
                    // resident KV for every sequence (--no-kv-offload).
                    let mut kernels = m.decode_kernels_no_attn();
                    for k in &mut kernels {
                        k.flops *= batch as f64;
                    }
                    phases.push(Phase::gpu("server.decode.matmul", 0.0005, kernels));
                    let attn = m.attention_cpu(decode_ctx.iter().sum());
                    // Per-layer GPU→CPU→GPU round trips (28 syncs/token).
                    phases.push(Phase::cpu("server.decode.attn", 0.02, attn));
                }
            }
        }
        // Prefill chunks: each prefilling slot's next tokens.
        for &(slot_idx, chunk) in &plan.prefill {
            let s = self.slots[slot_idx].as_ref().unwrap();
            let ctx_so_far = s.prefilled + chunk;
            match self.cfg.tuning.kv_placement {
                KvPlacement::Gpu => {
                    phases.push(Phase::gpu("server.prefill", 0.001, m.prefill_kernels(chunk)));
                }
                KvPlacement::Cpu => {
                    // Projection matmuls on GPU; attention over the growing
                    // CPU-resident context, quadratic-ish in chunk × ctx,
                    // with per-layer GPU→CPU round trips.
                    phases.push(Phase::gpu(
                        "server.prefill.matmul",
                        0.001,
                        m.prefill_kernels(chunk),
                    ));
                    let mut attn = m.attention_cpu(ctx_so_far);
                    attn.bytes *= (chunk as f64 / 48.0).max(1.0);
                    attn.flops *= chunk as f64;
                    phases.push(Phase::cpu("server.prefill.attn", 0.05, attn));
                }
            }
        }

        // Record what this iteration advances so `finish_iteration` can
        // commit it.
        self.pending_advance = Some(PendingAdvance {
            decode_slots: plan.decode_slots,
            prefill: plan.prefill,
        });

        Some(JobSpec {
            client: self.client,
            label: format!("server.iter{}", self.iteration_count),
            phases,
        })
    }

    fn finish_iteration(&mut self, now: f64) {
        let Some(adv) = self.pending_advance.take() else {
            return;
        };
        for &i in &adv.decode_slots {
            if let Some(s) = self.slots[i].as_mut() {
                s.decoded += 1;
                if s.first_token.is_none() {
                    s.first_token = Some(now);
                }
            }
        }
        for (i, chunk) in adv.prefill {
            if let Some(s) = self.slots[i].as_mut() {
                s.prefilled += chunk;
            }
        }
        // Retire finished slots.
        for slot in self.slots.iter_mut() {
            let done = slot
                .as_ref()
                .is_some_and(|s| s.decoded >= s.request.output_tokens);
            if done {
                let s = slot.take().unwrap();
                self.responses.push(ServerResponse {
                    id: s.request.id,
                    app: s.request.app,
                    submit: s.submit,
                    first_token: s.first_token.unwrap_or(now),
                    end: now,
                    prompt_tokens: s.request.prompt_tokens,
                    output_tokens: s.request.output_tokens,
                });
            }
        }
    }
}

/// A planned unified batch: which slots decode and which prefill how much.
/// `decode_slots` contribute exactly one token each; `prefill` entries are
/// `(slot index, tokens)` chunks. Total tokens never exceed `batch_size`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPlan {
    pub decode_slots: Vec<usize>,
    pub prefill: Vec<(usize, usize)>,
}

impl BatchPlan {
    /// Total tokens in the unified batch.
    pub fn tokens(&self) -> usize {
        self.decode_slots.len() + self.prefill.iter().map(|&(_, c)| c).sum::<usize>()
    }
}

/// Bookkeeping for the iteration in flight.
#[derive(Debug)]
struct PendingAdvance {
    decode_slots: Vec<usize>,
    prefill: Vec<(usize, usize)>,
}

fn avg(v: &[usize]) -> usize {
    if v.is_empty() {
        0
    } else {
        v.iter().sum::<usize>() / v.len()
    }
}

/// VRAM bytes the server needs at startup under its configuration.
pub fn server_vram_bytes(cfg: &ServerConfig) -> u64 {
    let kv = if cfg.tuning.kv_placement == KvPlacement::Gpu {
        cfg.profile.model.kv_cache_bytes(cfg.profile.context_window)
    } else {
        0
    };
    cfg.profile.model.weights_bytes + kv
}

/// Drive an engine + server pair until the server is idle (helper for tests
/// and benches).
pub fn run_server_to_idle(engine: &mut Engine, server: &mut InferenceServer) {
    loop {
        server.pump(engine, engine.now());
        let Some(t) = engine.next_event_time() else {
            break;
        };
        engine.run_until(t);
        for r in engine.take_completed() {
            server.on_job_done(&r);
        }
        if server.idle() && engine.next_event_time().is_none() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::models::llama_3_2_3b;
    use crate::gpusim::policy::Policy;
    use crate::gpusim::profiles::Testbed;

    fn setup(cfg: ServerConfig) -> (Engine, InferenceServer) {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let c = e.register_client("llama-server");
        let mut s = InferenceServer::new(cfg, c);
        s.start(&mut e, 0.0);
        e.run_all();
        e.take_completed();
        (e, s)
    }

    #[test]
    fn serves_a_single_request() {
        let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
        s.enqueue(
            ServerRequest {
                id: 0,
                app: "Chatbot",
                prompt_tokens: 64,
                output_tokens: 32,
            },
            e.now(),
        );
        run_server_to_idle(&mut e, &mut s);
        let rs = s.take_responses();
        assert_eq!(rs.len(), 1);
        let r = &rs[0];
        assert_eq!(r.output_tokens, 32);
        assert!(r.ttft() > 0.0);
        assert!(r.tpot() > 0.0);
        assert!(r.end > r.first_token);
    }

    #[test]
    fn kv_gpu_meets_chat_slo_when_alone() {
        let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
        for i in 0..4 {
            s.enqueue(
                ServerRequest {
                    id: i,
                    app: "Chatbot",
                    prompt_tokens: 64,
                    output_tokens: 64,
                },
                e.now(),
            );
        }
        run_server_to_idle(&mut e, &mut s);
        for r in s.take_responses() {
            assert!(r.ttft() < 1.0, "ttft {}", r.ttft());
            assert!(r.tpot() < 0.25, "tpot {}", r.tpot());
        }
    }

    #[test]
    fn kv_cpu_shifts_work_to_cpu() {
        let (mut e, mut s) = setup(ServerConfig::kv_cpu(llama_3_2_3b()));
        s.enqueue(
            ServerRequest {
                id: 0,
                app: "Chatbot",
                prompt_tokens: 128,
                output_tokens: 32,
            },
            e.now(),
        );
        run_server_to_idle(&mut e, &mut s);
        // With --no-kv-offload, no KV cache sits in VRAM …
        assert_eq!(e.vram().used(), s.config().profile.model.weights_bytes);
        // … and the CPU sees real utilization during decoding (Fig. 6).
        assert!(e.trace().iter().any(|t| t.cpu_util > 0.2));
    }

    #[test]
    fn kv_gpu_reserves_vram_for_context_window() {
        let cfg = ServerConfig::kv_gpu(llama_3_2_3b());
        let expected = server_vram_bytes(&cfg);
        let (e, _s) = setup(cfg);
        assert_eq!(e.vram().used(), expected);
    }

    #[test]
    fn large_kv_on_gpu_would_not_fit_with_other_apps() {
        // §4.2.1: 128K-context KV on the GPU (~14 GiB) + weights + ImageGen
        // exceeds 24 GB — the reason the paper moves it to the CPU.
        let mut cfg = ServerConfig::kv_cpu(llama_3_2_3b());
        cfg.tuning.kv_placement = KvPlacement::Gpu;
        let server_bytes = server_vram_bytes(&cfg);
        let imagegen = crate::apps::models::sd35_medium_turbo();
        let total = server_bytes + imagegen.weights_bytes + imagegen.activation_bytes;
        // Lands exactly at the 24 GiB capacity with zero headroom for
        // activations/workspace — i.e. it does not fit in practice.
        assert!(total >= 24 * (1u64 << 30), "total {total}");
    }

    #[test]
    fn batching_overlaps_requests() {
        // Two concurrent requests should finish in much less than 2x the
        // single-request time (decode iterations are batched).
        let solo = {
            let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
            s.enqueue(
                ServerRequest { id: 0, app: "Chatbot", prompt_tokens: 64, output_tokens: 64 },
                e.now(),
            );
            let t0 = e.now();
            run_server_to_idle(&mut e, &mut s);
            e.now() - t0
        };
        let duo = {
            let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
            for i in 0..2 {
                s.enqueue(
                    ServerRequest { id: i, app: "Chatbot", prompt_tokens: 64, output_tokens: 64 },
                    e.now(),
                );
            }
            let t0 = e.now();
            run_server_to_idle(&mut e, &mut s);
            e.now() - t0
        };
        assert!(duo < solo * 1.7, "duo {duo} vs solo {solo}");
    }

    #[test]
    fn queue_beyond_slots_is_served_eventually() {
        let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
        for i in 0..10 {
            s.enqueue(
                ServerRequest { id: i, app: "Chatbot", prompt_tokens: 32, output_tokens: 16 },
                e.now(),
            );
        }
        run_server_to_idle(&mut e, &mut s);
        assert_eq!(s.take_responses().len(), 10);
        assert!(s.idle());
    }

    #[test]
    fn reconfigure_before_start_rewrites_startup_tuning() {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let c = e.register_client("llama-server");
        let mut s = InferenceServer::new(ServerConfig::kv_cpu(llama_3_2_3b()), c);
        s.reconfigure(
            &mut e,
            0.0,
            ServerTuning { kv_placement: KvPlacement::Gpu, n_slots: 2, batch_size: 256 },
        );
        assert_eq!(s.tuning().kv_placement, KvPlacement::Gpu);
        assert_eq!(s.reconfigurations(), 0, "pre-start changes are free");
        s.start(&mut e, 0.0);
        e.run_all();
        e.take_completed();
        // KV was allocated on the GPU at startup under the new tuning.
        assert_eq!(e.vram().used(), server_vram_bytes(s.config()));
    }

    #[test]
    fn migration_moves_kv_between_devices_with_dma_cost() {
        let mut cfg = ServerConfig::kv_gpu(llama_3_2_3b());
        cfg.profile.context_window = 8_192;
        cfg.tuning.kv_placement = KvPlacement::Gpu;
        let (mut e, mut s) = setup(cfg);
        let weights = s.config().profile.model.weights_bytes;
        let kv = s
            .config()
            .profile
            .model
            .kv_cache_bytes(s.config().profile.context_window);
        assert_eq!(e.vram().used(), weights + kv);
        // Offload: KV leaves VRAM, weights stay; virtual time advances by
        // at least the fixed DMA latency.
        let t0 = e.now();
        s.reconfigure(
            &mut e,
            e.now(),
            ServerTuning { kv_placement: KvPlacement::Cpu, ..s.tuning() },
        );
        assert!(s.reconfig_pending());
        run_server_to_idle(&mut e, &mut s);
        assert_eq!(s.tuning().kv_placement, KvPlacement::Cpu);
        assert_eq!(e.vram().used(), weights);
        assert!(e.now() >= t0 + KV_DMA_LATENCY);
        assert_eq!(s.reconfigurations(), 1);
        // And back on: the region is re-allocated.
        s.reconfigure(
            &mut e,
            e.now(),
            ServerTuning { kv_placement: KvPlacement::Gpu, ..s.tuning() },
        );
        run_server_to_idle(&mut e, &mut s);
        assert_eq!(s.tuning().kv_placement, KvPlacement::Gpu);
        assert_eq!(e.vram().used(), weights + kv);
        assert_eq!(s.failed_migrations(), 0);
    }

    #[test]
    fn infeasible_onload_rolls_back_placement() {
        // 128K-context KV (~14 GiB) + a 12 GiB squatter cannot fit in
        // 24 GiB next to the weights: the migration job fails and the KV
        // stays in CPU DRAM.
        let (mut e, mut s) = setup(ServerConfig::kv_cpu(llama_3_2_3b()));
        let squatter = e.register_client("squatter");
        e.submit(
            JobSpec {
                client: squatter,
                label: "hog".into(),
                phases: vec![Phase::host("alloc", 0.0).with_mem_ops(vec![MemOp::Alloc {
                    label: "buf".into(),
                    bytes: 12 * (1u64 << 30),
                }])],
            },
            e.now(),
        );
        e.run_all();
        e.take_completed();
        s.reconfigure(
            &mut e,
            e.now(),
            ServerTuning { kv_placement: KvPlacement::Gpu, ..s.tuning() },
        );
        run_server_to_idle(&mut e, &mut s);
        assert_eq!(s.tuning().kv_placement, KvPlacement::Cpu, "OOM must roll back");
        assert_eq!(s.failed_migrations(), 1);
        assert_eq!(
            s.reconfigurations(),
            0,
            "a rolled-back migration must not count as a landed reconfiguration"
        );
        // The server still serves afterwards.
        s.enqueue(
            ServerRequest { id: 0, app: "Chatbot", prompt_tokens: 32, output_tokens: 8 },
            e.now(),
        );
        run_server_to_idle(&mut e, &mut s);
        assert_eq!(s.take_responses().len(), 1);
    }

    #[test]
    fn shrink_mid_flight_drains_slots_without_losing_requests() {
        let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
        for i in 0..8 {
            s.enqueue(
                ServerRequest { id: i, app: "Chatbot", prompt_tokens: 700, output_tokens: 24 },
                e.now(),
            );
        }
        // Let a few iterations run (mid-prefill), then shrink 4 → 1 slots
        // and halve the batch.
        for _ in 0..3 {
            s.pump(&mut e, e.now());
            let t = e.next_event_time().unwrap();
            e.run_until(t);
            for r in e.take_completed() {
                s.on_job_done(&r);
            }
        }
        assert!(s.active_slots() > 1, "setup: several slots mid-flight");
        s.reconfigure(
            &mut e,
            e.now(),
            ServerTuning { n_slots: 1, batch_size: 256, ..s.tuning() },
        );
        run_server_to_idle(&mut e, &mut s);
        let responses = s.take_responses();
        assert_eq!(responses.len(), 8, "no request lost or duplicated");
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
        assert!(s.idle());
        assert_eq!(s.tuning().n_slots, 1);
    }

    #[test]
    fn enqueue_clamps_output_tokens_to_the_context_window() {
        let mut cfg = ServerConfig::kv_gpu(llama_3_2_3b());
        cfg.profile.context_window = 64;
        let (mut e, mut s) = setup(cfg);
        s.enqueue(
            ServerRequest { id: 0, app: "Chatbot", prompt_tokens: 128, output_tokens: 1000 },
            e.now(),
        );
        run_server_to_idle(&mut e, &mut s);
        let rs = s.take_responses();
        assert_eq!(rs.len(), 1);
        let r = &rs[0];
        assert!(
            r.output_tokens <= 64,
            "decode must not exceed the provisioned window: {}",
            r.output_tokens
        );
        assert_eq!(r.prompt_tokens, 16, "prompt squeezed to the floor");
    }

    #[test]
    fn crash_mid_batch_requeues_slots_and_restarts() {
        let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
        let vram_started = e.vram().used();
        for i in 0..6 {
            s.enqueue(
                ServerRequest { id: i, app: "Chatbot", prompt_tokens: 700, output_tokens: 24 },
                e.now(),
            );
        }
        // A few iterations in flight, then the server process dies.
        for _ in 0..3 {
            s.pump(&mut e, e.now());
            let t = e.next_event_time().unwrap();
            e.run_until(t);
            for r in e.take_completed() {
                s.on_job_done(&r);
            }
        }
        assert!(s.active_slots() > 0, "setup: slots mid-flight");
        let restart = s.crash(&mut e, e.now());
        assert!(restart.is_some());
        assert_eq!(s.crashes(), 1);
        assert_eq!(s.active_slots(), 0, "slots drained back to the queue");
        run_server_to_idle(&mut e, &mut s);
        let responses = s.take_responses();
        assert_eq!(responses.len(), 6, "no request lost or duplicated");
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>());
        assert_eq!(
            e.vram().used(),
            vram_started,
            "crash freed everything; restart re-allocated exactly once"
        );
        assert!(s.idle());
    }

    #[test]
    fn crash_on_a_stopped_server_is_a_no_op() {
        let mut e = Engine::new(Testbed::intel_server(), Policy::Greedy);
        let c = e.register_client("llama-server");
        let mut s = InferenceServer::new(ServerConfig::kv_gpu(llama_3_2_3b()), c);
        assert!(s.crash(&mut e, 0.0).is_none());
        assert_eq!(s.crashes(), 0);
    }

    #[test]
    fn degraded_pcie_slows_kv_migration() {
        let migrate_time = |scale: f64| {
            let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
            s.set_dma_bw_scale(scale);
            s.enqueue(
                ServerRequest { id: 0, app: "Chatbot", prompt_tokens: 2000, output_tokens: 64 },
                e.now(),
            );
            // A few iterations so live KV cells exist to move.
            for _ in 0..4 {
                s.pump(&mut e, e.now());
                let t = e.next_event_time().unwrap();
                e.run_until(t);
                for r in e.take_completed() {
                    s.on_job_done(&r);
                }
            }
            let t0 = e.now();
            s.reconfigure(
                &mut e,
                e.now(),
                ServerTuning { kv_placement: KvPlacement::Cpu, ..s.tuning() },
            );
            while s.tuning().kv_placement != KvPlacement::Cpu {
                s.pump(&mut e, e.now());
                let t = e.next_event_time().expect("migration must land");
                e.run_until(t);
                for r in e.take_completed() {
                    s.on_job_done(&r);
                }
            }
            e.now() - t0
        };
        let full = migrate_time(1.0);
        let degraded = migrate_time(0.1);
        assert!(
            degraded > full,
            "a degraded link must slow the migration: {degraded} vs {full}"
        );
    }

    #[test]
    fn server_backend_governs_batch_kernels_and_serves() {
        use crate::gpusim::backend::KernelBackend;
        // A generic-torch server still serves every request, just slower:
        // same request shape, strictly later completion (more launches,
        // materialized attention intermediates).
        let run = |backend: KernelBackend| {
            let (mut e, mut s) =
                setup(ServerConfig::kv_gpu(llama_3_2_3b().with_backend(backend)));
            s.enqueue(
                ServerRequest { id: 0, app: "Chatbot", prompt_tokens: 64, output_tokens: 64 },
                e.now(),
            );
            let t0 = e.now();
            run_server_to_idle(&mut e, &mut s);
            assert_eq!(s.take_responses().len(), 1);
            e.now() - t0
        };
        let tuned = run(KernelBackend::TunedNative);
        let generic = run(KernelBackend::GenericTorch);
        assert!(generic > tuned, "generic {generic} must be slower than tuned {tuned}");
    }

    #[test]
    fn generic_backend_pays_higher_reconfigure_cost() {
        use crate::gpusim::backend::KernelBackend;
        let migrate_time = |backend: KernelBackend| {
            let mut cfg = ServerConfig::kv_gpu(llama_3_2_3b().with_backend(backend));
            cfg.profile.context_window = 1024;
            let (mut e, mut s) = setup(cfg);
            let t0 = e.now();
            s.reconfigure(
                &mut e,
                e.now(),
                ServerTuning { kv_placement: KvPlacement::Cpu, ..s.tuning() },
            );
            run_server_to_idle(&mut e, &mut s);
            assert_eq!(s.tuning().kv_placement, KvPlacement::Cpu);
            e.now() - t0
        };
        let tuned = migrate_time(KernelBackend::TunedNative);
        let generic = migrate_time(KernelBackend::GenericTorch);
        // No live tokens → the fixed latency dominates; the generic
        // framework pays its teardown/rebuild multiplier.
        assert!(
            generic > tuned * 2.0,
            "generic migration {generic} vs tuned {tuned}"
        );
    }

    #[test]
    fn plan_batch_matches_inflight_iteration() {
        let (mut e, mut s) = setup(ServerConfig::kv_gpu(llama_3_2_3b()));
        for i in 0..3 {
            s.enqueue(
                ServerRequest { id: i, app: "Chatbot", prompt_tokens: 900, output_tokens: 4 },
                e.now(),
            );
        }
        let before = s.iterations();
        s.pump(&mut e, e.now());
        assert_eq!(s.iterations(), before + 1);
        let plan = s.plan_batch().expect("an iteration is in flight");
        assert!(plan.tokens() <= s.tuning().batch_size);
        assert!(!plan.prefill.is_empty(), "fresh requests start with prefill");
        run_server_to_idle(&mut e, &mut s);
        assert_eq!(s.take_responses().len(), 3);
    }
}
