//! Seeded device-population sampling for fleet-scale sweeps.
//!
//! The paper evaluates two hand-picked testbeds; the ROADMAP's north star
//! ("millions of users") needs a *population* axis. This module synthesizes
//! end-user devices — edge boxes, laptops, desktops — as full
//! [`Testbed`]s: VRAM tier, SM count, memory bandwidth, thermal envelope,
//! and unified-vs-discrete memory architecture are all sampled from
//! class-conditional ranges via the crate's xorshift64* [`Rng`].
//!
//! Determinism contract: `population.device(i)` is a pure function of
//! `(population seed, i)` — each device forks its own RNG stream from the
//! population seed mixed with its index, and every profile field draws in a
//! fixed documented order. Sampling device 1 500 never requires sampling
//! devices 0..1 499, which is what lets fleet shards run devices in any
//! worker interleaving (and lets `--resume` skip devices entirely) while
//! remaining byte-identical.
//!
//! Sampled values are quantized to whole units (GB, GB/s, W, GFLOP/s per
//! SM) so the synthesized profiles read like spec sheets rather than float
//! noise, and so the population YAML echo in reports stays short.

use anyhow::{bail, Context, Result};

use crate::gpusim::profiles::{CpuProfile, GpuProfile, Testbed};
use crate::util::rng::Rng;
use crate::util::yaml;

/// Device class axis — the coarse market segment a sampled device belongs
/// to. Classes condition every other sampled dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DeviceClass {
    /// Fanless unified-memory edge hardware (SBCs, thin tablets).
    Edge,
    /// Unified-memory laptops (Apple-Silicon-like SoCs).
    Laptop,
    /// Discrete-GPU desktops and small workstations.
    Desktop,
}

/// All classes in canonical (report) order.
pub const DEVICE_CLASSES: [DeviceClass; 3] =
    [DeviceClass::Edge, DeviceClass::Laptop, DeviceClass::Desktop];

/// Stable report/journal key for a class.
pub fn class_key(class: DeviceClass) -> &'static str {
    match class {
        DeviceClass::Edge => "edge",
        DeviceClass::Laptop => "laptop",
        DeviceClass::Desktop => "desktop",
    }
}

/// VRAM tiers per class, in GB. Unified-memory classes share this capacity
/// between CPU and GPU (it doubles as the DRAM size); desktops carry it as
/// dedicated VRAM next to separately-sampled DRAM.
fn vram_tiers(class: DeviceClass) -> &'static [u64] {
    match class {
        DeviceClass::Edge => &[4, 6, 8],
        DeviceClass::Laptop => &[8, 16, 32],
        DeviceClass::Desktop => &[8, 12, 16, 24],
    }
}

/// A parsed (or programmatically built) population specification.
#[derive(Debug, Clone, PartialEq)]
pub struct PopulationSpec {
    pub name: String,
    /// Number of devices in the population.
    pub count: usize,
    /// Population seed — with `count` and the weights, the complete
    /// description of every synthesized device.
    pub seed: u64,
    /// Class-mix weights in [`DEVICE_CLASSES`] order (edge, laptop,
    /// desktop). Must be non-negative with a positive sum.
    pub weights: [f64; 3],
}

/// One sampled device: its class, headline VRAM tier, and the fully
/// synthesized testbed the scenario slice runs on.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub index: usize,
    pub class: DeviceClass,
    pub vram_gb: u64,
    pub testbed: Testbed,
}

impl PopulationSpec {
    /// The default population: a quarter edge, the plurality laptops, the
    /// rest desktops — a consumer-device mix, not a server rack.
    pub fn default_population(count: usize, seed: u64) -> PopulationSpec {
        PopulationSpec {
            name: "default".to_string(),
            count,
            seed,
            weights: [0.25, 0.45, 0.30],
        }
    }

    /// Parse the population YAML schema (see README "Fleet sweeps"):
    ///
    /// ```yaml
    /// population:
    ///   name: pilot        # optional, default "default"
    ///   count: 200
    ///   seed: 7            # optional, default 42
    ///   classes:           # optional, default 0.25/0.45/0.30
    ///     edge: 0.25
    ///     laptop: 0.45
    ///     desktop: 0.30
    /// ```
    pub fn parse_yaml(text: &str) -> Result<PopulationSpec> {
        let doc = yaml::parse(text).map_err(|e| {
            anyhow::anyhow!("population YAML, line {}: {}", e.line, e.msg)
        })?;
        let pop = doc
            .get("population")
            .context("population YAML: missing top-level `population:` map")?;
        let mut spec = PopulationSpec::default_population(0, 42);
        if let Some(name) = pop.get("name").and_then(yaml::Value::as_str) {
            spec.name = name.to_string();
        }
        spec.count = pop
            .get("count")
            .and_then(yaml::Value::as_i64)
            .context("population YAML: `count:` must be a positive integer")?
            as usize;
        if spec.count == 0 {
            bail!("population YAML: `count:` must be at least 1");
        }
        if let Some(seed) = pop.get("seed").and_then(yaml::Value::as_i64) {
            spec.seed = seed as u64;
        }
        if let Some(classes) = pop.get("classes") {
            let map = classes
                .as_map()
                .context("population YAML: `classes:` must be a map")?;
            let mut weights = [0.0f64; 3];
            for (key, value) in map {
                let slot = DEVICE_CLASSES
                    .iter()
                    .position(|&c| class_key(c) == key)
                    .with_context(|| {
                        format!("population YAML: unknown class `{key}` (edge|laptop|desktop)")
                    })?;
                weights[slot] = value
                    .as_f64()
                    .with_context(|| format!("population YAML: class `{key}` weight"))?;
            }
            if weights.iter().any(|&w| w < 0.0 || !w.is_finite())
                || weights.iter().sum::<f64>() <= 0.0
            {
                bail!("population YAML: class weights must be non-negative with a positive sum");
            }
            spec.weights = weights;
        }
        Ok(spec)
    }

    /// Canonical YAML rendering — the population half of the fleet spec
    /// digest, so any change to the population invalidates journal entries.
    pub fn to_yaml(&self) -> String {
        format!(
            "population:\n  name: {}\n  count: {}\n  seed: {}\n  classes:\n    edge: {}\n    laptop: {}\n    desktop: {}\n",
            self.name, self.count, self.seed, self.weights[0], self.weights[1], self.weights[2]
        )
    }

    /// Synthesize device `index`. Pure in `(self.seed, index)`; see the
    /// module docs for the determinism contract.
    pub fn device(&self, index: usize) -> DeviceSpec {
        let mix = self.seed ^ (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(mix);
        // Draw order is part of the format: class, VRAM tier, SM count,
        // bandwidth, thermal envelope, per-SM throughput, CPU dimensions.
        let class = DEVICE_CLASSES[rng.weighted_index(&self.weights)];
        let vram_gb = *rng.choice(vram_tiers(class));
        let (gpu, cpu) = match class {
            DeviceClass::Edge => synth_unified(
                &mut rng,
                vram_gb,
                UnifiedRanges {
                    gpu_names: ("EdgeGPU", "EdgeCPU"),
                    sms: (4, 10),
                    bw_gbs: (34, 120),
                    max_power_w: (6, 15),
                    gflops_per_sm: (120, 220),
                    cores: (4, 8),
                    cpu_gflops: (100, 300),
                },
            ),
            DeviceClass::Laptop => synth_unified(
                &mut rng,
                vram_gb,
                UnifiedRanges {
                    gpu_names: ("LaptopGPU", "LaptopCPU"),
                    sms: (8, 24),
                    bw_gbs: (100, 400),
                    max_power_w: (20, 60),
                    gflops_per_sm: (200, 330),
                    cores: (6, 12),
                    cpu_gflops: (300, 900),
                },
            ),
            DeviceClass::Desktop => synth_desktop(&mut rng, vram_gb),
        };
        DeviceSpec {
            index,
            class,
            vram_gb,
            testbed: Testbed { gpu, cpu },
        }
    }
}

/// Class-conditional sampling ranges for unified-memory devices. All
/// ranges are inclusive and quantized to whole units.
struct UnifiedRanges {
    gpu_names: (&'static str, &'static str),
    sms: (u64, u64),
    bw_gbs: (u64, u64),
    max_power_w: (u64, u64),
    gflops_per_sm: (u64, u64),
    cores: (u64, u64),
    cpu_gflops: (u64, u64),
}

/// Architectural constants shared by every synthesized GPU — the same
/// per-SM envelope the calibrated profiles use; only the sampled
/// dimensions vary across the population.
fn base_gpu(name: &'static str, num_sms: usize, unified: bool) -> GpuProfile {
    GpuProfile {
        name,
        num_sms,
        max_threads_per_sm: 1024,
        max_warps_per_sm: 32,
        warp_size: 32,
        regs_per_sm: 65_536,
        smem_per_sm: 65_536,
        max_blocks_per_sm: 16,
        vram_bytes: 0,
        mem_bw: 0.0,
        peak_flops: 0.0,
        launch_overhead: if unified { 8e-6 } else { 5e-6 },
        idle_power: 0.0,
        max_power: 0.0,
        occ_saturation: 0.40,
        unified_memory: unified,
    }
}

/// Sample a unified-memory SoC (edge / laptop): GPU and CPU share the
/// memory pool and bandwidth budget, like the M1 Pro profile.
fn synth_unified(rng: &mut Rng, vram_gb: u64, r: UnifiedRanges) -> (GpuProfile, CpuProfile) {
    // `range_u64` is exclusive at the top; the class tables read as
    // inclusive spec-sheet ranges, hence the `+ 1`s.
    let sms = rng.range_u64(r.sms.0, r.sms.1 + 1) as usize;
    let bw_gbs = rng.range_u64(r.bw_gbs.0, r.bw_gbs.1 + 1);
    let max_power = rng.range_u64(r.max_power_w.0, r.max_power_w.1 + 1) as f64;
    let gflops_per_sm = rng.range_u64(r.gflops_per_sm.0, r.gflops_per_sm.1 + 1);
    let cores = rng.range_u64(r.cores.0, r.cores.1 + 1) as usize;
    let cpu_gflops = rng.range_u64(r.cpu_gflops.0, r.cpu_gflops.1 + 1);
    let mut gpu = base_gpu(r.gpu_names.0, sms, true);
    gpu.vram_bytes = vram_gb * (1 << 30);
    gpu.mem_bw = bw_gbs as f64 * 1e9;
    gpu.peak_flops = sms as f64 * gflops_per_sm as f64 * 1e9;
    gpu.max_power = max_power;
    // Thermal envelope: unified SoCs idle near nothing (≈8% of TDP, ≥1 W).
    gpu.idle_power = (max_power * 0.08).max(1.0).round();
    let cpu = CpuProfile {
        name: r.gpu_names.1,
        num_cores: cores,
        peak_flops: cpu_gflops as f64 * 1e9,
        // The CPU cluster reaches roughly half the fabric bandwidth (the
        // calibrated M1 Pro profile's ratio).
        mem_bw: bw_gbs as f64 * 0.5e9,
        dram_bytes: vram_gb * (1 << 30),
        idle_power: 1.0,
        max_power,
        dispatch_overhead: 2e-6,
    };
    (gpu, cpu)
}

/// Sample a discrete-GPU desktop: dedicated VRAM, separately sampled DRAM,
/// server-class thermal envelope.
fn synth_desktop(rng: &mut Rng, vram_gb: u64) -> (GpuProfile, CpuProfile) {
    let sms = rng.range_u64(24, 85) as usize;
    let bw_gbs = rng.range_u64(256, 1009);
    let max_power = rng.range_u64(120, 451) as f64;
    let gflops_per_sm = rng.range_u64(180, 331);
    let cores = rng.range_u64(8, 33) as usize;
    let cpu_gflops = rng.range_u64(400, 1601);
    let dram_gb = *rng.choice(&[16u64, 32, 64]);
    let cpu_bw_gbs = rng.range_u64(40, 121);
    let mut gpu = base_gpu("DesktopGPU", sms, false);
    gpu.vram_bytes = vram_gb * (1 << 30);
    gpu.mem_bw = bw_gbs as f64 * 1e9;
    gpu.peak_flops = sms as f64 * gflops_per_sm as f64 * 1e9;
    gpu.max_power = max_power;
    // Discrete boards idle around a fifth of TDP (RTX 6000: 55 / 260 W).
    gpu.idle_power = (max_power * 0.2).round();
    let cpu = CpuProfile {
        name: "DesktopCPU",
        num_cores: cores,
        peak_flops: cpu_gflops as f64 * 1e9,
        mem_bw: cpu_bw_gbs as f64 * 1e9,
        dram_bytes: dram_gb * (1 << 30),
        idle_power: 15.0,
        max_power: 125.0,
        dispatch_overhead: 2e-6,
    };
    (gpu, cpu)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_is_pure_in_seed_and_index() {
        let pop = PopulationSpec::default_population(100, 7);
        let a = pop.device(42);
        let b = pop.device(42);
        assert_eq!(a.class, b.class);
        assert_eq!(a.vram_gb, b.vram_gb);
        assert_eq!(a.testbed.gpu, b.testbed.gpu);
        assert_eq!(a.testbed.cpu, b.testbed.cpu);
        // Random access must not depend on sampling earlier devices.
        let fresh = PopulationSpec::default_population(100, 7);
        for i in (0..100).rev() {
            let x = fresh.device(i);
            let y = pop.device(i);
            assert_eq!(x.testbed.gpu, y.testbed.gpu, "device {i}");
        }
    }

    #[test]
    fn different_seeds_differ_and_classes_all_appear() {
        let a = PopulationSpec::default_population(200, 1);
        let b = PopulationSpec::default_population(200, 2);
        assert!(
            (0..200).any(|i| a.device(i).testbed.gpu != b.device(i).testbed.gpu),
            "seed must matter"
        );
        for class in DEVICE_CLASSES {
            assert!(
                (0..200).any(|i| a.device(i).class == class),
                "class {} never sampled",
                class_key(class)
            );
        }
    }

    #[test]
    fn sampled_profiles_respect_class_envelopes() {
        let pop = PopulationSpec::default_population(300, 9);
        for i in 0..300 {
            let d = pop.device(i);
            let g = &d.testbed.gpu;
            let c = &d.testbed.cpu;
            assert!(vram_tiers(d.class).contains(&d.vram_gb), "device {i}");
            assert_eq!(g.vram_bytes, d.vram_gb * (1 << 30), "device {i}");
            assert!(g.idle_power < g.max_power, "device {i}");
            assert!(g.peak_flops > 0.0 && g.mem_bw > 0.0, "device {i}");
            match d.class {
                DeviceClass::Edge => {
                    assert!(g.unified_memory && g.num_sms <= 10 && g.max_power <= 15.0);
                    assert_eq!(g.vram_bytes, c.dram_bytes);
                }
                DeviceClass::Laptop => {
                    assert!(g.unified_memory && (8..=24).contains(&g.num_sms));
                    assert_eq!(g.vram_bytes, c.dram_bytes);
                }
                DeviceClass::Desktop => {
                    assert!(!g.unified_memory && g.num_sms >= 24 && g.max_power >= 120.0);
                    assert!(c.dram_bytes >= 16 * (1 << 30));
                }
            }
        }
    }

    #[test]
    fn yaml_roundtrip_and_validation() {
        let text = "\
population:
  name: pilot
  count: 50
  seed: 9
  classes:
    edge: 0.5
    laptop: 0.25
    desktop: 0.25
";
        let spec = PopulationSpec::parse_yaml(text).unwrap();
        assert_eq!(spec.name, "pilot");
        assert_eq!(spec.count, 50);
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.weights, [0.5, 0.25, 0.25]);
        let again = PopulationSpec::parse_yaml(&spec.to_yaml()).unwrap();
        assert_eq!(again, spec);

        assert!(PopulationSpec::parse_yaml("population:\n  count: 0\n").is_err());
        assert!(PopulationSpec::parse_yaml("count: 5\n").is_err());
        assert!(PopulationSpec::parse_yaml(
            "population:\n  count: 5\n  classes:\n    warp_drive: 1\n"
        )
        .is_err());
        assert!(PopulationSpec::parse_yaml(
            "population:\n  count: 5\n  classes:\n    edge: 0\n    laptop: 0\n    desktop: 0\n"
        )
        .is_err());
    }

    #[test]
    fn defaults_fill_optional_fields() {
        let spec = PopulationSpec::parse_yaml("population:\n  count: 12\n").unwrap();
        assert_eq!(spec.name, "default");
        assert_eq!(spec.seed, 42);
        assert_eq!(spec.weights, [0.25, 0.45, 0.30]);
        assert_eq!(spec.count, 12);
    }
}
