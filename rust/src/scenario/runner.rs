//! Matrix execution, sweep supervision, and the aggregate report.
//!
//! Each [`ScenarioSpec`] is rendered to YAML, parsed, and executed through
//! the regular coordinator pipeline (`config → dag → executor`), so the
//! matrix exercises exactly the code paths a hand-written config would.
//! Per scenario the runner aggregates SLO attainment, p50/p99 latency,
//! fairness (min/max attainment spread across SLO-bearing apps), and the
//! engine's trace digest; [`MatrixReport::to_json`] renders everything as a
//! deterministic JSON document — byte-identical across runs with the same
//! seed, which the golden-trace tests pin.
//!
//! # Parallel deterministic execution
//!
//! Scenarios are mutually independent: each one builds its own engine from
//! `(spec, seed)` and shares no mutable state, so [`run_matrix_jobs`] farms
//! the expansion across a work-stealing pool of scoped threads (an atomic
//! cursor over the spec list — idle workers steal the next undone index).
//! Workers may finish in any order; outcomes land in their canonical slot
//! and the report is assembled in matrix-expansion order, so the JSON is
//! **byte-identical for `--jobs 1` and `--jobs N`**.
//!
//! # Sweep supervision
//!
//! [`run_specs_supervised`] makes the sweep fault-tolerant end to end. A
//! scenario that fails, panics, or exhausts its deterministic event/
//! virtual-time budget becomes a structured [`ScenarioOutcome`] row
//! (`status: failed | panicked | budget_exhausted | timeout`) instead of
//! aborting the sweep: panics are caught with `catch_unwind` at the worker
//! boundary, typed budget errors are classified by downcast, and a failed
//! scenario is retried once with the identical seed before being
//! quarantined as a report row. Because budgets are pure functions of the
//! config, budget exhaustion is itself deterministic and digest-stable; the
//! wall-clock watchdog is defense-in-depth only — `timeout` outcomes are
//! host-dependent, so they are never checkpointed and never feed golden
//! digests. With a `--journal`, every terminal outcome is appended to a
//! JSONL checkpoint keyed by `(scenario name, sweep seed, spec digest)`;
//! `--resume` replays the journal and re-executes only the missing rows,
//! producing a byte-identical report whether the sweep ran straight through
//! or was killed and resumed, at any `--jobs`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::apps::Slo;
use crate::coordinator::{
    run_config_text, run_config_text_watchdog, ScenarioResult, WallClockTimeout,
};
use crate::gpusim::engine::{BudgetExhausted, Fnv1a};
use crate::scenario::matrix::{
    backend_key, chaos_key, server_mode_key, strategy_key, testbed_key, workflow_key,
    MatrixAxes, ScenarioSpec,
};
use crate::util::json::{
    json_num, json_opt_bool, json_opt_num, json_str, parse as json_parse, JsonValue,
};
use crate::util::stats::Summary;

/// Terminal status of one scenario row under sweep supervision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioStatus {
    /// The scenario ran to completion.
    Ok,
    /// The scenario returned an error (after the bounded retry).
    Failed,
    /// The scenario panicked; the payload was caught at the worker boundary.
    Panicked,
    /// The deterministic event/virtual-time budget tripped. Never retried —
    /// budgets are pure functions of the config, so a retry would trip
    /// identically.
    BudgetExhausted,
    /// The wall-clock watchdog fired. Host-dependent by construction: never
    /// checkpointed to a journal and never part of a golden digest.
    Timeout,
    /// The scenario was never executed (a `--fail-fast` abort cancelled the
    /// sweep before this row was claimed).
    Skipped,
}

impl ScenarioStatus {
    /// Stable serialization key (report JSON and journal lines).
    pub fn key(&self) -> &'static str {
        match self {
            ScenarioStatus::Ok => "ok",
            ScenarioStatus::Failed => "failed",
            ScenarioStatus::Panicked => "panicked",
            ScenarioStatus::BudgetExhausted => "budget_exhausted",
            ScenarioStatus::Timeout => "timeout",
            ScenarioStatus::Skipped => "skipped",
        }
    }

    /// Inverse of [`ScenarioStatus::key`].
    pub fn from_key(key: &str) -> Option<ScenarioStatus> {
        Some(match key {
            "ok" => ScenarioStatus::Ok,
            "failed" => ScenarioStatus::Failed,
            "panicked" => ScenarioStatus::Panicked,
            "budget_exhausted" => ScenarioStatus::BudgetExhausted,
            "timeout" => ScenarioStatus::Timeout,
            "skipped" => ScenarioStatus::Skipped,
            _ => return None,
        })
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, ScenarioStatus::Ok)
    }
}

/// Supervision knobs for one sweep (see [`run_specs_supervised`]).
#[derive(Debug, Clone, Default)]
pub struct SweepOptions {
    /// Worker threads (clamped to `1..=len`); `0` behaves like `1`.
    pub jobs: usize,
    /// Abort the sweep on the first non-`ok` outcome (old fail-fast
    /// semantics). In-flight scenarios finish; unclaimed rows become
    /// `skipped`.
    pub fail_fast: bool,
    /// Wall-clock watchdog per scenario attempt. Defense-in-depth only —
    /// `timeout` outcomes are host-dependent and never journaled.
    pub watchdog: Option<Duration>,
    /// Append-only JSONL checkpoint of terminal outcomes.
    pub journal: Option<PathBuf>,
    /// Prefill completed rows from the journal before executing the rest.
    pub resume: bool,
}

/// Aggregated result of one application node inside a scenario.
#[derive(Debug, Clone)]
pub struct AppOutcome {
    pub node: String,
    pub app: String,
    pub requests: usize,
    /// Whether the application carries an SLO (DeepResearch does not).
    pub has_slo: bool,
    /// `None` when no requests completed (rendered `null`, never 100%).
    pub attainment: Option<f64>,
    pub mean_normalized: f64,
    /// `None` when no requests completed (rendered `null`, never `0.0` —
    /// a zero-request app has no latency distribution, not a zero-second
    /// one).
    pub p50_latency: Option<f64>,
    /// `None` when no requests completed (rendered `null`, never `0.0`).
    pub p99_latency: Option<f64>,
    pub failed: Option<String>,
}

/// Aggregated result of one scenario.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub name: String,
    pub mix: String,
    pub strategy: String,
    pub arrival: String,
    pub testbed: String,
    /// `static` | `adaptive` — the serving-configuration axis.
    pub server_mode: String,
    /// Workflow-shape axis: `flat` for app-mix scenarios, otherwise the
    /// generated DAG shape (`pipeline`, `fanout`, `diamond`,
    /// `content_creation`).
    pub workflow: String,
    /// Kernel-backend axis: `tuned_native` | `generic_torch` |
    /// `fused_custom` (everything outside the ablation slice runs tuned).
    pub backend: String,
    /// Whether the scenario belongs to the backend-ablation slice (the
    /// population `summary.backends` aggregates over).
    pub backend_ablation: bool,
    /// Chaos axis: `none` for fault-free scenarios, otherwise the injected
    /// fault kind (`thermal_throttle`, `vram_ballast`, `suspend`,
    /// `server_crash`, `pcie_degrade`).
    pub chaos: String,
    /// Supervision status. Run-derived fields below are only meaningful
    /// (and only rendered) when this is [`ScenarioStatus::Ok`].
    pub status: ScenarioStatus,
    /// Error message for non-`ok` rows.
    pub error: Option<String>,
    /// Whether this outcome came from the bounded retry (second attempt
    /// with the identical seed).
    pub retried: bool,
    pub seed: u64,
    pub makespan: f64,
    /// End-to-end workflow latency (latest foreground-node completion).
    pub e2e_latency: f64,
    /// `e2e_latency <= workflow_slo`; `None` when no bound is configured.
    pub e2e_slo_met: Option<bool>,
    /// Critical-path attribution (`a -> b -> c`): which nodes bounded the
    /// run, root to sink.
    pub critical_path: String,
    /// FNV-1a digest of the canonical engine trace — the golden fingerprint.
    pub trace_digest: u64,
    pub min_attainment: f64,
    pub max_attainment: f64,
    /// max − min attainment across SLO-bearing apps (0 = perfectly fair).
    pub fairness_spread: f64,
    /// Runtime reconfigurations applied by the adaptive controller (0 for
    /// static scenarios).
    pub reconfigurations: usize,
    pub apps: Vec<AppOutcome>,
}

/// The aggregate report over a whole matrix.
#[derive(Debug, Clone)]
pub struct MatrixReport {
    pub seed: u64,
    pub scenarios: Vec<ScenarioOutcome>,
}

/// Execute one scenario spec through the coordinator (fail-fast: an error
/// propagates instead of becoming a structured row).
pub fn run_scenario(spec: &ScenarioSpec) -> Result<ScenarioOutcome> {
    let yaml = spec.to_yaml();
    let result = run_config_text(&yaml, None)
        .with_context(|| format!("scenario `{}`", spec.name))?;
    Ok(outcome_from(spec, &result))
}

/// Execute every scenario of the matrix in expansion order (single worker).
pub fn run_matrix(axes: &MatrixAxes) -> Result<MatrixReport> {
    run_matrix_jobs(axes, 1)
}

/// Execute the matrix on up to `jobs` worker threads.
///
/// The report is assembled in canonical expansion order regardless of which
/// worker finished which scenario first, so the output (and therefore
/// [`MatrixReport::to_json`]) is byte-identical for any `jobs` value. If
/// several scenarios fail, the error of the lowest-index one is returned —
/// also independent of scheduling.
pub fn run_matrix_jobs(axes: &MatrixAxes, jobs: usize) -> Result<MatrixReport> {
    run_specs_jobs(&axes.expand(), axes.seed, jobs)
}

/// Execute an explicit spec list (e.g. a `--filter`ed subset of a matrix)
/// on up to `jobs` workers with the old fail-fast contract: the first
/// (lowest canonical index) non-`ok` scenario aborts the sweep with an
/// error. Internally a thin wrapper over [`run_specs_supervised`].
pub fn run_specs_jobs(specs: &[ScenarioSpec], seed: u64, jobs: usize) -> Result<MatrixReport> {
    let opts = SweepOptions {
        jobs,
        fail_fast: true,
        ..SweepOptions::default()
    };
    let report = run_specs_supervised(specs, seed, &opts)?;
    for s in &report.scenarios {
        if !s.status.is_ok() {
            anyhow::bail!(
                "scenario `{}` {}: {}",
                s.name,
                s.status.key(),
                s.error.as_deref().unwrap_or("aborted")
            );
        }
    }
    Ok(report)
}

/// Execute a spec list under full sweep supervision (see the module docs):
/// panic isolation, deterministic budget classification, bounded retry,
/// quarantine of failing rows, optional JSONL checkpoint/resume. `Err` is
/// reserved for infrastructure problems (an unreadable or unwritable
/// journal) — scenario failures are rows, not errors.
pub fn run_specs_supervised(
    specs: &[ScenarioSpec],
    seed: u64,
    opts: &SweepOptions,
) -> Result<MatrixReport> {
    let n = specs.len();
    let jobs = opts.jobs.clamp(1, n.max(1));
    let digests: Vec<String> = specs.iter().map(spec_digest_hex).collect();
    let mut slots: Vec<Option<ScenarioOutcome>> = vec![None; n];
    if opts.resume {
        let path = opts
            .journal
            .as_ref()
            .context("resume requires a journal path")?;
        for (slot, loaded) in slots.iter_mut().zip(load_journal(path, specs, seed, &digests)?) {
            *slot = loaded;
        }
    }
    let journal = match &opts.journal {
        Some(path) => Some(Journal::open(path, opts.resume)?),
        None => None,
    };
    // Work-stealing over the canonical order of the *unfilled* slots. The
    // same scoped pool serves every `jobs` value (a single worker degrades
    // to the sequential order); indices are claimed in canonical order, so
    // under `fail_fast` every index below the first failure has still been
    // executed and the lowest-index-failure rule is scheduling-independent.
    let todo: Vec<usize> = (0..n).filter(|&i| slots[i].is_none()).collect();
    let cursor = AtomicUsize::new(0);
    let cancel = AtomicBool::new(false);
    let finished: Mutex<Vec<(usize, ScenarioOutcome)>> = Mutex::new(Vec::with_capacity(todo.len()));
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut local = Vec::new();
                loop {
                    if cancel.load(Ordering::Relaxed) {
                        break;
                    }
                    let t = cursor.fetch_add(1, Ordering::Relaxed);
                    if t >= todo.len() {
                        break;
                    }
                    let i = todo[t];
                    let outcome = supervise_one(&specs[i], opts.watchdog);
                    if opts.fail_fast && !outcome.status.is_ok() {
                        cancel.store(true, Ordering::Relaxed);
                    }
                    if let Some(journal) = &journal {
                        // Timeouts are wall-clock artifacts: checkpointing
                        // one would resurrect a host hiccup on resume, so
                        // they always re-execute.
                        if outcome.status != ScenarioStatus::Timeout {
                            journal.append_line(&journal_line(seed, &digests[i], &outcome));
                        }
                    }
                    local.push((i, outcome));
                }
                // A sibling worker that panicked while holding the lock
                // poisons it; the Vec inside is still intact (extend is the
                // only operation), so recover rather than double-panic.
                finished
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .extend(local);
            });
        }
    });
    if let Some(journal) = &journal {
        if let Some(err) = journal.take_error() {
            anyhow::bail!("writing journal: {err}");
        }
    }
    for (i, outcome) in finished.into_inner().unwrap_or_else(|e| e.into_inner()) {
        slots[i] = Some(outcome);
    }
    let scenarios = slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| slot.unwrap_or_else(|| skipped_outcome(&specs[i])))
        .collect();
    Ok(MatrixReport { seed, scenarios })
}

/// One attempt of one scenario: panic isolation + typed-error
/// classification. Never unwinds.
fn attempt_one(spec: &ScenarioSpec, watchdog: Option<Duration>) -> ScenarioOutcome {
    let yaml = spec.to_yaml();
    match catch_unwind(AssertUnwindSafe(|| {
        run_config_text_watchdog(&yaml, None, watchdog)
    })) {
        Ok(Ok(result)) => outcome_from(spec, &result),
        Ok(Err(err)) => {
            let status = if err.downcast_ref::<BudgetExhausted>().is_some() {
                ScenarioStatus::BudgetExhausted
            } else if err.downcast_ref::<WallClockTimeout>().is_some() {
                ScenarioStatus::Timeout
            } else {
                ScenarioStatus::Failed
            };
            failed_outcome(spec, status, format!("{err:#}"))
        }
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "panic with non-string payload".to_string());
            failed_outcome(spec, ScenarioStatus::Panicked, msg)
        }
    }
}

/// One supervised scenario: attempt, then retry failures exactly once with
/// the identical seed. Budget exhaustion is deterministic and not retried;
/// everything else (error, panic, watchdog) gets the second chance. The
/// second attempt's outcome wins and is marked `retried`.
fn supervise_one(spec: &ScenarioSpec, watchdog: Option<Duration>) -> ScenarioOutcome {
    let first = attempt_one(spec, watchdog);
    match first.status {
        ScenarioStatus::Failed | ScenarioStatus::Panicked | ScenarioStatus::Timeout => {
            let mut second = attempt_one(spec, watchdog);
            second.retried = true;
            second
        }
        _ => first,
    }
}

/// FNV-1a digest of the spec's canonical YAML — the journal key that makes
/// stale checkpoint entries (same name, different spec) detectable.
fn spec_digest_hex(spec: &ScenarioSpec) -> String {
    let mut h = Fnv1a::new();
    h.update(spec.to_yaml().as_bytes());
    format!("{:016x}", h.finish())
}

/// Spec-derived outcome skeleton; run-derived fields at their non-`ok`
/// placeholders.
fn base_outcome(spec: &ScenarioSpec) -> ScenarioOutcome {
    ScenarioOutcome {
        name: spec.name.clone(),
        mix: spec.mix.name.to_string(),
        strategy: strategy_key(spec.strategy).to_string(),
        arrival: spec.arrival.name().to_string(),
        testbed: testbed_key(spec.testbed).to_string(),
        server_mode: server_mode_key(spec.server_mode).to_string(),
        workflow: workflow_key(spec.workflow).to_string(),
        backend: backend_key(spec.backend).to_string(),
        backend_ablation: spec.backend_ablation,
        chaos: spec
            .chaos
            .map(|k| chaos_key(k).to_string())
            .unwrap_or_else(|| "none".to_string()),
        status: ScenarioStatus::Ok,
        error: None,
        retried: false,
        seed: spec.seed,
        makespan: 0.0,
        e2e_latency: 0.0,
        e2e_slo_met: None,
        critical_path: String::new(),
        trace_digest: 0,
        min_attainment: 0.0,
        max_attainment: 0.0,
        fairness_spread: 0.0,
        reconfigurations: 0,
        apps: Vec::new(),
    }
}

fn failed_outcome(spec: &ScenarioSpec, status: ScenarioStatus, error: String) -> ScenarioOutcome {
    let mut out = base_outcome(spec);
    out.status = status;
    out.error = Some(error);
    out
}

fn skipped_outcome(spec: &ScenarioSpec) -> ScenarioOutcome {
    let mut out = base_outcome(spec);
    out.status = ScenarioStatus::Skipped;
    out
}

fn outcome_from(spec: &ScenarioSpec, result: &ScenarioResult) -> ScenarioOutcome {
    let apps: Vec<AppOutcome> = result
        .nodes
        .iter()
        .map(|n| {
            let lats: Vec<f64> = n.metrics.iter().map(|m| m.latency).collect();
            let (p50, p99) = match Summary::of(&lats) {
                Some(s) => (Some(s.p50), Some(s.p99)),
                None => (None, None),
            };
            AppOutcome {
                node: n.id.clone(),
                app: n.app.to_string(),
                requests: n.metrics.len(),
                has_slo: !matches!(n.slo, Slo::None),
                attainment: n.attainment(),
                mean_normalized: n.mean_normalized(),
                p50_latency: p50,
                p99_latency: p99,
                failed: n.failed.clone(),
            }
        })
        .collect();
    // Fairness over healthy SLO-bearing apps. A failed app (e.g. setup OOM)
    // counts as zero attainment rather than being dropped — otherwise a
    // scenario whose every SLO app failed would report a perfect 100%. An
    // app that ran no requests without failing has no attainment and is
    // excluded.
    let attainments: Vec<f64> = apps
        .iter()
        .filter(|a| a.has_slo)
        .filter_map(|a| {
            if a.failed.is_some() {
                Some(0.0)
            } else {
                a.attainment
            }
        })
        .collect();
    let (min_attainment, max_attainment) = if attainments.is_empty() {
        // No SLO-bearing apps at all (e.g. a DeepResearch-only mix):
        // vacuously met.
        (1.0, 1.0)
    } else {
        (
            attainments.iter().copied().fold(f64::INFINITY, f64::min),
            attainments.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        )
    };
    let mut out = base_outcome(spec);
    out.makespan = result.makespan;
    out.e2e_latency = result.workflow.e2e_latency;
    out.e2e_slo_met = result.workflow.e2e_slo_met;
    out.critical_path = result.workflow.critical_path_str();
    // The engine-computed digest covers the complete recorded trace even in
    // streaming mode, where `result.trace` is only the tail window.
    out.trace_digest = result.trace_digest;
    out.min_attainment = min_attainment;
    out.max_attainment = max_attainment;
    out.fairness_spread = max_attainment - min_attainment;
    out.reconfigurations = result.reconfigurations;
    out.apps = apps;
    out
}

// ---------------------------------------------------------------------------
// Checkpoint journal
// ---------------------------------------------------------------------------

/// Append-only JSONL checkpoint shared by the worker pool. Write errors are
/// recorded (first one wins) instead of panicking inside a worker; the
/// supervisor surfaces them after the scope joins. Also reused by the fleet
/// runner ([`crate::scenario::fleet`]), which journals device records with
/// the same open/repair/append semantics.
pub(crate) struct Journal {
    file: Mutex<std::fs::File>,
    error: Mutex<Option<String>>,
}

impl Journal {
    pub(crate) fn open(path: &Path, resume: bool) -> Result<Journal> {
        use std::io::{Read, Seek, SeekFrom, Write};
        let mut options = std::fs::OpenOptions::new();
        if resume {
            options.read(true).append(true).create(true);
        } else {
            options.write(true).truncate(true).create(true);
        }
        let mut file = options
            .open(path)
            .with_context(|| format!("opening journal `{}`", path.display()))?;
        if resume {
            // A kill mid-write can leave a partial final line. Start our
            // appends on a fresh line so the corruption stays confined to
            // that one (discarded) tail — otherwise the next entry would
            // merge into it and be lost too.
            let len = file
                .metadata()
                .with_context(|| format!("stat journal `{}`", path.display()))?
                .len();
            if len > 0 {
                let mut last = [0u8; 1];
                file.seek(SeekFrom::Start(len - 1))
                    .and_then(|_| file.read_exact(&mut last))
                    .with_context(|| format!("reading journal `{}`", path.display()))?;
                if last[0] != b'\n' {
                    file.write_all(b"\n")
                        .with_context(|| format!("repairing journal `{}`", path.display()))?;
                }
            }
        }
        Ok(Journal {
            file: Mutex::new(file),
            error: Mutex::new(None),
        })
    }

    pub(crate) fn append_line(&self, line: &str) {
        use std::io::Write as _;
        let mut file = self.file.lock().unwrap_or_else(|e| e.into_inner());
        let result = file.write_all(line.as_bytes()).and_then(|()| file.flush());
        if let Err(e) = result {
            let mut slot = self.error.lock().unwrap_or_else(|e| e.into_inner());
            if slot.is_none() {
                *slot = Some(e.to_string());
            }
        }
    }

    pub(crate) fn take_error(&self) -> Option<String> {
        self.error.lock().unwrap_or_else(|e| e.into_inner()).take()
    }
}

/// One journal line (including the trailing newline) for a terminal
/// outcome. `row` carries the run-derived fields only for `ok` rows; the
/// encoders are the same shortest-roundtrip emitters as the report, so a
/// journal round-trip reproduces every float bit-exactly.
fn journal_line(seed: u64, spec_digest: &str, s: &ScenarioOutcome) -> String {
    let mut out = String::with_capacity(256);
    out.push_str("{\"v\": 1");
    out.push_str(&format!(", \"name\": {}", json_str(&s.name)));
    out.push_str(&format!(", \"seed\": {seed}"));
    out.push_str(&format!(", \"spec_digest\": {}", json_str(spec_digest)));
    out.push_str(&format!(", \"status\": {}", json_str(s.status.key())));
    match &s.error {
        Some(e) => out.push_str(&format!(", \"error\": {}", json_str(e))),
        None => out.push_str(", \"error\": null"),
    }
    out.push_str(&format!(", \"retried\": {}", s.retried));
    if s.status.is_ok() {
        out.push_str(", \"row\": {");
        out.push_str(&format!("\"makespan_s\": {}", json_num(s.makespan)));
        out.push_str(&format!(", \"e2e_latency_s\": {}", json_num(s.e2e_latency)));
        out.push_str(&format!(", \"e2e_slo_met\": {}", json_opt_bool(s.e2e_slo_met)));
        out.push_str(&format!(", \"critical_path\": {}", json_str(&s.critical_path)));
        out.push_str(&format!(", \"trace_digest\": \"{:016x}\"", s.trace_digest));
        out.push_str(&format!(", \"min_attainment\": {}", json_num(s.min_attainment)));
        out.push_str(&format!(", \"max_attainment\": {}", json_num(s.max_attainment)));
        out.push_str(&format!(", \"fairness_spread\": {}", json_num(s.fairness_spread)));
        out.push_str(&format!(", \"reconfigurations\": {}", s.reconfigurations));
        out.push_str(", \"apps\": [");
        for (j, a) in s.apps.iter().enumerate() {
            if j > 0 {
                out.push_str(", ");
            }
            out.push('{');
            out.push_str(&format!("\"node\": {}", json_str(&a.node)));
            out.push_str(&format!(", \"app\": {}", json_str(&a.app)));
            out.push_str(&format!(", \"requests\": {}", a.requests));
            out.push_str(&format!(", \"has_slo\": {}", a.has_slo));
            out.push_str(&format!(", \"attainment\": {}", json_opt_num(a.attainment)));
            out.push_str(&format!(
                ", \"mean_normalized\": {}",
                json_num(a.mean_normalized)
            ));
            out.push_str(&format!(", \"p50_latency_s\": {}", json_opt_num(a.p50_latency)));
            out.push_str(&format!(", \"p99_latency_s\": {}", json_opt_num(a.p99_latency)));
            match &a.failed {
                Some(e) => out.push_str(&format!(", \"failed\": {}", json_str(e))),
                None => out.push_str(", \"failed\": null"),
            }
            out.push('}');
        }
        out.push_str("]}");
    } else {
        out.push_str(", \"row\": null");
    }
    out.push_str("}\n");
    out
}

/// Replay a journal into per-spec slots. Tolerant by construction: a
/// missing file means nothing to resume; a line that fails to parse is
/// discarded (a killed-mid-write tail — and after a resume repaired such a
/// tail, one can sit mid-file); an entry whose version, sweep seed, name,
/// or spec digest does not match is skipped as stale. The last valid entry
/// per scenario wins.
fn load_journal(
    path: &Path,
    specs: &[ScenarioSpec],
    seed: u64,
    digests: &[String],
) -> Result<Vec<Option<ScenarioOutcome>>> {
    let mut slots: Vec<Option<ScenarioOutcome>> = vec![None; specs.len()];
    let text = match std::fs::read_to_string(path) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(slots),
        Err(e) => {
            return Err(e).with_context(|| format!("reading journal `{}`", path.display()))
        }
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = json_parse(line) else {
            continue;
        };
        if v.get("v").and_then(JsonValue::as_u64) != Some(1) {
            continue;
        }
        if v.get("seed").and_then(JsonValue::as_u64) != Some(seed) {
            continue;
        }
        let Some(name) = v.get("name").and_then(JsonValue::as_str) else {
            continue;
        };
        let Some(i) = specs.iter().position(|s| s.name == name) else {
            continue;
        };
        if v.get("spec_digest").and_then(JsonValue::as_str) != Some(digests[i].as_str()) {
            continue;
        }
        let Some(status) = v
            .get("status")
            .and_then(JsonValue::as_str)
            .and_then(ScenarioStatus::from_key)
        else {
            continue;
        };
        if matches!(status, ScenarioStatus::Timeout | ScenarioStatus::Skipped) {
            continue;
        }
        if let Some(outcome) = outcome_from_journal(&specs[i], status, &v) {
            slots[i] = Some(outcome);
        }
    }
    Ok(slots)
}

/// `Num` → the number; `null` → a non-finite stand-in. The emitters render
/// every non-finite as `null`, so reconstructing `null` as `inf` makes the
/// re-render byte-identical without remembering which non-finite it was.
fn jnum(v: &JsonValue) -> Option<f64> {
    match v {
        JsonValue::Num(n) => Some(*n),
        JsonValue::Null => Some(f64::INFINITY),
        _ => None,
    }
}

/// `Num` → `Some`, `null` → `None` (optional fields render `null` for
/// `None` and for non-finite alike, so `None` re-renders identically).
fn jopt(v: &JsonValue) -> Option<Option<f64>> {
    match v {
        JsonValue::Num(n) => Some(Some(*n)),
        JsonValue::Null => Some(None),
        _ => None,
    }
}

/// Reconstruct an outcome from one validated journal entry; `None` on any
/// shape mismatch (the caller then just re-executes the scenario).
fn outcome_from_journal(
    spec: &ScenarioSpec,
    status: ScenarioStatus,
    v: &JsonValue,
) -> Option<ScenarioOutcome> {
    let mut out = base_outcome(spec);
    out.status = status;
    out.error = match v.get("error")? {
        JsonValue::Null => None,
        e => Some(e.as_str()?.to_string()),
    };
    out.retried = v.get("retried")?.as_bool()?;
    if !status.is_ok() {
        return Some(out);
    }
    let row = v.get("row")?;
    out.makespan = jnum(row.get("makespan_s")?)?;
    out.e2e_latency = jnum(row.get("e2e_latency_s")?)?;
    out.e2e_slo_met = match row.get("e2e_slo_met")? {
        JsonValue::Null => None,
        b => Some(b.as_bool()?),
    };
    out.critical_path = row.get("critical_path")?.as_str()?.to_string();
    out.trace_digest = u64::from_str_radix(row.get("trace_digest")?.as_str()?, 16).ok()?;
    out.min_attainment = jnum(row.get("min_attainment")?)?;
    out.max_attainment = jnum(row.get("max_attainment")?)?;
    out.fairness_spread = jnum(row.get("fairness_spread")?)?;
    out.reconfigurations = row.get("reconfigurations")?.as_u64()? as usize;
    for a in row.get("apps")?.as_arr()? {
        out.apps.push(AppOutcome {
            node: a.get("node")?.as_str()?.to_string(),
            app: a.get("app")?.as_str()?.to_string(),
            requests: a.get("requests")?.as_u64()? as usize,
            has_slo: a.get("has_slo")?.as_bool()?,
            attainment: jopt(a.get("attainment")?)?,
            mean_normalized: jnum(a.get("mean_normalized")?)?,
            p50_latency: jopt(a.get("p50_latency_s")?)?,
            p99_latency: jopt(a.get("p99_latency_s")?)?,
            failed: match a.get("failed")? {
                JsonValue::Null => None,
                e => Some(e.as_str()?.to_string()),
            },
        });
    }
    Some(out)
}

/// One static/adaptive scenario pair and its attainment delta — the
/// measurable value of runtime adaptation (ISSUE 3 acceptance metric).
#[derive(Debug, Clone)]
pub struct AdaptiveDelta {
    /// Scenario name without the `/server=…` suffix.
    pub base: String,
    pub static_min_attainment: f64,
    pub adaptive_min_attainment: f64,
    /// adaptive − static min-attainment (positive = adaptation helped).
    pub delta: f64,
    /// Reconfigurations the adaptive run applied.
    pub reconfigurations: usize,
}

/// Aggregate of one kernel backend over the ablation slice — the
/// `summary.backends` comparison of request throughput and SLO attainment
/// per kernel implementation (the §6 tuned-vs-generic claim as a report
/// section).
#[derive(Debug, Clone)]
pub struct BackendRow {
    /// Backend key (`tuned_native`, `generic_torch`, `fused_custom`).
    pub backend: String,
    /// Ablation scenarios aggregated into this row.
    pub scenarios: usize,
    /// Mean of per-scenario completed-requests / makespan (requests/s).
    pub mean_throughput_rps: f64,
    /// Mean per-scenario min attainment across SLO-bearing apps.
    pub mean_min_attainment: f64,
}

/// One static/adaptive pair of the chaos slice and its attainment delta —
/// the `summary.chaos` measurement of how much runtime adaptation buys back
/// under each injected fault class (ISSUE 6 acceptance metric).
#[derive(Debug, Clone)]
pub struct ChaosRow {
    /// Injected fault kind (`thermal_throttle`, `server_crash`, …).
    pub chaos: String,
    /// Scenario name without the `/server=…` suffix.
    pub base: String,
    pub static_min_attainment: f64,
    pub adaptive_min_attainment: f64,
    /// adaptive − static min-attainment under the fault (positive =
    /// adaptation recovered attainment the static config lost).
    pub delta: f64,
    /// Reconfigurations the adaptive run applied while faults landed.
    pub reconfigurations: usize,
}

/// Aggregate of one (workflow shape, strategy) cell — the `summary.workflows`
/// comparison of end-to-end latency across strategies (which reproduces the
/// paper's finding that greedy allocation stretches the critical path while
/// SLO-aware scheduling shortens it).
#[derive(Debug, Clone)]
pub struct WorkflowRow {
    /// Shape key (`pipeline`, `fanout`, `diamond`, `content_creation`).
    pub workflow: String,
    pub strategy: String,
    /// Scenarios in this cell (testbed × server-mode variants).
    pub scenarios: usize,
    pub mean_e2e_latency: f64,
    /// Fraction of the cell's scenarios meeting their `workflow_slo`.
    pub e2e_slo_attainment: f64,
}

impl MatrixReport {
    /// Rows that ran to completion — the population every summary aggregate
    /// draws from (a quarantined row has no run-derived metrics to mix in).
    fn ok_rows(&self) -> impl Iterator<Item = &ScenarioOutcome> {
        self.scenarios.iter().filter(|s| s.status.is_ok())
    }

    /// Distinct strategies present among `ok` rows, in first-seen order.
    pub fn strategies(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for s in self.ok_rows() {
            if !out.contains(&s.strategy.as_str()) {
                out.push(&s.strategy);
            }
        }
        out
    }

    /// Per-(shape, strategy) end-to-end aggregates over the workflow slice,
    /// in first-seen (canonical) order. Empty when the matrix carries no
    /// workflow scenarios. Quarantined rows are excluded.
    pub fn workflow_rows(&self) -> Vec<WorkflowRow> {
        let mut keys: Vec<(&str, &str)> = Vec::new();
        for s in self.ok_rows() {
            if s.workflow == "flat" {
                continue;
            }
            let key = (s.workflow.as_str(), s.strategy.as_str());
            if !keys.contains(&key) {
                keys.push(key);
            }
        }
        keys.into_iter()
            .map(|(wf, strat)| {
                let rows: Vec<&ScenarioOutcome> = self
                    .ok_rows()
                    .filter(|s| s.workflow == wf && s.strategy == strat)
                    .collect();
                let n = rows.len().max(1) as f64;
                let met = rows
                    .iter()
                    .filter(|r| r.e2e_slo_met == Some(true))
                    .count() as f64;
                WorkflowRow {
                    workflow: wf.to_string(),
                    strategy: strat.to_string(),
                    scenarios: rows.len(),
                    mean_e2e_latency: rows.iter().map(|r| r.e2e_latency).sum::<f64>() / n,
                    e2e_slo_attainment: met / n,
                }
            })
            .collect()
    }

    /// Per-backend throughput/attainment aggregates over the
    /// backend-ablation slice, in first-seen (canonical) order. Empty when
    /// the matrix carries no ablation scenarios. Restricted to the slice —
    /// the rest of the matrix runs tuned by construction and would swamp
    /// the comparison. Quarantined rows are excluded.
    pub fn backend_rows(&self) -> Vec<BackendRow> {
        let mut keys: Vec<&str> = Vec::new();
        for s in self.ok_rows() {
            if s.backend_ablation && !keys.contains(&s.backend.as_str()) {
                keys.push(&s.backend);
            }
        }
        keys.into_iter()
            .map(|key| {
                let rows: Vec<&ScenarioOutcome> = self
                    .ok_rows()
                    .filter(|s| s.backend_ablation && s.backend == key)
                    .collect();
                let n = rows.len().max(1) as f64;
                let throughput = |r: &ScenarioOutcome| -> f64 {
                    let requests: usize = r.apps.iter().map(|a| a.requests).sum();
                    if r.makespan > 0.0 {
                        requests as f64 / r.makespan
                    } else {
                        0.0
                    }
                };
                BackendRow {
                    backend: key.to_string(),
                    scenarios: rows.len(),
                    mean_throughput_rps: rows.iter().map(|r| throughput(r)).sum::<f64>() / n,
                    mean_min_attainment: rows.iter().map(|r| r.min_attainment).sum::<f64>() / n,
                }
            })
            .collect()
    }

    /// Pair every adaptive scenario with its static twin (same axes, only
    /// the server mode differs), in canonical order. A pair with a
    /// quarantined half is dropped — a delta against a failed twin is
    /// meaningless.
    pub fn adaptive_deltas(&self) -> Vec<AdaptiveDelta> {
        let mut out = Vec::new();
        for s in self.ok_rows() {
            if s.server_mode != "adaptive" {
                continue;
            }
            let base = s
                .name
                .strip_suffix("/server=adaptive")
                .unwrap_or(&s.name)
                .to_string();
            let twin_name = format!("{base}/server=static");
            let Some(twin) = self.ok_rows().find(|t| t.name == twin_name) else {
                continue;
            };
            out.push(AdaptiveDelta {
                base,
                static_min_attainment: twin.min_attainment,
                adaptive_min_attainment: s.min_attainment,
                delta: s.min_attainment - twin.min_attainment,
                reconfigurations: s.reconfigurations,
            });
        }
        out
    }

    /// Pair every adaptive chaos scenario with its static twin, in canonical
    /// order. Restricted to the chaos slice — fault-free pairs are already
    /// covered by [`MatrixReport::adaptive_deltas`], and mixing regimes
    /// would hide what adaptation buys back specifically under faults.
    /// Quarantined halves drop the pair.
    pub fn chaos_rows(&self) -> Vec<ChaosRow> {
        let mut out = Vec::new();
        for s in self.ok_rows() {
            if s.chaos == "none" || s.server_mode != "adaptive" {
                continue;
            }
            let base = s
                .name
                .strip_suffix("/server=adaptive")
                .unwrap_or(&s.name)
                .to_string();
            let twin_name = format!("{base}/server=static");
            let Some(twin) = self.ok_rows().find(|t| t.name == twin_name) else {
                continue;
            };
            out.push(ChaosRow {
                chaos: s.chaos.clone(),
                base,
                static_min_attainment: twin.min_attainment,
                adaptive_min_attainment: s.min_attainment,
                delta: s.min_attainment - twin.min_attainment,
                reconfigurations: s.reconfigurations,
            });
        }
        out
    }

    /// Per-status row counts over the whole report, in taxonomy order.
    pub fn status_counts(&self) -> [(&'static str, usize); 6] {
        let count = |st: ScenarioStatus| self.scenarios.iter().filter(|s| s.status == st).count();
        [
            ("ok", count(ScenarioStatus::Ok)),
            ("failed", count(ScenarioStatus::Failed)),
            ("panicked", count(ScenarioStatus::Panicked)),
            ("budget_exhausted", count(ScenarioStatus::BudgetExhausted)),
            ("timeout", count(ScenarioStatus::Timeout)),
            ("skipped", count(ScenarioStatus::Skipped)),
        ]
    }

    /// Deterministic JSON rendering of the whole report.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\n");
        out.push_str("  \"consumerbench_scenario_matrix\": 2,\n");
        out.push_str(&format!("  \"seed\": {},\n", self.seed));
        out.push_str(&format!(
            "  \"num_scenarios\": {},\n",
            self.scenarios.len()
        ));
        out.push_str("  \"scenarios\": [\n");
        for (i, s) in self.scenarios.iter().enumerate() {
            let ok = s.status.is_ok();
            out.push_str("    {\n");
            out.push_str(&format!("      \"name\": {},\n", json_str(&s.name)));
            out.push_str(&format!("      \"mix\": {},\n", json_str(&s.mix)));
            out.push_str(&format!("      \"strategy\": {},\n", json_str(&s.strategy)));
            out.push_str(&format!("      \"arrival\": {},\n", json_str(&s.arrival)));
            out.push_str(&format!("      \"testbed\": {},\n", json_str(&s.testbed)));
            out.push_str(&format!(
                "      \"server_mode\": {},\n",
                json_str(&s.server_mode)
            ));
            out.push_str(&format!(
                "      \"workflow\": {},\n",
                json_str(&s.workflow)
            ));
            out.push_str(&format!(
                "      \"backend\": {},\n",
                json_str(&s.backend)
            ));
            out.push_str(&format!("      \"chaos\": {},\n", json_str(&s.chaos)));
            out.push_str(&format!(
                "      \"status\": {},\n",
                json_str(s.status.key())
            ));
            match &s.error {
                Some(e) => out.push_str(&format!("      \"error\": {},\n", json_str(e))),
                None => out.push_str("      \"error\": null,\n"),
            }
            out.push_str(&format!("      \"retried\": {},\n", s.retried));
            if ok {
                out.push_str(&format!(
                    "      \"reconfigurations\": {},\n",
                    s.reconfigurations
                ));
            } else {
                out.push_str("      \"reconfigurations\": null,\n");
            }
            out.push_str(&format!("      \"seed\": {},\n", s.seed));
            if ok {
                out.push_str(&format!(
                    "      \"makespan_s\": {},\n",
                    json_num(s.makespan)
                ));
                out.push_str(&format!(
                    "      \"e2e_latency_s\": {},\n",
                    json_num(s.e2e_latency)
                ));
                out.push_str(&format!(
                    "      \"e2e_slo_met\": {},\n",
                    json_opt_bool(s.e2e_slo_met)
                ));
                out.push_str(&format!(
                    "      \"critical_path\": {},\n",
                    json_str(&s.critical_path)
                ));
                out.push_str(&format!(
                    "      \"trace_digest\": \"{:016x}\",\n",
                    s.trace_digest
                ));
                out.push_str(&format!(
                    "      \"min_attainment\": {},\n",
                    json_num(s.min_attainment)
                ));
                out.push_str(&format!(
                    "      \"max_attainment\": {},\n",
                    json_num(s.max_attainment)
                ));
                out.push_str(&format!(
                    "      \"fairness_spread\": {},\n",
                    json_num(s.fairness_spread)
                ));
            } else {
                // A quarantined row has no run: render explicit nulls so
                // consumers never mistake placeholders for measurements.
                out.push_str("      \"makespan_s\": null,\n");
                out.push_str("      \"e2e_latency_s\": null,\n");
                out.push_str("      \"e2e_slo_met\": null,\n");
                out.push_str("      \"critical_path\": null,\n");
                out.push_str("      \"trace_digest\": null,\n");
                out.push_str("      \"min_attainment\": null,\n");
                out.push_str("      \"max_attainment\": null,\n");
                out.push_str("      \"fairness_spread\": null,\n");
            }
            out.push_str("      \"apps\": [\n");
            for (j, a) in s.apps.iter().enumerate() {
                out.push_str("        {");
                out.push_str(&format!("\"node\": {}, ", json_str(&a.node)));
                out.push_str(&format!("\"app\": {}, ", json_str(&a.app)));
                out.push_str(&format!("\"requests\": {}, ", a.requests));
                out.push_str(&format!("\"has_slo\": {}, ", a.has_slo));
                out.push_str(&format!(
                    "\"attainment\": {}, ",
                    json_opt_num(a.attainment)
                ));
                out.push_str(&format!(
                    "\"mean_normalized\": {}, ",
                    json_num(a.mean_normalized)
                ));
                out.push_str(&format!(
                    "\"p50_latency_s\": {}, ",
                    json_opt_num(a.p50_latency)
                ));
                out.push_str(&format!(
                    "\"p99_latency_s\": {}, ",
                    json_opt_num(a.p99_latency)
                ));
                match &a.failed {
                    Some(e) => out.push_str(&format!("\"failed\": {}", json_str(e))),
                    None => out.push_str("\"failed\": null"),
                }
                out.push('}');
                out.push_str(if j + 1 < s.apps.len() { ",\n" } else { "\n" });
            }
            out.push_str("      ]\n");
            out.push_str("    }");
            out.push_str(if i + 1 < self.scenarios.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ],\n");
        out.push_str("  \"summary\": {\n");
        out.push_str("    \"by_strategy\": [\n");
        let strategies = self.strategies();
        for (i, strat) in strategies.iter().enumerate() {
            let rows: Vec<&ScenarioOutcome> =
                self.ok_rows().filter(|s| s.strategy == *strat).collect();
            let avg = |vals: Vec<f64>| -> f64 {
                if vals.is_empty() {
                    0.0
                } else {
                    vals.iter().sum::<f64>() / vals.len() as f64
                }
            };
            let mean_min = avg(rows.iter().map(|r| r.min_attainment).collect());
            let mean_spread = avg(rows.iter().map(|r| r.fairness_spread).collect());
            let mean_makespan = avg(rows.iter().map(|r| r.makespan).collect());
            out.push_str(&format!(
                "      {{\"strategy\": {}, \"scenarios\": {}, \"mean_min_attainment\": {}, \"mean_fairness_spread\": {}, \"mean_makespan_s\": {}}}",
                json_str(strat),
                rows.len(),
                json_num(mean_min),
                json_num(mean_spread),
                json_num(mean_makespan),
            ));
            out.push_str(if i + 1 < strategies.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ],\n");
        out.push_str("    \"workflows\": [\n");
        let wf_rows = self.workflow_rows();
        for (i, w) in wf_rows.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"workflow\": {}, \"strategy\": {}, \"scenarios\": {}, \"mean_e2e_latency_s\": {}, \"e2e_slo_attainment\": {}}}",
                json_str(&w.workflow),
                json_str(&w.strategy),
                w.scenarios,
                json_num(w.mean_e2e_latency),
                json_num(w.e2e_slo_attainment),
            ));
            out.push_str(if i + 1 < wf_rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ],\n");
        out.push_str("    \"backends\": [\n");
        let b_rows = self.backend_rows();
        for (i, b) in b_rows.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"backend\": {}, \"scenarios\": {}, \"mean_throughput_rps\": {}, \"mean_min_attainment\": {}}}",
                json_str(&b.backend),
                b.scenarios,
                json_num(b.mean_throughput_rps),
                json_num(b.mean_min_attainment),
            ));
            out.push_str(if i + 1 < b_rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ],\n");
        out.push_str("    \"adaptive_vs_static\": [\n");
        let deltas = self.adaptive_deltas();
        for (i, d) in deltas.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"scenario\": {}, \"static_min_attainment\": {}, \"adaptive_min_attainment\": {}, \"attainment_delta\": {}, \"reconfigurations\": {}}}",
                json_str(&d.base),
                json_num(d.static_min_attainment),
                json_num(d.adaptive_min_attainment),
                json_num(d.delta),
                d.reconfigurations,
            ));
            out.push_str(if i + 1 < deltas.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ],\n");
        out.push_str("    \"chaos\": [\n");
        let c_rows = self.chaos_rows();
        for (i, c) in c_rows.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"chaos\": {}, \"scenario\": {}, \"static_min_attainment\": {}, \"adaptive_min_attainment\": {}, \"attainment_delta\": {}, \"reconfigurations\": {}}}",
                json_str(&c.chaos),
                json_str(&c.base),
                json_num(c.static_min_attainment),
                json_num(c.adaptive_min_attainment),
                json_num(c.delta),
                c.reconfigurations,
            ));
            out.push_str(if i + 1 < c_rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ],\n");
        out.push_str("    \"failures\": {\n");
        out.push_str("      \"counts\": {");
        for (i, (key, count)) in self.status_counts().iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{key}\": {count}"));
        }
        out.push_str("},\n");
        out.push_str("      \"rows\": [\n");
        let quarantined: Vec<&ScenarioOutcome> =
            self.scenarios.iter().filter(|s| !s.status.is_ok()).collect();
        for (i, s) in quarantined.iter().enumerate() {
            out.push_str(&format!(
                "        {{\"scenario\": {}, \"status\": {}, \"error\": {}, \"retried\": {}}}",
                json_str(&s.name),
                json_str(s.status.key()),
                match &s.error {
                    Some(e) => json_str(e),
                    None => "null".to_string(),
                },
                s.retried,
            ));
            out.push_str(if i + 1 < quarantined.len() { ",\n" } else { "\n" });
        }
        out.push_str("      ]\n");
        out.push_str("    }\n");
        out.push_str("  }\n");
        out.push_str("}\n");
        out
    }

    /// Human-readable summary table (one row per scenario). Quarantined
    /// rows print their status and dashes for the run-derived columns.
    pub fn summary_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<80} {:>16} {:>9} {:>7} {:>7} {:>6} {:>7}\n",
            "scenario", "status", "makespan", "min-att", "spread", "reconf", "digest"
        ));
        for s in &self.scenarios {
            if s.status.is_ok() {
                out.push_str(&format!(
                    "{:<80} {:>16} {:>8.1}s {:>6.0}% {:>7.2} {:>6} {:>7}\n",
                    s.name,
                    s.status.key(),
                    s.makespan,
                    s.min_attainment * 100.0,
                    s.fairness_spread,
                    s.reconfigurations,
                    &format!("{:016x}", s.trace_digest)[..7],
                ));
            } else {
                out.push_str(&format!(
                    "{:<80} {:>16} {:>9} {:>7} {:>7} {:>6} {:>7}\n",
                    s.name,
                    s.status.key(),
                    "-",
                    "-",
                    "-",
                    "-",
                    "-",
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{AppType, InjectFailure, Strategy, TestbedKind};
    use crate::gpusim::kernel::Device;
    use crate::scenario::matrix::{AppMix, ArrivalKind, MixEntry, ServerMode};

    fn tiny_axes(seed: u64) -> MatrixAxes {
        MatrixAxes {
            mixes: vec![AppMix {
                name: "captions",
                entries: vec![MixEntry {
                    app: AppType::LiveCaptions,
                    num_requests: 3,
                    device: Device::Gpu,
                }],
            }],
            strategies: vec![Strategy::Greedy, Strategy::FairShare],
            testbeds: vec![TestbedKind::IntelServer],
            arrivals: vec![ArrivalKind::Poisson],
            server_modes: vec![ServerMode::Static, ServerMode::Adaptive],
            workflows: vec![],
            workflow_strategies: vec![],
            backends: vec![],
            backend_strategies: vec![],
            chaos: vec![],
            seed,
        }
    }

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cb_runner_{}_{tag}.jsonl", std::process::id()))
    }

    #[test]
    fn tiny_matrix_runs_and_reports() {
        let report = run_matrix(&tiny_axes(42)).unwrap();
        assert_eq!(report.scenarios.len(), 2);
        for s in &report.scenarios {
            assert_eq!(s.status, ScenarioStatus::Ok);
            assert_eq!(s.apps.len(), 1);
            assert_eq!(s.apps[0].requests, 3);
            assert!(s.makespan > 0.0);
        }
        let json = report.to_json();
        assert!(json.contains("\"consumerbench_scenario_matrix\": 2"));
        assert!(json.contains("\"strategy\": \"greedy\""));
        assert!(json.contains("\"arrival\": \"poisson\""));
        assert!(json.contains("\"server_mode\": \"static\""));
        assert!(json.contains("\"status\": \"ok\""));
        assert!(json.contains("\"adaptive_vs_static\""));
        assert!(json.contains("\"failures\": {"));
        assert!(json.contains("\"ok\": 2"));
        assert!(!json.contains("inf"), "non-finite leaked into JSON");
    }

    #[test]
    fn status_keys_roundtrip() {
        for st in [
            ScenarioStatus::Ok,
            ScenarioStatus::Failed,
            ScenarioStatus::Panicked,
            ScenarioStatus::BudgetExhausted,
            ScenarioStatus::Timeout,
            ScenarioStatus::Skipped,
        ] {
            assert_eq!(ScenarioStatus::from_key(st.key()), Some(st));
        }
        assert_eq!(ScenarioStatus::from_key("bogus"), None);
    }

    #[test]
    fn adaptive_deltas_pair_twins_in_canonical_order() {
        // A text mix so both server modes expand.
        let mut axes = MatrixAxes::default_matrix(11);
        axes.mixes = vec![AppMix::chat()];
        axes.strategies.truncate(1);
        axes.arrivals.truncate(1);
        axes.workflows.clear();
        let report = run_matrix(&axes).unwrap();
        assert_eq!(report.scenarios.len(), 2, "one static + one adaptive");
        let deltas = report.adaptive_deltas();
        assert_eq!(deltas.len(), 1);
        let d = &deltas[0];
        assert!(d.base.contains("mix=chat"));
        assert!(!d.base.contains("server="));
        assert_eq!(
            d.delta,
            d.adaptive_min_attainment - d.static_min_attainment
        );
        let json = report.to_json();
        assert!(json.contains("\"attainment_delta\""), "{json}");
    }

    #[test]
    fn failed_slo_app_counts_as_zero_attainment() {
        use crate::coordinator::executor::NodeResult;
        let spec = tiny_axes(1).expand().remove(0);
        let result = ScenarioResult {
            nodes: vec![NodeResult {
                id: "Captions (livecaptions)".into(),
                app: "LiveCaptions",
                slo: Slo::SegmentTime(2.0),
                metrics: vec![],
                ready: 0.0,
                start: 0.0,
                end: 1.0,
                background: false,
                failed: Some("VRAM OOM".into()),
            }],
            workflow: crate::coordinator::WorkflowMetrics::default(),
            trace: crate::gpusim::engine::Trace::new(),
            trace_digest: 0,
            trace_aggregates: None,
            client_names: vec![],
            makespan: 1.0,
            policy: "greedy".into(),
            pjrt_calls: 0,
            reconfigurations: 0,
            controller_actions: vec![],
            gpu_idle_w: 0.0,
            cpu_idle_w: 0.0,
        };
        let outcome = outcome_from(&spec, &result);
        assert_eq!(outcome.min_attainment, 0.0);
        assert_eq!(outcome.max_attainment, 0.0);
        assert!(outcome.apps[0].failed.is_some());
        // The failed app's own attainment is `null`/absent, not a number —
        // only the fairness aggregate folds it to zero.
        assert_eq!(outcome.apps[0].attainment, None);
        // Zero completed requests means no latency distribution: `null`,
        // never a fabricated 0.0 percentile.
        assert_eq!(outcome.apps[0].p50_latency, None);
        assert_eq!(outcome.apps[0].p99_latency, None);
        let report = MatrixReport {
            seed: 1,
            scenarios: vec![outcome],
        };
        let json = report.to_json();
        assert!(json.contains("\"p50_latency_s\": null"), "{json}");
        assert!(json.contains("\"p99_latency_s\": null"), "{json}");
        assert!(!json.contains("\"p50_latency_s\": 0,"), "{json}");
    }

    #[test]
    fn workflow_scenarios_report_e2e_and_critical_path() {
        // One DAG shape, greedy only, static only: a fast slice that still
        // exercises the workflow reporting path end-to-end.
        let mut axes = MatrixAxes::default_matrix(3);
        axes.mixes.clear();
        axes.server_modes = vec![ServerMode::Static];
        axes.workflows = vec![crate::scenario::matrix::WorkflowShape::Pipeline];
        axes.workflow_strategies = vec![Strategy::Greedy];
        let report = run_matrix(&axes).unwrap();
        assert_eq!(report.scenarios.len(), 1);
        let s = &report.scenarios[0];
        assert_eq!(s.workflow, "pipeline");
        assert!(s.e2e_latency > 0.0);
        assert!(s.e2e_slo_met.is_some(), "pipeline carries a workflow_slo");
        assert_eq!(
            s.critical_path, "script -> storyboard -> captions",
            "a linear pipeline is its own critical path"
        );
        let rows = report.workflow_rows();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].workflow, "pipeline");
        assert_eq!(rows[0].scenarios, 1);
        assert!((rows[0].mean_e2e_latency - s.e2e_latency).abs() < 1e-12);
        let json = report.to_json();
        assert!(json.contains("\"workflow\": \"pipeline\""), "{json}");
        assert!(json.contains("\"critical_path\": \"script -> storyboard -> captions\""));
        assert!(json.contains("\"e2e_latency_s\""));
        assert!(json.contains("\"workflows\": ["));
    }

    #[test]
    fn backend_rows_aggregate_only_the_ablation_slice() {
        // Synthetic outcomes: two ablation scenarios per backend plus one
        // flat (tuned, non-ablation) scenario that must stay out of the
        // aggregate.
        let outcome = |name: &str, backend: &str, ablation: bool, makespan: f64, att: f64| {
            ScenarioOutcome {
                name: name.into(),
                mix: "chat+imagegen".into(),
                strategy: "greedy".into(),
                arrival: "closed".into(),
                testbed: "intel_server".into(),
                server_mode: "static".into(),
                workflow: "flat".into(),
                backend: backend.into(),
                backend_ablation: ablation,
                chaos: "none".into(),
                status: ScenarioStatus::Ok,
                error: None,
                retried: false,
                seed: 1,
                makespan,
                e2e_latency: makespan,
                e2e_slo_met: None,
                critical_path: String::new(),
                trace_digest: 0,
                min_attainment: att,
                max_attainment: att,
                fairness_spread: 0.0,
                reconfigurations: 0,
                apps: vec![AppOutcome {
                    node: "Chat (chatbot)".into(),
                    app: "Chatbot".into(),
                    requests: 10,
                    has_slo: true,
                    attainment: Some(att),
                    mean_normalized: 0.5,
                    p50_latency: Some(1.0),
                    p99_latency: Some(2.0),
                    failed: None,
                }],
            }
        };
        let report = MatrixReport {
            seed: 1,
            scenarios: vec![
                outcome("mix=chat+imagegen/...", "tuned_native", false, 10.0, 0.5),
                outcome("backend=tuned_native/a", "tuned_native", true, 10.0, 1.0),
                outcome("backend=tuned_native/b", "tuned_native", true, 20.0, 0.8),
                outcome("backend=generic_torch/a", "generic_torch", true, 40.0, 0.4),
            ],
        };
        let rows = report.backend_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].backend, "tuned_native");
        assert_eq!(rows[0].scenarios, 2, "the flat scenario must not count");
        // mean of 10/10 and 10/20 rps.
        assert!((rows[0].mean_throughput_rps - 0.75).abs() < 1e-12);
        assert!((rows[0].mean_min_attainment - 0.9).abs() < 1e-12);
        assert_eq!(rows[1].backend, "generic_torch");
        assert!((rows[1].mean_throughput_rps - 0.25).abs() < 1e-12);
        let json = report.to_json();
        assert!(json.contains("\"backends\": ["), "{json}");
        assert!(json.contains("\"mean_throughput_rps\""), "{json}");
        assert!(json.contains("\"backend\": \"generic_torch\""), "{json}");
    }

    #[test]
    fn chaos_rows_pair_twins_and_skip_fault_free_scenarios() {
        // Synthetic outcomes: one chaos static/adaptive pair, one fault-free
        // adaptive pair (must stay out of the chaos table), and one orphan
        // chaos adaptive scenario with no twin (skipped).
        let outcome = |name: &str, chaos: &str, mode: &str, att: f64, reconfs: usize| {
            ScenarioOutcome {
                name: name.into(),
                mix: "chat+imagegen".into(),
                strategy: "slo_aware".into(),
                arrival: "closed".into(),
                testbed: "intel_server".into(),
                server_mode: mode.into(),
                workflow: "flat".into(),
                backend: "tuned_native".into(),
                backend_ablation: false,
                chaos: chaos.into(),
                status: ScenarioStatus::Ok,
                error: None,
                retried: false,
                seed: 1,
                makespan: 10.0,
                e2e_latency: 10.0,
                e2e_slo_met: None,
                critical_path: String::new(),
                trace_digest: 0,
                min_attainment: att,
                max_attainment: att,
                fairness_spread: 0.0,
                reconfigurations: reconfs,
                apps: vec![],
            }
        };
        let report = MatrixReport {
            seed: 1,
            scenarios: vec![
                outcome("chaos=thermal_throttle/x/server=static", "thermal_throttle", "static", 0.4, 0),
                outcome("chaos=thermal_throttle/x/server=adaptive", "thermal_throttle", "adaptive", 0.9, 3),
                outcome("mix=chat/y/server=static", "none", "static", 1.0, 0),
                outcome("mix=chat/y/server=adaptive", "none", "adaptive", 1.0, 0),
                outcome("chaos=suspend/z/server=adaptive", "suspend", "adaptive", 0.7, 1),
            ],
        };
        let rows = report.chaos_rows();
        assert_eq!(rows.len(), 1, "only the twinned chaos pair");
        let r = &rows[0];
        assert_eq!(r.chaos, "thermal_throttle");
        assert_eq!(r.base, "chaos=thermal_throttle/x");
        assert!((r.delta - 0.5).abs() < 1e-12);
        assert_eq!(r.reconfigurations, 3);
        let json = report.to_json();
        assert!(json.contains("\"chaos\": [\n"), "{json}");
        assert!(json.contains("\"chaos\": \"thermal_throttle\""), "{json}");
        assert!(json.contains("\"chaos\": \"none\""), "{json}");
        // Both twinned pairs (chaos and fault-free) still show up in
        // adaptive_vs_static; the orphan is skipped there too.
        assert_eq!(report.adaptive_deltas().len(), 2);
    }

    #[test]
    fn summary_table_lists_every_scenario() {
        let report = run_matrix(&tiny_axes(7)).unwrap();
        let table = report.summary_table();
        assert_eq!(table.lines().count(), 1 + report.scenarios.len());
    }

    #[test]
    fn parallel_jobs_match_sequential_byte_for_byte() {
        let axes = tiny_axes(42);
        let sequential = run_matrix_jobs(&axes, 1).unwrap().to_json();
        let parallel = run_matrix_jobs(&axes, 2).unwrap().to_json();
        assert_eq!(sequential, parallel, "jobs must not change the report");
        // More workers than scenarios is fine (pool clamps to the matrix).
        let oversubscribed = run_matrix_jobs(&axes, 64).unwrap().to_json();
        assert_eq!(sequential, oversubscribed);
    }

    #[test]
    fn panicking_scenario_is_quarantined_and_siblings_complete() {
        let mut specs = tiny_axes(42).expand();
        specs[0].inject_failure = Some(InjectFailure::Panic);
        let opts = SweepOptions {
            jobs: 1,
            ..SweepOptions::default()
        };
        let report = run_specs_supervised(&specs, 42, &opts).unwrap();
        assert_eq!(report.scenarios.len(), specs.len());
        let bad = &report.scenarios[0];
        assert_eq!(bad.status, ScenarioStatus::Panicked);
        assert!(bad.retried, "a panic gets exactly one retry");
        assert!(bad.error.as_deref().unwrap().contains("injected failure"));
        for s in &report.scenarios[1..] {
            assert_eq!(s.status, ScenarioStatus::Ok, "siblings must complete");
        }
        let json = report.to_json();
        assert!(json.contains("\"panicked\": 1"), "{json}");
        assert!(json.contains("\"status\": \"panicked\""), "{json}");
        // Quarantined rows render nulls, never placeholder measurements.
        assert!(json.contains("\"trace_digest\": null"), "{json}");
        // Byte-identity holds with a quarantined row in the sweep.
        let wide = SweepOptions {
            jobs: 4,
            ..SweepOptions::default()
        };
        assert_eq!(json, run_specs_supervised(&specs, 42, &wide).unwrap().to_json());
        assert_eq!(json, run_specs_supervised(&specs, 42, &opts).unwrap().to_json());
    }

    #[test]
    fn budget_exhaustion_is_deterministic_and_not_retried() {
        let mut specs = tiny_axes(42).expand();
        specs[1].budget_events = Some(5);
        let opts = SweepOptions::default();
        let report = run_specs_supervised(&specs, 42, &opts).unwrap();
        let bad = &report.scenarios[1];
        assert_eq!(bad.status, ScenarioStatus::BudgetExhausted);
        assert!(!bad.retried, "deterministic exhaustion is never retried");
        assert!(bad.error.as_deref().unwrap().contains("budget exhausted"));
        assert_eq!(report.scenarios[0].status, ScenarioStatus::Ok);
        let again = run_specs_supervised(&specs, 42, &opts).unwrap();
        assert_eq!(report.to_json(), again.to_json());
    }

    #[test]
    fn fail_fast_aborts_and_skips_the_tail() {
        let mut specs = tiny_axes(42).expand();
        specs[0].inject_failure = Some(InjectFailure::Error);
        let opts = SweepOptions {
            jobs: 1,
            fail_fast: true,
            ..SweepOptions::default()
        };
        let report = run_specs_supervised(&specs, 42, &opts).unwrap();
        assert_eq!(report.scenarios[0].status, ScenarioStatus::Failed);
        assert_eq!(report.scenarios[1].status, ScenarioStatus::Skipped);
        // The legacy wrapper surfaces the lowest-index failure as an error.
        let err = run_specs_jobs(&specs, 42, 1).unwrap_err().to_string();
        assert!(err.contains("scenario `"), "{err}");
        assert!(err.contains("failed"), "{err}");
    }

    #[test]
    fn journal_resume_reproduces_the_report_byte_for_byte() {
        let specs = tiny_axes(42).expand();
        let path = tmp_path("resume");
        let _ = std::fs::remove_file(&path);
        let straight = run_specs_supervised(
            &specs,
            42,
            &SweepOptions {
                jobs: 1,
                journal: Some(path.clone()),
                ..SweepOptions::default()
            },
        )
        .unwrap()
        .to_json();
        // Full journal: resume executes nothing and reproduces the report.
        let resumed = run_specs_supervised(
            &specs,
            42,
            &SweepOptions {
                jobs: 2,
                journal: Some(path.clone()),
                resume: true,
                ..SweepOptions::default()
            },
        )
        .unwrap()
        .to_json();
        assert_eq!(straight, resumed);
        // Killed mid-write: keep the first line plus a truncated tail of the
        // second — the partial line is discarded, its scenario re-executed.
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let first = lines.next().unwrap();
        let second = lines.next().unwrap();
        std::fs::write(&path, format!("{first}\n{}", &second[..second.len() / 2])).unwrap();
        let recovered = run_specs_supervised(
            &specs,
            42,
            &SweepOptions {
                jobs: 1,
                journal: Some(path.clone()),
                resume: true,
                ..SweepOptions::default()
            },
        )
        .unwrap()
        .to_json();
        assert_eq!(straight, recovered);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stale_journal_entries_are_ignored() {
        let specs = tiny_axes(42).expand();
        let path = tmp_path("stale");
        let _ = std::fs::remove_file(&path);
        let straight = run_specs_supervised(
            &specs,
            42,
            &SweepOptions {
                journal: Some(path.clone()),
                ..SweepOptions::default()
            },
        )
        .unwrap()
        .to_json();
        // Tamper the first scenario's spec digest: the entry no longer
        // matches the spec that produced it and must be re-executed.
        let marker = format!("\"spec_digest\": \"{}\"", spec_digest_hex(&specs[0]));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(&marker));
        std::fs::write(
            &path,
            text.replacen(&marker, "\"spec_digest\": \"0000000000000000\"", 1),
        )
        .unwrap();
        let resumed = run_specs_supervised(
            &specs,
            42,
            &SweepOptions {
                journal: Some(path.clone()),
                resume: true,
                ..SweepOptions::default()
            },
        )
        .unwrap()
        .to_json();
        assert_eq!(straight, resumed);
        let _ = std::fs::remove_file(&path);
    }
}
