//! Matrix execution and the aggregate, machine-readable report.
//!
//! Each [`ScenarioSpec`] is rendered to YAML, parsed, and executed through
//! the regular coordinator pipeline (`config → dag → executor`), so the
//! matrix exercises exactly the code paths a hand-written config would.
//! Per scenario the runner aggregates SLO attainment, p50/p99 latency,
//! fairness (min/max attainment spread across SLO-bearing apps), and the
//! engine's trace digest; [`MatrixReport::to_json`] renders everything as a
//! deterministic JSON document — byte-identical across runs with the same
//! seed, which the golden-trace tests pin.
//!
//! # Parallel deterministic execution
//!
//! Scenarios are mutually independent: each one builds its own engine from
//! `(spec, seed)` and shares no mutable state, so [`run_matrix_jobs`] farms
//! the expansion across a work-stealing pool of scoped threads (an atomic
//! cursor over the spec list — idle workers steal the next undone index).
//! Workers may finish in any order; outcomes land in their canonical slot
//! and the report is assembled in matrix-expansion order, so the JSON is
//! **byte-identical for `--jobs 1` and `--jobs N`**. Errors are surfaced
//! deterministically too: the failure at the lowest canonical index wins.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::apps::Slo;
use crate::coordinator::{run_config_text, ScenarioResult};
use crate::gpusim::engine::trace_digest;
use crate::scenario::matrix::{
    backend_key, chaos_key, server_mode_key, strategy_key, testbed_key, workflow_key,
    MatrixAxes, ScenarioSpec,
};
use crate::util::json::{json_num, json_opt_bool, json_opt_num, json_str};
use crate::util::stats::Summary;

/// Aggregated result of one application node inside a scenario.
#[derive(Debug, Clone)]
pub struct AppOutcome {
    pub node: String,
    pub app: String,
    pub requests: usize,
    /// Whether the application carries an SLO (DeepResearch does not).
    pub has_slo: bool,
    /// `None` when no requests completed (rendered `null`, never 100%).
    pub attainment: Option<f64>,
    pub mean_normalized: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub failed: Option<String>,
}

/// Aggregated result of one scenario.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub name: String,
    pub mix: String,
    pub strategy: String,
    pub arrival: String,
    pub testbed: String,
    /// `static` | `adaptive` — the serving-configuration axis.
    pub server_mode: String,
    /// Workflow-shape axis: `flat` for app-mix scenarios, otherwise the
    /// generated DAG shape (`pipeline`, `fanout`, `diamond`,
    /// `content_creation`).
    pub workflow: String,
    /// Kernel-backend axis: `tuned_native` | `generic_torch` |
    /// `fused_custom` (everything outside the ablation slice runs tuned).
    pub backend: String,
    /// Whether the scenario belongs to the backend-ablation slice (the
    /// population `summary.backends` aggregates over).
    pub backend_ablation: bool,
    /// Chaos axis: `none` for fault-free scenarios, otherwise the injected
    /// fault kind (`thermal_throttle`, `vram_ballast`, `suspend`,
    /// `server_crash`, `pcie_degrade`).
    pub chaos: String,
    pub seed: u64,
    pub makespan: f64,
    /// End-to-end workflow latency (latest foreground-node completion).
    pub e2e_latency: f64,
    /// `e2e_latency <= workflow_slo`; `None` when no bound is configured.
    pub e2e_slo_met: Option<bool>,
    /// Critical-path attribution (`a -> b -> c`): which nodes bounded the
    /// run, root to sink.
    pub critical_path: String,
    /// FNV-1a digest of the canonical engine trace — the golden fingerprint.
    pub trace_digest: u64,
    pub min_attainment: f64,
    pub max_attainment: f64,
    /// max − min attainment across SLO-bearing apps (0 = perfectly fair).
    pub fairness_spread: f64,
    /// Runtime reconfigurations applied by the adaptive controller (0 for
    /// static scenarios).
    pub reconfigurations: usize,
    pub apps: Vec<AppOutcome>,
}

/// The aggregate report over a whole matrix.
#[derive(Debug, Clone)]
pub struct MatrixReport {
    pub seed: u64,
    pub scenarios: Vec<ScenarioOutcome>,
}

/// Execute one scenario spec through the coordinator.
pub fn run_scenario(spec: &ScenarioSpec) -> Result<ScenarioOutcome> {
    let yaml = spec.to_yaml();
    let result = run_config_text(&yaml, None)
        .with_context(|| format!("scenario `{}`", spec.name))?;
    Ok(outcome_from(spec, &result))
}

/// Execute every scenario of the matrix in expansion order (single worker).
pub fn run_matrix(axes: &MatrixAxes) -> Result<MatrixReport> {
    run_matrix_jobs(axes, 1)
}

/// Execute the matrix on up to `jobs` worker threads.
///
/// The report is assembled in canonical expansion order regardless of which
/// worker finished which scenario first, so the output (and therefore
/// [`MatrixReport::to_json`]) is byte-identical for any `jobs` value. If
/// several scenarios fail, the error of the lowest-index one is returned —
/// also independent of scheduling.
pub fn run_matrix_jobs(axes: &MatrixAxes, jobs: usize) -> Result<MatrixReport> {
    run_specs_jobs(&axes.expand(), axes.seed, jobs)
}

/// Execute an explicit spec list (e.g. a `--filter`ed subset of a matrix)
/// on up to `jobs` workers, with the same canonical-order/byte-identity
/// guarantees as [`run_matrix_jobs`].
pub fn run_specs_jobs(specs: &[ScenarioSpec], seed: u64, jobs: usize) -> Result<MatrixReport> {
    let n = specs.len();
    let jobs = jobs.clamp(1, n.max(1));
    let mut slots: Vec<Option<Result<ScenarioOutcome>>> = (0..n).map(|_| None).collect();
    if jobs <= 1 {
        // Sequential path keeps the old early-abort: the first failure stops
        // the sweep (the assembly loop below surfaces it before reaching any
        // unexecuted slot).
        for (slot, spec) in slots.iter_mut().zip(specs) {
            let outcome = run_scenario(spec);
            let failed = outcome.is_err();
            *slot = Some(outcome);
            if failed {
                break;
            }
        }
    } else {
        // Work-stealing over the canonical spec order: a shared atomic
        // cursor hands the next undone index to whichever worker is idle.
        // A failure cancels further stealing (in-flight scenarios finish);
        // because indices are claimed in order, every index below the first
        // failure has still been executed, so the lowest-index-error rule
        // of the assembly loop below is unaffected.
        let cursor = AtomicUsize::new(0);
        let cancel = AtomicBool::new(false);
        let finished: Mutex<Vec<(usize, Result<ScenarioOutcome>)>> =
            Mutex::new(Vec::with_capacity(n));
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        if cancel.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let outcome = run_scenario(&specs[i]);
                        if outcome.is_err() {
                            cancel.store(true, Ordering::Relaxed);
                        }
                        local.push((i, outcome));
                    }
                    finished.lock().unwrap().extend(local);
                });
            }
        });
        for (i, outcome) in finished.into_inner().unwrap() {
            slots[i] = Some(outcome);
        }
    }
    let mut scenarios = Vec::with_capacity(n);
    for (i, slot) in slots.into_iter().enumerate() {
        let outcome = slot.unwrap_or_else(|| panic!("scenario {i} was never executed"));
        scenarios.push(outcome?);
    }
    Ok(MatrixReport { seed, scenarios })
}

fn outcome_from(spec: &ScenarioSpec, result: &ScenarioResult) -> ScenarioOutcome {
    let apps: Vec<AppOutcome> = result
        .nodes
        .iter()
        .map(|n| {
            let lats: Vec<f64> = n.metrics.iter().map(|m| m.latency).collect();
            let (p50, p99) = Summary::of(&lats)
                .map(|s| (s.p50, s.p99))
                .unwrap_or((0.0, 0.0));
            AppOutcome {
                node: n.id.clone(),
                app: n.app.to_string(),
                requests: n.metrics.len(),
                has_slo: !matches!(n.slo, Slo::None),
                attainment: n.attainment(),
                mean_normalized: n.mean_normalized(),
                p50_latency: p50,
                p99_latency: p99,
                failed: n.failed.clone(),
            }
        })
        .collect();
    // Fairness over healthy SLO-bearing apps. A failed app (e.g. setup OOM)
    // counts as zero attainment rather than being dropped — otherwise a
    // scenario whose every SLO app failed would report a perfect 100%. An
    // app that ran no requests without failing has no attainment and is
    // excluded.
    let attainments: Vec<f64> = apps
        .iter()
        .filter(|a| a.has_slo)
        .filter_map(|a| {
            if a.failed.is_some() {
                Some(0.0)
            } else {
                a.attainment
            }
        })
        .collect();
    let (min_attainment, max_attainment) = if attainments.is_empty() {
        // No SLO-bearing apps at all (e.g. a DeepResearch-only mix):
        // vacuously met.
        (1.0, 1.0)
    } else {
        (
            attainments.iter().copied().fold(f64::INFINITY, f64::min),
            attainments.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        )
    };
    ScenarioOutcome {
        name: spec.name.clone(),
        mix: spec.mix.name.to_string(),
        strategy: strategy_key(spec.strategy).to_string(),
        arrival: spec.arrival.name().to_string(),
        testbed: testbed_key(spec.testbed).to_string(),
        server_mode: server_mode_key(spec.server_mode).to_string(),
        workflow: workflow_key(spec.workflow).to_string(),
        backend: backend_key(spec.backend).to_string(),
        backend_ablation: spec.backend_ablation,
        chaos: spec
            .chaos
            .map(|k| chaos_key(k).to_string())
            .unwrap_or_else(|| "none".to_string()),
        seed: spec.seed,
        makespan: result.makespan,
        e2e_latency: result.workflow.e2e_latency,
        e2e_slo_met: result.workflow.e2e_slo_met,
        critical_path: result.workflow.critical_path_str(),
        trace_digest: trace_digest(&result.trace),
        min_attainment,
        max_attainment,
        fairness_spread: max_attainment - min_attainment,
        reconfigurations: result.reconfigurations,
        apps,
    }
}

/// One static/adaptive scenario pair and its attainment delta — the
/// measurable value of runtime adaptation (ISSUE 3 acceptance metric).
#[derive(Debug, Clone)]
pub struct AdaptiveDelta {
    /// Scenario name without the `/server=…` suffix.
    pub base: String,
    pub static_min_attainment: f64,
    pub adaptive_min_attainment: f64,
    /// adaptive − static min-attainment (positive = adaptation helped).
    pub delta: f64,
    /// Reconfigurations the adaptive run applied.
    pub reconfigurations: usize,
}

/// Aggregate of one kernel backend over the ablation slice — the
/// `summary.backends` comparison of request throughput and SLO attainment
/// per kernel implementation (the §6 tuned-vs-generic claim as a report
/// section).
#[derive(Debug, Clone)]
pub struct BackendRow {
    /// Backend key (`tuned_native`, `generic_torch`, `fused_custom`).
    pub backend: String,
    /// Ablation scenarios aggregated into this row.
    pub scenarios: usize,
    /// Mean of per-scenario completed-requests / makespan (requests/s).
    pub mean_throughput_rps: f64,
    /// Mean per-scenario min attainment across SLO-bearing apps.
    pub mean_min_attainment: f64,
}

/// One static/adaptive pair of the chaos slice and its attainment delta —
/// the `summary.chaos` measurement of how much runtime adaptation buys back
/// under each injected fault class (ISSUE 6 acceptance metric).
#[derive(Debug, Clone)]
pub struct ChaosRow {
    /// Injected fault kind (`thermal_throttle`, `server_crash`, …).
    pub chaos: String,
    /// Scenario name without the `/server=…` suffix.
    pub base: String,
    pub static_min_attainment: f64,
    pub adaptive_min_attainment: f64,
    /// adaptive − static min-attainment under the fault (positive =
    /// adaptation recovered attainment the static config lost).
    pub delta: f64,
    /// Reconfigurations the adaptive run applied while faults landed.
    pub reconfigurations: usize,
}

/// Aggregate of one (workflow shape, strategy) cell — the `summary.workflows`
/// comparison of end-to-end latency across strategies (which reproduces the
/// paper's finding that greedy allocation stretches the critical path while
/// SLO-aware scheduling shortens it).
#[derive(Debug, Clone)]
pub struct WorkflowRow {
    /// Shape key (`pipeline`, `fanout`, `diamond`, `content_creation`).
    pub workflow: String,
    pub strategy: String,
    /// Scenarios in this cell (testbed × server-mode variants).
    pub scenarios: usize,
    pub mean_e2e_latency: f64,
    /// Fraction of the cell's scenarios meeting their `workflow_slo`.
    pub e2e_slo_attainment: f64,
}

impl MatrixReport {
    /// Distinct strategies present, in first-seen order.
    pub fn strategies(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for s in &self.scenarios {
            if !out.contains(&s.strategy.as_str()) {
                out.push(&s.strategy);
            }
        }
        out
    }

    /// Per-(shape, strategy) end-to-end aggregates over the workflow slice,
    /// in first-seen (canonical) order. Empty when the matrix carries no
    /// workflow scenarios.
    pub fn workflow_rows(&self) -> Vec<WorkflowRow> {
        let mut keys: Vec<(&str, &str)> = Vec::new();
        for s in &self.scenarios {
            if s.workflow == "flat" {
                continue;
            }
            let key = (s.workflow.as_str(), s.strategy.as_str());
            if !keys.contains(&key) {
                keys.push(key);
            }
        }
        keys.into_iter()
            .map(|(wf, strat)| {
                let rows: Vec<&ScenarioOutcome> = self
                    .scenarios
                    .iter()
                    .filter(|s| s.workflow == wf && s.strategy == strat)
                    .collect();
                let n = rows.len().max(1) as f64;
                let met = rows
                    .iter()
                    .filter(|r| r.e2e_slo_met == Some(true))
                    .count() as f64;
                WorkflowRow {
                    workflow: wf.to_string(),
                    strategy: strat.to_string(),
                    scenarios: rows.len(),
                    mean_e2e_latency: rows.iter().map(|r| r.e2e_latency).sum::<f64>() / n,
                    e2e_slo_attainment: met / n,
                }
            })
            .collect()
    }

    /// Per-backend throughput/attainment aggregates over the
    /// backend-ablation slice, in first-seen (canonical) order. Empty when
    /// the matrix carries no ablation scenarios. Restricted to the slice —
    /// the rest of the matrix runs tuned by construction and would swamp
    /// the comparison.
    pub fn backend_rows(&self) -> Vec<BackendRow> {
        let mut keys: Vec<&str> = Vec::new();
        for s in &self.scenarios {
            if s.backend_ablation && !keys.contains(&s.backend.as_str()) {
                keys.push(&s.backend);
            }
        }
        keys.into_iter()
            .map(|key| {
                let rows: Vec<&ScenarioOutcome> = self
                    .scenarios
                    .iter()
                    .filter(|s| s.backend_ablation && s.backend == key)
                    .collect();
                let n = rows.len().max(1) as f64;
                let throughput = |r: &ScenarioOutcome| -> f64 {
                    let requests: usize = r.apps.iter().map(|a| a.requests).sum();
                    if r.makespan > 0.0 {
                        requests as f64 / r.makespan
                    } else {
                        0.0
                    }
                };
                BackendRow {
                    backend: key.to_string(),
                    scenarios: rows.len(),
                    mean_throughput_rps: rows.iter().map(|r| throughput(r)).sum::<f64>() / n,
                    mean_min_attainment: rows.iter().map(|r| r.min_attainment).sum::<f64>() / n,
                }
            })
            .collect()
    }

    /// Pair every adaptive scenario with its static twin (same axes, only
    /// the server mode differs), in canonical order.
    pub fn adaptive_deltas(&self) -> Vec<AdaptiveDelta> {
        let mut out = Vec::new();
        for s in &self.scenarios {
            if s.server_mode != "adaptive" {
                continue;
            }
            let base = s
                .name
                .strip_suffix("/server=adaptive")
                .unwrap_or(&s.name)
                .to_string();
            let twin_name = format!("{base}/server=static");
            let Some(twin) = self.scenarios.iter().find(|t| t.name == twin_name) else {
                continue;
            };
            out.push(AdaptiveDelta {
                base,
                static_min_attainment: twin.min_attainment,
                adaptive_min_attainment: s.min_attainment,
                delta: s.min_attainment - twin.min_attainment,
                reconfigurations: s.reconfigurations,
            });
        }
        out
    }

    /// Pair every adaptive chaos scenario with its static twin, in canonical
    /// order. Restricted to the chaos slice — fault-free pairs are already
    /// covered by [`MatrixReport::adaptive_deltas`], and mixing regimes
    /// would hide what adaptation buys back specifically under faults.
    pub fn chaos_rows(&self) -> Vec<ChaosRow> {
        let mut out = Vec::new();
        for s in &self.scenarios {
            if s.chaos == "none" || s.server_mode != "adaptive" {
                continue;
            }
            let base = s
                .name
                .strip_suffix("/server=adaptive")
                .unwrap_or(&s.name)
                .to_string();
            let twin_name = format!("{base}/server=static");
            let Some(twin) = self.scenarios.iter().find(|t| t.name == twin_name) else {
                continue;
            };
            out.push(ChaosRow {
                chaos: s.chaos.clone(),
                base,
                static_min_attainment: twin.min_attainment,
                adaptive_min_attainment: s.min_attainment,
                delta: s.min_attainment - twin.min_attainment,
                reconfigurations: s.reconfigurations,
            });
        }
        out
    }

    /// Deterministic JSON rendering of the whole report.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\n");
        out.push_str("  \"consumerbench_scenario_matrix\": 1,\n");
        out.push_str(&format!("  \"seed\": {},\n", self.seed));
        out.push_str(&format!(
            "  \"num_scenarios\": {},\n",
            self.scenarios.len()
        ));
        out.push_str("  \"scenarios\": [\n");
        for (i, s) in self.scenarios.iter().enumerate() {
            out.push_str("    {\n");
            out.push_str(&format!("      \"name\": {},\n", json_str(&s.name)));
            out.push_str(&format!("      \"mix\": {},\n", json_str(&s.mix)));
            out.push_str(&format!("      \"strategy\": {},\n", json_str(&s.strategy)));
            out.push_str(&format!("      \"arrival\": {},\n", json_str(&s.arrival)));
            out.push_str(&format!("      \"testbed\": {},\n", json_str(&s.testbed)));
            out.push_str(&format!(
                "      \"server_mode\": {},\n",
                json_str(&s.server_mode)
            ));
            out.push_str(&format!(
                "      \"workflow\": {},\n",
                json_str(&s.workflow)
            ));
            out.push_str(&format!(
                "      \"backend\": {},\n",
                json_str(&s.backend)
            ));
            out.push_str(&format!("      \"chaos\": {},\n", json_str(&s.chaos)));
            out.push_str(&format!(
                "      \"reconfigurations\": {},\n",
                s.reconfigurations
            ));
            out.push_str(&format!("      \"seed\": {},\n", s.seed));
            out.push_str(&format!(
                "      \"makespan_s\": {},\n",
                json_num(s.makespan)
            ));
            out.push_str(&format!(
                "      \"e2e_latency_s\": {},\n",
                json_num(s.e2e_latency)
            ));
            out.push_str(&format!(
                "      \"e2e_slo_met\": {},\n",
                json_opt_bool(s.e2e_slo_met)
            ));
            out.push_str(&format!(
                "      \"critical_path\": {},\n",
                json_str(&s.critical_path)
            ));
            out.push_str(&format!(
                "      \"trace_digest\": \"{:016x}\",\n",
                s.trace_digest
            ));
            out.push_str(&format!(
                "      \"min_attainment\": {},\n",
                json_num(s.min_attainment)
            ));
            out.push_str(&format!(
                "      \"max_attainment\": {},\n",
                json_num(s.max_attainment)
            ));
            out.push_str(&format!(
                "      \"fairness_spread\": {},\n",
                json_num(s.fairness_spread)
            ));
            out.push_str("      \"apps\": [\n");
            for (j, a) in s.apps.iter().enumerate() {
                out.push_str("        {");
                out.push_str(&format!("\"node\": {}, ", json_str(&a.node)));
                out.push_str(&format!("\"app\": {}, ", json_str(&a.app)));
                out.push_str(&format!("\"requests\": {}, ", a.requests));
                out.push_str(&format!("\"has_slo\": {}, ", a.has_slo));
                out.push_str(&format!(
                    "\"attainment\": {}, ",
                    json_opt_num(a.attainment)
                ));
                out.push_str(&format!(
                    "\"mean_normalized\": {}, ",
                    json_num(a.mean_normalized)
                ));
                out.push_str(&format!("\"p50_latency_s\": {}, ", json_num(a.p50_latency)));
                out.push_str(&format!("\"p99_latency_s\": {}, ", json_num(a.p99_latency)));
                match &a.failed {
                    Some(e) => out.push_str(&format!("\"failed\": {}", json_str(e))),
                    None => out.push_str("\"failed\": null"),
                }
                out.push('}');
                out.push_str(if j + 1 < s.apps.len() { ",\n" } else { "\n" });
            }
            out.push_str("      ]\n");
            out.push_str("    }");
            out.push_str(if i + 1 < self.scenarios.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ],\n");
        out.push_str("  \"summary\": {\n");
        out.push_str("    \"by_strategy\": [\n");
        let strategies = self.strategies();
        for (i, strat) in strategies.iter().enumerate() {
            let rows: Vec<&ScenarioOutcome> = self
                .scenarios
                .iter()
                .filter(|s| s.strategy == *strat)
                .collect();
            let avg = |vals: Vec<f64>| -> f64 {
                if vals.is_empty() {
                    0.0
                } else {
                    vals.iter().sum::<f64>() / vals.len() as f64
                }
            };
            let mean_min = avg(rows.iter().map(|r| r.min_attainment).collect());
            let mean_spread = avg(rows.iter().map(|r| r.fairness_spread).collect());
            let mean_makespan = avg(rows.iter().map(|r| r.makespan).collect());
            out.push_str(&format!(
                "      {{\"strategy\": {}, \"scenarios\": {}, \"mean_min_attainment\": {}, \"mean_fairness_spread\": {}, \"mean_makespan_s\": {}}}",
                json_str(strat),
                rows.len(),
                json_num(mean_min),
                json_num(mean_spread),
                json_num(mean_makespan),
            ));
            out.push_str(if i + 1 < strategies.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ],\n");
        out.push_str("    \"workflows\": [\n");
        let wf_rows = self.workflow_rows();
        for (i, w) in wf_rows.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"workflow\": {}, \"strategy\": {}, \"scenarios\": {}, \"mean_e2e_latency_s\": {}, \"e2e_slo_attainment\": {}}}",
                json_str(&w.workflow),
                json_str(&w.strategy),
                w.scenarios,
                json_num(w.mean_e2e_latency),
                json_num(w.e2e_slo_attainment),
            ));
            out.push_str(if i + 1 < wf_rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ],\n");
        out.push_str("    \"backends\": [\n");
        let b_rows = self.backend_rows();
        for (i, b) in b_rows.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"backend\": {}, \"scenarios\": {}, \"mean_throughput_rps\": {}, \"mean_min_attainment\": {}}}",
                json_str(&b.backend),
                b.scenarios,
                json_num(b.mean_throughput_rps),
                json_num(b.mean_min_attainment),
            ));
            out.push_str(if i + 1 < b_rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ],\n");
        out.push_str("    \"adaptive_vs_static\": [\n");
        let deltas = self.adaptive_deltas();
        for (i, d) in deltas.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"scenario\": {}, \"static_min_attainment\": {}, \"adaptive_min_attainment\": {}, \"attainment_delta\": {}, \"reconfigurations\": {}}}",
                json_str(&d.base),
                json_num(d.static_min_attainment),
                json_num(d.adaptive_min_attainment),
                json_num(d.delta),
                d.reconfigurations,
            ));
            out.push_str(if i + 1 < deltas.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ],\n");
        out.push_str("    \"chaos\": [\n");
        let c_rows = self.chaos_rows();
        for (i, c) in c_rows.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"chaos\": {}, \"scenario\": {}, \"static_min_attainment\": {}, \"adaptive_min_attainment\": {}, \"attainment_delta\": {}, \"reconfigurations\": {}}}",
                json_str(&c.chaos),
                json_str(&c.base),
                json_num(c.static_min_attainment),
                json_num(c.adaptive_min_attainment),
                json_num(c.delta),
                c.reconfigurations,
            ));
            out.push_str(if i + 1 < c_rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("    ]\n");
        out.push_str("  }\n");
        out.push_str("}\n");
        out
    }

    /// Human-readable summary table (one row per scenario).
    pub fn summary_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<80} {:>9} {:>7} {:>7} {:>6} {:>7}\n",
            "scenario", "makespan", "min-att", "spread", "reconf", "digest"
        ));
        for s in &self.scenarios {
            out.push_str(&format!(
                "{:<80} {:>8.1}s {:>6.0}% {:>7.2} {:>6} {:>7}\n",
                s.name,
                s.makespan,
                s.min_attainment * 100.0,
                s.fairness_spread,
                s.reconfigurations,
                &format!("{:016x}", s.trace_digest)[..7],
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{AppType, Strategy, TestbedKind};
    use crate::gpusim::kernel::Device;
    use crate::scenario::matrix::{AppMix, ArrivalKind, MixEntry, ServerMode};

    fn tiny_axes(seed: u64) -> MatrixAxes {
        MatrixAxes {
            mixes: vec![AppMix {
                name: "captions",
                entries: vec![MixEntry {
                    app: AppType::LiveCaptions,
                    num_requests: 3,
                    device: Device::Gpu,
                }],
            }],
            strategies: vec![Strategy::Greedy, Strategy::FairShare],
            testbeds: vec![TestbedKind::IntelServer],
            arrivals: vec![ArrivalKind::Poisson],
            server_modes: vec![ServerMode::Static, ServerMode::Adaptive],
            workflows: vec![],
            workflow_strategies: vec![],
            backends: vec![],
            backend_strategies: vec![],
            chaos: vec![],
            seed,
        }
    }

    #[test]
    fn tiny_matrix_runs_and_reports() {
        let report = run_matrix(&tiny_axes(42)).unwrap();
        assert_eq!(report.scenarios.len(), 2);
        for s in &report.scenarios {
            assert_eq!(s.apps.len(), 1);
            assert_eq!(s.apps[0].requests, 3);
            assert!(s.makespan > 0.0);
        }
        let json = report.to_json();
        assert!(json.contains("\"consumerbench_scenario_matrix\": 1"));
        assert!(json.contains("\"strategy\": \"greedy\""));
        assert!(json.contains("\"arrival\": \"poisson\""));
        assert!(json.contains("\"server_mode\": \"static\""));
        assert!(json.contains("\"adaptive_vs_static\""));
        assert!(!json.contains("inf"), "non-finite leaked into JSON");
    }

    #[test]
    fn adaptive_deltas_pair_twins_in_canonical_order() {
        // A text mix so both server modes expand.
        let mut axes = MatrixAxes::default_matrix(11);
        axes.mixes = vec![AppMix::chat()];
        axes.strategies.truncate(1);
        axes.arrivals.truncate(1);
        axes.workflows.clear();
        let report = run_matrix(&axes).unwrap();
        assert_eq!(report.scenarios.len(), 2, "one static + one adaptive");
        let deltas = report.adaptive_deltas();
        assert_eq!(deltas.len(), 1);
        let d = &deltas[0];
        assert!(d.base.contains("mix=chat"));
        assert!(!d.base.contains("server="));
        assert_eq!(
            d.delta,
            d.adaptive_min_attainment - d.static_min_attainment
        );
        let json = report.to_json();
        assert!(json.contains("\"attainment_delta\""), "{json}");
    }

    #[test]
    fn failed_slo_app_counts_as_zero_attainment() {
        use crate::coordinator::executor::NodeResult;
        let spec = tiny_axes(1).expand().remove(0);
        let result = ScenarioResult {
            nodes: vec![NodeResult {
                id: "Captions (livecaptions)".into(),
                app: "LiveCaptions",
                slo: Slo::SegmentTime(2.0),
                metrics: vec![],
                ready: 0.0,
                start: 0.0,
                end: 1.0,
                background: false,
                failed: Some("VRAM OOM".into()),
            }],
            workflow: crate::coordinator::WorkflowMetrics::default(),
            trace: crate::gpusim::engine::Trace::new(),
            client_names: vec![],
            makespan: 1.0,
            policy: "greedy".into(),
            pjrt_calls: 0,
            reconfigurations: 0,
            controller_actions: vec![],
            gpu_idle_w: 0.0,
            cpu_idle_w: 0.0,
        };
        let outcome = outcome_from(&spec, &result);
        assert_eq!(outcome.min_attainment, 0.0);
        assert_eq!(outcome.max_attainment, 0.0);
        assert!(outcome.apps[0].failed.is_some());
        // The failed app's own attainment is `null`/absent, not a number —
        // only the fairness aggregate folds it to zero.
        assert_eq!(outcome.apps[0].attainment, None);
    }

    #[test]
    fn workflow_scenarios_report_e2e_and_critical_path() {
        // One DAG shape, greedy only, static only: a fast slice that still
        // exercises the workflow reporting path end-to-end.
        let mut axes = MatrixAxes::default_matrix(3);
        axes.mixes.clear();
        axes.server_modes = vec![ServerMode::Static];
        axes.workflows = vec![crate::scenario::matrix::WorkflowShape::Pipeline];
        axes.workflow_strategies = vec![Strategy::Greedy];
        let report = run_matrix(&axes).unwrap();
        assert_eq!(report.scenarios.len(), 1);
        let s = &report.scenarios[0];
        assert_eq!(s.workflow, "pipeline");
        assert!(s.e2e_latency > 0.0);
        assert!(s.e2e_slo_met.is_some(), "pipeline carries a workflow_slo");
        assert_eq!(
            s.critical_path, "script -> storyboard -> captions",
            "a linear pipeline is its own critical path"
        );
        let rows = report.workflow_rows();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].workflow, "pipeline");
        assert_eq!(rows[0].scenarios, 1);
        assert!((rows[0].mean_e2e_latency - s.e2e_latency).abs() < 1e-12);
        let json = report.to_json();
        assert!(json.contains("\"workflow\": \"pipeline\""), "{json}");
        assert!(json.contains("\"critical_path\": \"script -> storyboard -> captions\""));
        assert!(json.contains("\"e2e_latency_s\""));
        assert!(json.contains("\"workflows\": ["));
    }

    #[test]
    fn backend_rows_aggregate_only_the_ablation_slice() {
        // Synthetic outcomes: two ablation scenarios per backend plus one
        // flat (tuned, non-ablation) scenario that must stay out of the
        // aggregate.
        let outcome = |name: &str, backend: &str, ablation: bool, makespan: f64, att: f64| {
            ScenarioOutcome {
                name: name.into(),
                mix: "chat+imagegen".into(),
                strategy: "greedy".into(),
                arrival: "closed".into(),
                testbed: "intel_server".into(),
                server_mode: "static".into(),
                workflow: "flat".into(),
                backend: backend.into(),
                backend_ablation: ablation,
                chaos: "none".into(),
                seed: 1,
                makespan,
                e2e_latency: makespan,
                e2e_slo_met: None,
                critical_path: String::new(),
                trace_digest: 0,
                min_attainment: att,
                max_attainment: att,
                fairness_spread: 0.0,
                reconfigurations: 0,
                apps: vec![AppOutcome {
                    node: "Chat (chatbot)".into(),
                    app: "Chatbot".into(),
                    requests: 10,
                    has_slo: true,
                    attainment: Some(att),
                    mean_normalized: 0.5,
                    p50_latency: 1.0,
                    p99_latency: 2.0,
                    failed: None,
                }],
            }
        };
        let report = MatrixReport {
            seed: 1,
            scenarios: vec![
                outcome("mix=chat+imagegen/...", "tuned_native", false, 10.0, 0.5),
                outcome("backend=tuned_native/a", "tuned_native", true, 10.0, 1.0),
                outcome("backend=tuned_native/b", "tuned_native", true, 20.0, 0.8),
                outcome("backend=generic_torch/a", "generic_torch", true, 40.0, 0.4),
            ],
        };
        let rows = report.backend_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].backend, "tuned_native");
        assert_eq!(rows[0].scenarios, 2, "the flat scenario must not count");
        // mean of 10/10 and 10/20 rps.
        assert!((rows[0].mean_throughput_rps - 0.75).abs() < 1e-12);
        assert!((rows[0].mean_min_attainment - 0.9).abs() < 1e-12);
        assert_eq!(rows[1].backend, "generic_torch");
        assert!((rows[1].mean_throughput_rps - 0.25).abs() < 1e-12);
        let json = report.to_json();
        assert!(json.contains("\"backends\": ["), "{json}");
        assert!(json.contains("\"mean_throughput_rps\""), "{json}");
        assert!(json.contains("\"backend\": \"generic_torch\""), "{json}");
    }

    #[test]
    fn chaos_rows_pair_twins_and_skip_fault_free_scenarios() {
        // Synthetic outcomes: one chaos static/adaptive pair, one fault-free
        // adaptive pair (must stay out of the chaos table), and one orphan
        // chaos adaptive scenario with no twin (skipped).
        let outcome = |name: &str, chaos: &str, mode: &str, att: f64, reconfs: usize| {
            ScenarioOutcome {
                name: name.into(),
                mix: "chat+imagegen".into(),
                strategy: "slo_aware".into(),
                arrival: "closed".into(),
                testbed: "intel_server".into(),
                server_mode: mode.into(),
                workflow: "flat".into(),
                backend: "tuned_native".into(),
                backend_ablation: false,
                chaos: chaos.into(),
                seed: 1,
                makespan: 10.0,
                e2e_latency: 10.0,
                e2e_slo_met: None,
                critical_path: String::new(),
                trace_digest: 0,
                min_attainment: att,
                max_attainment: att,
                fairness_spread: 0.0,
                reconfigurations: reconfs,
                apps: vec![],
            }
        };
        let report = MatrixReport {
            seed: 1,
            scenarios: vec![
                outcome("chaos=thermal_throttle/x/server=static", "thermal_throttle", "static", 0.4, 0),
                outcome("chaos=thermal_throttle/x/server=adaptive", "thermal_throttle", "adaptive", 0.9, 3),
                outcome("mix=chat/y/server=static", "none", "static", 1.0, 0),
                outcome("mix=chat/y/server=adaptive", "none", "adaptive", 1.0, 0),
                outcome("chaos=suspend/z/server=adaptive", "suspend", "adaptive", 0.7, 1),
            ],
        };
        let rows = report.chaos_rows();
        assert_eq!(rows.len(), 1, "only the twinned chaos pair");
        let r = &rows[0];
        assert_eq!(r.chaos, "thermal_throttle");
        assert_eq!(r.base, "chaos=thermal_throttle/x");
        assert!((r.delta - 0.5).abs() < 1e-12);
        assert_eq!(r.reconfigurations, 3);
        let json = report.to_json();
        assert!(json.contains("\"chaos\": [\n"), "{json}");
        assert!(json.contains("\"chaos\": \"thermal_throttle\""), "{json}");
        assert!(json.contains("\"chaos\": \"none\""), "{json}");
        // Both twinned pairs (chaos and fault-free) still show up in
        // adaptive_vs_static; the orphan is skipped there too.
        assert_eq!(report.adaptive_deltas().len(), 2);
    }

    #[test]
    fn summary_table_lists_every_scenario() {
        let report = run_matrix(&tiny_axes(7)).unwrap();
        let table = report.summary_table();
        assert_eq!(table.lines().count(), 1 + report.scenarios.len());
    }

    #[test]
    fn parallel_jobs_match_sequential_byte_for_byte() {
        let axes = tiny_axes(42);
        let sequential = run_matrix_jobs(&axes, 1).unwrap().to_json();
        let parallel = run_matrix_jobs(&axes, 2).unwrap().to_json();
        assert_eq!(sequential, parallel, "jobs must not change the report");
        // More workers than scenarios is fine (pool clamps to the matrix).
        let oversubscribed = run_matrix_jobs(&axes, 64).unwrap().to_json();
        assert_eq!(sequential, oversubscribed);
    }
}
