//! Scenario-matrix generation: axes → cross-product → runnable configs.
//!
//! An axis point is one of:
//!
//! * **App mix** — which applications run concurrently and with how many
//!   requests each (Table 1 apps in realistic combinations, §4.2/§4.3).
//! * **Scheduling policy** — greedy / equal-partition / fair-share (§3.2).
//! * **Device profile** — which simulated testbed (Intel server RTX 6000,
//!   MacBook M1 Pro).
//! * **Arrival process** — the client model: the apps' built-in closed
//!   loop, a fixed-period open loop, an open-loop Poisson stream (heavy
//!   traffic), or a bursty trace replay.
//! * **Server mode** — for mixes with text apps (Chatbot/DeepResearch),
//!   whether the shared llama.cpp-style server keeps its KV-CPU
//!   configuration frozen (`static`, the paper's §4.2.1 pitfall) or runs
//!   under the adaptive feedback controller (`adaptive`, the §5.2 loop
//!   made live). Mixes without a text app carry no server and only appear
//!   as `static`.
//! * **Kernel backend** — which kernel implementation serves every model
//!   (`tuned_native` llama.cpp-class shapes, `generic_torch` eager
//!   PyTorch, `fused_custom` idealized hand-tuned). Swept as a curated
//!   ablation slice reproducing the paper's §6 tuned-vs-generic claim:
//!   backend scenarios run their apps *directly* (no shared server) so the
//!   comparison isolates the kernel implementation, exactly like the
//!   paper's runtime-vs-runtime measurements.
//! * **Chaos** — deterministic fault injection (thermal throttling, VRAM
//!   ballast, device suspend/resume, server crash + restart, PCIe
//!   degradation). Swept as a curated slice of static-vs-adaptive pairs
//!   under each fault class, so the report answers "which faults does the
//!   adaptive serving layer actually absorb?". Fault schedules derive from
//!   the scenario seed — the same seed replays byte-identically.
//!
//! [`MatrixAxes::expand`] enumerates the cross-product in a fixed order and
//! renders each point as a YAML workflow configuration understood by
//! [`crate::coordinator::config::BenchConfig`], so every generated scenario
//! is also a valid hand-runnable config (`consumerbench scenario --dump`
//! writes them out).

use crate::coordinator::config::{AppType, InjectFailure, Strategy, TestbedKind};
use crate::gpusim::backend::KernelBackend;
use crate::gpusim::chaos::{ChaosConfig, ChaosKind};
use crate::gpusim::kernel::Device;
use crate::gpusim::queue::QueueBackend;
use crate::gpusim::trace::TraceMode;
use crate::util::rng::Rng;

// `backend_key`/`chaos_key` live next to the other axis-key helpers they
// are used with.
pub use crate::gpusim::backend::backend_key;
pub use crate::gpusim::chaos::chaos_key;

/// One application instance inside a mix.
#[derive(Debug, Clone)]
pub struct MixEntry {
    pub app: AppType,
    pub num_requests: usize,
    pub device: Device,
}

/// A named set of concurrently running applications.
#[derive(Debug, Clone)]
pub struct AppMix {
    pub name: &'static str,
    pub entries: Vec<MixEntry>,
}

impl AppMix {
    /// Whether the mix contains an app that can route through a shared
    /// text-model server (the `server_mode` axis only applies to these).
    pub fn has_text_app(&self) -> bool {
        self.entries
            .iter()
            .any(|e| matches!(e.app, AppType::Chatbot | AppType::DeepResearch))
    }

    fn entry(app: AppType, num_requests: usize, device: Device) -> MixEntry {
        MixEntry {
            app,
            num_requests,
            device,
        }
    }

    /// Single latency-sensitive chat client (the exclusive baseline).
    pub fn chat() -> AppMix {
        AppMix {
            name: "chat",
            entries: vec![Self::entry(AppType::Chatbot, 3, Device::Gpu)],
        }
    }

    /// Chat sharing the GPU with a bulk image generator (§4.2 contention).
    pub fn chat_imagegen() -> AppMix {
        AppMix {
            name: "chat+imagegen",
            entries: vec![
                Self::entry(AppType::Chatbot, 3, Device::Gpu),
                Self::entry(AppType::ImageGen, 2, Device::Gpu),
            ],
        }
    }

    /// The paper's starvation pair: tiny-kernel captions vs. device-filling
    /// diffusion steps (Fig. 5).
    pub fn captions_imagegen() -> AppMix {
        AppMix {
            name: "captions+imagegen",
            entries: vec![
                Self::entry(AppType::LiveCaptions, 6, Device::Gpu),
                Self::entry(AppType::ImageGen, 2, Device::Gpu),
            ],
        }
    }

    /// All four Table 1 applications at once; DeepResearch runs on the CPU
    /// (the Fig. 2 placement) so the three GPU apps fit in VRAM together.
    pub fn full_stack() -> AppMix {
        AppMix {
            name: "full-stack",
            entries: vec![
                Self::entry(AppType::Chatbot, 2, Device::Gpu),
                Self::entry(AppType::ImageGen, 2, Device::Gpu),
                Self::entry(AppType::LiveCaptions, 4, Device::Gpu),
                Self::entry(AppType::DeepResearch, 1, Device::Cpu),
            ],
        }
    }
}

/// Arrival-process axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Application built-in client models (closed loop / audio cadence).
    Closed,
    /// Fixed-period open loop per app.
    Periodic,
    /// Open-loop Poisson stream per app — the heavy-traffic regime.
    Poisson,
    /// Bursty recorded-trace replay per app.
    TraceReplay,
}

impl ArrivalKind {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Closed => "closed",
            ArrivalKind::Periodic => "periodic",
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::TraceReplay => "trace",
        }
    }
}

/// Server-mode axis: how the shared text-model server is configured for
/// mixes containing Chatbot/DeepResearch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerMode {
    /// The §4.2.1 pitfall: a KV-CPU server configuration frozen for the
    /// run (text apps still share the server — only adaptation is off).
    Static,
    /// Same starting configuration plus the feedback controller, which may
    /// migrate the KV cache, adjust the SM reservation, or resize slots at
    /// runtime.
    Adaptive,
}

/// Stable key for a server mode in scenario names and YAML.
pub fn server_mode_key(m: ServerMode) -> &'static str {
    match m {
        ServerMode::Static => "static",
        ServerMode::Adaptive => "adaptive",
    }
}

/// Workflow-shape axis (§3.2's customizable multi-application workflows):
/// generated DAG shapes executed through the same `workflows:` config
/// machinery as hand-written runs, and reported with end-to-end latency,
/// e2e SLO attainment, and critical-path attribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkflowShape {
    /// No DAG: the flat app-mix scenarios (every task an independent root).
    Flat,
    /// Linear chain: script → storyboard → captions.
    Pipeline,
    /// One root fanning out to three parallel branches.
    Fanout,
    /// Fan-out then join: draft → {art, captions} → publish.
    Diamond,
    /// The paper's content-creation graph (Figs. 2–3): brainstorm (via a
    /// shared KV-CPU llama server) gates the outline, which fans out to
    /// cover art + captions — while two background side tasks contend the
    /// whole time (a deep-research analysis on the same server, and a
    /// b-roll render on the GPU).
    ContentCreation,
}

/// Stable key for a workflow shape in scenario names and reports.
pub fn workflow_key(w: WorkflowShape) -> &'static str {
    match w {
        WorkflowShape::Flat => "flat",
        WorkflowShape::Pipeline => "pipeline",
        WorkflowShape::Fanout => "fanout",
        WorkflowShape::Diamond => "diamond",
        WorkflowShape::ContentCreation => "content_creation",
    }
}

/// One node of a generated workflow shape.
struct WfNodeDef {
    id: &'static str,
    label: &'static str,
    app: AppType,
    num_requests: usize,
    device: Device,
    /// Route through the shared llama server (text apps only).
    server: bool,
    background: bool,
    deps: &'static [&'static str],
}

/// Plain GPU-placed foreground node.
const fn wf(
    id: &'static str,
    label: &'static str,
    app: AppType,
    num_requests: usize,
    deps: &'static [&'static str],
) -> WfNodeDef {
    WfNodeDef {
        id,
        label,
        app,
        num_requests,
        device: Device::Gpu,
        server: false,
        background: false,
        deps,
    }
}

static PIPELINE_NODES: [WfNodeDef; 3] = [
    wf("script", "Script", AppType::Chatbot, 4, &[]),
    wf("storyboard", "Storyboard", AppType::ImageGen, 2, &["script"]),
    wf("captions", "Captions", AppType::LiveCaptions, 6, &["storyboard"]),
];

static FANOUT_NODES: [WfNodeDef; 4] = [
    wf("brief", "Brief", AppType::Chatbot, 3, &[]),
    wf("art", "Art", AppType::ImageGen, 2, &["brief"]),
    wf("captions", "Captions", AppType::LiveCaptions, 6, &["brief"]),
    WfNodeDef {
        id: "research",
        label: "Research",
        app: AppType::DeepResearch,
        num_requests: 1,
        device: Device::Cpu,
        server: false,
        background: false,
        deps: &["brief"],
    },
];

static DIAMOND_NODES: [WfNodeDef; 4] = [
    wf("draft", "Draft", AppType::Chatbot, 3, &[]),
    wf("art", "Art", AppType::ImageGen, 2, &["draft"]),
    wf("captions", "Captions", AppType::LiveCaptions, 6, &["draft"]),
    wf("publish", "Publish", AppType::Chatbot, 2, &["art", "captions"]),
];

// The paper's five content-creation stages (Figs. 2–3). The two
// long-running side tasks are `background: true` — the deep-research
// analysis keeps the shared server busy and the b-roll render keeps the GPU
// busy for the whole run (the greedy-starvation sources), but neither is
// part of the user-perceived brainstorm → outline → {cover art, captions}
// completion, so they are excluded from the e2e latency and critical path.
static CONTENT_CREATION_NODES: [WfNodeDef; 6] = [
    WfNodeDef {
        id: "analysis",
        label: "Analysis",
        app: AppType::DeepResearch,
        num_requests: 1,
        device: Device::Gpu,
        server: true,
        background: true,
        deps: &[],
    },
    WfNodeDef {
        id: "brainstorm",
        label: "Brainstorm",
        app: AppType::Chatbot,
        num_requests: 4,
        device: Device::Gpu,
        server: true,
        background: false,
        deps: &[],
    },
    // 8 requests × 24 denoise steps ≈ the whole foreground chain: the
    // render overlaps brainstorm, outline, and both leaves under every
    // policy, so the greedy-vs-slo_aware comparison measures protection of
    // the text branch, not how much of the run happened to be contended.
    WfNodeDef {
        id: "broll",
        label: "BRoll",
        app: AppType::ImageGen,
        num_requests: 8,
        device: Device::Gpu,
        server: false,
        background: true,
        deps: &[],
    },
    wf("outline", "Outline", AppType::Chatbot, 4, &["brainstorm"]),
    wf("cover_art", "CoverArt", AppType::ImageGen, 2, &["outline"]),
    wf("captions", "Captions", AppType::LiveCaptions, 8, &["outline"]),
];

impl WorkflowShape {
    /// The DAG nodes of a generated shape (empty for `Flat`).
    fn nodes(&self) -> &'static [WfNodeDef] {
        match self {
            WorkflowShape::Flat => &[],
            WorkflowShape::Pipeline => &PIPELINE_NODES,
            WorkflowShape::Fanout => &FANOUT_NODES,
            WorkflowShape::Diamond => &DIAMOND_NODES,
            WorkflowShape::ContentCreation => &CONTENT_CREATION_NODES,
        }
    }

    /// End-to-end `workflow_slo:` bound (seconds) emitted for the shape.
    fn workflow_slo(&self) -> Option<f64> {
        match self {
            WorkflowShape::Flat => None,
            WorkflowShape::Pipeline => Some(120.0),
            WorkflowShape::Fanout => Some(150.0),
            WorkflowShape::Diamond => Some(180.0),
            WorkflowShape::ContentCreation => Some(300.0),
        }
    }

    /// Whether the shape routes text nodes through the shared llama server
    /// (gates the adaptive server mode, exactly like `has_text_app` gates
    /// it for flat mixes).
    pub fn has_server(&self) -> bool {
        self.nodes().iter().any(|n| n.server)
    }

    /// The shape's applications as an [`AppMix`] (one entry per DAG node),
    /// so workflow scenarios carry the same mix metadata as flat ones.
    fn mix(&self) -> AppMix {
        AppMix {
            name: workflow_key(*self),
            entries: self
                .nodes()
                .iter()
                .map(|n| MixEntry {
                    app: n.app,
                    num_requests: n.num_requests,
                    device: n.device,
                })
                .collect(),
        }
    }
}

/// Stable key for a strategy in scenario names and YAML.
pub fn strategy_key(s: Strategy) -> &'static str {
    match s {
        Strategy::Greedy => "greedy",
        Strategy::Partition => "partition",
        Strategy::FairShare => "fair_share",
        Strategy::SloAware => "slo_aware",
    }
}

/// Stable key for a testbed in scenario names and YAML.
pub fn testbed_key(t: TestbedKind) -> &'static str {
    match t {
        TestbedKind::IntelServer => "intel_server",
        TestbedKind::MacbookM1Pro => "macbook_m1_pro",
    }
}

/// The axes of a scenario matrix.
#[derive(Debug, Clone)]
pub struct MatrixAxes {
    pub mixes: Vec<AppMix>,
    pub strategies: Vec<Strategy>,
    pub testbeds: Vec<TestbedKind>,
    pub arrivals: Vec<ArrivalKind>,
    pub server_modes: Vec<ServerMode>,
    /// Generated DAG shapes appended to the sweep (the workflow axis).
    /// `Flat` entries are ignored — flat scenarios come from `mixes`.
    pub workflows: Vec<WorkflowShape>,
    /// Strategies the workflow slice crosses with. Kept separate from
    /// `strategies` so the default matrix can add a *curated* slice (the
    /// paper's greedy-vs-SLO-aware workflow comparison) without inflating
    /// the flat cross-product, while the full matrix takes the whole
    /// cross-product.
    pub workflow_strategies: Vec<Strategy>,
    /// Kernel backends swept by the ablation slice (the §6 tuned-vs-generic
    /// comparison). Empty → no backend scenarios. Like the workflow slice,
    /// the default matrix keeps this curated (greedy only) while the full
    /// matrix crosses it with `backend_strategies` × `testbeds`.
    pub backends: Vec<KernelBackend>,
    /// Strategies the backend-ablation slice crosses with.
    pub backend_strategies: Vec<Strategy>,
    /// Fault classes swept by the chaos slice. Empty → no chaos scenarios.
    /// Each kind contributes a static/adaptive pair (per testbed) of the
    /// `chat+imagegen` mix under `slo_aware`, with the kind's curated
    /// schedule — the slice measures fault absorption by the adaptive
    /// serving layer, one fault class at a time.
    pub chaos: Vec<ChaosKind>,
    pub seed: u64,
}

/// The curated app mixes of the backend-ablation slice: `chat+imagegen`
/// covers the llama + diffusion families under contention, and
/// `captions+imagegen` covers the whisper + diffusion starvation pair.
/// Both run their apps directly (no shared server) so the tuned-vs-generic
/// comparison measures kernel implementations, not the serving layer.
fn backend_ablation_mixes() -> Vec<AppMix> {
    vec![AppMix::chat_imagegen(), AppMix::captions_imagegen()]
}

impl MatrixAxes {
    /// The default matrix: 4 mixes × 3 policies × {closed, poisson} ×
    /// {static, adaptive} on the Intel testbed — 42 flat scenarios (the
    /// adaptive mode only applies to the 3 mixes with text apps) — plus a
    /// curated workflow slice (4 DAG shapes × {greedy, slo_aware} ×
    /// {static, adaptive where a server exists} = 10 scenarios) plus the
    /// curated backend-ablation slice (3 kernel backends × 2 mixes ×
    /// greedy = 6 scenarios) plus the curated chaos slice (5 fault classes
    /// × {static, adaptive} = 10 scenarios): 68 total. Covers every
    /// policy, every Table 1 application, open-loop heavy traffic, the
    /// serving ablation, the end-to-end workflow comparison, the §6
    /// tuned-vs-generic kernel ablation, and fault injection.
    // detlint: pin(default-matrix-count: 68)
    pub fn default_matrix(seed: u64) -> MatrixAxes {
        MatrixAxes {
            mixes: vec![
                AppMix::chat(),
                AppMix::chat_imagegen(),
                AppMix::captions_imagegen(),
                AppMix::full_stack(),
            ],
            strategies: vec![Strategy::Greedy, Strategy::Partition, Strategy::FairShare],
            testbeds: vec![TestbedKind::IntelServer],
            arrivals: vec![ArrivalKind::Closed, ArrivalKind::Poisson],
            server_modes: vec![ServerMode::Static, ServerMode::Adaptive],
            workflows: vec![
                WorkflowShape::Pipeline,
                WorkflowShape::Fanout,
                WorkflowShape::Diamond,
                WorkflowShape::ContentCreation,
            ],
            workflow_strategies: vec![Strategy::Greedy, Strategy::SloAware],
            backends: KernelBackend::ALL.to_vec(),
            backend_strategies: vec![Strategy::Greedy],
            chaos: ChaosKind::ALL.to_vec(),
            seed,
        }
    }

    /// The full sweep: adds periodic + trace-replay arrivals and the Apple
    /// Silicon testbed to the flat part (96 static + 72 adaptive), crosses
    /// the workflow shapes with every strategy and testbed (32 static + 8
    /// adaptive), takes the backend slice to its full cross-product
    /// (3 backends × 2 mixes × 4 strategies × 2 testbeds = 48), and runs
    /// the chaos slice on both testbeds (5 kinds × 2 testbeds ×
    /// {static, adaptive} = 20) — 276 scenarios.
    // detlint: pin(full-matrix-count: 276)
    pub fn full_matrix(seed: u64) -> MatrixAxes {
        MatrixAxes {
            testbeds: vec![TestbedKind::IntelServer, TestbedKind::MacbookM1Pro],
            arrivals: vec![
                ArrivalKind::Closed,
                ArrivalKind::Periodic,
                ArrivalKind::Poisson,
                ArrivalKind::TraceReplay,
            ],
            workflow_strategies: vec![
                Strategy::Greedy,
                Strategy::Partition,
                Strategy::FairShare,
                Strategy::SloAware,
            ],
            backend_strategies: vec![
                Strategy::Greedy,
                Strategy::Partition,
                Strategy::FairShare,
                Strategy::SloAware,
            ],
            ..Self::default_matrix(seed)
        }
    }

    /// Enumerate the cross-product in a fixed order: first the flat
    /// (mix, strategy, arrival, testbed, server-mode) scenarios, then the
    /// workflow (shape, strategy, testbed, server-mode) slice, then the
    /// backend-ablation (backend, mix, strategy, testbed) slice, then the
    /// chaos (kind, testbed, server-mode) slice. The order is part of the
    /// report format: re-running with the same seed must reproduce the
    /// report byte-for-byte. The adaptive server mode is skipped where
    /// there is no server to adapt (flat mixes with no text app; workflow
    /// shapes without a shared server). Workflow stages keep their
    /// applications' built-in client models, so the arrival axis does not
    /// cross the workflow slice; backend scenarios run closed-loop and
    /// static for the same reason — the ablation isolates the kernel
    /// implementation. Chaos scenarios pin everything except the fault
    /// class and the server mode, so each pair isolates adaptation under
    /// exactly one fault class.
    pub fn expand(&self) -> Vec<ScenarioSpec> {
        let mut specs = Vec::new();
        for mix in &self.mixes {
            for &strategy in &self.strategies {
                for &arrival in &self.arrivals {
                    for &testbed in &self.testbeds {
                        for &server_mode in &self.server_modes {
                            if server_mode == ServerMode::Adaptive && !mix.has_text_app() {
                                continue;
                            }
                            specs.push(ScenarioSpec {
                                name: format!(
                                    "mix={}/policy={}/arrival={}/testbed={}/server={}",
                                    mix.name,
                                    strategy_key(strategy),
                                    arrival.name(),
                                    testbed_key(testbed),
                                    server_mode_key(server_mode)
                                ),
                                mix: mix.clone(),
                                workflow: WorkflowShape::Flat,
                                strategy,
                                testbed,
                                arrival,
                                server_mode,
                                backend: KernelBackend::TunedNative,
                                backend_ablation: false,
                                chaos: None,
                                budget_events: None,
                                inject_failure: None,
                                event_queue: None,
                                trace_mode: None,
                                seed: self.seed,
                            });
                        }
                    }
                }
            }
        }
        for &shape in &self.workflows {
            if shape == WorkflowShape::Flat {
                continue;
            }
            for &strategy in &self.workflow_strategies {
                for &testbed in &self.testbeds {
                    for &server_mode in &self.server_modes {
                        if server_mode == ServerMode::Adaptive && !shape.has_server() {
                            continue;
                        }
                        specs.push(ScenarioSpec {
                            name: format!(
                                "workflow={}/policy={}/testbed={}/server={}",
                                workflow_key(shape),
                                strategy_key(strategy),
                                testbed_key(testbed),
                                server_mode_key(server_mode)
                            ),
                            mix: shape.mix(),
                            workflow: shape,
                            strategy,
                            testbed,
                            arrival: ArrivalKind::Closed,
                            server_mode,
                            backend: KernelBackend::TunedNative,
                            backend_ablation: false,
                            chaos: None,
                            budget_events: None,
                            inject_failure: None,
                            event_queue: None,
                            trace_mode: None,
                            seed: self.seed,
                        });
                    }
                }
            }
        }
        for &backend in &self.backends {
            for mix in backend_ablation_mixes() {
                for &strategy in &self.backend_strategies {
                    for &testbed in &self.testbeds {
                        specs.push(ScenarioSpec {
                            name: format!(
                                "backend={}/mix={}/policy={}/testbed={}",
                                backend_key(backend),
                                mix.name,
                                strategy_key(strategy),
                                testbed_key(testbed)
                            ),
                            mix: mix.clone(),
                            workflow: WorkflowShape::Flat,
                            strategy,
                            testbed,
                            arrival: ArrivalKind::Closed,
                            server_mode: ServerMode::Static,
                            backend,
                            backend_ablation: true,
                            chaos: None,
                            budget_events: None,
                            inject_failure: None,
                            event_queue: None,
                            trace_mode: None,
                            seed: self.seed,
                        });
                    }
                }
            }
        }
        for &kind in &self.chaos {
            for &testbed in &self.testbeds {
                for server_mode in [ServerMode::Static, ServerMode::Adaptive] {
                    let mix = AppMix::chat_imagegen();
                    specs.push(ScenarioSpec {
                        name: format!(
                            "chaos={}/mix={}/policy=slo_aware/testbed={}/server={}",
                            chaos_key(kind),
                            mix.name,
                            testbed_key(testbed),
                            server_mode_key(server_mode)
                        ),
                        mix,
                        workflow: WorkflowShape::Flat,
                        strategy: Strategy::SloAware,
                        testbed,
                        arrival: ArrivalKind::Closed,
                        server_mode,
                        backend: KernelBackend::TunedNative,
                        backend_ablation: false,
                        chaos: Some(kind),
                        budget_events: None,
                        inject_failure: None,
                        event_queue: None,
                        trace_mode: None,
                        seed: self.seed,
                    });
                }
            }
        }
        specs
    }
}

/// One fully specified scenario — an axis-point of the matrix.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub mix: AppMix,
    /// `Flat` for app-mix scenarios; otherwise the generated DAG shape.
    pub workflow: WorkflowShape,
    pub strategy: Strategy,
    pub testbed: TestbedKind,
    pub arrival: ArrivalKind,
    pub server_mode: ServerMode,
    /// Kernel implementation serving every task (`TunedNative` everywhere
    /// except the backend-ablation slice).
    pub backend: KernelBackend,
    /// Whether this scenario belongs to the backend-ablation slice: tasks
    /// then carry an explicit `backend:` key and run *directly* (no shared
    /// server), so the tuned/generic/fused trio differs in exactly one
    /// thing — the kernel implementation.
    pub backend_ablation: bool,
    /// Fault class injected during the run (`None` everywhere except the
    /// chaos slice, which emits the kind's curated `chaos:` block).
    pub chaos: Option<ChaosKind>,
    /// Deterministic event-budget override (`budget_events:` key in the
    /// rendered YAML). `None` — the default for every generated scenario —
    /// emits nothing, so pre-supervision YAML is byte-identical.
    pub budget_events: Option<u64>,
    /// Supervision-test fault hook (`inject_failure:` key). `None` emits
    /// nothing; set by the sweep-resilience tests and the CLI's
    /// `--inject-panic` / `--inject-error` flags.
    pub inject_failure: Option<InjectFailure>,
    /// Event-queue backend override (`event_queue:` key). `None` — the
    /// default for every generated scenario — emits nothing, keeping spec
    /// digests byte-identical to pre-campaign runs. Digest-neutral by the
    /// engine's determinism contract, so it is an execution knob, not a
    /// matrix axis.
    pub event_queue: Option<QueueBackend>,
    /// Trace-mode override (`trace_mode:`/`trace_window:` keys). Same
    /// emit-only-when-set rule as `event_queue`.
    pub trace_mode: Option<TraceMode>,
    pub seed: u64,
}

/// Task display label per application class.
fn app_label(app: AppType) -> &'static str {
    match app {
        AppType::Chatbot => "Chat",
        AppType::DeepResearch => "Research",
        AppType::ImageGen => "Image",
        AppType::LiveCaptions => "Captions",
    }
}

/// Open-loop period per application (seconds) for the periodic axis.
fn app_period(app: AppType) -> f64 {
    match app {
        AppType::Chatbot => 4.0,
        AppType::DeepResearch => 20.0,
        AppType::ImageGen => 6.0,
        AppType::LiveCaptions => 2.0,
    }
}

/// Poisson arrival rate per application (requests/second) for the
/// heavy-traffic axis.
fn app_rate(app: AppType) -> f64 {
    match app {
        AppType::Chatbot => 0.5,
        AppType::DeepResearch => 0.1,
        AppType::ImageGen => 0.25,
        AppType::LiveCaptions => 0.75,
    }
}

/// Context window of the matrix's shared text-model server. 32K keeps the
/// KV region (~3.5 GiB for the 3B model) small enough that an adaptive
/// onload can succeed next to ImageGen/LiveCaptions on both testbeds, while
/// still being large enough that the CPU-resident placement hurts (§4.2.1).
const MATRIX_SERVER_CONTEXT: usize = 32_768;

/// The shared llama-server block, used verbatim by both flat text mixes and
/// workflow shapes with a server — the two slices must always run the same
/// serving configuration or the static-vs-adaptive and flat-vs-workflow
/// comparisons stop measuring what they claim to.
fn shared_server_yaml() -> String {
    format!(
        "servers:\n  llama:\n    model: Llama-3.2-3B\n    context_window: {MATRIX_SERVER_CONTEXT}\n    kv_placement: cpu\n    n_slots: 4\n    batch_size: 512\n"
    )
}

/// The adaptive-mode controller block, shared for the same reason. No
/// reserve knobs: the flat matrix strategies carry no `SloAware`
/// reservation, so the adaptive axis exercises KV migration and slot
/// resizing; the workflow slice's `slo_aware` scenarios add the
/// reserve-adjustment rung on top.
const CONTROLLER_YAML: &str = "controller:\n  epoch: 2\n  window: 8\n  target_attainment: 0.9\n";

/// Explicit per-node `slo:` rendering for generated workflow tasks — the
/// application defaults (Table 1), spelled out so dumped configs are
/// self-describing. `generated_slo_overrides_match_app_defaults` pins these
/// strings to the applications' built-in SLOs.
fn app_slo_yaml(app: AppType) -> Option<&'static str> {
    match app {
        AppType::Chatbot => Some("[1s, 0.25s]"),
        AppType::ImageGen => Some("1s"),
        AppType::LiveCaptions => Some("2s"),
        AppType::DeepResearch => None,
    }
}

impl ScenarioSpec {
    /// Render the scenario as a YAML workflow configuration. Mixes with
    /// text apps route them through a shared KV-CPU server; the adaptive
    /// server mode additionally enables the feedback controller, so the
    /// static/adaptive pair differs in exactly one thing — whether the
    /// serving configuration may change at runtime. Workflow-shaped
    /// scenarios additionally emit the `workflows:` DAG (with `depend_on`
    /// edges and `background:` flags), per-node `slo:` bounds, and the
    /// shape's end-to-end `workflow_slo:`. Backend-ablation scenarios
    /// instead emit an explicit `backend:` key on every task and skip the
    /// shared server (the ablation isolates kernel implementations).
    pub fn to_yaml(&self) -> String {
        if self.workflow != WorkflowShape::Flat {
            return self.workflow_yaml();
        }
        let shared_server = self.mix.has_text_app() && !self.backend_ablation;
        let mut out = String::new();
        out.push_str(&format!("# scenario: {}\n", self.name));
        for (i, e) in self.mix.entries.iter().enumerate() {
            out.push_str(&format!(
                "{} ({}):\n  num_requests: {}\n  device: {}\n",
                app_label(e.app),
                e.app.name().to_ascii_lowercase(),
                e.num_requests,
                match e.device {
                    Device::Gpu => "gpu",
                    Device::Cpu => "cpu",
                }
            ));
            if self.backend_ablation || self.backend != KernelBackend::TunedNative {
                // Always explicit in the ablation slice (dumped configs are
                // self-describing, including the tuned run of the trio).
                out.push_str(&format!("  backend: {}\n", backend_key(self.backend)));
            }
            if shared_server && matches!(e.app, AppType::Chatbot | AppType::DeepResearch) {
                out.push_str("  server: llama\n");
            }
            // DeepResearch is the background agent; its closed loop is part
            // of the workload semantics, so arrival overrides only apply to
            // the interactive apps.
            if e.app != AppType::DeepResearch {
                match self.arrival {
                    ArrivalKind::Closed => {}
                    ArrivalKind::Periodic => {
                        out.push_str(&format!(
                            "  arrival: periodic\n  period: {}\n",
                            app_period(e.app)
                        ));
                    }
                    ArrivalKind::Poisson => {
                        out.push_str(&format!(
                            "  arrival: poisson\n  rate: {}\n",
                            app_rate(e.app)
                        ));
                    }
                    ArrivalKind::TraceReplay => {
                        let offsets =
                            burst_trace(e.num_requests, self.seed ^ ((i as u64 + 1) << 8));
                        let rendered: Vec<String> =
                            offsets.iter().map(|o| format!("{o:.3}")).collect();
                        out.push_str(&format!(
                            "  arrival: trace\n  trace: [{}]\n",
                            rendered.join(", ")
                        ));
                    }
                }
            }
        }
        if shared_server {
            out.push_str(&shared_server_yaml());
        }
        if self.server_mode == ServerMode::Adaptive {
            out.push_str(CONTROLLER_YAML);
        }
        // After the controller block: the static/adaptive pair of a chaos
        // scenario must still differ only in the controller lines.
        if let Some(kind) = self.chaos {
            out.push_str(&ChaosConfig::curated(kind).to_yaml());
        }
        self.push_supervision_yaml(&mut out);
        out.push_str(&format!("strategy: {}\n", strategy_key(self.strategy)));
        out.push_str(&format!("testbed: {}\n", testbed_key(self.testbed)));
        out.push_str(&format!("seed: {}\n", self.seed));
        out
    }

    /// Override keys (`budget_events:`, `inject_failure:`, `event_queue:`,
    /// `trace_mode:`): emitted only when set, so every generated scenario's
    /// YAML — and therefore its spec digest — is unchanged unless an
    /// override is applied.
    fn push_supervision_yaml(&self, out: &mut String) {
        if let Some(budget) = self.budget_events {
            out.push_str(&format!("budget_events: {budget}\n"));
        }
        if let Some(mode) = self.inject_failure {
            out.push_str(&format!(
                "inject_failure: {}\n",
                match mode {
                    InjectFailure::Panic => "panic",
                    InjectFailure::Error => "error",
                }
            ));
        }
        if let Some(queue) = self.event_queue {
            out.push_str(&format!("event_queue: {}\n", queue.key()));
        }
        match self.trace_mode {
            None => {}
            Some(TraceMode::Full) => out.push_str("trace_mode: full\n"),
            Some(TraceMode::Streaming { window }) => {
                out.push_str(&format!("trace_mode: streaming\ntrace_window: {window}\n"));
            }
        }
    }

    /// YAML for a workflow-shaped scenario: one task per DAG node, a
    /// `servers:` block when the shape shares a llama server, the
    /// `workflows:` DAG, and the shape's `workflow_slo:`.
    fn workflow_yaml(&self) -> String {
        let nodes = self.workflow.nodes();
        let mut out = String::new();
        out.push_str(&format!("# scenario: {}\n", self.name));
        for n in nodes {
            out.push_str(&format!(
                "{} ({}):\n  num_requests: {}\n  device: {}\n",
                n.label,
                n.app.name().to_ascii_lowercase(),
                n.num_requests,
                match n.device {
                    Device::Gpu => "gpu",
                    Device::Cpu => "cpu",
                }
            ));
            if let Some(slo) = app_slo_yaml(n.app) {
                out.push_str(&format!("  slo: {slo}\n"));
            }
            if n.server {
                out.push_str("  server: llama\n");
            }
        }
        if self.workflow.has_server() {
            out.push_str(&shared_server_yaml());
        }
        if self.server_mode == ServerMode::Adaptive {
            out.push_str(CONTROLLER_YAML);
        }
        out.push_str("workflows:\n");
        for n in nodes {
            out.push_str(&format!(
                "  {}:\n    uses: {} ({})\n",
                n.id,
                n.label,
                n.app.name().to_ascii_lowercase()
            ));
            if !n.deps.is_empty() {
                let deps: Vec<String> = n.deps.iter().map(|d| format!("\"{d}\"")).collect();
                out.push_str(&format!("    depend_on: [{}]\n", deps.join(", ")));
            }
            if n.background {
                out.push_str("    background: true\n");
            }
        }
        if let Some(bound) = self.workflow.workflow_slo() {
            out.push_str(&format!("workflow_slo: {bound}\n"));
        }
        self.push_supervision_yaml(&mut out);
        out.push_str(&format!("strategy: {}\n", strategy_key(self.strategy)));
        out.push_str(&format!("testbed: {}\n", testbed_key(self.testbed)));
        out.push_str(&format!("seed: {}\n", self.seed));
        out
    }

    /// Filesystem-safe name for `--dump`.
    pub fn file_name(&self) -> String {
        let mut s: String = self
            .name
            .chars()
            .map(|c| match c {
                '/' | '=' | '+' | ' ' => '_',
                c => c,
            })
            .collect();
        s.push_str(".yaml");
        s
    }
}

/// Deterministic bursty offsets for the trace-replay axis: requests arrive
/// in bursts of up to 3, 50 ms apart inside a burst, exponential gaps
/// between bursts (mean 4 s).
fn burst_trace(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut offsets = Vec::with_capacity(n);
    let mut t = 0.0f64;
    while offsets.len() < n {
        let burst = rng.range_usize(1, 4).min(n - offsets.len());
        for b in 0..burst {
            offsets.push(t + b as f64 * 0.05);
        }
        // Next burst starts strictly after this one ends, so the offsets
        // stay non-decreasing (the config layer rejects unsorted traces).
        t += (burst - 1) as f64 * 0.05 + rng.exponential(0.25);
    }
    offsets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::BenchConfig;

    #[test]
    fn supervision_overrides_render_and_parse() {
        let mut spec = MatrixAxes::default_matrix(7).expand().into_iter().next().unwrap();
        let before = spec.to_yaml();
        assert!(!before.contains("budget_events:"));
        assert!(!before.contains("inject_failure:"));
        assert!(!before.contains("event_queue:"));
        assert!(!before.contains("trace_mode:"));
        assert!(!before.contains("trace_window:"));
        spec.budget_events = Some(9);
        spec.inject_failure = Some(InjectFailure::Error);
        spec.event_queue = Some(QueueBackend::Wheel);
        spec.trace_mode = Some(TraceMode::Streaming { window: 128 });
        let yaml = spec.to_yaml();
        assert!(yaml.contains("budget_events: 9\n"));
        assert!(yaml.contains("inject_failure: error\n"));
        assert!(yaml.contains("event_queue: wheel\n"));
        assert!(yaml.contains("trace_mode: streaming\ntrace_window: 128\n"));
        let cfg = BenchConfig::parse(&yaml).unwrap();
        assert_eq!(cfg.budget_events, Some(9));
        assert_eq!(cfg.inject_failure, Some(InjectFailure::Error));
        assert_eq!(cfg.event_queue, QueueBackend::Wheel);
        assert_eq!(cfg.trace_mode, TraceMode::Streaming { window: 128 });
        // Explicit full mode also round-trips (and differs from absent).
        spec.trace_mode = Some(TraceMode::Full);
        let yaml = spec.to_yaml();
        assert!(yaml.contains("trace_mode: full\n"));
        assert!(!yaml.contains("trace_window:"));
        assert_eq!(BenchConfig::parse(&yaml).unwrap().trace_mode, TraceMode::Full);
    }

    #[test]
    fn default_matrix_covers_acceptance_floor() {
        let axes = MatrixAxes::default_matrix(42);
        let specs = axes.expand();
        assert_eq!(
            specs.len(),
            68,
            "24 static + 18 adaptive flat + 10 workflow + 6 backend-ablation + 10 chaos scenarios"
        );
        let strategies: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| strategy_key(s.strategy)).collect();
        assert_eq!(strategies.len(), 4, "3 flat policies + slo_aware on workflows");
        let mixes: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| s.mix.name).collect();
        assert!(mixes.len() >= 3, "{mixes:?}");
        assert!(specs.iter().any(|s| s.arrival == ArrivalKind::Poisson));
        assert!(specs.iter().any(|s| s.server_mode == ServerMode::Adaptive));
        // The workflow slice: every generated shape, greedy + slo_aware.
        let shapes: std::collections::BTreeSet<&str> = specs
            .iter()
            .filter(|s| s.workflow != WorkflowShape::Flat)
            .map(|s| workflow_key(s.workflow))
            .collect();
        assert_eq!(
            shapes.into_iter().collect::<Vec<_>>(),
            vec!["content_creation", "diamond", "fanout", "pipeline"]
        );
        for shape in ["pipeline", "content_creation"] {
            for policy in ["greedy", "slo_aware"] {
                assert!(
                    specs
                        .iter()
                        .any(|s| s.name.contains(&format!("workflow={shape}/policy={policy}"))),
                    "missing workflow={shape}/policy={policy}"
                );
            }
        }
        // The backend-ablation slice: every backend, both curated mixes.
        let backends: std::collections::BTreeSet<&str> = specs
            .iter()
            .filter(|s| s.backend_ablation)
            .map(|s| backend_key(s.backend))
            .collect();
        assert_eq!(
            backends.into_iter().collect::<Vec<_>>(),
            vec!["fused_custom", "generic_torch", "tuned_native"]
        );
        for backend in ["tuned_native", "generic_torch"] {
            for mix in ["chat+imagegen", "captions+imagegen"] {
                assert!(
                    specs
                        .iter()
                        .any(|s| s.name == format!(
                            "backend={backend}/mix={mix}/policy=greedy/testbed=intel_server"
                        )),
                    "missing backend={backend}/mix={mix}"
                );
            }
        }
        // The chaos slice: every fault class, as a static/adaptive pair.
        let kinds: std::collections::BTreeSet<&str> = specs
            .iter()
            .filter_map(|s| s.chaos.map(chaos_key))
            .collect();
        assert_eq!(
            kinds.into_iter().collect::<Vec<_>>(),
            vec!["pcie_degrade", "server_crash", "suspend", "thermal_throttle", "vram_ballast"]
        );
        for kind in ChaosKind::ALL {
            for mode in ["static", "adaptive"] {
                assert!(
                    specs.iter().any(|s| s.name
                        == format!(
                            "chaos={}/mix=chat+imagegen/policy=slo_aware/testbed=intel_server/server={mode}",
                            chaos_key(kind)
                        )),
                    "missing chaos={kind}/server={mode}"
                );
            }
        }
        // Names are unique (they key the report).
        let names: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names.len(), specs.len());
    }

    #[test]
    fn adaptive_mode_applies_only_where_a_server_exists() {
        let specs = MatrixAxes::full_matrix(1).expand();
        assert_eq!(
            specs.len(),
            96 + 72 + 32 + 8 + 48 + 20,
            "flat 96 static + 72 adaptive, workflow 32 static + 8 adaptive, \
             48 backend-ablation, 20 chaos"
        );
        for spec in &specs {
            let yaml = spec.to_yaml();
            let flat = spec.workflow == WorkflowShape::Flat;
            match spec.server_mode {
                ServerMode::Adaptive => {
                    assert!(spec.mix.has_text_app(), "{}", spec.name);
                    assert!(yaml.contains("controller:"), "{}", spec.name);
                    assert!(yaml.contains("server: llama"), "{}", spec.name);
                    if !flat {
                        assert!(spec.workflow.has_server(), "{}", spec.name);
                    }
                }
                ServerMode::Static => {
                    assert!(!yaml.contains("controller:"), "{}", spec.name);
                    // Flat text mixes still share the server — the static/
                    // adaptive pair differs only in the controller. Workflow
                    // shapes only share one when the shape declares it, and
                    // the backend-ablation slice never does (it isolates the
                    // kernel implementation from the serving layer).
                    let expect_server = if spec.backend_ablation {
                        false
                    } else if flat {
                        spec.mix.has_text_app()
                    } else {
                        spec.workflow.has_server()
                    };
                    assert_eq!(
                        yaml.contains("server: llama"),
                        expect_server,
                        "{}",
                        spec.name
                    );
                }
            }
        }
    }

    #[test]
    fn backend_ablation_trio_differs_only_in_the_backend_key() {
        let specs = MatrixAxes::default_matrix(9).expand();
        let slice: Vec<&ScenarioSpec> = specs.iter().filter(|s| s.backend_ablation).collect();
        assert_eq!(slice.len(), 6, "3 backends × 2 curated mixes");
        for spec in &slice {
            let yaml = spec.to_yaml();
            // Every task names its backend explicitly — dumped configs are
            // self-describing, including the tuned member of the trio.
            assert_eq!(
                yaml.matches("  backend: ").count(),
                spec.mix.entries.len(),
                "{}:\n{yaml}",
                spec.name
            );
            assert!(
                yaml.contains(&format!("backend: {}", backend_key(spec.backend))),
                "{}",
                spec.name
            );
            assert!(!yaml.contains("server: llama"), "{}: ablation runs direct", spec.name);
            assert_eq!(spec.server_mode, ServerMode::Static);
            assert_eq!(spec.arrival, ArrivalKind::Closed);
        }
        // Same-mix members differ from each other only in the backend line.
        let trio: Vec<&&ScenarioSpec> = slice
            .iter()
            .filter(|s| s.mix.name == "chat+imagegen")
            .collect();
        assert_eq!(trio.len(), 3);
        let strip = |s: &ScenarioSpec| -> Vec<String> {
            s.to_yaml()
                .lines()
                .skip(1) // name comment
                .filter(|l| !l.starts_with("  backend: "))
                .map(String::from)
                .collect()
        };
        assert_eq!(strip(trio[0]), strip(trio[1]));
        assert_eq!(strip(trio[1]), strip(trio[2]));
    }

    #[test]
    fn chaos_slice_emits_the_curated_block_and_nothing_else_does() {
        let specs = MatrixAxes::default_matrix(11).expand();
        let slice: Vec<&ScenarioSpec> = specs.iter().filter(|s| s.chaos.is_some()).collect();
        assert_eq!(slice.len(), 10, "5 fault classes × {{static, adaptive}}");
        for spec in &slice {
            let yaml = spec.to_yaml();
            let kind = spec.chaos.unwrap();
            assert!(yaml.contains("chaos:\n"), "{}", spec.name);
            assert!(
                yaml.contains(&format!("  kind: {}\n", chaos_key(kind))),
                "{}:\n{yaml}",
                spec.name
            );
            // Chaos pins the rest of the axis point: slo_aware, closed
            // arrivals, the shared server, the tuned backend.
            assert_eq!(spec.strategy, Strategy::SloAware);
            assert_eq!(spec.arrival, ArrivalKind::Closed);
            assert!(!spec.backend_ablation);
            assert!(yaml.contains("server: llama"), "{}", spec.name);
            // The parsed config carries the kind's curated schedule.
            let cfg = BenchConfig::parse(&yaml).unwrap();
            assert_eq!(cfg.chaos, Some(ChaosConfig::curated(kind)), "{}", spec.name);
        }
        for spec in specs.iter().filter(|s| s.chaos.is_none()) {
            assert!(
                !spec.to_yaml().contains("chaos:"),
                "{}: fault-free scenarios must stay fault-free",
                spec.name
            );
        }
    }

    #[test]
    fn workflow_yaml_carries_dag_slos_and_e2e_bound() {
        let specs = MatrixAxes::default_matrix(5).expand();
        let wf: Vec<&ScenarioSpec> = specs
            .iter()
            .filter(|s| s.workflow != WorkflowShape::Flat)
            .collect();
        assert!(!wf.is_empty());
        for spec in &wf {
            let yaml = spec.to_yaml();
            assert!(yaml.contains("workflows:"), "{}", spec.name);
            assert!(yaml.contains("depend_on: ["), "{}", spec.name);
            assert!(yaml.contains("workflow_slo: "), "{}", spec.name);
            assert!(yaml.contains("slo: "), "{}", spec.name);
            assert!(spec.name.starts_with("workflow="), "{}", spec.name);
            // The generated DAG validates (cycles, dup deps, unknown ids).
            let cfg = BenchConfig::parse(&yaml).unwrap();
            crate::coordinator::Dag::build(&cfg.workflow)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        }
        // content_creation carries the background analysis/b-roll nodes and
        // the diamond join exists in the diamond shape.
        let cc = wf
            .iter()
            .find(|s| s.workflow == WorkflowShape::ContentCreation)
            .unwrap();
        let yaml = cc.to_yaml();
        assert_eq!(yaml.matches("background: true").count(), 2, "{yaml}");
        assert!(yaml.contains("depend_on: [\"brainstorm\"]"), "{yaml}");
        let diamond = wf
            .iter()
            .find(|s| s.workflow == WorkflowShape::Diamond)
            .unwrap();
        assert!(
            diamond.to_yaml().contains("depend_on: [\"art\", \"captions\"]"),
            "{}",
            diamond.to_yaml()
        );
    }

    #[test]
    fn static_adaptive_pairs_differ_only_in_the_controller_block() {
        let specs = MatrixAxes::default_matrix(3).expand();
        for spec in specs.iter().filter(|s| s.server_mode == ServerMode::Adaptive) {
            let twin_name = spec.name.replace("/server=adaptive", "/server=static");
            let twin = specs.iter().find(|s| s.name == twin_name).unwrap();
            let adaptive_yaml = spec.to_yaml();
            let static_yaml = twin.to_yaml();
            let stripped: String = adaptive_yaml
                .lines()
                .filter(|l| {
                    !l.starts_with("controller:")
                        && !["  epoch:", "  window:", "  target_attainment:"]
                            .iter()
                            .any(|p| l.starts_with(p))
                })
                .map(|l| format!("{l}\n"))
                .collect();
            // Apart from the name comment, removing the controller block
            // recovers the static twin exactly.
            assert_eq!(
                stripped.lines().skip(1).collect::<Vec<_>>(),
                static_yaml.lines().skip(1).collect::<Vec<_>>(),
                "{}",
                spec.name
            );
        }
    }

    #[test]
    fn every_generated_config_parses() {
        for axes in [MatrixAxes::default_matrix(7), MatrixAxes::full_matrix(7)] {
            for spec in axes.expand() {
                let yaml = spec.to_yaml();
                let cfg = BenchConfig::parse(&yaml)
                    .unwrap_or_else(|e| panic!("{}: {e}\n{yaml}", spec.name));
                assert_eq!(cfg.tasks.len(), spec.mix.entries.len());
                assert_eq!(cfg.strategy, spec.strategy);
                assert_eq!(cfg.testbed, spec.testbed);
                assert_eq!(cfg.seed, spec.seed);
            }
        }
    }

    #[test]
    fn generated_slo_overrides_match_app_defaults() {
        use crate::apps::{Application, Chatbot, ImageGen, LiveCaptions, Slo};
        use crate::coordinator::config::SloSpec;
        // The explicit `slo:` strings emitted for workflow tasks must parse
        // back to the applications' built-in defaults — otherwise the
        // workflow slice silently measures different SLOs than the flat one.
        let apps: Vec<(AppType, Slo)> = vec![
            (AppType::Chatbot, Chatbot::new(0, 1).slo()),
            (AppType::ImageGen, ImageGen::new(0, 1).slo()),
            (AppType::LiveCaptions, LiveCaptions::new(0, 1).slo()),
        ];
        for (app, built_in) in apps {
            let rendered = app_slo_yaml(app).expect("SLO-bearing app");
            let cfg = BenchConfig::parse(&format!(
                "A ({}):\n  num_requests: 1\n  slo: {rendered}\n",
                app.name().to_ascii_lowercase()
            ))
            .unwrap();
            let parsed = cfg.tasks[0].slo.clone().expect("slo parsed");
            match (parsed, built_in) {
                (SloSpec::Chat(a, b), Slo::Chat { ttft, tpot }) => {
                    assert_eq!((a, b), (ttft, tpot));
                }
                (SloSpec::Single(x), Slo::StepTime(s) | Slo::SegmentTime(s)) => {
                    assert_eq!(x, s);
                }
                (parsed, built_in) => {
                    panic!("{app:?}: SLO kinds diverged: {parsed:?} vs {built_in:?}")
                }
            }
        }
        assert_eq!(app_slo_yaml(AppType::DeepResearch), None, "background app has no SLO");
    }

    #[test]
    fn yaml_rendering_is_deterministic() {
        let a = MatrixAxes::full_matrix(13).expand();
        let b = MatrixAxes::full_matrix(13).expand();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_yaml(), y.to_yaml());
        }
    }

    #[test]
    fn burst_trace_is_sorted_and_sized() {
        for n in [1, 2, 7, 20] {
            let t = burst_trace(n, 99);
            assert_eq!(t.len(), n);
            assert!(t.windows(2).all(|w| w[1] >= w[0]), "{t:?}");
            assert!(t[0] >= 0.0);
        }
    }

    #[test]
    fn file_names_are_fs_safe() {
        for spec in MatrixAxes::default_matrix(1).expand() {
            let f = spec.file_name();
            assert!(f.ends_with(".yaml"));
            assert!(!f.contains('/') && !f.contains('='), "{f}");
        }
    }
}
