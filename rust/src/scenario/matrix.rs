//! Scenario-matrix generation: axes → cross-product → runnable configs.
//!
//! An axis point is one of:
//!
//! * **App mix** — which applications run concurrently and with how many
//!   requests each (Table 1 apps in realistic combinations, §4.2/§4.3).
//! * **Scheduling policy** — greedy / equal-partition / fair-share (§3.2).
//! * **Device profile** — which simulated testbed (Intel server RTX 6000,
//!   MacBook M1 Pro).
//! * **Arrival process** — the client model: the apps' built-in closed
//!   loop, a fixed-period open loop, an open-loop Poisson stream (heavy
//!   traffic), or a bursty trace replay.
//! * **Server mode** — for mixes with text apps (Chatbot/DeepResearch),
//!   whether the shared llama.cpp-style server keeps its KV-CPU
//!   configuration frozen (`static`, the paper's §4.2.1 pitfall) or runs
//!   under the adaptive feedback controller (`adaptive`, the §5.2 loop
//!   made live). Mixes without a text app carry no server and only appear
//!   as `static`.
//!
//! [`MatrixAxes::expand`] enumerates the cross-product in a fixed order and
//! renders each point as a YAML workflow configuration understood by
//! [`crate::coordinator::config::BenchConfig`], so every generated scenario
//! is also a valid hand-runnable config (`consumerbench scenario --dump`
//! writes them out).

use crate::coordinator::config::{AppType, Strategy, TestbedKind};
use crate::gpusim::kernel::Device;
use crate::util::rng::Rng;

/// One application instance inside a mix.
#[derive(Debug, Clone)]
pub struct MixEntry {
    pub app: AppType,
    pub num_requests: usize,
    pub device: Device,
}

/// A named set of concurrently running applications.
#[derive(Debug, Clone)]
pub struct AppMix {
    pub name: &'static str,
    pub entries: Vec<MixEntry>,
}

impl AppMix {
    /// Whether the mix contains an app that can route through a shared
    /// text-model server (the `server_mode` axis only applies to these).
    pub fn has_text_app(&self) -> bool {
        self.entries
            .iter()
            .any(|e| matches!(e.app, AppType::Chatbot | AppType::DeepResearch))
    }

    fn entry(app: AppType, num_requests: usize, device: Device) -> MixEntry {
        MixEntry {
            app,
            num_requests,
            device,
        }
    }

    /// Single latency-sensitive chat client (the exclusive baseline).
    pub fn chat() -> AppMix {
        AppMix {
            name: "chat",
            entries: vec![Self::entry(AppType::Chatbot, 3, Device::Gpu)],
        }
    }

    /// Chat sharing the GPU with a bulk image generator (§4.2 contention).
    pub fn chat_imagegen() -> AppMix {
        AppMix {
            name: "chat+imagegen",
            entries: vec![
                Self::entry(AppType::Chatbot, 3, Device::Gpu),
                Self::entry(AppType::ImageGen, 2, Device::Gpu),
            ],
        }
    }

    /// The paper's starvation pair: tiny-kernel captions vs. device-filling
    /// diffusion steps (Fig. 5).
    pub fn captions_imagegen() -> AppMix {
        AppMix {
            name: "captions+imagegen",
            entries: vec![
                Self::entry(AppType::LiveCaptions, 6, Device::Gpu),
                Self::entry(AppType::ImageGen, 2, Device::Gpu),
            ],
        }
    }

    /// All four Table 1 applications at once; DeepResearch runs on the CPU
    /// (the Fig. 2 placement) so the three GPU apps fit in VRAM together.
    pub fn full_stack() -> AppMix {
        AppMix {
            name: "full-stack",
            entries: vec![
                Self::entry(AppType::Chatbot, 2, Device::Gpu),
                Self::entry(AppType::ImageGen, 2, Device::Gpu),
                Self::entry(AppType::LiveCaptions, 4, Device::Gpu),
                Self::entry(AppType::DeepResearch, 1, Device::Cpu),
            ],
        }
    }
}

/// Arrival-process axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Application built-in client models (closed loop / audio cadence).
    Closed,
    /// Fixed-period open loop per app.
    Periodic,
    /// Open-loop Poisson stream per app — the heavy-traffic regime.
    Poisson,
    /// Bursty recorded-trace replay per app.
    TraceReplay,
}

impl ArrivalKind {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Closed => "closed",
            ArrivalKind::Periodic => "periodic",
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::TraceReplay => "trace",
        }
    }
}

/// Server-mode axis: how the shared text-model server is configured for
/// mixes containing Chatbot/DeepResearch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerMode {
    /// The §4.2.1 pitfall: a KV-CPU server configuration frozen for the
    /// run (text apps still share the server — only adaptation is off).
    Static,
    /// Same starting configuration plus the feedback controller, which may
    /// migrate the KV cache, adjust the SM reservation, or resize slots at
    /// runtime.
    Adaptive,
}

/// Stable key for a server mode in scenario names and YAML.
pub fn server_mode_key(m: ServerMode) -> &'static str {
    match m {
        ServerMode::Static => "static",
        ServerMode::Adaptive => "adaptive",
    }
}

/// Stable key for a strategy in scenario names and YAML.
pub fn strategy_key(s: Strategy) -> &'static str {
    match s {
        Strategy::Greedy => "greedy",
        Strategy::Partition => "partition",
        Strategy::FairShare => "fair_share",
        Strategy::SloAware => "slo_aware",
    }
}

/// Stable key for a testbed in scenario names and YAML.
pub fn testbed_key(t: TestbedKind) -> &'static str {
    match t {
        TestbedKind::IntelServer => "intel_server",
        TestbedKind::MacbookM1Pro => "macbook_m1_pro",
    }
}

/// The axes of a scenario matrix.
#[derive(Debug, Clone)]
pub struct MatrixAxes {
    pub mixes: Vec<AppMix>,
    pub strategies: Vec<Strategy>,
    pub testbeds: Vec<TestbedKind>,
    pub arrivals: Vec<ArrivalKind>,
    pub server_modes: Vec<ServerMode>,
    pub seed: u64,
}

impl MatrixAxes {
    /// The default matrix: 4 mixes × 3 policies × {closed, poisson} ×
    /// {static, adaptive} on the Intel testbed — 42 scenarios (the
    /// adaptive mode only applies to the 3 mixes with text apps) covering
    /// every policy, every Table 1 application, open-loop heavy traffic,
    /// and the static-vs-adaptive serving ablation.
    pub fn default_matrix(seed: u64) -> MatrixAxes {
        MatrixAxes {
            mixes: vec![
                AppMix::chat(),
                AppMix::chat_imagegen(),
                AppMix::captions_imagegen(),
                AppMix::full_stack(),
            ],
            strategies: vec![Strategy::Greedy, Strategy::Partition, Strategy::FairShare],
            testbeds: vec![TestbedKind::IntelServer],
            arrivals: vec![ArrivalKind::Closed, ArrivalKind::Poisson],
            server_modes: vec![ServerMode::Static, ServerMode::Adaptive],
            seed,
        }
    }

    /// The full sweep: adds periodic + trace-replay arrivals and the Apple
    /// Silicon testbed (96 static + 72 adaptive = 168 scenarios).
    pub fn full_matrix(seed: u64) -> MatrixAxes {
        MatrixAxes {
            testbeds: vec![TestbedKind::IntelServer, TestbedKind::MacbookM1Pro],
            arrivals: vec![
                ArrivalKind::Closed,
                ArrivalKind::Periodic,
                ArrivalKind::Poisson,
                ArrivalKind::TraceReplay,
            ],
            ..Self::default_matrix(seed)
        }
    }

    /// Enumerate the cross-product in a fixed (mix, strategy, arrival,
    /// testbed, server-mode) order. The order is part of the report
    /// format: re-running with the same seed must reproduce the report
    /// byte-for-byte. The adaptive server mode is skipped for mixes with
    /// no text app (there is no server to adapt).
    pub fn expand(&self) -> Vec<ScenarioSpec> {
        let mut specs = Vec::new();
        for mix in &self.mixes {
            for &strategy in &self.strategies {
                for &arrival in &self.arrivals {
                    for &testbed in &self.testbeds {
                        for &server_mode in &self.server_modes {
                            if server_mode == ServerMode::Adaptive && !mix.has_text_app() {
                                continue;
                            }
                            specs.push(ScenarioSpec {
                                name: format!(
                                    "mix={}/policy={}/arrival={}/testbed={}/server={}",
                                    mix.name,
                                    strategy_key(strategy),
                                    arrival.name(),
                                    testbed_key(testbed),
                                    server_mode_key(server_mode)
                                ),
                                mix: mix.clone(),
                                strategy,
                                testbed,
                                arrival,
                                server_mode,
                                seed: self.seed,
                            });
                        }
                    }
                }
            }
        }
        specs
    }
}

/// One fully specified scenario — an axis-point of the matrix.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub mix: AppMix,
    pub strategy: Strategy,
    pub testbed: TestbedKind,
    pub arrival: ArrivalKind,
    pub server_mode: ServerMode,
    pub seed: u64,
}

/// Task display label per application class.
fn app_label(app: AppType) -> &'static str {
    match app {
        AppType::Chatbot => "Chat",
        AppType::DeepResearch => "Research",
        AppType::ImageGen => "Image",
        AppType::LiveCaptions => "Captions",
    }
}

/// Open-loop period per application (seconds) for the periodic axis.
fn app_period(app: AppType) -> f64 {
    match app {
        AppType::Chatbot => 4.0,
        AppType::DeepResearch => 20.0,
        AppType::ImageGen => 6.0,
        AppType::LiveCaptions => 2.0,
    }
}

/// Poisson arrival rate per application (requests/second) for the
/// heavy-traffic axis.
fn app_rate(app: AppType) -> f64 {
    match app {
        AppType::Chatbot => 0.5,
        AppType::DeepResearch => 0.1,
        AppType::ImageGen => 0.25,
        AppType::LiveCaptions => 0.75,
    }
}

/// Context window of the matrix's shared text-model server. 32K keeps the
/// KV region (~3.5 GiB for the 3B model) small enough that an adaptive
/// onload can succeed next to ImageGen/LiveCaptions on both testbeds, while
/// still being large enough that the CPU-resident placement hurts (§4.2.1).
const MATRIX_SERVER_CONTEXT: usize = 32_768;

impl ScenarioSpec {
    /// Render the scenario as a YAML workflow configuration. Mixes with
    /// text apps route them through a shared KV-CPU server; the adaptive
    /// server mode additionally enables the feedback controller, so the
    /// static/adaptive pair differs in exactly one thing — whether the
    /// serving configuration may change at runtime.
    pub fn to_yaml(&self) -> String {
        let shared_server = self.mix.has_text_app();
        let mut out = String::new();
        out.push_str(&format!("# scenario: {}\n", self.name));
        for (i, e) in self.mix.entries.iter().enumerate() {
            out.push_str(&format!(
                "{} ({}):\n  num_requests: {}\n  device: {}\n",
                app_label(e.app),
                e.app.name().to_ascii_lowercase(),
                e.num_requests,
                match e.device {
                    Device::Gpu => "gpu",
                    Device::Cpu => "cpu",
                }
            ));
            if shared_server && matches!(e.app, AppType::Chatbot | AppType::DeepResearch) {
                out.push_str("  server: llama\n");
            }
            // DeepResearch is the background agent; its closed loop is part
            // of the workload semantics, so arrival overrides only apply to
            // the interactive apps.
            if e.app != AppType::DeepResearch {
                match self.arrival {
                    ArrivalKind::Closed => {}
                    ArrivalKind::Periodic => {
                        out.push_str(&format!(
                            "  arrival: periodic\n  period: {}\n",
                            app_period(e.app)
                        ));
                    }
                    ArrivalKind::Poisson => {
                        out.push_str(&format!(
                            "  arrival: poisson\n  rate: {}\n",
                            app_rate(e.app)
                        ));
                    }
                    ArrivalKind::TraceReplay => {
                        let offsets =
                            burst_trace(e.num_requests, self.seed ^ ((i as u64 + 1) << 8));
                        let rendered: Vec<String> =
                            offsets.iter().map(|o| format!("{o:.3}")).collect();
                        out.push_str(&format!(
                            "  arrival: trace\n  trace: [{}]\n",
                            rendered.join(", ")
                        ));
                    }
                }
            }
        }
        if shared_server {
            out.push_str(&format!(
                "servers:\n  llama:\n    model: Llama-3.2-3B\n    context_window: {MATRIX_SERVER_CONTEXT}\n    kv_placement: cpu\n    n_slots: 4\n    batch_size: 512\n"
            ));
        }
        if self.server_mode == ServerMode::Adaptive {
            // No reserve knobs: the matrix strategies (greedy / partition /
            // fair_share) carry no `SloAware` reservation, so the adaptive
            // axis exercises KV migration and slot resizing; reserve
            // adjustment is covered by slo_aware hand-written configs.
            out.push_str("controller:\n  epoch: 2\n  window: 8\n  target_attainment: 0.9\n");
        }
        out.push_str(&format!("strategy: {}\n", strategy_key(self.strategy)));
        out.push_str(&format!("testbed: {}\n", testbed_key(self.testbed)));
        out.push_str(&format!("seed: {}\n", self.seed));
        out
    }

    /// Filesystem-safe name for `--dump`.
    pub fn file_name(&self) -> String {
        let mut s: String = self
            .name
            .chars()
            .map(|c| match c {
                '/' | '=' | '+' | ' ' => '_',
                c => c,
            })
            .collect();
        s.push_str(".yaml");
        s
    }
}

/// Deterministic bursty offsets for the trace-replay axis: requests arrive
/// in bursts of up to 3, 50 ms apart inside a burst, exponential gaps
/// between bursts (mean 4 s).
fn burst_trace(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut offsets = Vec::with_capacity(n);
    let mut t = 0.0f64;
    while offsets.len() < n {
        let burst = rng.range_usize(1, 4).min(n - offsets.len());
        for b in 0..burst {
            offsets.push(t + b as f64 * 0.05);
        }
        // Next burst starts strictly after this one ends, so the offsets
        // stay non-decreasing (the config layer rejects unsorted traces).
        t += (burst - 1) as f64 * 0.05 + rng.exponential(0.25);
    }
    offsets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::BenchConfig;

    #[test]
    fn default_matrix_covers_acceptance_floor() {
        let axes = MatrixAxes::default_matrix(42);
        let specs = axes.expand();
        assert_eq!(specs.len(), 42, "24 static + 18 adaptive scenarios");
        let strategies: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| strategy_key(s.strategy)).collect();
        assert_eq!(strategies.len(), 3);
        let mixes: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| s.mix.name).collect();
        assert!(mixes.len() >= 3, "{mixes:?}");
        assert!(specs.iter().any(|s| s.arrival == ArrivalKind::Poisson));
        assert!(specs.iter().any(|s| s.server_mode == ServerMode::Adaptive));
        // Names are unique (they key the report).
        let names: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names.len(), specs.len());
    }

    #[test]
    fn adaptive_mode_applies_only_to_text_mixes() {
        let specs = MatrixAxes::full_matrix(1).expand();
        assert_eq!(specs.len(), 96 + 72, "96 static + 72 adaptive");
        for spec in &specs {
            let yaml = spec.to_yaml();
            match spec.server_mode {
                ServerMode::Adaptive => {
                    assert!(spec.mix.has_text_app(), "{}", spec.name);
                    assert!(yaml.contains("controller:"), "{}", spec.name);
                    assert!(yaml.contains("server: llama"), "{}", spec.name);
                }
                ServerMode::Static => {
                    assert!(!yaml.contains("controller:"), "{}", spec.name);
                    // Text mixes still share the server — the static/
                    // adaptive pair differs only in the controller.
                    assert_eq!(
                        yaml.contains("server: llama"),
                        spec.mix.has_text_app(),
                        "{}",
                        spec.name
                    );
                }
            }
        }
    }

    #[test]
    fn static_adaptive_pairs_differ_only_in_the_controller_block() {
        let specs = MatrixAxes::default_matrix(3).expand();
        for spec in specs.iter().filter(|s| s.server_mode == ServerMode::Adaptive) {
            let twin_name = spec.name.replace("/server=adaptive", "/server=static");
            let twin = specs.iter().find(|s| s.name == twin_name).unwrap();
            let adaptive_yaml = spec.to_yaml();
            let static_yaml = twin.to_yaml();
            let stripped: String = adaptive_yaml
                .lines()
                .filter(|l| {
                    !l.starts_with("controller:")
                        && !["  epoch:", "  window:", "  target_attainment:"]
                            .iter()
                            .any(|p| l.starts_with(p))
                })
                .map(|l| format!("{l}\n"))
                .collect();
            // Apart from the name comment, removing the controller block
            // recovers the static twin exactly.
            assert_eq!(
                stripped.lines().skip(1).collect::<Vec<_>>(),
                static_yaml.lines().skip(1).collect::<Vec<_>>(),
                "{}",
                spec.name
            );
        }
    }

    #[test]
    fn every_generated_config_parses() {
        for axes in [MatrixAxes::default_matrix(7), MatrixAxes::full_matrix(7)] {
            for spec in axes.expand() {
                let yaml = spec.to_yaml();
                let cfg = BenchConfig::parse(&yaml)
                    .unwrap_or_else(|e| panic!("{}: {e}\n{yaml}", spec.name));
                assert_eq!(cfg.tasks.len(), spec.mix.entries.len());
                assert_eq!(cfg.strategy, spec.strategy);
                assert_eq!(cfg.testbed, spec.testbed);
                assert_eq!(cfg.seed, spec.seed);
            }
        }
    }

    #[test]
    fn yaml_rendering_is_deterministic() {
        let a = MatrixAxes::full_matrix(13).expand();
        let b = MatrixAxes::full_matrix(13).expand();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_yaml(), y.to_yaml());
        }
    }

    #[test]
    fn burst_trace_is_sorted_and_sized() {
        for n in [1, 2, 7, 20] {
            let t = burst_trace(n, 99);
            assert_eq!(t.len(), n);
            assert!(t.windows(2).all(|w| w[1] >= w[0]), "{t:?}");
            assert!(t[0] >= 0.0);
        }
    }

    #[test]
    fn file_names_are_fs_safe() {
        for spec in MatrixAxes::default_matrix(1).expand() {
            let f = spec.file_name();
            assert!(f.ends_with(".yaml"));
            assert!(!f.contains('/') && !f.contains('='), "{f}");
        }
    }
}
