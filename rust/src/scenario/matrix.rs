//! Scenario-matrix generation: axes → cross-product → runnable configs.
//!
//! An axis point is one of:
//!
//! * **App mix** — which applications run concurrently and with how many
//!   requests each (Table 1 apps in realistic combinations, §4.2/§4.3).
//! * **Scheduling policy** — greedy / equal-partition / fair-share (§3.2).
//! * **Device profile** — which simulated testbed (Intel server RTX 6000,
//!   MacBook M1 Pro).
//! * **Arrival process** — the client model: the apps' built-in closed
//!   loop, a fixed-period open loop, an open-loop Poisson stream (heavy
//!   traffic), or a bursty trace replay.
//!
//! [`MatrixAxes::expand`] enumerates the cross-product in a fixed order and
//! renders each point as a YAML workflow configuration understood by
//! [`crate::coordinator::config::BenchConfig`], so every generated scenario
//! is also a valid hand-runnable config (`consumerbench scenario --dump`
//! writes them out).

use crate::coordinator::config::{AppType, Strategy, TestbedKind};
use crate::gpusim::kernel::Device;
use crate::util::rng::Rng;

/// One application instance inside a mix.
#[derive(Debug, Clone)]
pub struct MixEntry {
    pub app: AppType,
    pub num_requests: usize,
    pub device: Device,
}

/// A named set of concurrently running applications.
#[derive(Debug, Clone)]
pub struct AppMix {
    pub name: &'static str,
    pub entries: Vec<MixEntry>,
}

impl AppMix {
    fn entry(app: AppType, num_requests: usize, device: Device) -> MixEntry {
        MixEntry {
            app,
            num_requests,
            device,
        }
    }

    /// Single latency-sensitive chat client (the exclusive baseline).
    pub fn chat() -> AppMix {
        AppMix {
            name: "chat",
            entries: vec![Self::entry(AppType::Chatbot, 3, Device::Gpu)],
        }
    }

    /// Chat sharing the GPU with a bulk image generator (§4.2 contention).
    pub fn chat_imagegen() -> AppMix {
        AppMix {
            name: "chat+imagegen",
            entries: vec![
                Self::entry(AppType::Chatbot, 3, Device::Gpu),
                Self::entry(AppType::ImageGen, 2, Device::Gpu),
            ],
        }
    }

    /// The paper's starvation pair: tiny-kernel captions vs. device-filling
    /// diffusion steps (Fig. 5).
    pub fn captions_imagegen() -> AppMix {
        AppMix {
            name: "captions+imagegen",
            entries: vec![
                Self::entry(AppType::LiveCaptions, 6, Device::Gpu),
                Self::entry(AppType::ImageGen, 2, Device::Gpu),
            ],
        }
    }

    /// All four Table 1 applications at once; DeepResearch runs on the CPU
    /// (the Fig. 2 placement) so the three GPU apps fit in VRAM together.
    pub fn full_stack() -> AppMix {
        AppMix {
            name: "full-stack",
            entries: vec![
                Self::entry(AppType::Chatbot, 2, Device::Gpu),
                Self::entry(AppType::ImageGen, 2, Device::Gpu),
                Self::entry(AppType::LiveCaptions, 4, Device::Gpu),
                Self::entry(AppType::DeepResearch, 1, Device::Cpu),
            ],
        }
    }
}

/// Arrival-process axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Application built-in client models (closed loop / audio cadence).
    Closed,
    /// Fixed-period open loop per app.
    Periodic,
    /// Open-loop Poisson stream per app — the heavy-traffic regime.
    Poisson,
    /// Bursty recorded-trace replay per app.
    TraceReplay,
}

impl ArrivalKind {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Closed => "closed",
            ArrivalKind::Periodic => "periodic",
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::TraceReplay => "trace",
        }
    }
}

/// Stable key for a strategy in scenario names and YAML.
pub fn strategy_key(s: Strategy) -> &'static str {
    match s {
        Strategy::Greedy => "greedy",
        Strategy::Partition => "partition",
        Strategy::FairShare => "fair_share",
        Strategy::SloAware => "slo_aware",
    }
}

/// Stable key for a testbed in scenario names and YAML.
pub fn testbed_key(t: TestbedKind) -> &'static str {
    match t {
        TestbedKind::IntelServer => "intel_server",
        TestbedKind::MacbookM1Pro => "macbook_m1_pro",
    }
}

/// The axes of a scenario matrix.
#[derive(Debug, Clone)]
pub struct MatrixAxes {
    pub mixes: Vec<AppMix>,
    pub strategies: Vec<Strategy>,
    pub testbeds: Vec<TestbedKind>,
    pub arrivals: Vec<ArrivalKind>,
    pub seed: u64,
}

impl MatrixAxes {
    /// The default matrix: 4 mixes × 3 policies × {closed, poisson} on the
    /// Intel testbed — 24 scenarios covering every policy, every Table 1
    /// application, and open-loop heavy traffic.
    pub fn default_matrix(seed: u64) -> MatrixAxes {
        MatrixAxes {
            mixes: vec![
                AppMix::chat(),
                AppMix::chat_imagegen(),
                AppMix::captions_imagegen(),
                AppMix::full_stack(),
            ],
            strategies: vec![Strategy::Greedy, Strategy::Partition, Strategy::FairShare],
            testbeds: vec![TestbedKind::IntelServer],
            arrivals: vec![ArrivalKind::Closed, ArrivalKind::Poisson],
            seed,
        }
    }

    /// The full sweep: adds periodic + trace-replay arrivals and the Apple
    /// Silicon testbed (4 × 3 × 4 × 2 = 96 scenarios).
    pub fn full_matrix(seed: u64) -> MatrixAxes {
        MatrixAxes {
            testbeds: vec![TestbedKind::IntelServer, TestbedKind::MacbookM1Pro],
            arrivals: vec![
                ArrivalKind::Closed,
                ArrivalKind::Periodic,
                ArrivalKind::Poisson,
                ArrivalKind::TraceReplay,
            ],
            ..Self::default_matrix(seed)
        }
    }

    /// Enumerate the cross-product in a fixed (mix, strategy, arrival,
    /// testbed) order. The order is part of the report format: re-running
    /// with the same seed must reproduce the report byte-for-byte.
    pub fn expand(&self) -> Vec<ScenarioSpec> {
        let mut specs = Vec::new();
        for mix in &self.mixes {
            for &strategy in &self.strategies {
                for &arrival in &self.arrivals {
                    for &testbed in &self.testbeds {
                        specs.push(ScenarioSpec {
                            name: format!(
                                "mix={}/policy={}/arrival={}/testbed={}",
                                mix.name,
                                strategy_key(strategy),
                                arrival.name(),
                                testbed_key(testbed)
                            ),
                            mix: mix.clone(),
                            strategy,
                            testbed,
                            arrival,
                            seed: self.seed,
                        });
                    }
                }
            }
        }
        specs
    }
}

/// One fully specified scenario — an axis-point of the matrix.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub mix: AppMix,
    pub strategy: Strategy,
    pub testbed: TestbedKind,
    pub arrival: ArrivalKind,
    pub seed: u64,
}

/// Task display label per application class.
fn app_label(app: AppType) -> &'static str {
    match app {
        AppType::Chatbot => "Chat",
        AppType::DeepResearch => "Research",
        AppType::ImageGen => "Image",
        AppType::LiveCaptions => "Captions",
    }
}

/// Open-loop period per application (seconds) for the periodic axis.
fn app_period(app: AppType) -> f64 {
    match app {
        AppType::Chatbot => 4.0,
        AppType::DeepResearch => 20.0,
        AppType::ImageGen => 6.0,
        AppType::LiveCaptions => 2.0,
    }
}

/// Poisson arrival rate per application (requests/second) for the
/// heavy-traffic axis.
fn app_rate(app: AppType) -> f64 {
    match app {
        AppType::Chatbot => 0.5,
        AppType::DeepResearch => 0.1,
        AppType::ImageGen => 0.25,
        AppType::LiveCaptions => 0.75,
    }
}

impl ScenarioSpec {
    /// Render the scenario as a YAML workflow configuration.
    pub fn to_yaml(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("# scenario: {}\n", self.name));
        for (i, e) in self.mix.entries.iter().enumerate() {
            out.push_str(&format!(
                "{} ({}):\n  num_requests: {}\n  device: {}\n",
                app_label(e.app),
                e.app.name().to_ascii_lowercase(),
                e.num_requests,
                match e.device {
                    Device::Gpu => "gpu",
                    Device::Cpu => "cpu",
                }
            ));
            // DeepResearch is the background agent; its closed loop is part
            // of the workload semantics, so arrival overrides only apply to
            // the interactive apps.
            if e.app != AppType::DeepResearch {
                match self.arrival {
                    ArrivalKind::Closed => {}
                    ArrivalKind::Periodic => {
                        out.push_str(&format!(
                            "  arrival: periodic\n  period: {}\n",
                            app_period(e.app)
                        ));
                    }
                    ArrivalKind::Poisson => {
                        out.push_str(&format!(
                            "  arrival: poisson\n  rate: {}\n",
                            app_rate(e.app)
                        ));
                    }
                    ArrivalKind::TraceReplay => {
                        let offsets =
                            burst_trace(e.num_requests, self.seed ^ ((i as u64 + 1) << 8));
                        let rendered: Vec<String> =
                            offsets.iter().map(|o| format!("{o:.3}")).collect();
                        out.push_str(&format!(
                            "  arrival: trace\n  trace: [{}]\n",
                            rendered.join(", ")
                        ));
                    }
                }
            }
        }
        out.push_str(&format!("strategy: {}\n", strategy_key(self.strategy)));
        out.push_str(&format!("testbed: {}\n", testbed_key(self.testbed)));
        out.push_str(&format!("seed: {}\n", self.seed));
        out
    }

    /// Filesystem-safe name for `--dump`.
    pub fn file_name(&self) -> String {
        let mut s: String = self
            .name
            .chars()
            .map(|c| match c {
                '/' | '=' | '+' | ' ' => '_',
                c => c,
            })
            .collect();
        s.push_str(".yaml");
        s
    }
}

/// Deterministic bursty offsets for the trace-replay axis: requests arrive
/// in bursts of up to 3, 50 ms apart inside a burst, exponential gaps
/// between bursts (mean 4 s).
fn burst_trace(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut offsets = Vec::with_capacity(n);
    let mut t = 0.0f64;
    while offsets.len() < n {
        let burst = rng.range_usize(1, 4).min(n - offsets.len());
        for b in 0..burst {
            offsets.push(t + b as f64 * 0.05);
        }
        // Next burst starts strictly after this one ends, so the offsets
        // stay non-decreasing (the config layer rejects unsorted traces).
        t += (burst - 1) as f64 * 0.05 + rng.exponential(0.25);
    }
    offsets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::BenchConfig;

    #[test]
    fn default_matrix_covers_acceptance_floor() {
        let axes = MatrixAxes::default_matrix(42);
        let specs = axes.expand();
        assert!(specs.len() >= 20, "{} scenarios", specs.len());
        let strategies: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| strategy_key(s.strategy)).collect();
        assert_eq!(strategies.len(), 3);
        let mixes: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| s.mix.name).collect();
        assert!(mixes.len() >= 3, "{mixes:?}");
        assert!(specs.iter().any(|s| s.arrival == ArrivalKind::Poisson));
        // Names are unique (they key the report).
        let names: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names.len(), specs.len());
    }

    #[test]
    fn every_generated_config_parses() {
        for axes in [MatrixAxes::default_matrix(7), MatrixAxes::full_matrix(7)] {
            for spec in axes.expand() {
                let yaml = spec.to_yaml();
                let cfg = BenchConfig::parse(&yaml)
                    .unwrap_or_else(|e| panic!("{}: {e}\n{yaml}", spec.name));
                assert_eq!(cfg.tasks.len(), spec.mix.entries.len());
                assert_eq!(cfg.strategy, spec.strategy);
                assert_eq!(cfg.testbed, spec.testbed);
                assert_eq!(cfg.seed, spec.seed);
            }
        }
    }

    #[test]
    fn yaml_rendering_is_deterministic() {
        let a = MatrixAxes::full_matrix(13).expand();
        let b = MatrixAxes::full_matrix(13).expand();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_yaml(), y.to_yaml());
        }
    }

    #[test]
    fn burst_trace_is_sorted_and_sized() {
        for n in [1, 2, 7, 20] {
            let t = burst_trace(n, 99);
            assert_eq!(t.len(), n);
            assert!(t.windows(2).all(|w| w[1] >= w[0]), "{t:?}");
            assert!(t[0] >= 0.0);
        }
    }

    #[test]
    fn file_names_are_fs_safe() {
        for spec in MatrixAxes::default_matrix(1).expand() {
            let f = spec.file_name();
            assert!(f.ends_with(".yaml"));
            assert!(!f.contains('/') && !f.contains('='), "{f}");
        }
    }
}
