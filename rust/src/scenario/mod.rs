//! Scenario-matrix subsystem: programmatic sweeps over realistic
//! multi-application scenarios.
//!
//! The paper evaluates a handful of hand-written configurations; this
//! module generalizes them into a generator over the axes — application
//! mix × scheduling policy × device profile × arrival process × server
//! mode, plus a workflow axis of generated DAG shapes (pipeline, fanout,
//! diamond, and the paper's content-creation graph) reported with
//! end-to-end latency and critical-path attribution, plus a kernel-backend
//! axis (tuned_native / generic_torch / fused_custom — the §6
//! tuned-vs-generic ablation), plus a chaos axis of seed-derived fault
//! schedules (thermal throttle, VRAM ballast, suspend/resume, server
//! crash, PCIe degradation) reported as static-vs-adaptive attainment
//! deltas — and executes the expanded cross-product through the regular
//! coordinator pipeline on the deterministic simulator:
//!
//! ```text
//! MatrixAxes ──expand──▶ [ScenarioSpec] ──to_yaml──▶ BenchConfig
//!      │                                                  │
//!      └────────── run_matrix ──▶ ScenarioRunner ─────────┘
//!                       │
//!                       ▼
//!         MatrixReport (SLO attainment, p50/p99, fairness,
//!                       trace digests) ──▶ deterministic JSON
//! ```
//!
//! Because the simulator is deterministic and the report rendering is
//! canonical, re-running a matrix with the same seed reproduces the JSON
//! byte-for-byte — the golden-trace tests (`tests/golden_trace.rs`) turn
//! that property into a regression harness for every engine refactor.
//!
//! Sweeps are fault-tolerant: [`runner::run_specs_supervised`] isolates
//! panics, classifies deterministic budget exhaustion, retries failures
//! once, quarantines them as report rows, and checkpoints terminal
//! outcomes to a JSONL journal for kill-and-resume — all without breaking
//! the byte-identity contract (`tests/sweep_resilience.rs`).
//!
//! Exposed on the command line as `consumerbench scenario`.
//!
//! Beyond the two hand-picked testbeds, [`population`] samples synthetic
//! device populations (edge / laptop / desktop tiers) and [`fleet`] sweeps
//! them at scale with bounded-memory streaming aggregation — exposed as
//! `consumerbench fleet`.

pub mod fleet;
pub mod matrix;
pub mod population;
pub mod runner;

pub use fleet::{
    run_fleet, DeviceRecord, FleetAggregate, FleetOptions, FleetReport, FleetSpec, OutlierRow,
    TierAgg, DEFAULT_FLEET_TRACE_WINDOW, DEFAULT_OUTLIER_K, DEFAULT_SHARD_SIZE,
};
pub use population::{class_key, DeviceClass, DeviceSpec, PopulationSpec, DEVICE_CLASSES};

pub use matrix::{
    backend_key, chaos_key, server_mode_key, strategy_key, testbed_key, workflow_key, AppMix,
    ArrivalKind, MatrixAxes, MixEntry, ScenarioSpec, ServerMode, WorkflowShape,
};
pub use runner::{
    run_matrix, run_matrix_jobs, run_scenario, run_specs_jobs, run_specs_supervised, AdaptiveDelta,
    AppOutcome, BackendRow, ChaosRow, MatrixReport, ScenarioOutcome, ScenarioStatus, SweepOptions,
    WorkflowRow,
};
