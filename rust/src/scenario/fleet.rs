//! Fleet-scale device-population sweeps with bounded-memory aggregation.
//!
//! The matrix ([`crate::scenario::runner`]) sweeps two hand-picked testbeds;
//! the fleet runner sweeps a *population* of synthesized devices (see
//! [`crate::scenario::population`]) and answers population-level questions:
//! what are the fleet-wide p50/p99 request latencies, how is SLO attainment
//! distributed across device tiers, which concrete devices are the worst
//! outliers?
//!
//! # Memory model
//!
//! At fleet scale the matrix approach — materialize every outcome with its
//! full trace, then summarize — cannot hold. Instead the population is cut
//! into fixed-size shards; each device's scenario runs under
//! [`TraceMode::Streaming`] and its metrics are folded into the owning
//! shard's [`FleetAggregate`] (fixed-bin histograms + streaming moments)
//! *immediately*, after which the result is dropped. Full (windowed) traces
//! are retained only for the worst-`k`-attainment outlier candidates per
//! shard. Peak resident aggregation state is therefore
//! `O(shards × (bins + outlier_k × trace_window))` — independent of the
//! device count — and the report carries its own capacity accounting
//! (`aggregation.resident_cells` / `aggregation.bound_cells`) so a test can
//! pin the bound at a 2,000-device population.
//!
//! # Determinism
//!
//! Shard partitioning is a pure function of `(count, shard_size)` and every
//! per-shard aggregate folds its devices in index order, so the merged
//! report is **byte-identical for `--jobs 1` and `--jobs N`** — workers race
//! only for whole shards, never for fold order. Histogram merges are exact
//! (`u64` bin counts); moment merges are floating-point, which is why the
//! final merge always runs in canonical shard order on one thread.
//!
//! With `--journal`, every terminal device record is checkpointed as JSONL
//! keyed by `(device index, population seed, fleet spec digest)` using the
//! same shortest-roundtrip float encoders as the report; `--resume` replays
//! the journal and re-executes only missing devices, re-folding the
//! journaled records bit-exactly — a killed 2,000-device sweep resumes to a
//! byte-identical report. Wall-clock `timeout` records are host-dependent
//! and never journaled, mirroring the matrix supervision contract.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::apps::Slo;
use crate::coordinator::{run_config_text_on, ScenarioResult, Strategy, TestbedKind, WallClockTimeout};
use crate::gpusim::engine::{BudgetExhausted, Fnv1a};
use crate::gpusim::trace::{Trace, TraceMode};
use crate::scenario::matrix::{
    strategy_key, AppMix, ArrivalKind, ScenarioSpec, ServerMode, WorkflowShape,
};
use crate::scenario::population::{class_key, DeviceClass, PopulationSpec};
use crate::scenario::runner::{Journal, ScenarioStatus};
use crate::util::json::{json_num, json_opt_num, json_str, parse as json_parse, JsonValue};
use crate::util::stats::{FixedHistogram, Moments};

/// Devices per shard (one aggregate per shard).
pub const DEFAULT_SHARD_SIZE: usize = 50;
/// Worst-k outlier rows retained per shard (and in the final report).
pub const DEFAULT_OUTLIER_K: usize = 8;
/// Per-device streaming trace window (rows). Deliberately smaller than the
/// engine default: fleets trade per-device forensics for breadth.
pub const DEFAULT_FLEET_TRACE_WINDOW: usize = 128;

/// Request-latency histogram: log-scale 0.1 ms .. 10 000 s, 12 bins per
/// decade. Relative quantile error ≤ `(hi/lo)^(1/(2·bins)) − 1` ≈ 10.1 %.
const LATENCY_HIST_LO: f64 = 1e-4;
const LATENCY_HIST_HI: f64 = 1e4;
const LATENCY_HIST_BINS: usize = 96;
/// Attainment histogram: linear on `[0, 1]`, absolute error ≤ 0.005.
const ATTAIN_HIST_BINS: usize = 100;

/// Fixed per-outlier-row scalar cells (index, class, vram, status, error
/// slot, attainment, makespan, digest) used by the capacity accounting.
const OUTLIER_ROW_CELLS: usize = 8;
/// Upper bound on distinct `(class, vram_gb)` tiers a population can
/// produce (3 edge + 3 laptop + 4 desktop VRAM tiers).
const MAX_TIERS: usize = 10;

fn latency_hist() -> FixedHistogram {
    FixedHistogram::log_scale(LATENCY_HIST_LO, LATENCY_HIST_HI, LATENCY_HIST_BINS)
}

fn attain_hist() -> FixedHistogram {
    FixedHistogram::linear(0.0, 1.0, ATTAIN_HIST_BINS)
}

// ---------------------------------------------------------------------------
// Spec + options
// ---------------------------------------------------------------------------

/// A fleet sweep: a device population plus the scenario slice every device
/// runs and the aggregation knobs.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub population: PopulationSpec,
    /// Application mix every device runs (flat workflow, closed arrivals).
    pub mix: AppMix,
    pub strategy: Strategy,
    /// Devices per shard; the unit of work-stealing and of aggregation.
    pub shard_size: usize,
    /// Worst-k attainment rows retained (with their streaming trace tails).
    pub outlier_k: usize,
    /// Streaming trace window per device scenario.
    pub trace_window: usize,
}

impl FleetSpec {
    /// Default slice for a population: the chatbot mix under the greedy
    /// strategy — the paper's baseline single-app regime, cheap enough to
    /// run thousands of times.
    pub fn new(population: PopulationSpec) -> FleetSpec {
        FleetSpec {
            population,
            mix: AppMix::chat(),
            strategy: Strategy::Greedy,
            shard_size: DEFAULT_SHARD_SIZE,
            outlier_k: DEFAULT_OUTLIER_K,
            trace_window: DEFAULT_FLEET_TRACE_WINDOW,
        }
    }

    /// Number of shards the population cuts into.
    pub fn shards(&self) -> usize {
        let size = self.shard_size.max(1);
        self.population.count.div_ceil(size).max(1)
    }

    /// Device index range `[lo, hi)` of one shard.
    pub fn shard_range(&self, shard: usize) -> (usize, usize) {
        let size = self.shard_size.max(1);
        let lo = shard * size;
        (lo.min(self.population.count), ((shard + 1) * size).min(self.population.count))
    }

    /// The scenario one device runs. The `testbed:` key in the rendered
    /// YAML is an inert placeholder — execution injects the synthesized
    /// [`crate::gpusim::Testbed`] via [`run_config_text_on`]. The scenario
    /// seed is decorrelated from the sampler stream for the same device so
    /// hardware draws and workload draws never alias.
    pub fn device_scenario(&self, index: usize) -> ScenarioSpec {
        let seed = (self.population.seed
            ^ (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .rotate_left(17);
        ScenarioSpec {
            name: format!("device-{index:05}"),
            mix: self.mix.clone(),
            workflow: WorkflowShape::Flat,
            strategy: self.strategy,
            testbed: TestbedKind::IntelServer,
            arrival: ArrivalKind::Closed,
            server_mode: ServerMode::Static,
            backend: crate::gpusim::backend::KernelBackend::TunedNative,
            backend_ablation: false,
            chaos: None,
            budget_events: None,
            inject_failure: None,
            event_queue: None,
            trace_mode: Some(TraceMode::Streaming {
                window: self.trace_window.max(1),
            }),
            seed,
        }
    }

    /// FNV-1a digest of the canonical population YAML plus the device-0
    /// scenario template — the journal key that makes stale checkpoint
    /// entries (same device index, different population or slice)
    /// detectable. Aggregation-only knobs (`shard_size`, `outlier_k`) do
    /// not affect execution and are deliberately excluded, so a journal
    /// survives re-sharding.
    pub fn digest_hex(&self) -> String {
        let mut h = Fnv1a::new();
        h.update(self.population.to_yaml().as_bytes());
        h.update(self.device_scenario(0).to_yaml().as_bytes());
        format!("{:016x}", h.finish())
    }
}

/// Execution knobs for one fleet sweep.
#[derive(Debug, Clone, Default)]
pub struct FleetOptions {
    /// Worker threads (clamped to `1..=shards`); `0` behaves like `1`.
    pub jobs: usize,
    /// Wall-clock watchdog per device attempt. Defense-in-depth only —
    /// `timeout` records are host-dependent and never journaled.
    pub watchdog: Option<Duration>,
    /// Append-only JSONL checkpoint of terminal device records.
    pub journal: Option<PathBuf>,
    /// Prefill completed devices from the journal before executing the rest.
    pub resume: bool,
}

// ---------------------------------------------------------------------------
// Per-device record
// ---------------------------------------------------------------------------

/// The folded-and-journaled residue of one device's scenario run —
/// everything the aggregates and the outlier table need, *without* the
/// trace or the full `ScenarioResult`.
#[derive(Debug, Clone)]
pub struct DeviceRecord {
    pub device: usize,
    pub class: DeviceClass,
    pub vram_gb: u64,
    pub status: ScenarioStatus,
    pub error: Option<String>,
    pub retried: bool,
    /// Min SLO attainment across SLO-bearing apps (failed app → 0.0; a mix
    /// with no SLO apps is vacuously 1.0). `None` for non-`ok` records.
    pub attainment: Option<f64>,
    pub makespan: f64,
    pub e2e_latency: f64,
    /// Digest of the *complete* trace (streaming mode included).
    pub trace_digest: u64,
    /// Rows in the retained streaming tail window.
    pub trace_rows: usize,
    /// Per-request latencies (finite only), in completion order. Small —
    /// the closed-loop mixes issue a handful of requests per device — and
    /// journaled bit-exactly so a resumed sweep re-folds identically.
    pub latencies: Vec<f64>,
}

fn record_from(
    spec: &FleetSpec,
    index: usize,
    status: ScenarioStatus,
    error: Option<String>,
) -> DeviceRecord {
    let dev = spec.population.device(index);
    DeviceRecord {
        device: index,
        class: dev.class,
        vram_gb: dev.vram_gb,
        status,
        error,
        retried: false,
        attainment: None,
        makespan: 0.0,
        e2e_latency: 0.0,
        trace_digest: 0,
        trace_rows: 0,
        latencies: Vec::new(),
    }
}

/// Fold one `ScenarioResult` into a terminal `ok` record (plus the trace
/// tail, which the caller may retain for outlier forensics).
fn record_ok(spec: &FleetSpec, index: usize, result: ScenarioResult) -> (DeviceRecord, Trace) {
    let mut rec = record_from(spec, index, ScenarioStatus::Ok, None);
    // Same fairness convention as the matrix runner: a failed app counts as
    // zero attainment; an SLO-free mix is vacuously met.
    let attainments: Vec<f64> = result
        .nodes
        .iter()
        .filter(|n| !matches!(n.slo, Slo::None))
        .filter_map(|n| {
            if n.failed.is_some() {
                Some(0.0)
            } else {
                n.attainment()
            }
        })
        .collect();
    rec.attainment = Some(if attainments.is_empty() {
        // No SLO-bearing apps at all: vacuously met.
        1.0
    } else {
        attainments.iter().copied().fold(f64::INFINITY, f64::min)
    });
    rec.makespan = result.makespan;
    rec.e2e_latency = result.workflow.e2e_latency;
    rec.trace_digest = result.trace_digest;
    rec.trace_rows = result.trace.len();
    rec.latencies = result
        .nodes
        .iter()
        .flat_map(|n| n.metrics.iter().map(|m| m.latency))
        .filter(|l| l.is_finite())
        .collect();
    (rec, result.trace)
}

/// One attempt of one device: panic isolation + typed-error classification,
/// mirroring the matrix runner's `attempt_one`. Never unwinds.
fn attempt_device(
    spec: &FleetSpec,
    index: usize,
    watchdog: Option<Duration>,
) -> (DeviceRecord, Option<Trace>) {
    let scenario = spec.device_scenario(index);
    let yaml = scenario.to_yaml();
    let testbed = spec.population.device(index).testbed;
    match catch_unwind(AssertUnwindSafe(|| {
        run_config_text_on(&yaml, None, watchdog, Some(testbed))
    })) {
        Ok(Ok(result)) => {
            let (rec, trace) = record_ok(spec, index, result);
            (rec, Some(trace))
        }
        Ok(Err(err)) => {
            let status = if err.downcast_ref::<BudgetExhausted>().is_some() {
                ScenarioStatus::BudgetExhausted
            } else if err.downcast_ref::<WallClockTimeout>().is_some() {
                ScenarioStatus::Timeout
            } else {
                ScenarioStatus::Failed
            };
            (record_from(spec, index, status, Some(format!("{err:#}"))), None)
        }
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "panic with non-string payload".to_string());
            (record_from(spec, index, ScenarioStatus::Panicked, Some(msg)), None)
        }
    }
}

/// Supervised device run: attempt, then retry failures exactly once with
/// the identical seed (budget exhaustion is deterministic and not retried).
fn supervise_device(
    spec: &FleetSpec,
    index: usize,
    watchdog: Option<Duration>,
) -> (DeviceRecord, Option<Trace>) {
    let first = attempt_device(spec, index, watchdog);
    match first.0.status {
        ScenarioStatus::Failed | ScenarioStatus::Panicked | ScenarioStatus::Timeout => {
            let (mut rec, trace) = attempt_device(spec, index, watchdog);
            rec.retried = true;
            (rec, trace)
        }
        _ => first,
    }
}

// ---------------------------------------------------------------------------
// Mergeable aggregate
// ---------------------------------------------------------------------------

/// One `(class, vram_gb)` tier's sub-aggregate.
#[derive(Debug, Clone)]
pub struct TierAgg {
    pub class: DeviceClass,
    pub vram_gb: u64,
    pub devices: usize,
    pub ok: usize,
    pub attain: Moments,
    pub latency_hist: FixedHistogram,
}

/// One retained outlier row: the journaled scalar fields plus (in memory
/// only) the streaming trace tail for forensics. The trace never feeds the
/// report JSON — resumed devices have no trace, and the report must be
/// byte-identical either way.
#[derive(Debug, Clone)]
pub struct OutlierRow {
    pub device: usize,
    pub class: DeviceClass,
    pub vram_gb: u64,
    pub status: ScenarioStatus,
    pub error: Option<String>,
    pub attainment: Option<f64>,
    pub makespan: f64,
    pub trace_digest: u64,
    pub trace_rows: usize,
    pub trace: Option<Trace>,
}

/// Worst-first outlier rank: non-`ok` devices sort before any attainment.
fn outlier_rank(status: ScenarioStatus, attainment: Option<f64>) -> f64 {
    if status.is_ok() {
        attainment.unwrap_or(0.0)
    } else {
        -1.0
    }
}

/// The bounded-memory fold target for one shard (and, after merging, for
/// the whole fleet): status counts, latency/attainment/makespan sketches,
/// per-tier sub-aggregates, and the worst-k outlier rows. Merge is
/// order-independent for every exact field; the float moment merges are
/// sequenced canonically by the runner.
#[derive(Debug, Clone)]
pub struct FleetAggregate {
    devices: usize,
    /// Counts in status taxonomy order: ok, failed, panicked,
    /// budget_exhausted, timeout, skipped.
    status: [usize; 6],
    retried: usize,
    latency_hist: FixedHistogram,
    latency_moments: Moments,
    attain_hist: FixedHistogram,
    attain_moments: Moments,
    makespan_moments: Moments,
    e2e_moments: Moments,
    tiers: Vec<TierAgg>,
    outlier_k: usize,
    trace_window: usize,
    outliers: Vec<OutlierRow>,
}

fn status_slot(status: ScenarioStatus) -> usize {
    match status {
        ScenarioStatus::Ok => 0,
        ScenarioStatus::Failed => 1,
        ScenarioStatus::Panicked => 2,
        ScenarioStatus::BudgetExhausted => 3,
        ScenarioStatus::Timeout => 4,
        ScenarioStatus::Skipped => 5,
    }
}

impl FleetAggregate {
    pub fn new(outlier_k: usize, trace_window: usize) -> FleetAggregate {
        FleetAggregate {
            devices: 0,
            status: [0; 6],
            retried: 0,
            latency_hist: latency_hist(),
            latency_moments: Moments::new(),
            attain_hist: attain_hist(),
            attain_moments: Moments::new(),
            makespan_moments: Moments::new(),
            e2e_moments: Moments::new(),
            tiers: Vec::new(),
            outlier_k,
            trace_window: trace_window.max(1),
            outliers: Vec::new(),
        }
    }

    fn tier_mut(&mut self, class: DeviceClass, vram_gb: u64) -> &mut TierAgg {
        let key = |t: &TierAgg| (t.class as usize, t.vram_gb);
        let probe = (class as usize, vram_gb);
        let at = self.tiers.partition_point(|t| key(t) < probe);
        if self.tiers.get(at).map(key) != Some(probe) {
            self.tiers.insert(
                at,
                TierAgg {
                    class,
                    vram_gb,
                    devices: 0,
                    ok: 0,
                    attain: Moments::new(),
                    latency_hist: latency_hist(),
                },
            );
        }
        &mut self.tiers[at]
    }

    /// Fold one terminal device record (and optionally its trace tail, for
    /// outlier retention). The record can be dropped afterwards.
    pub fn fold(&mut self, rec: &DeviceRecord, trace: Option<Trace>) {
        self.devices += 1;
        self.status[status_slot(rec.status)] += 1;
        if rec.retried {
            self.retried += 1;
        }
        {
            let tier = self.tier_mut(rec.class, rec.vram_gb);
            tier.devices += 1;
            if rec.status.is_ok() {
                tier.ok += 1;
                for &l in &rec.latencies {
                    tier.latency_hist.fold(l);
                }
                if let Some(a) = rec.attainment {
                    tier.attain.push(a);
                }
            }
        }
        if rec.status.is_ok() {
            for &l in &rec.latencies {
                self.latency_hist.fold(l);
                self.latency_moments.push(l);
            }
            if let Some(a) = rec.attainment {
                self.attain_hist.fold(a);
                self.attain_moments.push(a);
            }
            self.makespan_moments.push(rec.makespan);
            self.e2e_moments.push(rec.e2e_latency);
        }
        self.push_outlier(OutlierRow {
            device: rec.device,
            class: rec.class,
            vram_gb: rec.vram_gb,
            status: rec.status,
            error: rec.error.clone(),
            attainment: rec.attainment,
            makespan: rec.makespan,
            trace_digest: rec.trace_digest,
            trace_rows: rec.trace_rows,
            trace,
        });
    }

    /// Insert a candidate into the worst-first bounded outlier list; an
    /// evicted row's retained trace is freed immediately.
    fn push_outlier(&mut self, row: OutlierRow) {
        if self.outlier_k == 0 {
            return;
        }
        let key = |r: &OutlierRow| (outlier_rank(r.status, r.attainment), r.device);
        let probe = key(&row);
        let at = self.outliers.partition_point(|r| {
            let k = key(r);
            k.0.total_cmp(&probe.0).then(k.1.cmp(&probe.1)).is_lt()
        });
        if at >= self.outlier_k {
            return;
        }
        self.outliers.insert(at, row);
        self.outliers.truncate(self.outlier_k);
    }

    /// Merge another shard's aggregate in. Exact fields (histograms, status
    /// counts, outlier selection) are order-independent; moment merges are
    /// floating-point, so the runner always merges in canonical shard order.
    pub fn merge(&mut self, other: FleetAggregate) {
        self.devices += other.devices;
        for (slot, v) in self.status.iter_mut().zip(other.status) {
            *slot += v;
        }
        self.retried += other.retried;
        self.latency_hist.merge(&other.latency_hist);
        self.latency_moments.merge(&other.latency_moments);
        self.attain_hist.merge(&other.attain_hist);
        self.attain_moments.merge(&other.attain_moments);
        self.makespan_moments.merge(&other.makespan_moments);
        self.e2e_moments.merge(&other.e2e_moments);
        for t in other.tiers {
            let tier = self.tier_mut(t.class, t.vram_gb);
            tier.devices += t.devices;
            tier.ok += t.ok;
            tier.attain.merge(&t.attain);
            tier.latency_hist.merge(&t.latency_hist);
        }
        for row in other.outliers {
            self.push_outlier(row);
        }
    }

    pub fn device_count(&self) -> usize {
        self.devices
    }

    pub fn status_count(&self, status: ScenarioStatus) -> usize {
        self.status[status_slot(status)]
    }

    pub fn latency_count(&self) -> u64 {
        self.latency_hist.count()
    }

    pub fn latency_quantile(&self, q: f64) -> Option<f64> {
        self.latency_hist.quantile(q)
    }

    pub fn attainment_quantile(&self, q: f64) -> Option<f64> {
        self.attain_hist.quantile(q)
    }

    pub fn outliers(&self) -> &[OutlierRow] {
        &self.outliers
    }

    pub fn tiers(&self) -> &[TierAgg] {
        &self.tiers
    }

    /// Capacity-based resident-cell accounting. Outlier slots are charged
    /// their *capacity* (`OUTLIER_ROW_CELLS + trace_window`) rather than
    /// actual retention so the number is identical whether a row's trace
    /// came from a live run (retained) or a journal resume (absent) — the
    /// report stays byte-identical across both paths, and the figure is an
    /// honest upper bound either way.
    pub fn cells(&self) -> usize {
        let tier_cells: usize = self
            .tiers
            .iter()
            .map(|t| t.latency_hist.cells() + t.attain.cells() + 2)
            .sum();
        self.latency_hist.cells()
            + self.attain_hist.cells()
            + self.latency_moments.cells()
            + self.attain_moments.cells()
            + self.makespan_moments.cells()
            + self.e2e_moments.cells()
            + self.status.len()
            + tier_cells
            + self.outliers.len() * (OUTLIER_ROW_CELLS + self.trace_window)
    }

    /// Analytic per-shard capacity bound: what one shard's aggregate can
    /// grow to regardless of how many devices fold into it.
    pub fn shard_bound_cells(outlier_k: usize, trace_window: usize) -> usize {
        let tier_cells =
            MAX_TIERS * (latency_hist().cells() + Moments::new().cells() + 2);
        latency_hist().cells()
            + attain_hist().cells()
            + 4 * Moments::new().cells()
            + 6
            + tier_cells
            + outlier_k * (OUTLIER_ROW_CELLS + trace_window.max(1))
    }
}

// ---------------------------------------------------------------------------
// Report
// ---------------------------------------------------------------------------

/// The population report: the merged aggregate plus provenance and the
/// memory accounting. `to_json` renders the `consumerbench_fleet: 1`
/// schema deterministically.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub spec_digest: String,
    pub population: PopulationSpec,
    pub mix: String,
    pub strategy: String,
    pub shard_size: usize,
    pub shards: usize,
    pub outlier_k: usize,
    pub trace_window: usize,
    pub agg: FleetAggregate,
    /// Σ over shard aggregates of [`FleetAggregate::cells`] at their peak
    /// (just before the canonical merge) — jobs- and resume-invariant.
    pub resident_cells: usize,
    /// `shards ×` [`FleetAggregate::shard_bound_cells`] — independent of
    /// the device count by construction.
    pub bound_cells: usize,
}

fn moments_json(m: &Moments, suffix: &str) -> String {
    let opt = |v: f64| json_opt_num(if m.count() == 0 { None } else { Some(v) });
    format!(
        "\"mean{suffix}\": {}, \"std{suffix}\": {}, \"min{suffix}\": {}, \"max{suffix}\": {}",
        opt(m.mean()),
        opt(m.std()),
        opt(m.min()),
        opt(m.max())
    )
}

impl FleetReport {
    /// Deterministic JSON rendering — byte-identical across `--jobs`
    /// values, repeats, and kill/resume for the same spec.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("{\n  \"consumerbench_fleet\": 1,\n");
        out.push_str(&format!("  \"spec_digest\": {},\n", json_str(&self.spec_digest)));
        out.push_str(&format!(
            "  \"population\": {{\"name\": {}, \"count\": {}, \"seed\": {}, \"weights\": {{\"edge\": {}, \"laptop\": {}, \"desktop\": {}}}}},\n",
            json_str(&self.population.name),
            self.population.count,
            self.population.seed,
            json_num(self.population.weights[0]),
            json_num(self.population.weights[1]),
            json_num(self.population.weights[2]),
        ));
        out.push_str(&format!(
            "  \"slice\": {{\"mix\": {}, \"strategy\": {}, \"shard_size\": {}, \"shards\": {}, \"outlier_k\": {}, \"trace_window\": {}}},\n",
            json_str(&self.mix),
            json_str(&self.strategy),
            self.shard_size,
            self.shards,
            self.outlier_k,
            self.trace_window,
        ));
        let a = &self.agg;
        out.push_str(&format!(
            "  \"devices\": {{\"total\": {}, \"ok\": {}, \"failed\": {}, \"panicked\": {}, \"budget_exhausted\": {}, \"timeout\": {}, \"skipped\": {}, \"retried\": {}}},\n",
            a.devices,
            a.status[0],
            a.status[1],
            a.status[2],
            a.status[3],
            a.status[4],
            a.status[5],
            a.retried,
        ));
        out.push_str(&format!(
            "  \"latency\": {{\"requests\": {}, {}, \"p50_s\": {}, \"p90_s\": {}, \"p99_s\": {}, \"rel_error_bound\": {}}},\n",
            a.latency_hist.count(),
            moments_json(&a.latency_moments, "_s"),
            json_opt_num(a.latency_hist.quantile(0.50)),
            json_opt_num(a.latency_hist.quantile(0.90)),
            json_opt_num(a.latency_hist.quantile(0.99)),
            json_num(a.latency_hist.error_bound()),
        ));
        out.push_str(&format!(
            "  \"attainment\": {{\"devices\": {}, {}, \"p10\": {}, \"p50\": {}, \"p90\": {}, \"abs_error_bound\": {}}},\n",
            a.attain_moments.count(),
            moments_json(&a.attain_moments, ""),
            json_opt_num(a.attain_hist.quantile(0.10)),
            json_opt_num(a.attain_hist.quantile(0.50)),
            json_opt_num(a.attain_hist.quantile(0.90)),
            json_num(a.attain_hist.error_bound()),
        ));
        out.push_str(&format!(
            "  \"makespan\": {{{}}},\n  \"e2e_latency\": {{{}}},\n",
            moments_json(&a.makespan_moments, "_s"),
            moments_json(&a.e2e_moments, "_s"),
        ));
        out.push_str("  \"tiers\": [\n");
        for (i, t) in a.tiers.iter().enumerate() {
            let mean_attain = json_opt_num(if t.attain.count() == 0 {
                None
            } else {
                Some(t.attain.mean())
            });
            out.push_str(&format!(
                "    {{\"class\": {}, \"vram_gb\": {}, \"devices\": {}, \"ok\": {}, \"mean_attainment\": {}, \"p50_latency_s\": {}, \"p99_latency_s\": {}}}{}\n",
                json_str(class_key(t.class)),
                t.vram_gb,
                t.devices,
                t.ok,
                mean_attain,
                json_opt_num(t.latency_hist.quantile(0.50)),
                json_opt_num(t.latency_hist.quantile(0.99)),
                if i + 1 < a.tiers.len() { "," } else { "" },
            ));
        }
        out.push_str("  ],\n  \"outliers\": [\n");
        for (i, r) in a.outliers.iter().enumerate() {
            let error = match &r.error {
                Some(e) => json_str(e),
                None => "null".to_string(),
            };
            out.push_str(&format!(
                "    {{\"device\": {}, \"class\": {}, \"vram_gb\": {}, \"status\": {}, \"error\": {}, \"attainment\": {}, \"makespan_s\": {}, \"trace_digest\": \"{:016x}\", \"trace_rows\": {}}}{}\n",
                r.device,
                json_str(class_key(r.class)),
                r.vram_gb,
                json_str(r.status.key()),
                error,
                json_opt_num(r.attainment),
                json_num(r.makespan),
                r.trace_digest,
                r.trace_rows,
                if i + 1 < a.outliers.len() { "," } else { "" },
            ));
        }
        out.push_str("  ],\n");
        out.push_str(&format!(
            "  \"aggregation\": {{\"resident_cells\": {}, \"bound_cells\": {}, \"shards\": {}}}\n",
            self.resident_cells, self.bound_cells, self.shards,
        ));
        out.push_str("}\n");
        out
    }

    /// Human-oriented terminal summary.
    pub fn summary_table(&self) -> String {
        let a = &self.agg;
        let mut out = String::new();
        out.push_str(&format!(
            "fleet `{}`: {} devices, {} shards × {} (seed {}, mix {}, strategy {})\n",
            self.population.name,
            a.devices,
            self.shards,
            self.shard_size,
            self.population.seed,
            self.mix,
            self.strategy,
        ));
        out.push_str(&format!(
            "status: ok {} | failed {} | panicked {} | budget {} | timeout {} | retried {}\n",
            a.status[0], a.status[1], a.status[2], a.status[3], a.status[4], a.retried,
        ));
        let q = |v: Option<f64>| match v {
            Some(x) => format!("{x:.3}"),
            None => "n/a".to_string(),
        };
        out.push_str(&format!(
            "latency: n={} p50 {}s p90 {}s p99 {}s (±{:.1}% bin error)\n",
            a.latency_hist.count(),
            q(a.latency_hist.quantile(0.50)),
            q(a.latency_hist.quantile(0.90)),
            q(a.latency_hist.quantile(0.99)),
            a.latency_hist.error_bound() * 100.0,
        ));
        out.push_str(&format!(
            "attainment: p10 {} p50 {} p90 {} mean {}\n",
            q(a.attain_hist.quantile(0.10)),
            q(a.attain_hist.quantile(0.50)),
            q(a.attain_hist.quantile(0.90)),
            q(if a.attain_moments.count() == 0 {
                None
            } else {
                Some(a.attain_moments.mean())
            }),
        ));
        for t in &a.tiers {
            out.push_str(&format!(
                "  tier {:7} {:>3} GB: {:>4} devices ({} ok), attainment {}, p99 latency {}s\n",
                class_key(t.class),
                t.vram_gb,
                t.devices,
                t.ok,
                q(if t.attain.count() == 0 {
                    None
                } else {
                    Some(t.attain.mean())
                }),
                q(t.latency_hist.quantile(0.99)),
            ));
        }
        for r in &a.outliers {
            out.push_str(&format!(
                "  outlier device-{:05} {:7} {:>3} GB: {} attainment {}{}\n",
                r.device,
                class_key(r.class),
                r.vram_gb,
                r.status.key(),
                q(r.attainment),
                match &r.error {
                    Some(e) => format!(" ({e})"),
                    None => String::new(),
                },
            ));
        }
        out.push_str(&format!(
            "aggregation: {} resident cells (bound {})\n",
            self.resident_cells, self.bound_cells,
        ));
        out
    }
}

// ---------------------------------------------------------------------------
// Journal
// ---------------------------------------------------------------------------

/// One fleet journal line (with trailing newline). Same encoders as the
/// report, so a journal round-trip reproduces every float bit-exactly.
fn device_line(seed: u64, spec_digest: &str, rec: &DeviceRecord) -> String {
    let mut out = String::with_capacity(256);
    out.push_str("{\"v\": 1, \"fleet\": 1");
    out.push_str(&format!(", \"device\": {}", rec.device));
    out.push_str(&format!(", \"seed\": {seed}"));
    out.push_str(&format!(", \"spec_digest\": {}", json_str(spec_digest)));
    out.push_str(&format!(", \"status\": {}", json_str(rec.status.key())));
    match &rec.error {
        Some(e) => out.push_str(&format!(", \"error\": {}", json_str(e))),
        None => out.push_str(", \"error\": null"),
    }
    out.push_str(&format!(", \"retried\": {}", rec.retried));
    if rec.status.is_ok() {
        out.push_str(", \"record\": {");
        out.push_str(&format!("\"attainment\": {}", json_opt_num(rec.attainment)));
        out.push_str(&format!(", \"makespan_s\": {}", json_num(rec.makespan)));
        out.push_str(&format!(", \"e2e_latency_s\": {}", json_num(rec.e2e_latency)));
        out.push_str(&format!(", \"trace_digest\": \"{:016x}\"", rec.trace_digest));
        out.push_str(&format!(", \"trace_rows\": {}", rec.trace_rows));
        out.push_str(", \"latencies_s\": [");
        for (j, l) in rec.latencies.iter().enumerate() {
            if j > 0 {
                out.push_str(", ");
            }
            out.push_str(&json_num(*l));
        }
        out.push_str("]}");
    } else {
        out.push_str(", \"record\": null");
    }
    out.push_str("}\n");
    out
}

/// `Num` → the number; `null` → a non-finite stand-in (see the matrix
/// journal's identical convention).
fn jnum(v: &JsonValue) -> Option<f64> {
    match v {
        JsonValue::Num(n) => Some(*n),
        JsonValue::Null => Some(f64::INFINITY),
        _ => None,
    }
}

/// Reconstruct a device record from one validated journal entry; `None` on
/// any shape mismatch (the caller then just re-executes the device).
/// Class/VRAM are re-derived from the population — the spec digest already
/// guarantees the journal and the population agree.
fn record_from_journal(
    spec: &FleetSpec,
    index: usize,
    status: ScenarioStatus,
    v: &JsonValue,
) -> Option<DeviceRecord> {
    let mut rec = record_from(spec, index, status, None);
    rec.error = match v.get("error")? {
        JsonValue::Null => None,
        e => Some(e.as_str()?.to_string()),
    };
    rec.retried = v.get("retried")?.as_bool()?;
    if !status.is_ok() {
        return Some(rec);
    }
    let row = v.get("record")?;
    rec.attainment = match row.get("attainment")? {
        JsonValue::Num(n) => Some(*n),
        JsonValue::Null => None,
        _ => return None,
    };
    rec.makespan = jnum(row.get("makespan_s")?)?;
    rec.e2e_latency = jnum(row.get("e2e_latency_s")?)?;
    rec.trace_digest = u64::from_str_radix(row.get("trace_digest")?.as_str()?, 16).ok()?;
    rec.trace_rows = usize::try_from(row.get("trace_rows")?.as_u64()?).ok()?;
    let lats = match row.get("latencies_s")? {
        JsonValue::Arr(items) => items,
        _ => return None,
    };
    rec.latencies = Vec::with_capacity(lats.len());
    for l in lats {
        match l {
            JsonValue::Num(n) => rec.latencies.push(*n),
            _ => return None,
        }
    }
    Some(rec)
}

/// Replay a fleet journal into per-device slots. Same tolerance contract
/// as the matrix journal: unparseable lines and stale entries are skipped,
/// the last valid entry per device wins, `timeout`/`skipped` never resume.
fn load_fleet_journal(
    path: &Path,
    spec: &FleetSpec,
    spec_digest: &str,
) -> Result<Vec<Option<DeviceRecord>>> {
    let mut slots: Vec<Option<DeviceRecord>> = vec![None; spec.population.count];
    let text = match std::fs::read_to_string(path) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(slots),
        Err(e) => {
            return Err(e).with_context(|| format!("reading journal `{}`", path.display()))
        }
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = json_parse(line) else {
            continue;
        };
        if v.get("v").and_then(JsonValue::as_u64) != Some(1) {
            continue;
        }
        if v.get("fleet").and_then(JsonValue::as_u64) != Some(1) {
            continue;
        }
        if v.get("seed").and_then(JsonValue::as_u64) != Some(spec.population.seed) {
            continue;
        }
        if v.get("spec_digest").and_then(JsonValue::as_str) != Some(spec_digest) {
            continue;
        }
        let Some(index) = v
            .get("device")
            .and_then(JsonValue::as_u64)
            .and_then(|d| usize::try_from(d).ok())
        else {
            continue;
        };
        if index >= slots.len() {
            continue;
        }
        let Some(status) = v
            .get("status")
            .and_then(JsonValue::as_str)
            .and_then(ScenarioStatus::from_key)
        else {
            continue;
        };
        if matches!(status, ScenarioStatus::Timeout | ScenarioStatus::Skipped) {
            continue;
        }
        if let Some(rec) = record_from_journal(spec, index, status, &v) {
            slots[index] = Some(rec);
        }
    }
    Ok(slots)
}

// ---------------------------------------------------------------------------
// Runner
// ---------------------------------------------------------------------------

/// Run a fleet sweep: shard the population, execute each shard's devices in
/// index order on a work-stealing pool (stealing whole shards), fold every
/// device into its shard's [`FleetAggregate`] as it completes, then merge
/// the shard aggregates in canonical order. `Err` is reserved for
/// infrastructure problems (an unreadable or unwritable journal) — device
/// failures are aggregate rows, not errors.
pub fn run_fleet(spec: &FleetSpec, opts: &FleetOptions) -> Result<FleetReport> {
    let shards = spec.shards();
    let jobs = opts.jobs.clamp(1, shards);
    let spec_digest = spec.digest_hex();
    let prefilled: Vec<Option<DeviceRecord>> = if opts.resume {
        let path = opts
            .journal
            .as_ref()
            .context("resume requires a journal path")?;
        load_fleet_journal(path, spec, &spec_digest)?
    } else {
        vec![None; spec.population.count]
    };
    let journal = match &opts.journal {
        Some(path) => Some(Journal::open(path, opts.resume)?),
        None => None,
    };
    // Work-stealing over shard indices: a worker claims a whole shard and
    // folds its devices in index order, so per-shard aggregates (float
    // moment state included) are scheduling-independent.
    let cursor = AtomicUsize::new(0);
    let finished: Mutex<Vec<(usize, FleetAggregate)>> = Mutex::new(Vec::with_capacity(shards));
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut local = Vec::new();
                loop {
                    let s = cursor.fetch_add(1, Ordering::Relaxed);
                    if s >= shards {
                        break;
                    }
                    let (lo, hi) = spec.shard_range(s);
                    let mut agg = FleetAggregate::new(spec.outlier_k, spec.trace_window);
                    for i in lo..hi {
                        if let Some(rec) = &prefilled[i] {
                            // Resumed from the journal: fold the bit-exact
                            // record; no trace to retain, nothing to
                            // re-journal.
                            agg.fold(rec, None);
                            continue;
                        }
                        let (rec, trace) = supervise_device(spec, i, opts.watchdog);
                        if let Some(journal) = &journal {
                            // Timeouts are wall-clock artifacts: never
                            // checkpointed, so they always re-execute.
                            if rec.status != ScenarioStatus::Timeout {
                                journal.append_line(&device_line(
                                    spec.population.seed,
                                    &spec_digest,
                                    &rec,
                                ));
                            }
                        }
                        agg.fold(&rec, trace);
                        // `rec` (and, unless retained as an outlier, the
                        // trace) drops here — nothing per-device survives
                        // the fold.
                    }
                    local.push((s, agg));
                }
                finished
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .extend(local);
            });
        }
    });
    if let Some(journal) = &journal {
        if let Some(err) = journal.take_error() {
            anyhow::bail!("writing journal: {err}");
        }
    }
    let mut shard_aggs = finished.into_inner().unwrap_or_else(|e| e.into_inner());
    shard_aggs.sort_by_key(|(s, _)| *s);
    // Peak resident aggregation state: every shard aggregate alive at once,
    // just before the merge. Jobs- and resume-invariant by construction.
    let resident_cells: usize = shard_aggs.iter().map(|(_, a)| a.cells()).sum();
    let mut merged = FleetAggregate::new(spec.outlier_k, spec.trace_window);
    for (_, agg) in shard_aggs {
        merged.merge(agg);
    }
    Ok(FleetReport {
        spec_digest,
        population: spec.population.clone(),
        mix: spec.mix.name.to_string(),
        strategy: strategy_key(spec.strategy).to_string(),
        shard_size: spec.shard_size.max(1),
        shards,
        outlier_k: spec.outlier_k,
        trace_window: spec.trace_window.max(1),
        agg: merged,
        resident_cells,
        bound_cells: shards * FleetAggregate::shard_bound_cells(spec.outlier_k, spec.trace_window),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::population::DEVICE_CLASSES;

    fn tiny_spec(count: usize) -> FleetSpec {
        let mut spec = FleetSpec::new(PopulationSpec::default_population(count, 7));
        spec.shard_size = 4;
        spec.outlier_k = 3;
        spec
    }

    #[test]
    fn shard_partitioning_covers_population_exactly_once() {
        let spec = tiny_spec(10);
        assert_eq!(spec.shards(), 3);
        let mut seen = Vec::new();
        for s in 0..spec.shards() {
            let (lo, hi) = spec.shard_range(s);
            seen.extend(lo..hi);
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn digest_tracks_population_and_slice_but_not_sharding() {
        let spec = tiny_spec(10);
        let base = spec.digest_hex();
        let mut resharded = spec.clone();
        resharded.shard_size = 2;
        resharded.outlier_k = 1;
        assert_eq!(base, resharded.digest_hex());
        let mut reseeded = spec.clone();
        reseeded.population.seed = 8;
        assert_ne!(base, reseeded.digest_hex());
        let mut restrategied = spec.clone();
        restrategied.strategy = Strategy::FairShare;
        assert_ne!(base, restrategied.digest_hex());
    }

    #[test]
    fn outlier_list_is_bounded_and_worst_first() {
        let spec = tiny_spec(10);
        let mut agg = FleetAggregate::new(3, 8);
        for i in 0..10 {
            let mut rec = record_from(&spec, i, ScenarioStatus::Ok, None);
            rec.attainment = Some(i as f64 / 10.0);
            agg.fold(&rec, None);
        }
        let ranks: Vec<usize> = agg.outliers().iter().map(|r| r.device).collect();
        assert_eq!(ranks, vec![0, 1, 2]);
        // A non-ok record outranks (sorts before) every ok attainment.
        let rec = record_from(&spec, 9, ScenarioStatus::Panicked, Some("boom".into()));
        agg.fold(&rec, None);
        assert_eq!(agg.outliers()[0].device, 9);
        assert_eq!(agg.outliers().len(), 3);
    }

    #[test]
    fn aggregate_merge_matches_single_fold() {
        let spec = tiny_spec(12);
        let mut recs = Vec::new();
        for i in 0..12 {
            let mut rec = record_from(&spec, i, ScenarioStatus::Ok, None);
            rec.attainment = Some((i % 5) as f64 / 4.0);
            rec.makespan = 1.0 + i as f64;
            rec.latencies = vec![0.01 * (i + 1) as f64, 0.2];
            recs.push(rec);
        }
        let mut whole = FleetAggregate::new(4, 8);
        for r in &recs {
            whole.fold(r, None);
        }
        let mut left = FleetAggregate::new(4, 8);
        let mut right = FleetAggregate::new(4, 8);
        for r in &recs[..6] {
            left.fold(r, None);
        }
        for r in &recs[6..] {
            right.fold(r, None);
        }
        left.merge(right);
        assert_eq!(whole.device_count(), left.device_count());
        assert_eq!(whole.latency_count(), left.latency_count());
        assert_eq!(whole.latency_quantile(0.5), left.latency_quantile(0.5));
        assert_eq!(whole.latency_quantile(0.99), left.latency_quantile(0.99));
        assert_eq!(whole.attainment_quantile(0.5), left.attainment_quantile(0.5));
        assert_eq!(
            whole.outliers().iter().map(|r| r.device).collect::<Vec<_>>(),
            left.outliers().iter().map(|r| r.device).collect::<Vec<_>>(),
        );
        assert_eq!(whole.tiers().len(), left.tiers().len());
    }

    #[test]
    fn cells_accounting_is_capacity_based_and_bounded() {
        let spec = tiny_spec(40);
        let bound = FleetAggregate::shard_bound_cells(spec.outlier_k, spec.trace_window);
        let mut agg = FleetAggregate::new(spec.outlier_k, spec.trace_window);
        for i in 0..40 {
            let mut rec = record_from(&spec, i, ScenarioStatus::Ok, None);
            rec.attainment = Some(0.5);
            rec.latencies = vec![0.1; 4];
            agg.fold(&rec, None);
        }
        assert!(agg.cells() <= bound, "{} > {}", agg.cells(), bound);
        // The bound is a pure function of the knobs — no device-count term.
        assert_eq!(
            bound,
            FleetAggregate::shard_bound_cells(spec.outlier_k, spec.trace_window)
        );
    }

    #[test]
    fn device_line_roundtrips_bit_exactly() {
        let spec = tiny_spec(10);
        let mut rec = record_from(&spec, 3, ScenarioStatus::Ok, None);
        rec.attainment = Some(0.875);
        rec.makespan = 12.125;
        rec.e2e_latency = 11.0625;
        rec.trace_digest = 0xdead_beef_0123_4567;
        rec.trace_rows = 96;
        rec.latencies = vec![0.1, 0.30000000000000004, 2.5];
        let line = device_line(spec.population.seed, "cafebabe", &rec);
        let v = json_parse(line.trim()).expect("journal line parses");
        let status = ScenarioStatus::from_key(v.get("status").unwrap().as_str().unwrap()).unwrap();
        let back = record_from_journal(&spec, 3, status, &v).expect("roundtrip");
        assert_eq!(back.latencies, rec.latencies);
        assert_eq!(back.makespan.to_bits(), rec.makespan.to_bits());
        assert_eq!(back.trace_digest, rec.trace_digest);
        assert_eq!(back.attainment, rec.attainment);
        // Re-rendering the reconstructed record reproduces the line.
        assert_eq!(device_line(spec.population.seed, "cafebabe", &back), line);
    }

    #[test]
    fn failed_device_line_roundtrips() {
        let spec = tiny_spec(10);
        let mut rec = record_from(
            &spec,
            7,
            ScenarioStatus::Failed,
            Some("setup OOM: 9 GB model into 4 GB VRAM".to_string()),
        );
        rec.retried = true;
        let line = device_line(spec.population.seed, "cafebabe", &rec);
        let v = json_parse(line.trim()).expect("line parses");
        let status = ScenarioStatus::from_key(v.get("status").unwrap().as_str().unwrap()).unwrap();
        let back = record_from_journal(&spec, 7, status, &v).expect("roundtrip");
        assert_eq!(back.status, ScenarioStatus::Failed);
        assert!(back.retried);
        assert_eq!(back.error.as_deref(), Some("setup OOM: 9 GB model into 4 GB VRAM"));
        assert_eq!(device_line(spec.population.seed, "cafebabe", &back), line);
    }

    #[test]
    fn tier_table_stays_sorted_and_bounded() {
        let spec = FleetSpec::new(PopulationSpec::default_population(300, 11));
        let mut agg = FleetAggregate::new(2, 8);
        for i in 0..300 {
            let mut rec = record_from(&spec, i, ScenarioStatus::Ok, None);
            rec.attainment = Some(0.9);
            agg.fold(&rec, None);
        }
        let keys: Vec<(usize, u64)> = agg
            .tiers()
            .iter()
            .map(|t| (t.class as usize, t.vram_gb))
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        assert!(keys.len() <= MAX_TIERS, "{} tiers", keys.len());
        assert!(DEVICE_CLASSES.len() <= keys.len());
    }
}
