//! Deterministic feedback controller for the adaptive serving layer.
//!
//! The paper's §5.2 "practical insight" — SLO-aware scheduling recovers
//! chat attainment that static configurations lose under contention — is
//! made a *runtime* mechanism here: the controller samples per-app SLO
//! attainment over a sliding window of **virtual time** and issues
//! reconfiguration actions (migrate the shared server's KV cache, grow or
//! shrink the `SloAware` SM reservation, resize serving slots) from a pure
//! function of the observed metrics.
//!
//! # Determinism contract
//!
//! The controller holds no clock and draws no randomness.
//! [`Controller::decide`] is a pure function of (the observation window,
//! the observed reserve/server state, its own cooldown counters) — all of
//! which are
//! themselves deterministic products of the scenario seed. The executor
//! invokes it at fixed virtual-time epoch boundaries, so two runs with the
//! same seed issue byte-identical action sequences and the engine traces —
//! including every reconfiguration event — digest identically. This is what
//! lets the scenario matrix treat `server_mode: adaptive` as just another
//! axis with golden, byte-reproducible reports.

use std::collections::VecDeque;

use crate::server::KvPlacement;

/// Tunables of the feedback loop (the YAML `controller:` block).
#[derive(Debug, Clone, PartialEq)]
pub struct ControllerConfig {
    /// Virtual-time spacing of controller decisions (seconds).
    pub epoch: f64,
    /// Sliding observation window (seconds of virtual time).
    pub window: f64,
    /// SLO-attainment target for latency-sensitive apps.
    pub target: f64,
    /// Reserve adjustment per action under `SloAware`.
    pub reserve_step: usize,
    pub max_reserve: usize,
    pub min_reserve: usize,
    /// Decision epochs to hold off after acting, so an action's effect
    /// shows up in the window before the controller reacts again.
    pub cooldown_epochs: u32,
    /// Minimum tight-SLO observations in the window before acting.
    pub min_observations: usize,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            epoch: 2.0,
            window: 8.0,
            target: 0.9,
            reserve_step: 8,
            max_reserve: 32,
            min_reserve: 4,
            cooldown_epochs: 2,
            min_observations: 3,
        }
    }
}

/// One completed request as the controller sees it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    /// Virtual completion time.
    pub end: f64,
    pub slo_met: bool,
    /// Whether the app carries a tight (sub-second-scale) SLO — only these
    /// drive the feedback loop.
    pub tight: bool,
}

/// Server state observed at decision time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerView {
    pub kv_placement: KvPlacement,
    pub n_slots: usize,
    /// Whether the server currently holds queued or active work.
    pub busy: bool,
    /// Whether the KV region would currently fit in VRAM (always true when
    /// it already lives there). An infeasible onload must not pin the
    /// escalation ladder on its first rung — `decide` falls through to the
    /// next knob instead.
    pub kv_fits_gpu: bool,
}

/// A reconfiguration decision. The executor validates feasibility (e.g.
/// VRAM headroom for a KV onload) before applying — a skipped action is
/// itself deterministic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ControllerAction {
    /// Set the `SloAware` SM reservation.
    SetReserve { reserve_sms: usize },
    /// Migrate server `server`'s KV region to `to`.
    MigrateKv { server: usize, to: KvPlacement },
    /// Resize server `server` to `n_slots` concurrent sequences.
    ResizeSlots { server: usize, n_slots: usize },
}

impl std::fmt::Display for ControllerAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControllerAction::SetReserve { reserve_sms } => {
                write!(f, "set-reserve({reserve_sms})")
            }
            ControllerAction::MigrateKv { server, to } => {
                write!(f, "migrate-kv(server{server} -> {to})")
            }
            ControllerAction::ResizeSlots { server, n_slots } => {
                write!(f, "resize-slots(server{server} -> {n_slots})")
            }
        }
    }
}

/// The feedback controller.
pub struct Controller {
    cfg: ControllerConfig,
    window: VecDeque<Observation>,
    /// Epochs left before the next action may fire.
    cooldown: u32,
    /// Consecutive healthy epochs (hysteresis for releasing the reserve).
    healthy_epochs: u32,
    /// `(virtual time, rendered action)` log for reports.
    log: Vec<(f64, String)>,
}

impl Controller {
    pub fn new(cfg: ControllerConfig) -> Self {
        assert!(cfg.epoch > 0.0, "controller epoch must be > 0");
        assert!(cfg.window >= cfg.epoch, "window must cover >= one epoch");
        assert!(
            cfg.target > 0.0 && cfg.target <= 1.0,
            "target attainment must be in (0, 1]"
        );
        Controller {
            cfg,
            window: VecDeque::new(),
            cooldown: 0,
            healthy_epochs: 0,
            log: Vec::new(),
        }
    }

    pub fn config(&self) -> &ControllerConfig {
        &self.cfg
    }

    /// Record a completed request. Only tight-SLO observations enter the
    /// window — everything else is invisible to the feedback loop.
    pub fn observe(&mut self, obs: Observation) {
        if obs.tight {
            self.window.push_back(obs);
        }
    }

    /// Time-stamped rendering of every action issued so far.
    pub fn log(&self) -> &[(f64, String)] {
        &self.log
    }

    /// A fault transition landed (chaos injection). The regime just
    /// changed underneath the controller, so whatever it believed about
    /// the recent past is stale: drop the action cooldown and the healthy
    /// streak so the next epoch can react immediately instead of waiting
    /// out a gate earned under the old regime.
    pub fn observe_fault(&mut self, now: f64) {
        self.cooldown = 0;
        self.healthy_epochs = 0;
        self.log.push((now, "observed-fault".into()));
    }

    /// Tight-SLO attainment over the window ending at `now`, with the
    /// sample count. When fewer than `min_observations` completions fall
    /// inside the time window — the slow regime where a single contended
    /// request outlasts it, which is precisely when intervention matters —
    /// the freshest `min_observations` completions are used instead.
    pub fn window_attainment(&self, now: f64) -> Option<(f64, usize)> {
        let cutoff = now - self.cfg.window;
        let in_window = self.window.iter().filter(|o| o.end >= cutoff).count();
        let samples: Vec<bool> = if in_window >= self.cfg.min_observations {
            self.window
                .iter()
                .filter(|o| o.end >= cutoff)
                .map(|o| o.slo_met)
                .collect()
        } else {
            self.window
                .iter()
                .rev()
                .take(self.cfg.min_observations)
                .map(|o| o.slo_met)
                .collect()
        };
        if samples.is_empty() {
            return None;
        }
        let met = samples.iter().filter(|&&m| m).count();
        Some((met as f64 / samples.len() as f64, samples.len()))
    }

    /// The decision function, invoked once per epoch at virtual time `now`.
    ///
    /// Escalation ladder when tight-SLO attainment falls below target,
    /// biggest hammer first (mirroring §4.2.1's root cause ordering):
    /// 1. a busy server with a CPU-resident KV cache whose region would
    ///    fit in VRAM → migrate it to the GPU (the dominant interference
    ///    source);
    /// 2. grow the `SloAware` SM reservation, when the policy carries one;
    /// 3. shrink a busy server's slots so long prefills stop crowding the
    ///    unified batch.
    ///
    /// When attainment holds above target for consecutive epochs, the SM
    /// reservation is released back toward `min_reserve` (work
    /// conservation). KV migration is one-way hysteresis: the controller
    /// never migrates back to the CPU, avoiding oscillation.
    pub fn decide(
        &mut self,
        now: f64,
        reserve: Option<usize>,
        servers: &[ServerView],
    ) -> Vec<ControllerAction> {
        // Evict observations that fell out of the window, always retaining
        // the freshest `min_observations` (see `window_attainment`).
        let cutoff = now - self.cfg.window;
        while self.window.len() > self.cfg.min_observations
            && self.window.front().is_some_and(|o| o.end < cutoff)
        {
            self.window.pop_front();
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return Vec::new();
        }
        // Whether the time window itself holds enough samples; the
        // freshest-K fallback may only *escalate* (stale misses are still
        // misses), never certify health (stale successes say nothing about
        // requests currently stuck in flight).
        let in_window = self.window.iter().filter(|o| o.end >= cutoff).count();
        let fresh = in_window >= self.cfg.min_observations;
        let Some((attainment, samples)) = self.window_attainment(now) else {
            return Vec::new();
        };
        if samples < self.cfg.min_observations {
            return Vec::new();
        }

        let mut actions = Vec::new();
        if attainment < self.cfg.target {
            self.healthy_epochs = 0;
            if let Some((i, _)) = servers
                .iter()
                .enumerate()
                .find(|(_, s)| s.kv_placement == KvPlacement::Cpu && s.busy && s.kv_fits_gpu)
            {
                actions.push(ControllerAction::MigrateKv {
                    server: i,
                    to: KvPlacement::Gpu,
                });
            } else if let Some(r) = reserve {
                let next = (r + self.cfg.reserve_step).min(self.cfg.max_reserve);
                // Strict inequality: a no-op SetReserve would reset the
                // cooldown and wedge the ladder without changing anything.
                if next > r {
                    actions.push(ControllerAction::SetReserve { reserve_sms: next });
                }
            }
            if actions.is_empty() {
                if let Some((i, s)) = servers
                    .iter()
                    .enumerate()
                    .find(|(_, s)| s.busy && s.n_slots > 2)
                {
                    actions.push(ControllerAction::ResizeSlots {
                        server: i,
                        n_slots: s.n_slots - 1,
                    });
                }
            }
            if !actions.is_empty() {
                self.cooldown = self.cfg.cooldown_epochs;
            }
        } else if fresh {
            self.healthy_epochs += 1;
            if self.healthy_epochs >= self.cfg.cooldown_epochs.max(1) {
                if let Some(r) = reserve {
                    let next = r
                        .saturating_sub(self.cfg.reserve_step)
                        .max(self.cfg.min_reserve);
                    if next < r {
                        actions.push(ControllerAction::SetReserve { reserve_sms: next });
                        self.healthy_epochs = 0;
                    }
                }
            }
        }
        actions
    }

    /// Record what the executor did with a decided action. `applied:
    /// false` marks a deterministic feasibility skip (e.g. the previous
    /// reconfiguration has not landed yet) and is rendered with a
    /// `skipped ` prefix so reports distinguish decided from done.
    pub fn record_outcome(&mut self, now: f64, action: ControllerAction, applied: bool) {
        let prefix = if applied { "" } else { "skipped " };
        self.log.push((now, format!("{prefix}{action}")));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(end: f64, slo_met: bool) -> Observation {
        Observation {
            end,
            slo_met,
            tight: true,
        }
    }

    fn cpu_server(busy: bool) -> ServerView {
        ServerView {
            kv_placement: KvPlacement::Cpu,
            n_slots: 4,
            busy,
            kv_fits_gpu: true,
        }
    }

    #[test]
    fn no_action_without_enough_observations() {
        let mut c = Controller::new(ControllerConfig::default());
        c.observe(obs(1.0, false));
        assert!(c.decide(2.0, Some(8), &[cpu_server(true)]).is_empty());
    }

    #[test]
    fn missed_slo_migrates_busy_cpu_kv_server_first() {
        let mut c = Controller::new(ControllerConfig::default());
        for i in 0..4 {
            c.observe(obs(i as f64 * 0.5, false));
        }
        let actions = c.decide(3.0, Some(8), &[cpu_server(true)]);
        assert_eq!(
            actions,
            vec![ControllerAction::MigrateKv {
                server: 0,
                to: KvPlacement::Gpu
            }]
        );
        // Cooldown suppresses the next decisions.
        for _ in 0..ControllerConfig::default().cooldown_epochs {
            assert!(c.decide(4.0, Some(8), &[cpu_server(true)]).is_empty());
        }
    }

    #[test]
    fn idle_cpu_kv_server_is_not_migrated() {
        let mut c = Controller::new(ControllerConfig::default());
        for i in 0..4 {
            c.observe(obs(i as f64 * 0.5, false));
        }
        // Idle server: fall through to the reserve ladder.
        let actions = c.decide(3.0, Some(8), &[cpu_server(false)]);
        assert_eq!(actions, vec![ControllerAction::SetReserve { reserve_sms: 16 }]);
    }

    #[test]
    fn infeasible_migration_falls_through_to_the_next_rung() {
        // A busy CPU-KV server whose region cannot fit must not pin the
        // ladder on an action the executor would skip forever.
        let mut c = Controller::new(ControllerConfig::default());
        for i in 0..4 {
            c.observe(obs(i as f64 * 0.5, false));
        }
        let blocked = ServerView {
            kv_fits_gpu: false,
            ..cpu_server(true)
        };
        let actions = c.decide(3.0, Some(8), &[blocked]);
        assert_eq!(actions, vec![ControllerAction::SetReserve { reserve_sms: 16 }]);
        // And with no reserve either, the slot knob is reached.
        let mut c = Controller::new(ControllerConfig::default());
        for i in 0..4 {
            c.observe(obs(i as f64 * 0.5, false));
        }
        let actions = c.decide(3.0, None, &[blocked]);
        assert_eq!(
            actions,
            vec![ControllerAction::ResizeSlots { server: 0, n_slots: 3 }]
        );
    }

    #[test]
    fn reserve_grows_until_max_then_slots_shrink() {
        let cfg = ControllerConfig {
            cooldown_epochs: 0,
            ..ControllerConfig::default()
        };
        let mut c = Controller::new(cfg.clone());
        for i in 0..6 {
            c.observe(obs(i as f64 * 0.1, false));
        }
        let gpu_server = ServerView {
            kv_placement: KvPlacement::Gpu,
            n_slots: 4,
            busy: true,
            kv_fits_gpu: true,
        };
        // At max reserve the controller reaches for the slot knob.
        let actions = c.decide(1.0, Some(cfg.max_reserve), &[gpu_server]);
        assert_eq!(
            actions,
            vec![ControllerAction::ResizeSlots { server: 0, n_slots: 3 }]
        );
    }

    #[test]
    fn sustained_health_releases_reserve_with_hysteresis() {
        let cfg = ControllerConfig::default();
        let mut c = Controller::new(cfg.clone());
        for i in 0..5 {
            c.observe(obs(10.0 + i as f64 * 0.1, true));
        }
        // First healthy epoch: hysteresis holds.
        assert!(c.decide(11.0, Some(16), &[]).is_empty());
        // Second: release one step.
        let actions = c.decide(11.5, Some(16), &[]);
        assert_eq!(actions, vec![ControllerAction::SetReserve { reserve_sms: 8 }]);
        // Never below the floor.
        assert!(c.decide(11.6, Some(cfg.min_reserve), &[]).is_empty());
        assert!(c.decide(11.7, Some(cfg.min_reserve), &[]).is_empty());
    }

    #[test]
    fn stale_successes_do_not_certify_health() {
        // The freshest-K fallback may escalate on stale misses, but stale
        // successes say nothing about requests currently stuck in flight:
        // the reserve must not be released during total completion
        // starvation.
        let mut c = Controller::new(ControllerConfig::default());
        for i in 0..5 {
            c.observe(obs(1.0 + i as f64 * 0.1, true));
        }
        for t in [100.0, 102.0, 104.0, 106.0] {
            assert!(
                c.decide(t, Some(16), &[]).is_empty(),
                "stale successes released the reserve at t={t}"
            );
        }
    }

    #[test]
    fn slow_regime_retains_the_freshest_observations() {
        // Requests can outlast the time window under heavy contention —
        // the controller must still reason over the freshest completions
        // rather than going blind exactly when intervention matters.
        let mut c = Controller::new(ControllerConfig::default());
        for i in 0..5 {
            c.observe(obs(i as f64 * 0.1, false));
        }
        let (att, samples) = c.window_attainment(100.0).unwrap();
        assert_eq!(att, 0.0);
        assert_eq!(samples, ControllerConfig::default().min_observations);
        let actions = c.decide(100.0, Some(8), &[cpu_server(true)]);
        assert_eq!(
            actions,
            vec![ControllerAction::MigrateKv {
                server: 0,
                to: KvPlacement::Gpu
            }]
        );
        // Eviction keeps exactly the retained minimum.
        assert!(c.window_attainment(100.0).is_some());
    }

    #[test]
    fn non_tight_observations_are_invisible() {
        let mut c = Controller::new(ControllerConfig::default());
        for i in 0..6 {
            c.observe(Observation {
                end: i as f64,
                slo_met: false,
                tight: false,
            });
        }
        assert_eq!(c.window_attainment(6.0), None);
        assert!(c.decide(6.0, Some(8), &[cpu_server(true)]).is_empty());
    }

    #[test]
    fn decisions_are_reproducible() {
        let run = || {
            let mut c = Controller::new(ControllerConfig::default());
            let mut out = Vec::new();
            for step in 0..20 {
                let t = step as f64;
                c.observe(obs(t, step % 3 == 0));
                out.extend(c.decide(t, Some(8), &[cpu_server(true)]));
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn log_distinguishes_applied_from_skipped() {
        let mut c = Controller::new(ControllerConfig::default());
        for i in 0..4 {
            c.observe(obs(2.0 + i as f64 * 0.1, false));
        }
        let actions = c.decide(3.0, None, &[cpu_server(true)]);
        assert_eq!(actions.len(), 1);
        assert!(c.log().is_empty(), "decide only decides; the executor logs");
        c.record_outcome(3.0, actions[0], true);
        c.record_outcome(3.5, actions[0], false);
        assert_eq!(c.log().len(), 2);
        assert!(c.log()[0].1.starts_with("migrate-kv"));
        assert_eq!(c.log()[0].0, 3.0);
        assert!(c.log()[1].1.starts_with("skipped migrate-kv"));
    }
}
