//! Workflow configuration schema (the paper's YAML input, Fig. 2 / Fig. 23).
//!
//! A configuration has:
//!
//! * **Task definitions** — top-level mappings naming an application
//!   instance: `"Creating Cover Art (ImageGen)"` with `model`,
//!   `num_requests`, `device`, `slo`, `mps`, and optionally `server` (route
//!   requests through a shared inference server).
//! * **`workflows:`** — DAG nodes: `uses` references a task, `depend_on`
//!   lists upstream node ids, `background` marks long-running tasks.
//! * **Benchmark-level keys** — `strategy` (greedy | partition |
//!   fair_share), `testbed` (intel_server | macbook_m1_pro), `seed`,
//!   and a `servers:` section defining shared llama.cpp-style servers.
//!
//! Without a `workflows:` section every task becomes an independent root
//! node (the concurrent-execution scenarios of §4.2).

use std::collections::BTreeSet;

use anyhow::{bail, Context, Result};

use crate::coordinator::controller::ControllerConfig;
use crate::gpusim::backend::KernelBackend;
use crate::gpusim::chaos::{ChaosConfig, ChaosKind};
use crate::gpusim::kernel::Device;
use crate::gpusim::queue::QueueBackend;
use crate::gpusim::trace::{TraceMode, DEFAULT_STREAM_WINDOW};
use crate::server::KvPlacement;
use crate::util::yaml::{self, Value};

/// Application class of a task (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppType {
    Chatbot,
    DeepResearch,
    ImageGen,
    LiveCaptions,
}

impl AppType {
    pub fn parse(s: &str) -> Option<AppType> {
        match s.to_ascii_lowercase().replace(['-', '_', ' '], "").as_str() {
            "chatbot" | "chat" => Some(AppType::Chatbot),
            "deepresearch" | "research" => Some(AppType::DeepResearch),
            "imagegen" | "imagegeneration" => Some(AppType::ImageGen),
            "livecaptions" | "livecaption" | "captions" => Some(AppType::LiveCaptions),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AppType::Chatbot => "Chatbot",
            AppType::DeepResearch => "DeepResearch",
            AppType::ImageGen => "ImageGen",
            AppType::LiveCaptions => "LiveCaptions",
        }
    }
}

/// SLO specification, possibly overriding the app default.
#[derive(Debug, Clone, PartialEq)]
pub enum SloSpec {
    /// Single bound (step time / segment time / e2e latency).
    Single(f64),
    /// `[ttft, tpot]` for chat.
    Chat(f64, f64),
}

/// Arrival-process override for a task (`arrival:` key).
///
/// Without an override each application uses its built-in client model
/// (closed loop for Chatbot/ImageGen/DeepResearch, the fixed audio cadence
/// for LiveCaptions). Overrides let a scenario model open-loop heavy
/// traffic instead of `num_requests` back-to-back requests:
///
/// ```yaml
/// Chat (chatbot):
///   num_requests: 20
///   arrival: poisson      # also: closed | periodic | trace
///   rate: 2.0             # requests/second (poisson)
/// ```
///
/// `closed` takes `think:`, `periodic` takes `period:`, `trace` takes
/// `trace: [0, 0.5s, ...]` (non-decreasing offsets from the task start).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    Closed { think: f64 },
    Periodic { period: f64 },
    Poisson { rate: f64 },
    Trace { offsets: Vec<f64> },
}

/// One task definition.
#[derive(Debug, Clone)]
pub struct TaskConfig {
    pub name: String,
    pub app_type: AppType,
    pub model: Option<String>,
    pub num_requests: usize,
    pub device: Device,
    pub slo: Option<SloSpec>,
    /// MPS active-thread percentage (0–100]; used by the partition strategy.
    pub mps: f64,
    /// Shared-server routing (references `servers:`).
    pub server: Option<String>,
    /// Arrival-process override (None → the application's built-in model).
    pub arrival: Option<ArrivalSpec>,
    /// Kernel implementation serving this task's model (`backend:` key).
    /// Configs that name none run `TunedNative` — the pre-backend-axis
    /// behaviour, now explicit. Server-routed tasks execute their GPU work
    /// under the *server's* backend; this field then only shapes the
    /// task-local (non-server) jobs.
    pub backend: KernelBackend,
}

/// One workflow DAG node.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkflowNodeConfig {
    pub id: String,
    pub uses: String,
    pub depend_on: Vec<String>,
    pub background: bool,
}

/// Shared inference-server definition.
#[derive(Debug, Clone)]
pub struct ServerDef {
    pub name: String,
    pub model: Option<String>,
    pub context_window: usize,
    pub kv_placement: KvPlacement,
    pub n_slots: usize,
    /// Max tokens per unified batch (runtime-tunable, like `n_slots` and
    /// `kv_placement` — see `server::ServerTuning`).
    pub batch_size: usize,
    /// Kernel implementation for the server's batched iterations
    /// (`backend:` key; default `TunedNative` = llama.cpp).
    pub backend: KernelBackend,
}

/// GPU sharing strategy (§3.2 resource orchestrator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Greedy,
    Partition,
    FairShare,
    /// §5.2 extension: latency-sensitive clients get scheduling priority
    /// plus a small SM reservation (see `gpusim::Policy::SloAware`).
    SloAware,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().replace(['-', ' '], "_").as_str() {
            "greedy" => Some(Strategy::Greedy),
            "partition" | "static_partition" | "mps" => Some(Strategy::Partition),
            "fair_share" | "fairshare" | "fair" => Some(Strategy::FairShare),
            "slo_aware" | "sloaware" => Some(Strategy::SloAware),
            _ => None,
        }
    }
}

/// Which simulated testbed to run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TestbedKind {
    IntelServer,
    MacbookM1Pro,
}

/// Supervision-test fault hook (`inject_failure:` key): make the executor
/// fail *deterministically* at run start, before any virtual time elapses.
/// Exists so sweep-resilience tests and CI can exercise panic isolation and
/// quarantine without contriving a genuinely broken workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectFailure {
    /// `panic!` inside the executor (exercises `catch_unwind` isolation).
    Panic,
    /// Return an ordinary `Err` from the executor.
    Error,
}

/// The full parsed benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub tasks: Vec<TaskConfig>,
    pub workflow: Vec<WorkflowNodeConfig>,
    pub servers: Vec<ServerDef>,
    pub strategy: Strategy,
    pub testbed: TestbedKind,
    pub seed: u64,
    /// Adaptive-serving feedback controller (`controller:` block). `None`
    /// keeps every server/policy configuration static for the run.
    pub controller: Option<ControllerConfig>,
    /// End-to-end workflow SLO (`workflow_slo:` key, seconds): the bound on
    /// the latest completion of any foreground workflow node, evaluated
    /// alongside the per-node `slo:` bounds. `None` = no workflow-level SLO.
    pub workflow_slo: Option<f64>,
    /// Deterministic fault injection (`chaos:` block). `None` = no faults,
    /// the pre-chaos behaviour of every existing config.
    pub chaos: Option<ChaosConfig>,
    /// Deterministic event budget (`budget_events:` key): the executor
    /// aborts with a typed `BudgetExhausted` error once the engine has
    /// processed this many events. `None` → the built-in default. A pure
    /// function of the config, so exhaustion is digest-stable.
    pub budget_events: Option<u64>,
    /// Deterministic virtual-time budget in seconds
    /// (`budget_virtual_time:` key). `None` → the built-in default.
    pub budget_virtual_time: Option<f64>,
    /// Supervision-test fault hook (`inject_failure: panic|error`).
    pub inject_failure: Option<InjectFailure>,
    /// Event-queue backend for the engine (`event_queue: heap|wheel`).
    /// Both produce byte-identical traces; `wheel` trades the heap's
    /// O(log n) pops for amortized O(1) bucket operations.
    pub event_queue: QueueBackend,
    /// Trace recording mode (`trace_mode: full|streaming` plus optional
    /// `trace_window: N`). Streaming folds rows into the digest and running
    /// aggregates, keeping only the last N rows materialized — peak trace
    /// memory O(N) instead of O(events).
    pub trace_mode: TraceMode,
}

impl BenchConfig {
    /// Parse a YAML document.
    pub fn parse(text: &str) -> Result<BenchConfig> {
        let root = yaml::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut tasks = Vec::new();
        let mut workflow = Vec::new();
        let mut servers = Vec::new();
        let mut strategy = Strategy::Greedy;
        let mut testbed = TestbedKind::IntelServer;
        let mut seed = 42u64;
        let mut controller = None;
        let mut workflow_slo = None;
        let mut chaos = None;
        let mut budget_events = None;
        let mut budget_virtual_time = None;
        let mut inject_failure = None;
        let mut event_queue = QueueBackend::default();
        let mut trace_mode_key: Option<String> = None;
        let mut trace_window: Option<usize> = None;

        for key in root.keys() {
            let value = root.get(key).unwrap();
            match key {
                "workflows" => workflow = parse_workflows(value)?,
                "servers" => servers = parse_servers(value)?,
                "controller" => controller = parse_controller(value)?,
                "chaos" => chaos = parse_chaos(value)?,
                "workflow_slo" => {
                    let bound = parse_duration_value("workflow_slo", value)?;
                    if bound <= 0.0 {
                        bail!("workflow_slo must be > 0");
                    }
                    workflow_slo = Some(bound);
                }
                "budget_events" => {
                    let n = value.as_i64().context("budget_events must be an integer")?;
                    if n <= 0 {
                        bail!("budget_events must be > 0");
                    }
                    budget_events = Some(n as u64);
                }
                "budget_virtual_time" => {
                    let t = parse_duration_value("budget_virtual_time", value)?;
                    if t <= 0.0 {
                        bail!("budget_virtual_time must be > 0");
                    }
                    budget_virtual_time = Some(t);
                }
                "inject_failure" => {
                    let s = value.as_str().context("inject_failure must be a string")?;
                    inject_failure = Some(match s {
                        "panic" => InjectFailure::Panic,
                        "error" => InjectFailure::Error,
                        other => bail!("unknown inject_failure `{other}` (panic | error)"),
                    });
                }
                "strategy" => {
                    let s = value.as_str().context("strategy must be a string")?;
                    strategy =
                        Strategy::parse(s).with_context(|| format!("unknown strategy `{s}`"))?;
                }
                "testbed" => {
                    let s = value.as_str().context("testbed must be a string")?;
                    testbed = match s {
                        "intel_server" => TestbedKind::IntelServer,
                        "macbook_m1_pro" => TestbedKind::MacbookM1Pro,
                        other => bail!("unknown testbed `{other}`"),
                    };
                }
                "seed" => {
                    seed = value.as_i64().context("seed must be an integer")? as u64;
                }
                "event_queue" => {
                    let s = value.as_str().context("event_queue must be a string")?;
                    event_queue = QueueBackend::parse(s)
                        .with_context(|| format!("unknown event_queue `{s}` (heap | wheel)"))?;
                }
                "trace_mode" => {
                    let s = value.as_str().context("trace_mode must be a string")?;
                    trace_mode_key = Some(s.to_string());
                }
                "trace_window" => {
                    let n = value.as_i64().context("trace_window must be an integer")?;
                    if n < 1 {
                        bail!("trace_window must be >= 1");
                    }
                    trace_window = Some(n as usize);
                }
                _ => tasks.push(parse_task(key, value)?),
            }
        }

        if tasks.is_empty() {
            bail!("configuration defines no tasks");
        }
        // `trace_window` only means something under streaming: a window on
        // a config that materializes everything would silently do nothing.
        let trace_mode = match trace_mode_key.as_deref() {
            None | Some("full") => {
                if let Some(w) = trace_window {
                    bail!("trace_window ({w}) requires `trace_mode: streaming`");
                }
                TraceMode::Full
            }
            Some("streaming") => TraceMode::Streaming {
                window: trace_window.unwrap_or(DEFAULT_STREAM_WINDOW),
            },
            Some(other) => bail!("unknown trace_mode `{other}` (full | streaming)"),
        };
        // Implicit workflow: every task is a root node.
        if workflow.is_empty() {
            workflow = tasks
                .iter()
                .map(|t| WorkflowNodeConfig {
                    id: t.name.clone(),
                    uses: t.name.clone(),
                    depend_on: Vec::new(),
                    background: false,
                })
                .collect();
        }
        let cfg = BenchConfig {
            tasks,
            workflow,
            servers,
            strategy,
            testbed,
            seed,
            controller,
            workflow_slo,
            chaos,
            budget_events,
            budget_virtual_time,
            inject_failure,
            event_queue,
            trace_mode,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<BenchConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        BenchConfig::parse(&text)
    }

    pub fn task(&self, name: &str) -> Option<&TaskConfig> {
        self.tasks.iter().find(|t| t.name == name)
    }

    pub fn server(&self, name: &str) -> Option<&ServerDef> {
        self.servers.iter().find(|s| s.name == name)
    }

    fn validate(&self) -> Result<()> {
        let mut ids = BTreeSet::new();
        for n in &self.workflow {
            if !ids.insert(n.id.as_str()) {
                bail!("duplicate workflow node id `{}`", n.id);
            }
            if self.task(&n.uses).is_none() {
                bail!("workflow node `{}` uses unknown task `{}`", n.id, n.uses);
            }
        }
        for n in &self.workflow {
            for d in &n.depend_on {
                if !ids.contains(d.as_str()) {
                    bail!("workflow node `{}` depends on unknown node `{}`", n.id, d);
                }
            }
        }
        for t in &self.tasks {
            if let Some(srv) = &t.server {
                if self.server(srv).is_none() {
                    bail!("task `{}` references unknown server `{srv}`", t.name);
                }
                if !matches!(t.app_type, AppType::Chatbot | AppType::DeepResearch) {
                    bail!(
                        "task `{}`: only text-model tasks can share a server",
                        t.name
                    );
                }
                // Server-backed DeepResearch drives its multi-iteration agent
                // loop through per-node state in the executor, which assumes
                // one in-flight task at a time (the closed loop guarantees
                // that). Open-loop arrivals would interleave tasks and
                // corrupt that state, so reject the combination.
                if t.app_type == AppType::DeepResearch
                    && !matches!(t.arrival, None | Some(ArrivalSpec::Closed { .. }))
                {
                    bail!(
                        "task `{}`: server-backed DeepResearch only supports closed-loop arrivals",
                        t.name
                    );
                }
            }
            if !(0.0..=100.0).contains(&t.mps) || t.mps == 0.0 {
                bail!("task `{}`: mps must be in (0, 100]", t.name);
            }
        }
        Ok(())
    }
}

fn parse_task(name: &str, v: &Value) -> Result<TaskConfig> {
    if v.as_map().is_none() {
        bail!("task `{name}` must be a mapping");
    }
    // App type: explicit `type:` field, else the "(AppType)" suffix of the
    // task name (the Fig. 2 convention).
    let app_type = if let Some(t) = v.get("type").and_then(|t| t.as_str()) {
        AppType::parse(t).with_context(|| format!("task `{name}`: unknown type `{t}`"))?
    } else if let Some(open) = name.rfind('(') {
        let inner = name[open + 1..].trim_end_matches(')');
        AppType::parse(inner)
            .with_context(|| format!("task `{name}`: cannot infer app type from `{inner}`"))?
    } else {
        bail!("task `{name}`: no `type:` field and no `(AppType)` suffix");
    };

    let device = match v.get("device").and_then(|d| d.as_str()).unwrap_or("gpu") {
        "gpu" => Device::Gpu,
        "cpu" => Device::Cpu,
        other => bail!("task `{name}`: unknown device `{other}`"),
    };

    let num_requests = v
        .get("num_requests")
        .map(|n| n.as_i64().with_context(|| format!("task `{name}`: num_requests must be int")))
        .transpose()?
        .unwrap_or(1) as usize;

    let slo = v.get("slo").map(|s| parse_slo(name, s)).transpose()?;

    let mps = v
        .get("mps")
        .map(|m| m.as_f64().with_context(|| format!("task `{name}`: mps must be numeric")))
        .transpose()?
        .unwrap_or(100.0);

    Ok(TaskConfig {
        name: name.to_string(),
        app_type,
        model: v
            .get("model")
            .or_else(|| v.get("server_model"))
            .and_then(|m| m.as_str())
            .map(String::from),
        num_requests,
        device,
        slo,
        mps,
        server: v.get("server").and_then(|s| s.as_str()).map(String::from),
        arrival: parse_arrival(name, v)?,
        backend: parse_backend(name, v)?,
    })
}

/// Parse an optional `backend:` key (tasks and server definitions share the
/// spelling). Absent → `TunedNative`, the semantics every pre-backend
/// config implicitly had.
fn parse_backend(owner: &str, v: &Value) -> Result<KernelBackend> {
    match v.get("backend") {
        None => Ok(KernelBackend::TunedNative),
        Some(b) => {
            let s = b
                .as_str()
                .with_context(|| format!("`{owner}`: backend must be a string"))?;
            KernelBackend::parse(s)
                .with_context(|| format!("`{owner}`: unknown backend `{s}` (tuned_native | generic_torch | fused_custom)"))
        }
    }
}

fn parse_arrival(task: &str, v: &Value) -> Result<Option<ArrivalSpec>> {
    let Some(kind) = v.get("arrival") else {
        return Ok(None);
    };
    let kind = kind
        .as_str()
        .with_context(|| format!("task `{task}`: arrival must be a string"))?;
    let spec = match kind.to_ascii_lowercase().replace(['-', '_', ' '], "").as_str() {
        "closed" | "closedloop" => {
            let think = match v.get("think") {
                Some(t) => parse_duration_value(task, t)?,
                None => 1.0,
            };
            if think < 0.0 {
                bail!("task `{task}`: think must be >= 0");
            }
            ArrivalSpec::Closed { think }
        }
        "periodic" | "openloop" | "open" => {
            let period = v
                .get("period")
                .with_context(|| format!("task `{task}`: periodic arrival needs `period`"))?;
            let period = parse_duration_value(task, period)?;
            if period <= 0.0 {
                bail!("task `{task}`: period must be > 0");
            }
            ArrivalSpec::Periodic { period }
        }
        "poisson" => {
            let rate = v
                .get("rate")
                .and_then(|r| r.as_f64())
                .with_context(|| format!("task `{task}`: poisson arrival needs numeric `rate`"))?;
            if rate <= 0.0 {
                bail!("task `{task}`: poisson rate must be > 0");
            }
            ArrivalSpec::Poisson { rate }
        }
        "trace" | "replay" | "tracereplay" => {
            let items = v
                .get("trace")
                .and_then(|t| t.as_seq())
                .with_context(|| format!("task `{task}`: trace arrival needs `trace: [..]`"))?;
            if items.is_empty() {
                bail!("task `{task}`: trace arrival needs at least one offset");
            }
            let mut offsets = Vec::with_capacity(items.len());
            for item in items {
                offsets.push(parse_duration_value(task, item)?);
            }
            if offsets.iter().any(|&o| o < 0.0) {
                bail!("task `{task}`: trace offsets must be >= 0");
            }
            if offsets.windows(2).any(|w| w[1] < w[0]) {
                bail!("task `{task}`: trace offsets must be non-decreasing");
            }
            ArrivalSpec::Trace { offsets }
        }
        other => bail!("task `{task}`: unknown arrival kind `{other}`"),
    };
    Ok(Some(spec))
}

fn parse_workflows(v: &Value) -> Result<Vec<WorkflowNodeConfig>> {
    let map = v.as_map().context("`workflows` must be a mapping")?;
    let mut nodes = Vec::new();
    for (id, body) in map {
        let uses = body
            .get("uses")
            .and_then(|u| u.as_str())
            .with_context(|| format!("workflow node `{id}` missing `uses`"))?
            .to_string();
        let depend_on = match body.get("depend_on") {
            None => Vec::new(),
            Some(Value::Seq(items)) => items
                .iter()
                .map(|i| {
                    i.as_str()
                        .map(String::from)
                        .with_context(|| format!("workflow node `{id}`: deps must be strings"))
                })
                .collect::<Result<Vec<_>>>()?,
            Some(Value::Str(s)) => vec![s.clone()],
            Some(other) => bail!("workflow node `{id}`: bad depend_on `{other}`"),
        };
        let background = body.get("background").and_then(|b| b.as_bool()).unwrap_or(false);
        nodes.push(WorkflowNodeConfig {
            id: id.clone(),
            uses,
            depend_on,
            background,
        });
    }
    Ok(nodes)
}

fn parse_servers(v: &Value) -> Result<Vec<ServerDef>> {
    let map = v.as_map().context("`servers` must be a mapping")?;
    let mut servers = Vec::new();
    for (name, body) in map {
        // Validate before casting: a negative i64 would wrap to a huge
        // usize and sail past the >= 1 checks below.
        let context_window = body
            .get("context_window")
            .and_then(|c| c.as_i64())
            .unwrap_or(16_384);
        if context_window < 1 {
            bail!("server `{name}`: context_window must be >= 1");
        }
        let context_window = context_window as usize;
        let kv_placement = match body
            .get("kv_placement")
            .and_then(|k| k.as_str())
            .unwrap_or("gpu")
        {
            "gpu" => KvPlacement::Gpu,
            "cpu" => KvPlacement::Cpu,
            other => bail!("server `{name}`: unknown kv_placement `{other}`"),
        };
        let n_slots = body.get("n_slots").and_then(|n| n.as_i64()).unwrap_or(4);
        if n_slots < 1 {
            bail!("server `{name}`: n_slots must be >= 1");
        }
        let n_slots = n_slots as usize;
        let batch_size = body
            .get("batch_size")
            .and_then(|b| b.as_i64())
            .unwrap_or(512);
        if batch_size < 1 {
            bail!("server `{name}`: batch_size must be >= 1");
        }
        let batch_size = batch_size as usize;
        servers.push(ServerDef {
            name: name.clone(),
            model: body.get("model").and_then(|m| m.as_str()).map(String::from),
            context_window,
            kv_placement,
            n_slots,
            batch_size,
            backend: parse_backend(name, body)?,
        });
    }
    Ok(servers)
}

/// Parse the `controller:` block into the adaptive-serving feedback
/// controller's configuration. `enabled: false` turns the block off
/// without deleting it; every other key overrides a
/// [`ControllerConfig::default`] field:
///
/// ```yaml
/// controller:
///   epoch: 2s               # decision spacing (virtual time)
///   window: 8s              # sliding observation window
///   target_attainment: 0.9  # tight-SLO attainment target
///   reserve_step: 8         # SM-reserve adjustment per action
///   max_reserve: 32
///   min_reserve: 4
///   cooldown_epochs: 2
///   min_observations: 3
/// ```
fn parse_controller(v: &Value) -> Result<Option<ControllerConfig>> {
    if v.as_map().is_none() {
        bail!("`controller` must be a mapping");
    }
    if let Some(e) = v.get("enabled") {
        let enabled = e
            .as_bool()
            .context("controller: enabled must be a boolean")?;
        if !enabled {
            return Ok(None);
        }
    }
    let mut cfg = ControllerConfig::default();
    if let Some(e) = v.get("epoch") {
        cfg.epoch = parse_duration_value("controller", e)?;
    }
    if let Some(w) = v.get("window") {
        cfg.window = parse_duration_value("controller", w)?;
    }
    if let Some(t) = v.get("target_attainment").or_else(|| v.get("target")) {
        cfg.target = t.as_f64().context("controller: target must be numeric")?;
    }
    let usize_key = |key: &str, slot: &mut usize| -> Result<()> {
        if let Some(n) = v.get(key) {
            let n = n
                .as_i64()
                .with_context(|| format!("controller: {key} must be an integer"))?;
            if n < 0 {
                bail!("controller: {key} must be >= 0");
            }
            *slot = n as usize;
        }
        Ok(())
    };
    usize_key("reserve_step", &mut cfg.reserve_step)?;
    usize_key("max_reserve", &mut cfg.max_reserve)?;
    usize_key("min_reserve", &mut cfg.min_reserve)?;
    usize_key("min_observations", &mut cfg.min_observations)?;
    if let Some(n) = v.get("cooldown_epochs") {
        let n = n
            .as_i64()
            .context("controller: cooldown_epochs must be an integer")?;
        if n < 0 {
            bail!("controller: cooldown_epochs must be >= 0");
        }
        cfg.cooldown_epochs = n as u32;
    }
    if cfg.epoch <= 0.0 {
        bail!("controller: epoch must be > 0");
    }
    if cfg.window < cfg.epoch {
        bail!("controller: window must cover at least one epoch");
    }
    if !(cfg.target > 0.0 && cfg.target <= 1.0) {
        bail!("controller: target_attainment must be in (0, 1]");
    }
    if cfg.min_reserve > cfg.max_reserve {
        bail!("controller: min_reserve must be <= max_reserve");
    }
    if cfg.reserve_step == 0 {
        bail!("controller: reserve_step must be >= 1");
    }
    Ok(Some(cfg))
}

/// Parse the `chaos:` block into a deterministic fault-injection config.
/// `kind:` is required; every other key overrides the kind's
/// [`ChaosConfig::curated`] default. `enabled: false` turns the block off
/// without deleting it:
///
/// ```yaml
/// chaos:
///   kind: thermal_throttle  # vram_ballast | suspend | server_crash | pcie_degrade
///   start: 1s               # nominal first episode
///   period: 6s              # nominal spacing
///   count: 4                # episodes
///   duration: 5s            # window length (windowed kinds)
///   intensity: 0.35         # clock cap / VRAM fraction / DMA scale
///   jitter: 0.25            # uniform start jitter, fraction of period
/// ```
fn parse_chaos(v: &Value) -> Result<Option<ChaosConfig>> {
    if v.as_map().is_none() {
        bail!("`chaos` must be a mapping");
    }
    if let Some(e) = v.get("enabled") {
        let enabled = e.as_bool().context("chaos: enabled must be a boolean")?;
        if !enabled {
            return Ok(None);
        }
    }
    let kind = v
        .get("kind")
        .and_then(|k| k.as_str())
        .context("chaos: `kind` is required and must be a string")?;
    let kind = ChaosKind::parse(kind).with_context(|| {
        format!(
            "chaos: unknown kind `{kind}` (thermal_throttle | vram_ballast | suspend | \
             server_crash | pcie_degrade)"
        )
    })?;
    let mut cfg = ChaosConfig::curated(kind);
    if let Some(s) = v.get("start") {
        cfg.start = parse_duration_value("chaos", s)?;
    }
    if let Some(p) = v.get("period") {
        cfg.period = parse_duration_value("chaos", p)?;
    }
    if let Some(d) = v.get("duration") {
        cfg.duration = parse_duration_value("chaos", d)?;
    }
    if let Some(n) = v.get("count") {
        let n = n.as_i64().context("chaos: count must be an integer")?;
        if n < 1 {
            bail!("chaos: count must be >= 1");
        }
        cfg.count = n as usize;
    }
    if let Some(i) = v.get("intensity") {
        cfg.intensity = i.as_f64().context("chaos: intensity must be numeric")?;
    }
    if let Some(j) = v.get("jitter") {
        cfg.jitter = j.as_f64().context("chaos: jitter must be numeric")?;
    }
    cfg.validate().map_err(|e| anyhow::anyhow!("chaos: {e}"))?;
    Ok(Some(cfg))
}

fn parse_slo(task: &str, v: &Value) -> Result<SloSpec> {
    match v {
        Value::Seq(items) if items.len() == 2 => {
            let ttft = parse_duration_value(task, &items[0])?;
            let tpot = parse_duration_value(task, &items[1])?;
            Ok(SloSpec::Chat(ttft, tpot))
        }
        other => Ok(SloSpec::Single(parse_duration_value(task, other)?)),
    }
}

fn parse_duration_value(task: &str, v: &Value) -> Result<f64> {
    match v {
        Value::Int(i) => Ok(*i as f64),
        Value::Float(f) => Ok(*f),
        Value::Str(s) => parse_duration(s).with_context(|| format!("task `{task}`: bad duration `{s}`")),
        other => bail!("task `{task}`: bad SLO value `{other}`"),
    }
}

/// Parse `"1s"`, `"0.25s"`, `"500ms"` into seconds.
pub fn parse_duration(s: &str) -> Result<f64> {
    let s = s.trim();
    if let Some(ms) = s.strip_suffix("ms") {
        return Ok(ms.trim().parse::<f64>()? / 1000.0);
    }
    if let Some(sec) = s.strip_suffix('s') {
        return Ok(sec.trim().parse::<f64>()?);
    }
    Ok(s.parse::<f64>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIG2_STYLE: &str = "\
Analysis (DeepResearch):
  model: Llama-3.2-3B
  num_requests: 1
  device: cpu
Creating Cover Art (ImageGen):
  model: SD-3.5-Medium-Turbo
  num_requests: 5
  device: gpu
  slo: 1s
Generating Captions (LiveCaptions):
  model: Whisper-Large-V3-Turbo
  num_requests: 1
  device: gpu
  slo: 2s
workflows:
  analysis_1:
    uses: Analysis (DeepResearch)
  cover_art:
    uses: Creating Cover Art (ImageGen)
    depend_on: [\"analysis_1\"]
  generate_captions:
    uses: Generating Captions (LiveCaptions)
    depend_on: [\"cover_art\"]
";

    #[test]
    fn parses_fig2_config() {
        let cfg = BenchConfig::parse(FIG2_STYLE).unwrap();
        assert_eq!(cfg.tasks.len(), 3);
        assert_eq!(cfg.workflow.len(), 3);
        let analysis = cfg.task("Analysis (DeepResearch)").unwrap();
        assert_eq!(analysis.app_type, AppType::DeepResearch);
        assert_eq!(analysis.device, Device::Cpu);
        let img = cfg.task("Creating Cover Art (ImageGen)").unwrap();
        assert_eq!(img.app_type, AppType::ImageGen);
        assert_eq!(img.slo, Some(SloSpec::Single(1.0)));
        assert_eq!(img.num_requests, 5);
        let node = cfg.workflow.iter().find(|n| n.id == "cover_art").unwrap();
        assert_eq!(node.depend_on, vec!["analysis_1"]);
    }

    #[test]
    fn type_field_wins_over_suffix() {
        let cfg = BenchConfig::parse("Brainstorm (chatbot):\n  type: chatbot\n  num_requests: 2\n").unwrap();
        assert_eq!(cfg.tasks[0].app_type, AppType::Chatbot);
    }

    #[test]
    fn chat_slo_list() {
        let cfg =
            BenchConfig::parse("Chat (chatbot):\n  slo: [1s, 0.25s]\n  num_requests: 1\n").unwrap();
        assert_eq!(cfg.tasks[0].slo, Some(SloSpec::Chat(1.0, 0.25)));
    }

    #[test]
    fn implicit_workflow_when_missing() {
        let cfg = BenchConfig::parse(
            "A (chatbot):\n  num_requests: 1\nB (imagegen):\n  num_requests: 1\n",
        )
        .unwrap();
        assert_eq!(cfg.workflow.len(), 2);
        assert!(cfg.workflow.iter().all(|n| n.depend_on.is_empty()));
    }

    #[test]
    fn servers_and_routing() {
        let text = "\
Brainstorm (chatbot):
  num_requests: 10
  server: shared_llama
servers:
  shared_llama:
    model: Llama-3.2-3B
    context_window: 131072
    kv_placement: cpu
strategy: greedy
seed: 7
";
        let cfg = BenchConfig::parse(text).unwrap();
        assert_eq!(cfg.seed, 7);
        let srv = cfg.server("shared_llama").unwrap();
        assert_eq!(srv.context_window, 131_072);
        assert_eq!(srv.kv_placement, KvPlacement::Cpu);
        assert_eq!(cfg.tasks[0].server.as_deref(), Some("shared_llama"));
    }

    #[test]
    fn unknown_server_rejected() {
        let err = BenchConfig::parse("A (chatbot):\n  server: nope\n  num_requests: 1\n")
            .unwrap_err();
        assert!(err.to_string().contains("unknown server"));
    }

    #[test]
    fn unknown_dep_rejected() {
        let text = "\
A (chatbot):
  num_requests: 1
workflows:
  a:
    uses: A (chatbot)
    depend_on: [\"ghost\"]
";
        let err = BenchConfig::parse(text).unwrap_err();
        assert!(err.to_string().contains("unknown node"));
    }

    #[test]
    fn strategy_parsing() {
        assert_eq!(Strategy::parse("greedy"), Some(Strategy::Greedy));
        assert_eq!(Strategy::parse("MPS"), Some(Strategy::Partition));
        assert_eq!(Strategy::parse("fair-share"), Some(Strategy::FairShare));
        assert_eq!(Strategy::parse("wat"), None);
    }

    #[test]
    fn durations() {
        assert_eq!(parse_duration("1s").unwrap(), 1.0);
        assert_eq!(parse_duration("0.25s").unwrap(), 0.25);
        assert_eq!(parse_duration("500ms").unwrap(), 0.5);
        assert_eq!(parse_duration("2").unwrap(), 2.0);
        assert!(parse_duration("fast").is_err());
    }

    #[test]
    fn no_tasks_rejected() {
        assert!(BenchConfig::parse("strategy: greedy\n").is_err());
    }

    #[test]
    fn arrival_overrides_parse() {
        let cfg = BenchConfig::parse(
            "A (chatbot):\n  num_requests: 4\n  arrival: poisson\n  rate: 2.5\n",
        )
        .unwrap();
        assert_eq!(cfg.tasks[0].arrival, Some(ArrivalSpec::Poisson { rate: 2.5 }));

        let cfg = BenchConfig::parse(
            "A (chatbot):\n  num_requests: 4\n  arrival: periodic\n  period: 500ms\n",
        )
        .unwrap();
        assert_eq!(cfg.tasks[0].arrival, Some(ArrivalSpec::Periodic { period: 0.5 }));

        let cfg = BenchConfig::parse(
            "A (chatbot):\n  num_requests: 3\n  arrival: trace\n  trace: [0, 0.5s, 2]\n",
        )
        .unwrap();
        assert_eq!(
            cfg.tasks[0].arrival,
            Some(ArrivalSpec::Trace { offsets: vec![0.0, 0.5, 2.0] })
        );

        let cfg = BenchConfig::parse(
            "A (chatbot):\n  num_requests: 2\n  arrival: closed\n  think: 2s\n",
        )
        .unwrap();
        assert_eq!(cfg.tasks[0].arrival, Some(ArrivalSpec::Closed { think: 2.0 }));

        let cfg = BenchConfig::parse("A (chatbot):\n  num_requests: 2\n").unwrap();
        assert_eq!(cfg.tasks[0].arrival, None);
    }

    #[test]
    fn arrival_overrides_validated() {
        for bad in [
            "A (chatbot):\n  num_requests: 1\n  arrival: poisson\n",
            "A (chatbot):\n  num_requests: 1\n  arrival: poisson\n  rate: 0\n",
            "A (chatbot):\n  num_requests: 1\n  arrival: periodic\n",
            "A (chatbot):\n  num_requests: 1\n  arrival: trace\n  trace: [1, 0.5]\n",
            "A (chatbot):\n  num_requests: 1\n  arrival: trace\n  trace: []\n",
            "A (chatbot):\n  num_requests: 1\n  arrival: warp\n",
        ] {
            assert!(BenchConfig::parse(bad).is_err(), "should reject:\n{bad}");
        }
    }

    #[test]
    fn server_backed_deepresearch_rejects_open_loop() {
        let cfg = |arrival: &str| {
            format!(
                "R (deepresearch):\n  num_requests: 2\n  server: s\n{arrival}servers:\n  s:\n    model: Llama-3.2-3B\n"
            )
        };
        // Closed loop (default or explicit) is fine …
        assert!(BenchConfig::parse(&cfg("")).is_ok());
        assert!(BenchConfig::parse(&cfg("  arrival: closed\n")).is_ok());
        // … open-loop arrivals would interleave the agent loop: rejected.
        let err = BenchConfig::parse(&cfg("  arrival: poisson\n  rate: 1\n")).unwrap_err();
        assert!(err.to_string().contains("closed-loop"), "{err}");
        assert!(BenchConfig::parse(&cfg("  arrival: periodic\n  period: 5\n")).is_err());
    }

    #[test]
    fn controller_block_parses_with_defaults_and_overrides() {
        let base = "A (chatbot):\n  num_requests: 1\n";
        let cfg = BenchConfig::parse(base).unwrap();
        assert!(cfg.controller.is_none(), "no block => static run");

        let cfg = BenchConfig::parse(&format!("{base}controller:\n  epoch: 1s\n")).unwrap();
        let c = cfg.controller.expect("controller enabled");
        assert_eq!(c.epoch, 1.0);
        assert_eq!(c.window, ControllerConfig::default().window);

        let text = format!(
            "{base}controller:\n  epoch: 500ms\n  window: 4\n  target_attainment: 0.8\n  \
             reserve_step: 4\n  max_reserve: 16\n  min_reserve: 2\n  cooldown_epochs: 1\n  \
             min_observations: 5\n"
        );
        let c = BenchConfig::parse(&text).unwrap().controller.unwrap();
        assert_eq!(c.epoch, 0.5);
        assert_eq!(c.window, 4.0);
        assert_eq!(c.target, 0.8);
        assert_eq!(c.reserve_step, 4);
        assert_eq!(c.max_reserve, 16);
        assert_eq!(c.min_reserve, 2);
        assert_eq!(c.cooldown_epochs, 1);
        assert_eq!(c.min_observations, 5);

        let cfg =
            BenchConfig::parse(&format!("{base}controller:\n  enabled: false\n  epoch: 1\n"))
                .unwrap();
        assert!(cfg.controller.is_none(), "enabled: false => static run");
    }

    #[test]
    fn controller_block_validated() {
        let base = "A (chatbot):\n  num_requests: 1\n";
        for bad in [
            "controller:\n  epoch: 0\n",
            "controller:\n  epoch: 4\n  window: 2\n",
            "controller:\n  target_attainment: 0\n",
            "controller:\n  target_attainment: 1.5\n",
            "controller:\n  min_reserve: 64\n  max_reserve: 8\n",
            "controller: greedy\n",
            // A malformed `enabled` must error, not silently leave the
            // controller on.
            "controller:\n  enabled: 0\n",
            // A zero step would wedge the escalation ladder on no-op
            // reserve updates.
            "controller:\n  reserve_step: 0\n",
        ] {
            let text = format!("{base}{bad}");
            assert!(BenchConfig::parse(&text).is_err(), "should reject:\n{text}");
        }
    }

    #[test]
    fn chaos_block_parses_with_defaults_and_overrides() {
        let base = "A (chatbot):\n  num_requests: 1\n";
        let cfg = BenchConfig::parse(base).unwrap();
        assert!(cfg.chaos.is_none(), "no block => no faults");

        let cfg =
            BenchConfig::parse(&format!("{base}chaos:\n  kind: thermal_throttle\n")).unwrap();
        let c = cfg.chaos.expect("chaos enabled");
        assert_eq!(c.kind, ChaosKind::ThermalThrottle);
        assert_eq!(c, ChaosConfig::curated(ChaosKind::ThermalThrottle));

        let text = format!(
            "{base}chaos:\n  kind: pcie_degrade\n  start: 500ms\n  period: 3s\n  count: 2\n  \
             duration: 1s\n  intensity: 0.5\n  jitter: 0.1\n"
        );
        let c = BenchConfig::parse(&text).unwrap().chaos.unwrap();
        assert_eq!(c.kind, ChaosKind::PcieDegrade);
        assert_eq!(c.start, 0.5);
        assert_eq!(c.period, 3.0);
        assert_eq!(c.count, 2);
        assert_eq!(c.duration, 1.0);
        assert_eq!(c.intensity, 0.5);
        assert_eq!(c.jitter, 0.1);

        let cfg = BenchConfig::parse(&format!(
            "{base}chaos:\n  enabled: false\n  kind: server_crash\n"
        ))
        .unwrap();
        assert!(cfg.chaos.is_none(), "enabled: false => no faults");

        // The generated YAML round-trips through the parser.
        for kind in ChaosKind::ALL {
            let block = ChaosConfig::curated(kind).to_yaml();
            let cfg = BenchConfig::parse(&format!("{base}{block}")).unwrap();
            assert_eq!(cfg.chaos, Some(ChaosConfig::curated(kind)), "{block}");
        }
    }

    #[test]
    fn chaos_block_validated() {
        let base = "A (chatbot):\n  num_requests: 1\n";
        for bad in [
            "chaos: thermal_throttle\n",                       // not a mapping
            "chaos:\n  start: 1\n",                            // kind missing
            "chaos:\n  kind: gamma_rays\n",                    // unknown kind
            "chaos:\n  kind: suspend\n  count: 0\n",           // no episodes
            "chaos:\n  kind: suspend\n  period: 0\n",          // zero spacing
            "chaos:\n  kind: suspend\n  jitter: 1.0\n",        // jitter out of range
            "chaos:\n  kind: suspend\n  duration: 0\n",        // windowed needs > 0
            "chaos:\n  kind: suspend\n  duration: 10\n",       // window >= period
            "chaos:\n  kind: thermal_throttle\n  intensity: 0\n",
            "chaos:\n  kind: thermal_throttle\n  intensity: 1.5\n",
            "chaos:\n  enabled: 0\n  kind: suspend\n",         // malformed enabled
        ] {
            let text = format!("{base}{bad}");
            assert!(BenchConfig::parse(&text).is_err(), "should reject:\n{text}");
        }
    }

    #[test]
    fn server_batch_size_parses_and_validates() {
        let text = "\
A (chatbot):
  num_requests: 1
  server: s
servers:
  s:
    model: Llama-3.2-3B
    batch_size: 256
";
        let cfg = BenchConfig::parse(text).unwrap();
        assert_eq!(cfg.server("s").unwrap().batch_size, 256);
        assert_eq!(cfg.server("s").unwrap().n_slots, 4);
        // Zero and negative values are both rejected (a negative i64 must
        // not wrap into a huge usize).
        for bad_field in [
            "batch_size: 0",
            "batch_size: -5",
            "n_slots: 0",
            "n_slots: -1",
            "context_window: -1",
        ] {
            let bad = text.replace("batch_size: 256", bad_field);
            assert!(BenchConfig::parse(&bad).is_err(), "should reject {bad_field}");
        }
    }

    #[test]
    fn workflow_slo_parses_and_validates() {
        let base = "A (chatbot):\n  num_requests: 1\n";
        assert_eq!(BenchConfig::parse(base).unwrap().workflow_slo, None);
        let cfg = BenchConfig::parse(&format!("{base}workflow_slo: 90s\n")).unwrap();
        assert_eq!(cfg.workflow_slo, Some(90.0));
        let cfg = BenchConfig::parse(&format!("{base}workflow_slo: 500ms\n")).unwrap();
        assert_eq!(cfg.workflow_slo, Some(0.5));
        for bad in ["workflow_slo: 0\n", "workflow_slo: -3\n", "workflow_slo: fast\n"] {
            assert!(
                BenchConfig::parse(&format!("{base}{bad}")).is_err(),
                "should reject {bad}"
            );
        }
    }

    #[test]
    fn backend_key_parses_on_tasks_and_servers() {
        // Default: absent key means the tuned (llama.cpp-native) backend —
        // the semantics every pre-backend config implicitly had.
        let cfg = BenchConfig::parse("A (chatbot):\n  num_requests: 1\n").unwrap();
        assert_eq!(cfg.tasks[0].backend, KernelBackend::TunedNative);

        let cfg = BenchConfig::parse(
            "A (chatbot):\n  num_requests: 1\n  backend: generic_torch\n",
        )
        .unwrap();
        assert_eq!(cfg.tasks[0].backend, KernelBackend::GenericTorch);
        // Alias spellings work.
        let cfg =
            BenchConfig::parse("A (imagegen):\n  num_requests: 1\n  backend: fused\n").unwrap();
        assert_eq!(cfg.tasks[0].backend, KernelBackend::FusedCustom);

        let text = "\
A (chatbot):
  num_requests: 1
  server: s
servers:
  s:
    model: Llama-3.2-3B
    backend: generic_torch
";
        let cfg = BenchConfig::parse(text).unwrap();
        assert_eq!(cfg.server("s").unwrap().backend, KernelBackend::GenericTorch);
        let tuned = BenchConfig::parse(&text.replace("    backend: generic_torch\n", "")).unwrap();
        assert_eq!(tuned.server("s").unwrap().backend, KernelBackend::TunedNative);

        // Unknown or non-string backends are rejected.
        for bad in [
            "A (chatbot):\n  num_requests: 1\n  backend: npu\n",
            "A (chatbot):\n  num_requests: 1\n  backend: 3\n",
        ] {
            let err = BenchConfig::parse(bad).unwrap_err();
            assert!(err.to_string().contains("backend"), "{err}");
        }
        let err = BenchConfig::parse(
            "A (chatbot):\n  num_requests: 1\n  server: s\nservers:\n  s:\n    backend: cuda9\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown backend"), "{err}");
    }

    #[test]
    fn event_queue_and_trace_mode_parse_and_validate() {
        let base = "A (chatbot):\n  num_requests: 1\n";
        // Defaults: heap queue, full trace — the pre-campaign semantics.
        let cfg = BenchConfig::parse(base).unwrap();
        assert_eq!(cfg.event_queue, QueueBackend::Heap);
        assert_eq!(cfg.trace_mode, TraceMode::Full);

        let cfg = BenchConfig::parse(&format!("{base}event_queue: wheel\n")).unwrap();
        assert_eq!(cfg.event_queue, QueueBackend::Wheel);
        let cfg = BenchConfig::parse(&format!("{base}event_queue: timer_wheel\n")).unwrap();
        assert_eq!(cfg.event_queue, QueueBackend::Wheel);

        let cfg = BenchConfig::parse(&format!("{base}trace_mode: streaming\n")).unwrap();
        assert_eq!(
            cfg.trace_mode,
            TraceMode::Streaming { window: DEFAULT_STREAM_WINDOW }
        );
        let cfg = BenchConfig::parse(&format!(
            "{base}trace_mode: streaming\ntrace_window: 64\n"
        ))
        .unwrap();
        assert_eq!(cfg.trace_mode, TraceMode::Streaming { window: 64 });
        let cfg = BenchConfig::parse(&format!("{base}trace_mode: full\n")).unwrap();
        assert_eq!(cfg.trace_mode, TraceMode::Full);

        for bad in [
            "event_queue: splay_tree\n",
            "event_queue: 3\n",
            "trace_mode: ring\n",
            "trace_window: 64\n",                      // window without streaming
            "trace_mode: full\ntrace_window: 64\n",    // ditto, explicit full
            "trace_mode: streaming\ntrace_window: 0\n",
            "trace_mode: streaming\ntrace_window: -4\n",
        ] {
            let text = format!("{base}{bad}");
            assert!(BenchConfig::parse(&text).is_err(), "should reject:\n{text}");
        }
    }

    #[test]
    fn mps_bounds_checked() {
        let err =
            BenchConfig::parse("A (chatbot):\n  num_requests: 1\n  mps: 0\n").unwrap_err();
        assert!(err.to_string().contains("mps"));
    }
}
