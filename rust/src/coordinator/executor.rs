//! Workflow execution engine (§3.2, step ③).
//!
//! Lowers a validated [`BenchConfig`] onto the simulated testbed and drives
//! it to completion: the DAG scheduler submits each node's
//! `setup → exec × N → cleanup` lifecycle as its dependencies resolve, the
//! resource orchestrator installs the configured sharing policy, shared
//! inference servers are pumped as virtual time advances, and every
//! completed request is evaluated against its SLO. When AOT artifacts are
//! present, each request additionally executes its model's real HLO through
//! the PJRT runtime (numerics validation; virtual time stays authoritative
//! for all reported latencies).

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{Context, Result};

use crate::apps::{
    mean_normalized, slo_attainment, AppContext, Application, Arrival, Chatbot, DeepResearch,
    ImageGen, LiveCaptions, RequestMetrics, Slo,
};
use crate::apps::models::{llama_3_1_8b, llama_3_2_3b};
use crate::coordinator::config::{
    AppType, ArrivalSpec, BenchConfig, InjectFailure, Strategy, TestbedKind,
};
use crate::coordinator::controller::{Controller, ControllerAction, Observation, ServerView};
use crate::coordinator::dag::{Dag, NodeId};
use crate::gpusim::chaos::{FaultAction, FaultEvent, FaultSchedule};
use crate::gpusim::engine::{
    BudgetExhausted, Engine, EngineError, EngineOptions, JobId, JobResult, JobSpec, MemOp, Phase,
    Trace, TraceAggregates,
};
use crate::gpusim::kernel::Device;
use crate::gpusim::policy::Policy;
use crate::gpusim::profiles::Testbed;
use crate::runtime::Runtime;
use crate::server::{
    InferenceServer, KvPlacement, ServerConfig, ServerProfile, ServerRequest, ServerTuning,
};

/// What a completed engine job meant to the runner.
#[derive(Debug, Clone, Copy, PartialEq)]
enum JobKind {
    Setup,
    Request(usize),
    Cleanup,
    /// Host-side delay before enqueuing server request `idx` (think time /
    /// agent tool time).
    Timer(usize),
    /// Adaptive-serving controller epoch boundary (node id is unused).
    ControllerTick,
    /// Fault transition `i` of the chaos schedule (node id is unused).
    Chaos(usize),
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum NodeState {
    Waiting,
    Setup,
    Running,
    Cleanup,
    Complete,
}

struct NodeRuntime {
    app: Box<dyn Application>,
    ctx: AppContext,
    /// Arrival process driving this node's requests: the task's `arrival:`
    /// override when present, otherwise the application's built-in model.
    arrival: Arrival,
    /// Index into `servers` when requests route through a shared server.
    server: Option<usize>,
    state: NodeState,
    issued: usize,
    finished: usize,
    metrics: Vec<RequestMetrics>,
    /// When every dependency had completed (0 for roots) — the first point
    /// of the node's `(ready, start, end)` lifecycle.
    ready: f64,
    start: f64,
    end: f64,
    failed: Option<String>,
    /// DeepResearch-over-server: per-request iteration progress.
    dr_iteration: usize,
    /// Start time of the in-flight server-backed request.
    req_started: f64,
}

struct ServerRuntime {
    name: String,
    server: InferenceServer,
    /// server request id → (node, request idx). Ordered map: request
    /// ids are handed out sequentially and any iteration over in-flight
    /// requests must be digest-stable.
    routing: BTreeMap<u64, (NodeId, usize)>,
    next_req_id: u64,
}

/// Epochs of zero progress and zero actions after which the controller
/// stops scheduling ticks (so a genuinely stalled workflow still trips the
/// executor's deadlock detection instead of ticking forever).
const CONTROLLER_MAX_IDLE_EPOCHS: u32 = 10_000;

/// Default deterministic event budget: the largest default-matrix scenario
/// processes a few million engine events, so 50M is two orders of headroom
/// while still converting an accidental livelock into a typed, digestable
/// failure instead of a hang. Override per-config via `budget_events:`.
pub const DEFAULT_EVENT_BUDGET: u64 = 50_000_000;

/// Default virtual-time horizon (seconds): no curated scenario runs past a
/// few virtual hours; ~11.6 virtual days means only a genuinely divergent
/// timeline trips it. Override per-config via `budget_virtual_time:`.
pub const DEFAULT_VIRTUAL_TIME_BUDGET: f64 = 1_000_000.0;

/// Watchdog iteration stride: the wall clock is sampled once per this many
/// main-loop iterations, keeping the (nondeterministic) `Instant::now` call
/// off the per-event hot path.
const WATCHDOG_STRIDE: u64 = 1024;

/// Typed error for the wall-clock watchdog — defense-in-depth behind the
/// deterministic budgets. Host-dependent, so supervision layers must mark
/// these outcomes `timeout` and keep them out of golden digests; the
/// message deliberately carries only the configured limit, never elapsed
/// time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WallClockTimeout {
    pub limit_secs: u64,
}

impl std::fmt::Display for WallClockTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wall-clock watchdog fired (limit {}s)", self.limit_secs)
    }
}

impl std::error::Error for WallClockTimeout {}

/// Runtime state of deterministic fault injection: the pre-generated
/// schedule, plus the engine client its transition jobs (and ballast
/// allocations) run under — faults are ordinary trace-visible events.
struct ChaosRuntime {
    client: crate::gpusim::engine::ClientId,
    events: Vec<FaultEvent>,
}

/// Runtime state of the adaptive-serving feedback loop.
struct ControllerRuntime {
    controller: Controller,
    /// Engine client the epoch-tick jobs run under (ticks are ordinary
    /// host jobs, so controller activity is visible in the trace).
    client: crate::gpusim::engine::ClientId,
    tick_count: u64,
    /// `(completed nodes, finished requests)` at the last tick.
    last_progress: (usize, usize),
    idle_epochs: u32,
    /// Applied `SetReserve` actions (policy-side reconfigurations; the
    /// server-side ones are counted by the servers themselves).
    reserve_updates: usize,
}

/// Result of one workflow node.
#[derive(Debug, Clone)]
pub struct NodeResult {
    pub id: String,
    pub app: &'static str,
    pub slo: Slo,
    pub metrics: Vec<RequestMetrics>,
    /// When the node's dependencies had all completed (0 for roots).
    pub ready: f64,
    pub start: f64,
    pub end: f64,
    /// Whether the node was declared `background: true` — excluded from the
    /// workflow's end-to-end latency and critical-path attribution.
    pub background: bool,
    pub failed: Option<String>,
}

impl NodeResult {
    /// SLO attainment, `None` when no requests completed (rendered `n/a`).
    pub fn attainment(&self) -> Option<f64> {
        slo_attainment(&self.metrics)
    }

    pub fn mean_normalized(&self) -> f64 {
        mean_normalized(&self.metrics)
    }

    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// Per-stage lifecycle of one foreground workflow node, with its slack
/// against the workflow's end-to-end completion.
#[derive(Debug, Clone)]
pub struct StageStat {
    pub id: String,
    pub app: &'static str,
    /// All dependencies completed.
    pub ready: f64,
    /// Node started (setup submitted).
    pub start: f64,
    /// Node completed (cleanup done).
    pub end: f64,
    /// How much later this node could have finished without delaying the
    /// workflow's end-to-end completion (0 on the critical path).
    pub slack: f64,
    pub on_critical_path: bool,
}

/// Workflow-level metrics: end-to-end latency, the e2e SLO verdict, and the
/// weighted critical path over the completed DAG (§3.2 — which nodes
/// bounded the run, and how much slack the others had).
///
/// Background nodes (`background: true`) are excluded from the end-to-end
/// latency and the stage table: they model long-running side work, not the
/// user-perceived workflow completion. A background node can still appear
/// *on* the critical path when a foreground node's start was gated by it.
#[derive(Debug, Clone, Default)]
pub struct WorkflowMetrics {
    /// Latest completion across foreground nodes (the user-perceived
    /// workflow latency; `makespan` also counts background nodes).
    pub e2e_latency: f64,
    /// The configured `workflow_slo:` bound, if any.
    pub workflow_slo: Option<f64>,
    /// Whether any foreground node failed (e.g. setup OOM): the workflow
    /// never completed, so its `e2e_latency` is the truncated span of what
    /// did run, not a real end-to-end latency.
    pub failed: bool,
    /// `e2e_latency <= workflow_slo`; `None` when no bound is configured.
    /// A workflow with a failed foreground node never meets its bound — a
    /// failed node ends *early*, which would otherwise fabricate a short
    /// e2e and a spurious `met` verdict.
    pub e2e_slo_met: Option<bool>,
    /// Node ids from a root to the latest-finishing foreground node,
    /// following at each step the dependency that gated the node's start.
    pub critical_path: Vec<String>,
    /// Sum of node durations along the critical path (the weighted length;
    /// the gap to `e2e_latency` is scheduling/queueing time between stages).
    pub critical_path_len: f64,
    /// Foreground stages in workflow-declaration order.
    pub stages: Vec<StageStat>,
}

impl WorkflowMetrics {
    /// `a -> b -> c` rendering of the critical path (report columns).
    pub fn critical_path_str(&self) -> String {
        self.critical_path.join(" -> ")
    }
}

/// Compute workflow-level metrics from the completed node results.
///
/// Deterministic by construction: ties (equal completion times) resolve to
/// the lowest node index, and all inputs are pure functions of the run.
fn workflow_metrics(
    dag: &Dag,
    nodes: &[NodeResult],
    workflow_slo: Option<f64>,
) -> WorkflowMetrics {
    debug_assert_eq!(dag.len(), nodes.len());
    if nodes.is_empty() {
        return WorkflowMetrics::default();
    }
    // Foreground scope; degenerate all-background workflows fall back to
    // every node so the metrics stay defined.
    let mut in_scope: Vec<bool> = (0..dag.len()).map(|i| !dag.is_background(i)).collect();
    if !in_scope.iter().any(|&b| b) {
        in_scope.iter_mut().for_each(|b| *b = true);
    }
    // Sink: latest-finishing in-scope node (first index wins ties).
    let mut sink = None;
    for i in 0..dag.len() {
        if !in_scope[i] {
            continue;
        }
        match sink {
            None => sink = Some(i),
            Some(s) if nodes[i].end > nodes[s].end => sink = Some(i),
            _ => {}
        }
    }
    let sink = sink.expect("non-empty scope");
    let e2e = nodes[sink].end;

    // Critical path: walk back from the sink, at each node following the
    // dependency whose completion gated its start (latest dep end; first
    // declared wins ties). Background gates are kept — they bounded the run.
    let mut path = vec![sink];
    let mut cur = sink;
    while let Some((&first, rest)) = dag.deps(cur).split_first() {
        let mut binding = first;
        for &d in rest {
            if nodes[d].end > nodes[binding].end {
                binding = d;
            }
        }
        path.push(binding);
        cur = binding;
    }
    path.reverse();
    let critical_path_len: f64 = path.iter().map(|&i| nodes[i].duration()).sum();
    let on_path: BTreeSet<NodeId> = path.iter().copied().collect();

    // Slack by reverse-CPM over the actual schedule: a node may finish as
    // late as the earliest point where an in-scope dependent would have
    // started anyway (its actual start plus its own slack); sinks may
    // finish as late as the e2e completion itself.
    let order = dag.toposort().expect("validated DAG");
    let mut slack = vec![0.0f64; dag.len()];
    for &n in order.iter().rev() {
        let mut allow = f64::INFINITY;
        for &d in dag.dependents(n) {
            if in_scope[d] {
                allow = allow.min(nodes[d].start + slack[d]);
            }
        }
        if allow.is_infinite() {
            allow = e2e;
        }
        slack[n] = (allow - nodes[n].end).max(0.0);
    }

    let stages = (0..dag.len())
        .filter(|&i| in_scope[i])
        .map(|i| StageStat {
            id: nodes[i].id.clone(),
            app: nodes[i].app,
            ready: nodes[i].ready,
            start: nodes[i].start,
            end: nodes[i].end,
            slack: slack[i],
            on_critical_path: on_path.contains(&i),
        })
        .collect();

    let failed = (0..dag.len()).any(|i| in_scope[i] && nodes[i].failed.is_some());
    WorkflowMetrics {
        e2e_latency: e2e,
        workflow_slo,
        failed,
        e2e_slo_met: workflow_slo.map(|bound| !failed && e2e <= bound),
        critical_path: path.iter().map(|&i| nodes[i].id.clone()).collect(),
        critical_path_len,
        stages,
    }
}

/// Result of a full scenario run.
#[derive(Debug)]
pub struct ScenarioResult {
    pub nodes: Vec<NodeResult>,
    /// Workflow-level metrics: end-to-end latency, e2e SLO verdict, and the
    /// weighted critical path with per-stage slack.
    pub workflow: WorkflowMetrics,
    /// Columnar monitor trace (right-sized when drained from the engine).
    /// Under `TraceMode::Streaming` this is only the configured tail
    /// window; `trace_digest`/`trace_aggregates` still cover every row.
    pub trace: Trace,
    /// Canonical FNV-1a digest of the *complete* recorded trace, read from
    /// the engine before the trace was drained. Identical across queue
    /// backends and trace modes for the same run.
    pub trace_digest: u64,
    /// Streaming-mode running aggregates over the complete trace (`None`
    /// for full-mode runs — fold them from `trace` instead).
    pub trace_aggregates: Option<TraceAggregates>,
    pub client_names: Vec<String>,
    pub makespan: f64,
    pub policy: String,
    /// Number of PJRT executions performed (0 when artifacts are absent).
    pub pjrt_calls: usize,
    /// Runtime reconfigurations that landed: server tuning changes that
    /// actually took effect (rolled-back migrations excluded) plus
    /// policy-reserve updates. 0 for static runs.
    pub reconfigurations: usize,
    /// Time-stamped adaptive-controller action log
    /// (`"t=12.3 migrate-kv(…)"`); actions the executor's feasibility
    /// checks rejected carry a `skipped ` prefix.
    pub controller_actions: Vec<String>,
    /// Idle-floor draws of the testbed the scenario ran on. The monitor
    /// needs them to price grid points that precede the first trace sample.
    pub gpu_idle_w: f64,
    pub cpu_idle_w: f64,
}

impl ScenarioResult {
    pub fn node(&self, id: &str) -> Option<&NodeResult> {
        self.nodes.iter().find(|n| n.id == id)
    }

    /// All nodes of a given application type.
    pub fn nodes_of(&self, app: &str) -> Vec<&NodeResult> {
        self.nodes.iter().filter(|n| n.app == app).collect()
    }
}

/// The scenario runner.
pub struct ScenarioRunner {
    engine: Engine,
    dag: Dag,
    nodes: Vec<NodeRuntime>,
    servers: Vec<ServerRuntime>,
    controller: Option<ControllerRuntime>,
    chaos: Option<ChaosRuntime>,
    job_map: BTreeMap<JobId, (NodeId, JobKind)>,
    completed: BTreeSet<NodeId>,
    runtime: Option<Runtime>,
    pjrt_calls: usize,
    seed: u64,
    workflow_slo: Option<f64>,
    /// Deterministic virtual-time horizon (config or default); exceeding it
    /// returns `BudgetExhausted::VirtualTime`.
    virtual_time_budget: f64,
    /// Wall-clock watchdog: `(deadline, configured limit)`. Never set from
    /// the config — only supervision layers install it, and its outcomes
    /// are excluded from golden digests.
    deadline: Option<(std::time::Instant, u64)>,
    /// Supervision-test fault hook (`inject_failure:` key).
    inject: Option<InjectFailure>,
}

impl ScenarioRunner {
    /// Build a runner from a parsed configuration. `runtime` enables the
    /// real-compute path when AOT artifacts are available.
    pub fn new(cfg: &BenchConfig, runtime: Option<Runtime>) -> Result<ScenarioRunner> {
        let testbed = match cfg.testbed {
            TestbedKind::IntelServer => Testbed::intel_server(),
            TestbedKind::MacbookM1Pro => Testbed::macbook_m1_pro(),
        };
        Self::with_testbed(cfg, testbed, runtime)
    }

    /// Build a runner on an explicit [`Testbed`] instead of the config's
    /// named `testbed:` kind. This is the fleet subsystem's injection seam:
    /// population-sampled devices are synthesized `Testbed`s, not members of
    /// [`TestbedKind`], so every device-dependent decision below (engine
    /// construction, Apple-tuned app variants, partition sizing) keys off
    /// the profile itself — `unified_memory`, `num_sms` — never off
    /// `cfg.testbed`.
    pub fn with_testbed(
        cfg: &BenchConfig,
        testbed: Testbed,
        runtime: Option<Runtime>,
    ) -> Result<ScenarioRunner> {
        // Unified-memory devices get the Apple-tuned application variants
        // (the profile property is what those configs are tuned *for*); for
        // the two named testbeds this is exactly the old
        // `cfg.testbed == MacbookM1Pro` behaviour.
        let unified = testbed.gpu.unified_memory;
        // Pre-size the engine for this config's expected load: roughly one
        // burst of pending events per request plus workflow bookkeeping.
        // Purely a capacity hint — behaviour is identical at any value.
        let capacity_hint = cfg.tasks.iter().map(|t| t.num_requests).sum::<usize>()
            + cfg.workflow.len() * 2
            + 16;
        let mut engine = Engine::with_options(
            testbed,
            Policy::Greedy,
            EngineOptions {
                queue: cfg.event_queue,
                trace_mode: cfg.trace_mode,
                capacity_hint,
            },
        );
        let dag = Dag::build(&cfg.workflow)?;

        // Shared servers first (stable client ids).
        let mut servers = Vec::new();
        for def in &cfg.servers {
            let client = engine.register_client(format!("server:{}", def.name));
            // The server's backend governs its batched iteration kernels and
            // its reconfiguration cost (YAML `backend:` on the server def).
            let model = match def.model.as_deref() {
                Some(m) if m.contains("8B") => llama_3_1_8b(),
                _ => llama_3_2_3b(),
            }
            .with_backend(def.backend);
            let scfg = ServerConfig {
                profile: ServerProfile {
                    model,
                    context_window: def.context_window,
                },
                tuning: ServerTuning {
                    kv_placement: def.kv_placement,
                    n_slots: def.n_slots,
                    batch_size: def.batch_size,
                },
            };
            servers.push(ServerRuntime {
                name: def.name.clone(),
                server: InferenceServer::new(scfg, client),
                routing: BTreeMap::new(),
                next_req_id: 0,
            });
        }

        // One client per workflow node.
        let mut nodes = Vec::new();
        for n in 0..dag.len() {
            let task = cfg
                .task(dag.uses(n))
                .with_context(|| format!("node `{}`: task missing", dag.id(n)))?;
            let client = engine.register_client(format!("{}:{}", task.app_type.name(), dag.id(n)));
            let seed = cfg.seed ^ (n as u64 + 1).wrapping_mul(0x9E37_79B9);
            // The task's `backend:` key selects the kernel implementation
            // for its directly-submitted jobs (server-routed work runs
            // under the server's backend instead).
            let app: Box<dyn Application> = match task.app_type {
                AppType::Chatbot => {
                    let model = match task.model.as_deref() {
                        Some(m) if m.contains("8B") => llama_3_1_8b(),
                        _ => llama_3_2_3b(),
                    }
                    .with_backend(task.backend);
                    Box::new(Chatbot::with_model(seed, task.num_requests, model))
                }
                AppType::DeepResearch => {
                    Box::new(DeepResearch::new(seed, task.num_requests).with_backend(task.backend))
                }
                AppType::ImageGen => {
                    let app = if unified {
                        ImageGen::apple_config(seed, task.num_requests)
                    } else {
                        ImageGen::new(seed, task.num_requests)
                    };
                    Box::new(app.with_backend(task.backend))
                }
                AppType::LiveCaptions => {
                    let app = if unified {
                        LiveCaptions::apple_config(seed, task.num_requests)
                    } else {
                        LiveCaptions::new(seed, task.num_requests)
                    };
                    Box::new(app.with_backend(task.backend))
                }
            };
            let server = task
                .server
                .as_deref()
                .map(|sname| {
                    servers
                        .iter()
                        .position(|s| s.name == sname)
                        .with_context(|| format!("unknown server `{sname}`"))
                })
                .transpose()?;
            let arrival = match &task.arrival {
                None => app.arrival(),
                Some(spec) => resolve_arrival(spec, seed),
            };
            nodes.push(NodeRuntime {
                app,
                ctx: AppContext {
                    client,
                    device: task.device,
                },
                arrival,
                server,
                state: NodeState::Waiting,
                issued: 0,
                finished: 0,
                metrics: Vec::new(),
                ready: 0.0,
                start: 0.0,
                end: 0.0,
                failed: None,
                dr_iteration: 0,
                req_started: 0.0,
            });
        }

        // Resource orchestrator: install the sharing policy now that all
        // clients exist.
        let policy = build_policy(cfg, &engine, &nodes, &servers);
        engine.set_policy(policy);

        // Adaptive-serving feedback loop (registered last so static runs
        // keep their client numbering).
        let controller = cfg.controller.as_ref().map(|spec| ControllerRuntime {
            controller: Controller::new(spec.clone()),
            client: engine.register_client("controller"),
            tick_count: 0,
            last_progress: (0, 0),
            idle_epochs: 0,
            reserve_updates: 0,
        });

        // Deterministic fault injection (registered after the controller so
        // fault-free runs keep their client numbering).
        let chaos = cfg.chaos.as_ref().map(|spec| ChaosRuntime {
            client: engine.register_client("chaos"),
            events: FaultSchedule::generate(spec, cfg.seed).events,
        });

        // Deterministic budgets: pure functions of the config, so a
        // budget-exhausted scenario fails identically on every host.
        engine.set_event_budget(Some(cfg.budget_events.unwrap_or(DEFAULT_EVENT_BUDGET)));

        Ok(ScenarioRunner {
            engine,
            dag,
            nodes,
            servers,
            controller,
            chaos,
            job_map: BTreeMap::new(),
            completed: BTreeSet::new(),
            runtime,
            pjrt_calls: 0,
            seed: cfg.seed,
            workflow_slo: cfg.workflow_slo,
            virtual_time_budget: cfg.budget_virtual_time.unwrap_or(DEFAULT_VIRTUAL_TIME_BUDGET),
            deadline: None,
            inject: cfg.inject_failure,
        })
    }

    /// Arm the wall-clock watchdog: `run` fails with [`WallClockTimeout`]
    /// once this much host time elapses (checked every [`WATCHDOG_STRIDE`]
    /// loop iterations — defense-in-depth, not a precise limit).
    pub fn with_watchdog(mut self, timeout: std::time::Duration) -> Self {
        self.deadline = Some((
            // detlint: allow(no-wall-clock) -- the watchdog is the documented
            // wall-clock boundary: host time arms a defense-in-depth timeout
            // whose outcomes are never journaled or digested (see `deadline`).
            std::time::Instant::now() + timeout,
            timeout.as_secs().max(1),
        ));
        self
    }

    /// Run the workflow to completion and produce the scenario result.
    pub fn run(mut self) -> Result<ScenarioResult> {
        // Supervision-test fault hook: fail before any virtual time elapses
        // so the outcome is trivially deterministic.
        match self.inject {
            Some(InjectFailure::Panic) => panic!("injected failure (inject_failure: panic)"),
            Some(InjectFailure::Error) => {
                anyhow::bail!("injected failure (inject_failure: error)")
            }
            None => {}
        }
        // Start servers and root nodes at t = 0.
        for s in &mut self.servers {
            s.server.start(&mut self.engine, 0.0);
        }
        for root in self.dag.roots() {
            self.start_node(root, 0.0);
        }
        if self.controller.is_some() {
            self.submit_tick(0.0);
        }
        // The whole fault schedule is known up-front (seed-derived), so every
        // transition is submitted now at its virtual-time deadline. Episodes
        // scheduled past workflow completion simply never execute.
        self.submit_chaos_jobs();

        // Main loop: advance virtual time event by event. Runaway scenarios
        // are cut off by the deterministic budgets (event count inside
        // `run_until_budgeted`, virtual-time horizon below) so the failure
        // is typed and digest-stable; the optional wall-clock watchdog is a
        // host-dependent last resort behind both.
        let mut iterations = 0u64;
        while self.completed.len() < self.dag.len() {
            iterations += 1;
            if let Some((deadline, limit_secs)) = self.deadline {
                // detlint: allow(no-wall-clock) -- watchdog boundary: the
                // strided deadline probe reads host time only to abort a
                // runaway attempt; timeout rows never reach a digest.
                if iterations % WATCHDOG_STRIDE == 0 && std::time::Instant::now() >= deadline {
                    return Err(anyhow::Error::new(WallClockTimeout { limit_secs }));
                }
            }
            // Pump servers (may submit new iteration jobs).
            let now = self.engine.now();
            for s in &mut self.servers {
                s.server.pump(&mut self.engine, now);
            }
            let Some(t) = self.engine.next_event_time() else {
                // No events and workflow incomplete: nothing can make
                // progress unless a server still holds queued work (handled
                // by pump above) — this is a deadlock.
                anyhow::bail!(
                    "workflow stalled at t={:.3}: {}/{} nodes complete",
                    self.engine.now(),
                    self.completed.len(),
                    self.dag.len()
                );
            };
            if t > self.virtual_time_budget {
                return Err(anyhow::Error::new(BudgetExhausted::VirtualTime {
                    limit: self.virtual_time_budget,
                    at: self.engine.now(),
                }));
            }
            // Budget exhaustion is unwrapped to the bare `BudgetExhausted`
            // so supervision layers can keep classifying it by downcast;
            // other engine failures surface as the typed `EngineError`.
            self.engine.run_until_budgeted(t).map_err(|e| match e {
                EngineError::Budget(b) => anyhow::Error::new(b),
                other => anyhow::Error::new(other),
            })?;
            let results = self.engine.take_completed();
            for r in results {
                self.route(r)?;
            }
        }

        let makespan = self
            .nodes
            .iter()
            .map(|n| n.end)
            .fold(0.0f64, f64::max);
        let policy = format!("{}", self.engine.policy());
        let client_names: Vec<String> = (0..self.engine.num_clients())
            .map(|i| self.engine.client_name(crate::gpusim::engine::ClientId(i)).to_string())
            .collect();
        let gpu_idle_w = self.engine.testbed().gpu.idle_power;
        let cpu_idle_w = self.engine.testbed().cpu.idle_power;
        // Digest and aggregates must be read *before* draining the trace:
        // in streaming mode the recorder (and its fold) is consumed by
        // `take_trace`, and in full mode the digest covers every row.
        let trace_digest = self.engine.current_trace_digest();
        let trace_aggregates = self.engine.trace_aggregates();
        let trace = self.engine.take_trace();
        let nodes: Vec<NodeResult> = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| NodeResult {
                id: self.dag.id(i).to_string(),
                app: n.app.name(),
                slo: n.app.slo(),
                metrics: n.metrics.clone(),
                ready: n.ready,
                start: n.start,
                end: n.end,
                background: self.dag.is_background(i),
                failed: n.failed.clone(),
            })
            .collect();
        let workflow = workflow_metrics(&self.dag, &nodes, self.workflow_slo);
        let server_reconfigs: usize = self
            .servers
            .iter()
            .map(|s| s.server.reconfigurations() as usize)
            .sum();
        let (policy_reconfigs, controller_actions) = match &self.controller {
            Some(ctl) => (
                ctl.reserve_updates,
                ctl.controller
                    .log()
                    .iter()
                    .map(|(t, a)| format!("t={t:.3} {a}"))
                    .collect(),
            ),
            None => (0, Vec::new()),
        };
        Ok(ScenarioResult {
            nodes,
            workflow,
            trace,
            trace_digest,
            trace_aggregates,
            client_names,
            makespan,
            policy,
            pjrt_calls: self.pjrt_calls,
            reconfigurations: server_reconfigs + policy_reconfigs,
            controller_actions,
            gpu_idle_w,
            cpu_idle_w,
        })
    }

    fn start_node(&mut self, n: NodeId, at: f64) {
        let node = &mut self.nodes[n];
        debug_assert_eq!(node.state, NodeState::Waiting);
        node.state = NodeState::Setup;
        // The scheduler starts a node the instant its last dependency
        // completes, so ready == start today; both are recorded so the
        // lifecycle stays meaningful if admission control ever delays one.
        node.ready = at;
        node.start = at;
        let spec = if node.server.is_some() {
            // Server-backed: the model is owned by the server; setup is a
            // cheap attach.
            JobSpec {
                client: node.ctx.client,
                label: format!("{}.attach", self.dag.id(n)),
                phases: vec![Phase::host("setup.attach", 0.01)],
            }
        } else {
            node.app.setup_job(&node.ctx)
        };
        let id = self.engine.submit(spec, at);
        self.job_map.insert(id, (n, JobKind::Setup));
    }

    fn route(&mut self, r: JobResult) -> Result<()> {
        // Server iteration jobs.
        let mut served = false;
        for s in &mut self.servers {
            if s.server.on_job_done(&r) {
                served = true;
                break;
            }
        }
        if served {
            self.collect_server_responses();
            return Ok(());
        }
        let Some(&(n, kind)) = self.job_map.get(&r.id) else {
            return Ok(()); // server start job or other unmapped job
        };
        self.job_map.remove(&r.id);
        match kind {
            JobKind::Setup => self.on_setup_done(n, r)?,
            JobKind::Request(idx) => self.on_request_done(n, idx, r)?,
            JobKind::Timer(idx) => self.on_timer_done(n, idx, r),
            JobKind::Cleanup => self.on_cleanup_done(n, r),
            JobKind::ControllerTick => self.on_tick(r.end),
            JobKind::Chaos(i) => self.on_chaos(i, r.end),
        }
        Ok(())
    }

    /// Submit every fault transition of the chaos schedule as a zero-length
    /// host job at its virtual-time deadline. Ballast is expressed purely as
    /// the job's mem-ops: an allocation that does not fit fails the job and
    /// the engine's rollback keeps VRAM accounting exact, which is exactly
    /// the memory pressure the fault models.
    fn submit_chaos_jobs(&mut self) {
        let Some(ch) = &self.chaos else { return };
        let client = ch.client;
        let capacity = self.engine.vram().capacity();
        let events = ch.events.clone();
        for (i, ev) in events.iter().enumerate() {
            let mut phase = Phase::host(ev.action.tag(), 0.0);
            phase = match ev.action {
                FaultAction::BallastStart { frac } => phase.with_mem_ops(vec![MemOp::Alloc {
                    label: format!("ballast{}", ev.episode),
                    bytes: (frac * capacity as f64) as u64,
                }]),
                // `free_labeled` returns 0 on a miss, so releasing a ballast
                // whose allocation failed is a safe no-op.
                FaultAction::BallastEnd => phase.with_mem_ops(vec![MemOp::Free {
                    label: format!("ballast{}", ev.episode),
                }]),
                _ => phase,
            };
            let spec = JobSpec {
                client,
                label: format!("{}.{}", ev.action.tag(), ev.episode),
                phases: vec![phase],
            };
            let id = self.engine.submit(spec, ev.at);
            self.job_map.insert(id, (0, JobKind::Chaos(i)));
        }
    }

    /// Apply the side effect of fault transition `i`. Every transition also
    /// wakes the adaptive controller: a fault epoch resets its cooldown so
    /// recovery actions are not gated behind a stale healthy streak.
    fn on_chaos(&mut self, i: usize, now: f64) {
        let Some(ch) = &self.chaos else { return };
        let action = ch.events[i].action;
        match action {
            FaultAction::ThrottleStart { factor } => self.engine.set_gpu_clock_scale(factor),
            FaultAction::ThrottleEnd => self.engine.set_gpu_clock_scale(1.0),
            FaultAction::SuspendStart => self.engine.set_gpu_suspended(true),
            FaultAction::SuspendEnd => self.engine.set_gpu_suspended(false),
            FaultAction::ServerCrash => {
                if let Some(s) = self.servers.iter_mut().find(|s| s.server.is_started()) {
                    s.server.crash(&mut self.engine, now);
                }
            }
            FaultAction::PcieDegradeStart { scale } => {
                for s in &mut self.servers {
                    s.server.set_dma_bw_scale(scale);
                }
            }
            FaultAction::PcieDegradeEnd => {
                for s in &mut self.servers {
                    s.server.set_dma_bw_scale(1.0);
                }
            }
            // Ballast already happened as the job's own mem-ops.
            FaultAction::BallastStart { .. } | FaultAction::BallastEnd => {}
        }
        if let Some(ctl) = self.controller.as_mut() {
            ctl.controller.observe_fault(now);
        }
    }

    /// Schedule the next controller epoch boundary as an ordinary host job
    /// — tick timing rides the same deterministic event heap as everything
    /// else, so adaptive runs replay byte-for-byte.
    fn submit_tick(&mut self, at: f64) {
        let ctl = self.controller.as_mut().expect("controller enabled");
        let epoch = ctl.controller.config().epoch;
        let spec = JobSpec {
            client: ctl.client,
            label: format!("controller.tick{}", ctl.tick_count),
            phases: vec![Phase::host("controller.epoch", epoch)],
        };
        ctl.tick_count += 1;
        let id = self.engine.submit(spec, at);
        self.job_map.insert(id, (0, JobKind::ControllerTick));
    }

    /// One controller epoch: evaluate the window, apply feasible actions,
    /// and schedule the next tick while the workflow is still running.
    fn on_tick(&mut self, now: f64) {
        if self.controller.is_none() {
            return;
        }
        let reserve = self.engine.policy().reserve_sms();
        let views: Vec<ServerView> = self
            .servers
            .iter()
            .map(|s| {
                let t = s.server.tuning();
                let p = &s.server.config().profile;
                ServerView {
                    kv_placement: t.kv_placement,
                    n_slots: t.n_slots,
                    busy: !s.server.idle(),
                    kv_fits_gpu: t.kv_placement == KvPlacement::Gpu
                        || self
                            .engine
                            .vram()
                            .would_fit(p.model.kv_cache_bytes(p.context_window)),
                }
            })
            .collect();
        let actions = {
            let ctl = self.controller.as_mut().unwrap();
            ctl.controller.decide(now, reserve, &views)
        };
        let mut applied = 0;
        let mut reserve_updates = 0;
        for &a in &actions {
            let ok = self.apply_action(&a, now);
            if ok {
                applied += 1;
                if matches!(a, ControllerAction::SetReserve { .. }) {
                    reserve_updates += 1;
                }
            }
            self.controller
                .as_mut()
                .unwrap()
                .controller
                .record_outcome(now, a, ok);
        }
        let progress = (
            self.completed.len(),
            self.nodes.iter().map(|n| n.finished).sum::<usize>(),
        );
        let workflow_running = self.completed.len() < self.dag.len();
        let ctl = self.controller.as_mut().unwrap();
        ctl.reserve_updates += reserve_updates;
        if progress == ctl.last_progress && applied == 0 {
            ctl.idle_epochs += 1;
        } else {
            ctl.idle_epochs = 0;
            ctl.last_progress = progress;
        }
        if workflow_running && ctl.idle_epochs < CONTROLLER_MAX_IDLE_EPOCHS {
            self.submit_tick(now);
        }
    }

    /// Execute one controller action against the engine/servers, after
    /// deterministic feasibility checks. Returns whether it was applied.
    fn apply_action(&mut self, action: &ControllerAction, now: f64) -> bool {
        match *action {
            ControllerAction::SetReserve { reserve_sms } => self
                .engine
                .update_policy(|p| p.set_reserve_sms(reserve_sms)),
            ControllerAction::MigrateKv { server, to } => {
                let s = &mut self.servers[server];
                if s.server.reconfig_pending() {
                    return false; // the previous change has not landed yet
                }
                if to == KvPlacement::Gpu {
                    let p = &s.server.config().profile;
                    let bytes = p.model.kv_cache_bytes(p.context_window);
                    if !self.engine.vram().would_fit(bytes) {
                        return false; // the onload would OOM: skip, retry later
                    }
                }
                let tuning = ServerTuning {
                    kv_placement: to,
                    ..s.server.tuning()
                };
                s.server.reconfigure(&mut self.engine, now, tuning);
                true
            }
            ControllerAction::ResizeSlots { server, n_slots } => {
                let s = &mut self.servers[server];
                if s.server.reconfig_pending() || n_slots == 0 {
                    return false;
                }
                let tuning = ServerTuning {
                    n_slots,
                    ..s.server.tuning()
                };
                s.server.reconfigure(&mut self.engine, now, tuning);
                true
            }
        }
    }

    /// Feed a completed request into the controller's observation window.
    fn observe_request(&mut self, n: NodeId, end: f64, slo_met: bool) {
        let tight = matches!(
            self.nodes[n].app.slo(),
            Slo::Chat { .. } | Slo::SegmentTime(_)
        );
        if let Some(ctl) = self.controller.as_mut() {
            ctl.controller.observe(Observation {
                end,
                slo_met,
                tight,
            });
        }
    }

    fn on_setup_done(&mut self, n: NodeId, r: JobResult) -> Result<()> {
        if let Some(err) = &r.error {
            // e.g. VRAM OOM: the node fails; the workflow continues.
            self.nodes[n].failed = Some(err.clone());
            self.finish_node(n, r.end);
            return Ok(());
        }
        self.nodes[n].state = NodeState::Running;
        let now = r.end;
        let total = self.nodes[n].app.num_requests();
        if total == 0 {
            self.submit_cleanup(n, now);
            return Ok(());
        }
        match self.nodes[n].arrival.schedule(total, now) {
            // Open-loop: the full arrival schedule is a pure function of the
            // arrival process, so every request is issued upfront and queues
            // independently of completions.
            Some(times) => {
                for (i, at) in times.into_iter().enumerate() {
                    self.issue_request(n, i, at);
                }
            }
            // Closed loop: issue the first request; the rest follow
            // completions (see `request_finished`).
            None => {
                self.issue_request(n, 0, now);
            }
        }
        Ok(())
    }

    fn issue_request(&mut self, n: NodeId, idx: usize, at: f64) {
        self.nodes[n].issued += 1;
        if self.nodes[n].server.is_some() {
            // Delay via a host timer job, then enqueue into the server.
            let client = self.nodes[n].ctx.client;
            let delay = (at - self.engine.now()).max(0.0);
            let spec = JobSpec {
                client,
                label: format!("{}.timer{}", self.dag.id(n), idx),
                phases: vec![Phase::host("timer", delay)],
            };
            let id = self.engine.submit(spec, self.engine.now());
            self.job_map.insert(id, (n, JobKind::Timer(idx)));
        } else {
            let spec = self.nodes[n].app.request_job(&self.nodes[n].ctx, idx);
            let id = self.engine.submit(spec, at);
            self.job_map.insert(id, (n, JobKind::Request(idx)));
        }
    }

    fn on_timer_done(&mut self, n: NodeId, idx: usize, r: JobResult) {
        let now = r.end;
        let sidx = self.nodes[n].server.expect("timer only for server-backed nodes");
        self.nodes[n].req_started = now;
        // Build the server request for this node's request idx.
        let (prompt, output) = self.server_request_shape(n, idx);
        let s = &mut self.servers[sidx];
        let rid = s.next_req_id;
        s.next_req_id += 1;
        s.routing.insert(rid, (n, idx));
        let app_name = self.nodes[n].app.name();
        s.server.enqueue(
            ServerRequest {
                id: rid,
                app: app_name,
                prompt_tokens: prompt,
                output_tokens: output,
            },
            now,
        );
        s.server.pump(&mut self.engine, now);
    }

    /// Request shape for a server-backed node. Chatbot sends the sampled
    /// LMSYS request; DeepResearch re-sends the full iteration context each
    /// agent step (the stateless OpenAI-compatible API pattern).
    fn server_request_shape(&self, n: NodeId, idx: usize) -> (usize, usize) {
        let node = &self.nodes[n];
        if let Some(chat) = node.app.as_any().downcast_ref::<Chatbot>() {
            let r = &chat.requests()[idx];
            (r.prompt_tokens, r.output_tokens)
        } else if let Some(dr) = node.app.as_any().downcast_ref::<DeepResearch>() {
            let task = &dr.tasks()[idx];
            let it = &task.iterations[node.dr_iteration.min(task.iterations.len() - 1)];
            (it.context_tokens, it.decode_tokens)
        } else {
            (64, 64)
        }
    }

    fn collect_server_responses(&mut self) {
        let now = self.engine.now();
        let mut finished: Vec<(NodeId, usize, crate::server::ServerResponse)> = Vec::new();
        for s in &mut self.servers {
            for resp in s.server.take_responses() {
                if let Some(&(n, idx)) = s.routing.get(&resp.id) {
                    s.routing.remove(&resp.id);
                    finished.push((n, idx, resp));
                }
            }
        }
        for (n, idx, resp) in finished {
            self.on_server_response(n, idx, resp, now);
        }
        // New capacity may be available.
        for s in &mut self.servers {
            s.server.pump(&mut self.engine, now);
        }
    }

    fn on_server_response(
        &mut self,
        n: NodeId,
        idx: usize,
        resp: crate::server::ServerResponse,
        now: f64,
    ) {
        let is_dr = self.nodes[n].app.as_any().downcast_ref::<DeepResearch>().is_some();
        if is_dr {
            // Advance the agent loop: more iterations of this task?
            let (iters, tool_time) = {
                let dr = self.nodes[n].app.as_any().downcast_ref::<DeepResearch>().unwrap();
                let task = &dr.tasks()[idx];
                let next = self.nodes[n].dr_iteration + 1;
                let tt = task
                    .iterations
                    .get(next)
                    .map(|it| it.tool_time)
                    .unwrap_or(0.0);
                (task.iterations.len(), tt)
            };
            self.nodes[n].dr_iteration += 1;
            if self.nodes[n].dr_iteration < iters {
                // Same request idx, next iteration after tool time.
                let client = self.nodes[n].ctx.client;
                let spec = JobSpec {
                    client,
                    label: format!("{}.tool{}", self.dag.id(n), self.nodes[n].dr_iteration),
                    phases: vec![Phase::host("timer", tool_time)],
                };
                let id = self.engine.submit(spec, now);
                self.job_map.insert(id, (n, JobKind::Timer(idx)));
                return;
            }
            // Task complete.
            let latency = now - self.nodes[n].req_started;
            self.nodes[n].metrics.push(RequestMetrics {
                label: format!("{}.task{idx}", self.dag.id(n)),
                latency,
                normalized: 0.0,
                slo_met: true,
                components: vec![("e2e", latency)],
            });
            self.nodes[n].dr_iteration = 0;
            self.observe_request(n, now, true);
            self.request_finished(n, now);
        } else {
            // Chat-style SLO evaluation from serving timestamps.
            let slo = self.nodes[n].app.slo();
            let (slo_ttft, slo_tpot) = match slo {
                Slo::Chat { ttft, tpot } => (ttft, tpot),
                _ => (f64::INFINITY, f64::INFINITY),
            };
            let normalized = (resp.ttft() / slo_ttft).max(resp.tpot() / slo_tpot);
            self.nodes[n].metrics.push(RequestMetrics {
                label: format!("{}.req{idx}", self.dag.id(n)),
                latency: resp.end - resp.submit,
                normalized,
                slo_met: normalized <= 1.0,
                components: vec![("ttft", resp.ttft()), ("tpot", resp.tpot())],
            });
            self.observe_request(n, now, normalized <= 1.0);
            self.request_finished(n, now);
        }
        self.run_real_compute(n, idx);
    }

    fn on_request_done(&mut self, n: NodeId, idx: usize, r: JobResult) -> Result<()> {
        if let Some(err) = &r.error {
            self.nodes[n].metrics.push(RequestMetrics {
                label: r.label.clone(),
                latency: r.latency(),
                normalized: f64::INFINITY,
                slo_met: false,
                components: vec![],
            });
            self.nodes[n].failed = Some(err.clone());
            self.observe_request(n, r.end, false);
        } else {
            let m = self.nodes[n].app.evaluate(&r);
            let met = m.slo_met;
            self.nodes[n].metrics.push(m);
            self.observe_request(n, r.end, met);
        }
        self.run_real_compute(n, idx);
        self.request_finished(n, r.end);
        Ok(())
    }

    fn request_finished(&mut self, n: NodeId, now: f64) {
        self.nodes[n].finished += 1;
        let total = self.nodes[n].app.num_requests();
        if self.nodes[n].finished >= total {
            self.submit_cleanup(n, now);
            return;
        }
        let think = match &self.nodes[n].arrival {
            Arrival::ClosedLoop { think } => Some(*think),
            _ => None, // open loop: all arrivals were issued at setup time
        };
        if let Some(think) = think {
            if self.nodes[n].issued < total {
                let next = self.nodes[n].issued;
                self.issue_request(n, next, now + think);
            }
        }
    }

    fn submit_cleanup(&mut self, n: NodeId, now: f64) {
        self.nodes[n].state = NodeState::Cleanup;
        let spec = if self.nodes[n].server.is_some() {
            JobSpec {
                client: self.nodes[n].ctx.client,
                label: format!("{}.detach", self.dag.id(n)),
                phases: vec![Phase::host("cleanup.detach", 0.01)],
            }
        } else {
            self.nodes[n].app.cleanup_job(&self.nodes[n].ctx)
        };
        let id = self.engine.submit(spec, now);
        self.job_map.insert(id, (n, JobKind::Cleanup));
    }

    fn on_cleanup_done(&mut self, n: NodeId, r: JobResult) {
        self.finish_node(n, r.end);
    }

    fn finish_node(&mut self, n: NodeId, now: f64) {
        self.nodes[n].state = NodeState::Complete;
        self.nodes[n].end = now;
        self.completed.insert(n);
        for ready in self.dag.ready_after(&self.completed, n) {
            if self.nodes[ready].state == NodeState::Waiting {
                self.start_node(ready, now);
            }
        }
    }

    /// Execute the node's model HLO through PJRT once per request — the
    /// real-numerics validation path (L1/L2 composing with L3).
    fn run_real_compute(&mut self, n: NodeId, idx: usize) {
        let Some(rt) = &self.runtime else { return };
        let artifact = match self.nodes[n].app.name() {
            "Chatbot" | "DeepResearch" => "tiny_llama_decode",
            "ImageGen" => "tiny_diffusion_step",
            "LiveCaptions" => "tiny_whisper_encode",
            _ => return,
        };
        if rt.spec(artifact).is_some() {
            let seed = self.seed ^ ((n as u64) << 32) ^ idx as u64;
            if rt.execute_seeded(artifact, seed).is_ok() {
                self.pjrt_calls += 1;
            }
        }
    }
}

/// Lower a config-level arrival override to the runtime arrival process.
/// Poisson draws take the node's derived seed so two nodes with the same
/// rate still see decorrelated arrival streams.
fn resolve_arrival(spec: &ArrivalSpec, seed: u64) -> Arrival {
    match spec {
        ArrivalSpec::Closed { think } => Arrival::ClosedLoop { think: *think },
        ArrivalSpec::Periodic { period } => Arrival::OpenLoop { period: *period },
        ArrivalSpec::Poisson { rate } => Arrival::Poisson {
            rate: *rate,
            seed: seed ^ 0xA076_1D64_78BD_642F,
        },
        ArrivalSpec::Trace { offsets } => Arrival::Trace {
            offsets: offsets.clone(),
        },
    }
}

/// Build the engine policy from the configured strategy.
fn build_policy(
    cfg: &BenchConfig,
    _engine: &Engine,
    nodes: &[NodeRuntime],
    servers: &[ServerRuntime],
) -> Policy {
    match cfg.strategy {
        Strategy::Greedy => Policy::Greedy,
        Strategy::FairShare => Policy::FairShare,
        Strategy::SloAware => {
            // Priority set: GPU-placed nodes whose application carries a
            // tight (sub-second-scale) SLO — Chatbot and LiveCaptions.
            let mut priority = Vec::new();
            for node in nodes.iter() {
                let tight = matches!(
                    node.app.slo(),
                    crate::apps::Slo::Chat { .. } | crate::apps::Slo::SegmentTime(_)
                );
                if tight && node.ctx.device == Device::Gpu {
                    priority.push(node.ctx.client);
                }
                // A shared server inherits priority from the tight-SLO apps
                // it serves: their GPU kernels run under the *server's*
                // client, so that is where the reservation must bite.
                if tight {
                    if let Some(sidx) = node.server {
                        let c = servers[sidx].server.client();
                        if !priority.contains(&c) {
                            priority.push(c);
                        }
                    }
                }
            }
            if priority.is_empty() {
                return Policy::Greedy;
            }
            Policy::SloAware {
                priority,
                reserve_sms: 8,
            }
        }
        Strategy::Partition => {
            // The engine owns the actual device (possibly a synthesized
            // fleet testbed), so partition capacity comes from there — not
            // from re-deriving a named profile out of `cfg.testbed`.
            let total = engine.testbed().gpu.num_sms;
            // GPU-placed clients participate in the partition.
            let mut gpu_clients = Vec::new();
            for (i, node) in nodes.iter().enumerate() {
                if node.ctx.device == Device::Gpu && node.server.is_none() {
                    let task = cfg.task(&cfg.workflow[i].uses);
                    let mps = task.map(|t| t.mps).unwrap_or(100.0);
                    gpu_clients.push((node.ctx.client, mps));
                }
            }
            for s in servers {
                gpu_clients.push((s.server.client(), 100.0));
            }
            if gpu_clients.is_empty() {
                return Policy::Greedy;
            }
            // mps == 100 for everyone → equal split (the paper's 33% each);
            // otherwise honor the per-task percentages.
            let all_default = gpu_clients.iter().all(|(_, m)| *m >= 99.9);
            let caps = if all_default {
                let share = (total / gpu_clients.len()).max(1);
                gpu_clients.iter().map(|(c, _)| (*c, share)).collect()
            } else {
                gpu_clients
                    .iter()
                    .map(|(c, m)| (*c, ((m / 100.0 * total as f64) as usize).max(1)))
                    .collect()
            };
            Policy::Partition(caps)
        }
    }
}

/// Convenience: parse + run a config text with an optional artifacts dir.
pub fn run_config_text(text: &str, artifacts_dir: Option<&str>) -> Result<ScenarioResult> {
    run_config_text_watchdog(text, artifacts_dir, None)
}

/// [`run_config_text`] with an optional wall-clock watchdog (supervision
/// layers only; see [`WallClockTimeout`] for why configs can't set one).
pub fn run_config_text_watchdog(
    text: &str,
    artifacts_dir: Option<&str>,
    watchdog: Option<std::time::Duration>,
) -> Result<ScenarioResult> {
    run_config_text_on(text, artifacts_dir, watchdog, None)
}

/// [`run_config_text_watchdog`] with an optional explicit [`Testbed`]
/// override. The fleet runner uses this to execute a scenario slice on a
/// population-sampled synthesized device; `None` resolves the config's
/// named `testbed:` kind as always (the YAML key is then inert apart from
/// parsing).
pub fn run_config_text_on(
    text: &str,
    artifacts_dir: Option<&str>,
    watchdog: Option<std::time::Duration>,
    testbed: Option<Testbed>,
) -> Result<ScenarioResult> {
    let cfg = BenchConfig::parse(text)?;
    let runtime = match artifacts_dir {
        Some(d) if Runtime::available(d) => Some(Runtime::load_dir(d)?),
        _ => None,
    };
    let mut runner = match testbed {
        Some(tb) => ScenarioRunner::with_testbed(&cfg, tb, runtime)?,
        None => ScenarioRunner::new(&cfg, runtime)?,
    };
    if let Some(limit) = watchdog {
        runner = runner.with_watchdog(limit);
    }
    runner.run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_chatbot_node_runs() {
        let text = "\
Chat (chatbot):
  num_requests: 3
  device: gpu
";
        let result = run_config_text(text, None).unwrap();
        assert_eq!(result.nodes.len(), 1);
        let node = &result.nodes[0];
        assert_eq!(node.metrics.len(), 3);
        assert!(node.failed.is_none());
        let att = node.attainment().unwrap();
        assert!(att > 0.99, "attainment {att}");
        assert!(result.makespan > 0.0);
        assert!(!result.trace.is_empty());
        // A single-node workflow is its own critical path.
        assert_eq!(result.workflow.critical_path, vec!["Chat (chatbot)"]);
        assert_eq!(result.workflow.e2e_latency, node.end);
        assert_eq!(result.workflow.e2e_slo_met, None, "no workflow_slo configured");
    }

    #[test]
    fn event_budget_key_trips_typed_and_deterministic() {
        let text = "\
Chat (chatbot):
  num_requests: 3
  device: gpu
budget_events: 5
";
        let run = || run_config_text(text, None).unwrap_err();
        let e1 = run();
        let b1 = e1
            .downcast_ref::<BudgetExhausted>()
            .expect("typed BudgetExhausted must survive the anyhow chain");
        assert!(matches!(b1, BudgetExhausted::Events { budget: 5, .. }), "{b1:?}");
        // Identical config → identical failure, message and all.
        assert_eq!(e1.to_string(), run().to_string());
    }

    #[test]
    fn virtual_time_budget_key_trips_typed() {
        let text = "\
Chat (chatbot):
  num_requests: 3
  device: gpu
budget_virtual_time: 0.001
";
        let err = run_config_text(text, None).unwrap_err();
        let b = err.downcast_ref::<BudgetExhausted>().expect("typed error");
        assert!(
            matches!(b, BudgetExhausted::VirtualTime { .. }),
            "expected VirtualTime, got {b:?}"
        );
    }

    #[test]
    fn inject_error_fails_at_run_start() {
        let text = "\
Chat (chatbot):
  num_requests: 1
inject_failure: error
";
        let err = run_config_text(text, None).unwrap_err();
        assert!(err.to_string().contains("injected failure"), "{err:#}");
    }

    #[test]
    fn inject_panic_panics_and_is_catchable() {
        let text = "\
Chat (chatbot):
  num_requests: 1
inject_failure: panic
";
        let r = std::panic::catch_unwind(|| run_config_text(text, None));
        let payload = r.expect_err("must panic");
        let msg = payload
            .downcast_ref::<String>()
            .map(String::as_str)
            .or_else(|| payload.downcast_ref::<&str>().copied())
            .unwrap_or("");
        assert!(msg.contains("injected failure"), "payload: {msg}");
    }

    #[test]
    fn dependency_ordering_respected() {
        let text = "\
A (imagegen):
  num_requests: 1
B (livecaptions):
  num_requests: 2
workflows:
  first:
    uses: A (imagegen)
  second:
    uses: B (livecaptions)
    depend_on: [\"first\"]
";
        let result = run_config_text(text, None).unwrap();
        let a = result.node("first").unwrap();
        let b = result.node("second").unwrap();
        assert!(b.start >= a.end - 1e-9, "b.start {} a.end {}", b.start, a.end);
    }

    #[test]
    fn concurrent_roots_overlap() {
        let text = "\
A (chatbot):
  num_requests: 2
B (imagegen):
  num_requests: 2
";
        let result = run_config_text(text, None).unwrap();
        let a = result.node("A (chatbot)").unwrap();
        let b = result.node("B (imagegen)").unwrap();
        // Both start at t=0 (concurrent execution).
        assert!(a.start < 1e-9 && b.start < 1e-9);
        let overlap = a.end.min(b.end) - a.start.max(b.start);
        assert!(overlap > 0.0, "nodes must overlap in time");
    }

    #[test]
    fn server_backed_chat_runs() {
        let text = "\
Brainstorm (chatbot):
  num_requests: 3
  server: llama
servers:
  llama:
    model: Llama-3.2-3B
    context_window: 16384
    kv_placement: gpu
";
        let result = run_config_text(text, None).unwrap();
        let node = &result.nodes[0];
        assert_eq!(node.metrics.len(), 3);
        // Exclusive server with KV on GPU → chat meets its SLO.
        let att = node.attainment().unwrap();
        assert!(att > 0.99, "attainment {att}");
    }

    #[test]
    fn partition_policy_installed() {
        let text = "\
A (chatbot):
  num_requests: 1
B (imagegen):
  num_requests: 1
strategy: partition
";
        let result = run_config_text(text, None).unwrap();
        assert!(result.policy.starts_with("partition"), "{}", result.policy);
    }

    #[test]
    fn poisson_arrival_issues_all_requests() {
        let text = "\
Chat (chatbot):
  num_requests: 4
  device: gpu
  arrival: poisson
  rate: 2.0
seed: 11
";
        let result = run_config_text(text, None).unwrap();
        let node = &result.nodes[0];
        assert_eq!(node.metrics.len(), 4);
        assert!(node.failed.is_none());
        // Deterministic across runs.
        let again = run_config_text(text, None).unwrap();
        let lat = |r: &ScenarioResult| -> Vec<f64> {
            r.nodes[0].metrics.iter().map(|m| m.latency).collect()
        };
        assert_eq!(lat(&result), lat(&again));
    }

    #[test]
    fn trace_arrival_respects_offsets() {
        let text = "\
Img (imagegen):
  num_requests: 3
  device: gpu
  arrival: trace
  trace: [0, 8, 30]
seed: 5
";
        let result = run_config_text(text, None).unwrap();
        let node = &result.nodes[0];
        assert_eq!(node.metrics.len(), 3);
        // The last request cannot finish before its 30 s arrival offset.
        assert!(node.end > 30.0, "end {}", node.end);
    }

    #[test]
    fn open_loop_overrides_apply_to_server_backed_nodes() {
        let text = "\
Chat (chatbot):
  num_requests: 3
  server: llama
  arrival: poisson
  rate: 1.0
servers:
  llama:
    model: Llama-3.2-3B
    context_window: 16384
    kv_placement: gpu
seed: 3
";
        let result = run_config_text(text, None).unwrap();
        assert_eq!(result.nodes[0].metrics.len(), 3);
    }

    #[test]
    fn task_backend_selects_the_kernel_implementation() {
        let run = |backend_line: &str| {
            run_config_text(
                &format!(
                    "Chat (chatbot):\n  num_requests: 2\n  device: gpu\n{backend_line}seed: 6\n"
                ),
                None,
            )
            .unwrap()
        };
        let tuned = run("");
        let generic = run("  backend: generic_torch\n");
        // Same seed → same sampled requests; the generic implementation is
        // strictly slower on every one of them (more launches, register-
        // hungry attention with materialized intermediates).
        assert_eq!(tuned.nodes[0].metrics.len(), generic.nodes[0].metrics.len());
        for (t, g) in tuned.nodes[0].metrics.iter().zip(&generic.nodes[0].metrics) {
            assert!(
                g.latency > t.latency,
                "generic {} must exceed tuned {}",
                g.latency,
                t.latency
            );
        }
        // Exclusive GPU: even generic still meets the per-request SLO.
        assert!(generic.nodes[0].attainment().unwrap() > 0.99);
    }

    #[test]
    fn static_run_reports_zero_reconfigurations() {
        let text = "\
Chat (chatbot):
  num_requests: 2
  device: gpu
";
        let result = run_config_text(text, None).unwrap();
        assert_eq!(result.reconfigurations, 0);
        assert!(result.controller_actions.is_empty());
    }

    #[test]
    fn controller_block_wires_into_the_run_loop() {
        // Light wiring check: with a healthy server the controller ticks
        // along, makes no changes, and the workflow completes normally.
        // The heavy contention ablation (migration firing, strict
        // attainment improvement, byte-identical replays) is pinned in
        // `tests/adaptive_serving.rs`.
        let text = "\
Chat (chatbot):
  num_requests: 3
  server: llama
servers:
  llama:
    model: Llama-3.2-3B
    context_window: 16384
    kv_placement: gpu
controller:
  epoch: 1s
  window: 8s
seed: 4
";
        let result = run_config_text(text, None).unwrap();
        assert_eq!(result.nodes[0].metrics.len(), 3);
        // GPU-resident KV, exclusive server: nothing for the loop to fix.
        assert_eq!(result.reconfigurations, 0, "{:?}", result.controller_actions);
        assert!(result.nodes[0].attainment().unwrap() > 0.99);
    }

    #[test]
    fn critical_path_follows_the_binding_dependency() {
        // fanout: first → {slow (imagegen), fast (livecaptions)} — the
        // critical path must run through whichever branch finished last,
        // and the other branch carries the slack.
        let text = "\
A (chatbot):
  num_requests: 1
Slow (imagegen):
  num_requests: 3
Fast (livecaptions):
  num_requests: 2
workflows:
  first:
    uses: A (chatbot)
  slow:
    uses: Slow (imagegen)
    depend_on: [\"first\"]
  fast:
    uses: Fast (livecaptions)
    depend_on: [\"first\"]
seed: 2
";
        let result = run_config_text(text, None).unwrap();
        let wf = &result.workflow;
        let slow = result.node("slow").unwrap();
        let fast = result.node("fast").unwrap();
        let (tail, other) = if slow.end > fast.end {
            ("slow", fast)
        } else {
            ("fast", slow)
        };
        assert_eq!(wf.critical_path, vec!["first", tail]);
        assert_eq!(wf.e2e_latency, slow.end.max(fast.end));
        // Stage stats: critical stages have zero slack; the other branch's
        // slack is exactly the gap to the e2e completion (both are leaves).
        for s in &wf.stages {
            if s.on_critical_path {
                assert!(s.slack.abs() < 1e-9, "{}: slack {}", s.id, s.slack);
            }
        }
        let other_stage = wf.stages.iter().find(|s| s.id == other.id).unwrap();
        assert!(
            (other_stage.slack - (wf.e2e_latency - other.end)).abs() < 1e-9,
            "leaf slack {} vs gap {}",
            other_stage.slack,
            wf.e2e_latency - other.end
        );
        // Lifecycle: both branches became ready when `first` completed.
        let first = result.node("first").unwrap();
        assert_eq!(slow.ready, first.end);
        assert_eq!(fast.ready, first.end);
        assert!(wf.critical_path_len <= wf.e2e_latency + 1e-9);
    }

    #[test]
    fn workflow_slo_verdict_and_background_exclusion() {
        let base = "\
Bg (imagegen):
  num_requests: 2
Fg (livecaptions):
  num_requests: 2
workflows:
  bg:
    uses: Bg (imagegen)
    background: true
  fg:
    uses: Fg (livecaptions)
";
        let result = run_config_text(&format!("{base}workflow_slo: 10000\n"), None).unwrap();
        let fg = result.node("fg").unwrap();
        // Background node excluded from e2e and the stage table …
        assert_eq!(result.workflow.e2e_latency, fg.end);
        assert_eq!(result.workflow.critical_path, vec!["fg"]);
        assert_eq!(result.workflow.stages.len(), 1);
        assert!(result.nodes.iter().any(|n| n.background && n.id == "bg"));
        // … but still counted in the makespan.
        assert!(result.makespan >= result.workflow.e2e_latency);
        assert_eq!(result.workflow.e2e_slo_met, Some(true));
        assert_eq!(result.workflow.workflow_slo, Some(10000.0));

        let tight = run_config_text(&format!("{base}workflow_slo: 1ms\n"), None).unwrap();
        assert_eq!(tight.workflow.e2e_slo_met, Some(false));
    }

    #[test]
    fn oom_setup_fails_node_not_workflow() {
        // Two tasks that cannot both fit: an 8B chatbot on GPU (16 GiB) plus
        // ImageGen (8 GiB) plus chat KV — the second setup OOMs but the
        // workflow still completes.
        let text = "\
Big (chatbot):
  model: Llama-3.1-8B
  num_requests: 1
  device: gpu
Img (imagegen):
  num_requests: 8
  device: gpu
Research (deepresearch):
  num_requests: 1
  device: gpu
";
        let result = run_config_text(text, None).unwrap();
        let failed: Vec<&NodeResult> =
            result.nodes.iter().filter(|n| n.failed.is_some()).collect();
        assert!(!failed.is_empty(), "expected at least one OOM node");
        // Workflow still produced results for the others.
        assert!(result.nodes.iter().any(|n| n.failed.is_none() && !n.metrics.is_empty()));
        assert!(result.workflow.failed, "a failed node marks the workflow failed");

        // Regression: a failed node ends *early*, which used to fabricate a
        // short e2e latency and a spurious `met` verdict under a generous
        // workflow_slo. A failed workflow never meets its bound.
        let with_slo = run_config_text(&format!("{text}workflow_slo: 10000\n"), None).unwrap();
        assert!(with_slo.workflow.failed);
        assert_eq!(with_slo.workflow.e2e_slo_met, Some(false));
    }
}
