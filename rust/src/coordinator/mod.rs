//! The ConsumerBench coordinator — the paper's system contribution.
//!
//! Pipeline (Fig. 1): ① parse the user's YAML configuration
//! ([`config::BenchConfig`]) → ② build + validate the workflow DAG
//! ([`dag::Dag`]) → ③ execute under the configured resource-sharing
//! strategy ([`executor::ScenarioRunner`]) while the system monitor records
//! utilization/power → ④ generate the benchmark report
//! ([`report::generate`]).

pub mod config;
pub mod controller;
pub mod dag;
pub mod executor;
pub mod report;

pub use config::{AppType, ArrivalSpec, BenchConfig, InjectFailure, Strategy, TestbedKind};
pub use controller::{Controller, ControllerAction, ControllerConfig, Observation, ServerView};
pub use dag::Dag;
pub use executor::{
    run_config_text, run_config_text_on, run_config_text_watchdog, NodeResult, ScenarioResult,
    ScenarioRunner, StageStat, WallClockTimeout, WorkflowMetrics, DEFAULT_EVENT_BUDGET,
    DEFAULT_VIRTUAL_TIME_BUDGET,
};
pub use report::{generate, to_csv, to_json_summary, BenchmarkReport};
