//! Benchmark report generation (§3.2, step ④).
//!
//! After a workflow completes, ConsumerBench evaluates each application
//! against its SLOs and emits a report covering per-application latency
//! distributions, SLO attainment, and system-level resource efficiency —
//! the content of the paper's figures as text tables + CSV.

use crate::apps::Slo;
use crate::coordinator::executor::ScenarioResult;
use crate::monitor::MonitorReport;
use crate::util::stats::Summary;

/// A rendered benchmark report.
#[derive(Debug)]
pub struct BenchmarkReport {
    pub text: String,
    pub monitor: MonitorReport,
}

/// Build the report for a scenario result.
pub fn generate(result: &ScenarioResult) -> BenchmarkReport {
    let monitor = MonitorReport::from_trace(&result.trace, &result.client_names, 0.1);
    let mut out = String::new();
    out.push_str("==============================================================\n");
    out.push_str(" ConsumerBench report\n");
    out.push_str("==============================================================\n");
    out.push_str(&format!("policy:            {}\n", result.policy));
    out.push_str(&format!("workflow makespan: {:.2} s\n", result.makespan));
    out.push_str(&format!("PJRT validations:  {}\n", result.pjrt_calls));
    out.push('\n');

    out.push_str("-- Applications ----------------------------------------------\n");
    out.push_str(&format!(
        "{:<28} {:>5} {:>9} {:>9} {:>9} {:>10} {:>8}\n",
        "node", "reqs", "mean lat", "p99 lat", "norm", "SLO attain", "span"
    ));
    for node in &result.nodes {
        let lats: Vec<f64> = node.metrics.iter().map(|m| m.latency).collect();
        let s = Summary::of(&lats);
        let (mean, p99) = s.map(|s| (s.mean, s.p99)).unwrap_or((0.0, 0.0));
        out.push_str(&format!(
            "{:<28} {:>5} {:>8.2}s {:>8.2}s {:>9.2} {:>9.0}% {:>7.1}s{}\n",
            truncate(&node.id, 28),
            node.metrics.len(),
            mean,
            p99,
            node.mean_normalized(),
            node.attainment() * 100.0,
            node.duration(),
            node.failed
                .as_ref()
                .map(|e| format!("  FAILED: {e}"))
                .unwrap_or_default()
        ));
        out.push_str(&format!(
            "{:<28} slo: {}\n",
            "",
            slo_brief(&node.slo)
        ));
    }
    out.push('\n');

    out.push_str("-- System metrics --------------------------------------------\n");
    out.push_str(&format!(
        "GPU: SMACT(busy mean) {:>5.1}%  SMOCC(busy mean) {:>5.1}%  peak VRAM {:>5.1} GiB\n",
        monitor.mean_busy_smact() * 100.0,
        monitor.mean_busy_smocc() * 100.0,
        monitor.peak_vram_gib(),
    ));
    out.push_str(&format!(
        "energy: GPU {:>8.0} J   CPU {:>8.0} J\n",
        monitor.gpu_energy(),
        monitor.cpu_energy()
    ));
    let spark_max = 1.0;
    out.push_str(&format!(
        "SMACT  {}\nSMOCC  {}\nCPU    {}\n",
        monitor.gpu_smact.sparkline(60, spark_max),
        monitor.gpu_smocc.sparkline(60, spark_max),
        monitor.cpu_util.sparkline(60, spark_max),
    ));
    out.push('\n');

    out.push_str("-- Per-client GPU reservation --------------------------------\n");
    for (i, name) in result.client_names.iter().enumerate() {
        let (act, _) = &monitor.per_client[i];
        if act.values().iter().any(|&v| v > 1e-6) {
            out.push_str(&format!("{:<28} {}\n", truncate(name, 28), act.sparkline(60, 1.0)));
        }
    }

    BenchmarkReport { text: out, monitor }
}

/// CSV export of the core per-request data (one row per request).
pub fn to_csv(result: &ScenarioResult) -> String {
    let mut out = String::from("node,app,request,latency_s,normalized,slo_met\n");
    for node in &result.nodes {
        for m in &node.metrics {
            out.push_str(&format!(
                "{},{},{},{:.6},{:.4},{}\n",
                node.id, node.app, m.label, m.latency, m.normalized, m.slo_met
            ));
        }
    }
    out
}

fn slo_brief(slo: &Slo) -> String {
    slo.describe()
}

fn truncate(s: &str, n: usize) -> String {
    if s.chars().count() <= n {
        s.to_string()
    } else {
        let cut: String = s.chars().take(n - 1).collect();
        format!("{cut}…")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::run_config_text;

    #[test]
    fn report_renders_for_simple_scenario() {
        let result = run_config_text("Chat (chatbot):\n  num_requests: 2\n", None).unwrap();
        let report = generate(&result);
        assert!(report.text.contains("ConsumerBench report"));
        assert!(report.text.contains("Chat (chatbot)"));
        assert!(report.text.contains("TTFT:1s"));
        assert!(report.text.contains("SMACT"));
        // Attainment column shows 100% for exclusive GPU chat.
        assert!(report.text.contains("100%"), "{}", report.text);
    }

    #[test]
    fn csv_has_row_per_request() {
        let result = run_config_text("Chat (chatbot):\n  num_requests: 3\n", None).unwrap();
        let csv = to_csv(&result);
        assert_eq!(csv.lines().count(), 4); // header + 3 requests
        assert!(csv.starts_with("node,app,request"));
    }

    #[test]
    fn truncate_handles_long_names() {
        assert_eq!(truncate("short", 28), "short");
        let long = "x".repeat(64);
        assert_eq!(truncate(&long, 28).chars().count(), 28);
    }
}
