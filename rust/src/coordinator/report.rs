//! Benchmark report generation (§3.2, step ④).
//!
//! After a workflow completes, ConsumerBench evaluates each application
//! against its SLOs and emits a report covering per-application latency
//! distributions, SLO attainment, and system-level resource efficiency —
//! the content of the paper's figures as text tables + CSV.

use crate::apps::Slo;
use crate::coordinator::executor::ScenarioResult;
use crate::monitor::MonitorReport;
use crate::util::json::{json_num, json_opt_bool, json_opt_num, json_str};
use crate::util::stats::Summary;

/// A rendered benchmark report.
#[derive(Debug)]
pub struct BenchmarkReport {
    pub text: String,
    pub monitor: MonitorReport,
}

/// Build the report for a scenario result.
pub fn generate(result: &ScenarioResult) -> BenchmarkReport {
    let monitor = MonitorReport::from_trace(
        &result.trace,
        &result.client_names,
        crate::monitor::DEFAULT_INTERVAL,
        result.gpu_idle_w,
        result.cpu_idle_w,
    );
    let mut out = String::new();
    out.push_str("==============================================================\n");
    out.push_str(" ConsumerBench report\n");
    out.push_str("==============================================================\n");
    out.push_str(&format!("policy:            {}\n", result.policy));
    out.push_str(&format!("workflow makespan: {:.2} s\n", result.makespan));
    out.push_str(&format!("PJRT validations:  {}\n", result.pjrt_calls));
    out.push_str(&format!(
        "reconfigurations:  {}\n",
        result.reconfigurations
    ));
    for action in &result.controller_actions {
        out.push_str(&format!("  controller: {action}\n"));
    }
    out.push('\n');

    out.push_str("-- Applications ----------------------------------------------\n");
    out.push_str(&format!(
        "{:<28} {:>5} {:>9} {:>9} {:>9} {:>10} {:>8}\n",
        "node", "reqs", "mean lat", "p99 lat", "norm", "SLO attain", "span"
    ));
    for node in &result.nodes {
        let lats: Vec<f64> = node.metrics.iter().map(|m| m.latency).collect();
        let s = Summary::of(&lats);
        let (mean, p99) = s.map(|s| (s.mean, s.p99)).unwrap_or((0.0, 0.0));
        // A node with no completed requests has no attainment — `n/a`, not
        // the perfect score the old 1.0 default printed.
        let attain = match node.attainment() {
            Some(a) => format!("{:>9.0}%", a * 100.0),
            None => format!("{:>10}", "n/a"),
        };
        out.push_str(&format!(
            "{:<28} {:>5} {:>8.2}s {:>8.2}s {:>9.2} {} {:>7.1}s{}\n",
            truncate(&node.id, 28),
            node.metrics.len(),
            mean,
            p99,
            node.mean_normalized(),
            attain,
            node.duration(),
            node.failed
                .as_ref()
                .map(|e| format!("  FAILED: {e}"))
                .unwrap_or_default()
        ));
        out.push_str(&format!(
            "{:<28} slo: {}\n",
            "",
            slo_brief(&node.slo)
        ));
    }
    out.push('\n');

    out.push_str("-- Workflow --------------------------------------------------\n");
    let wf = &result.workflow;
    let verdict = match (wf.workflow_slo, wf.e2e_slo_met) {
        (Some(bound), Some(true)) => format!("  (SLO {bound}s: met)"),
        (Some(bound), Some(false)) if wf.failed => {
            format!("  (SLO {bound}s: MISSED — a workflow node failed)")
        }
        (Some(bound), Some(false)) => format!("  (SLO {bound}s: MISSED)"),
        _ if wf.failed => String::from("  (no workflow SLO; a workflow node failed)"),
        _ => String::from("  (no workflow SLO)"),
    };
    out.push_str(&format!("e2e latency:   {:.2} s{verdict}\n", wf.e2e_latency));
    out.push_str(&format!(
        "critical path: {}  ({:.2} s of work on the path)\n",
        wf.critical_path_str(),
        wf.critical_path_len
    ));
    out.push_str(&format!(
        "{:<28} {:>8} {:>8} {:>8} {:>9} {:>8}\n",
        "stage", "ready", "start", "end", "duration", "slack"
    ));
    for s in &wf.stages {
        out.push_str(&format!(
            "{:<28} {:>7.2}s {:>7.2}s {:>7.2}s {:>8.2}s {:>7.2}s{}\n",
            truncate(&s.id, 28),
            s.ready,
            s.start,
            s.end,
            s.end - s.start,
            s.slack,
            if s.on_critical_path { "  *" } else { "" }
        ));
    }
    out.push_str("(* = on the critical path)\n");
    out.push('\n');

    out.push_str("-- System metrics --------------------------------------------\n");
    out.push_str(&format!(
        "GPU: SMACT(busy mean) {:>5.1}%  SMOCC(busy mean) {:>5.1}%  peak VRAM {:>5.1} GiB\n",
        monitor.mean_busy_smact() * 100.0,
        monitor.mean_busy_smocc() * 100.0,
        monitor.peak_vram_gib(),
    ));
    out.push_str(&format!(
        "energy: GPU {:>8.0} J   CPU {:>8.0} J\n",
        monitor.gpu_energy(),
        monitor.cpu_energy()
    ));
    let spark_max = 1.0;
    out.push_str(&format!(
        "SMACT  {}\nSMOCC  {}\nCPU    {}\n",
        monitor.gpu_smact.sparkline(60, spark_max),
        monitor.gpu_smocc.sparkline(60, spark_max),
        monitor.cpu_util.sparkline(60, spark_max),
    ));
    out.push('\n');

    out.push_str("-- Per-client GPU reservation --------------------------------\n");
    for (i, name) in result.client_names.iter().enumerate() {
        let (act, _) = &monitor.per_client[i];
        if act.values().iter().any(|&v| v > 1e-6) {
            out.push_str(&format!("{:<28} {}\n", truncate(name, 28), act.sparkline(60, 1.0)));
        }
    }

    BenchmarkReport { text: out, monitor }
}

/// Deterministic machine-readable summary of a workflow run (per-node SLO
/// attainment + system metrics), rendered with the shared `util::json`
/// primitives — the same canonical style as the scenario-matrix report.
/// Takes the already-resampled `monitor` (from [`generate`]) so the trace
/// is not walked a second time.
pub fn to_json_summary(result: &ScenarioResult, monitor: &MonitorReport) -> String {
    let mut out = String::with_capacity(1024);
    out.push_str("{\n");
    out.push_str("  \"consumerbench_run\": 1,\n");
    out.push_str(&format!("  \"policy\": {},\n", json_str(&result.policy)));
    out.push_str(&format!(
        "  \"makespan_s\": {},\n",
        json_num(result.makespan)
    ));
    out.push_str(&format!("  \"pjrt_calls\": {},\n", result.pjrt_calls));
    out.push_str(&format!(
        "  \"reconfigurations\": {},\n",
        result.reconfigurations
    ));
    out.push_str("  \"controller_actions\": [");
    for (i, a) in result.controller_actions.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&json_str(a));
    }
    out.push_str("],\n");
    out.push_str("  \"nodes\": [\n");
    for (i, node) in result.nodes.iter().enumerate() {
        let lats: Vec<f64> = node.metrics.iter().map(|m| m.latency).collect();
        let (p50, p99) = Summary::of(&lats)
            .map(|s| (s.p50, s.p99))
            .unwrap_or((0.0, 0.0));
        out.push_str("    {");
        out.push_str(&format!("\"node\": {}, ", json_str(&node.id)));
        out.push_str(&format!("\"app\": {}, ", json_str(node.app)));
        out.push_str(&format!("\"requests\": {}, ", node.metrics.len()));
        // null = no completed requests (never a fabricated 100%).
        out.push_str(&format!(
            "\"attainment\": {}, ",
            json_opt_num(node.attainment())
        ));
        out.push_str(&format!("\"p50_latency_s\": {}, ", json_num(p50)));
        out.push_str(&format!("\"p99_latency_s\": {}, ", json_num(p99)));
        match &node.failed {
            Some(e) => out.push_str(&format!("\"failed\": {}", json_str(e))),
            None => out.push_str("\"failed\": null"),
        }
        out.push('}');
        out.push_str(if i + 1 < result.nodes.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    let wf = &result.workflow;
    out.push_str("  \"workflow\": {\n");
    out.push_str(&format!(
        "    \"e2e_latency_s\": {},\n",
        json_num(wf.e2e_latency)
    ));
    out.push_str(&format!(
        "    \"workflow_slo_s\": {},\n",
        json_opt_num(wf.workflow_slo)
    ));
    out.push_str(&format!("    \"failed\": {},\n", wf.failed));
    out.push_str(&format!(
        "    \"e2e_slo_met\": {},\n",
        json_opt_bool(wf.e2e_slo_met)
    ));
    out.push_str("    \"critical_path\": [");
    for (i, id) in wf.critical_path.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&json_str(id));
    }
    out.push_str("],\n");
    out.push_str(&format!(
        "    \"critical_path_len_s\": {},\n",
        json_num(wf.critical_path_len)
    ));
    out.push_str("    \"stages\": [\n");
    for (i, s) in wf.stages.iter().enumerate() {
        out.push_str("      {");
        out.push_str(&format!("\"id\": {}, ", json_str(&s.id)));
        out.push_str(&format!("\"ready_s\": {}, ", json_num(s.ready)));
        out.push_str(&format!("\"start_s\": {}, ", json_num(s.start)));
        out.push_str(&format!("\"end_s\": {}, ", json_num(s.end)));
        out.push_str(&format!("\"slack_s\": {}, ", json_num(s.slack)));
        out.push_str(&format!("\"critical\": {}", s.on_critical_path));
        out.push('}');
        out.push_str(if i + 1 < wf.stages.len() { ",\n" } else { "\n" });
    }
    out.push_str("    ]\n");
    out.push_str("  },\n");
    out.push_str("  \"system\": {\n");
    out.push_str(&format!(
        "    \"mean_busy_smact\": {},\n",
        json_num(monitor.mean_busy_smact())
    ));
    out.push_str(&format!(
        "    \"mean_busy_smocc\": {},\n",
        json_num(monitor.mean_busy_smocc())
    ));
    out.push_str(&format!(
        "    \"peak_vram_gib\": {},\n",
        json_num(monitor.peak_vram_gib())
    ));
    out.push_str(&format!(
        "    \"gpu_energy_j\": {},\n",
        json_num(monitor.gpu_energy())
    ));
    out.push_str(&format!(
        "    \"cpu_energy_j\": {}\n",
        json_num(monitor.cpu_energy())
    ));
    out.push_str("  }\n");
    out.push_str("}\n");
    out
}

/// CSV export of the core per-request data (one row per request).
pub fn to_csv(result: &ScenarioResult) -> String {
    let mut out = String::from("node,app,request,latency_s,normalized,slo_met\n");
    for node in &result.nodes {
        for m in &node.metrics {
            out.push_str(&format!(
                "{},{},{},{:.6},{:.4},{}\n",
                node.id, node.app, m.label, m.latency, m.normalized, m.slo_met
            ));
        }
    }
    out
}

fn slo_brief(slo: &Slo) -> String {
    slo.describe()
}

fn truncate(s: &str, n: usize) -> String {
    if s.chars().count() <= n {
        s.to_string()
    } else {
        let cut: String = s.chars().take(n - 1).collect();
        format!("{cut}…")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::run_config_text;

    #[test]
    fn report_renders_for_simple_scenario() {
        let result = run_config_text("Chat (chatbot):\n  num_requests: 2\n", None).unwrap();
        let report = generate(&result);
        assert!(report.text.contains("ConsumerBench report"));
        assert!(report.text.contains("Chat (chatbot)"));
        assert!(report.text.contains("TTFT:1s"));
        assert!(report.text.contains("SMACT"));
        // Attainment column shows 100% for exclusive GPU chat.
        assert!(report.text.contains("100%"), "{}", report.text);
    }

    #[test]
    fn workflow_section_renders_critical_path_and_slo() {
        let text = "\
A (chatbot):
  num_requests: 1
B (imagegen):
  num_requests: 1
workflows:
  first:
    uses: A (chatbot)
  second:
    uses: B (imagegen)
    depend_on: [\"first\"]
workflow_slo: 10000
";
        let result = run_config_text(text, None).unwrap();
        let report = generate(&result);
        assert!(report.text.contains("-- Workflow --"), "{}", report.text);
        assert!(report.text.contains("first -> second"), "{}", report.text);
        assert!(report.text.contains("SLO 10000s: met"), "{}", report.text);
        let json = to_json_summary(&result, &report.monitor);
        assert!(json.contains("\"critical_path\": [\"first\", \"second\"]"), "{json}");
        assert!(json.contains("\"e2e_slo_met\": true"), "{json}");
        assert!(json.contains("\"stages\""), "{json}");
    }

    #[test]
    fn empty_attainment_renders_na_not_perfect() {
        // Both GPU tasks cannot coexist with the 8B chatbot: the OOM'd
        // node(s) must render `n/a` / null attainment, never 100%.
        let text = "\
Big (chatbot):
  model: Llama-3.1-8B
  num_requests: 1
  device: gpu
Img (imagegen):
  num_requests: 6
  device: gpu
Research (deepresearch):
  num_requests: 1
  device: gpu
";
        let result = run_config_text(text, None).unwrap();
        let failed = result
            .nodes
            .iter()
            .find(|n| n.failed.is_some() && n.metrics.is_empty())
            .expect("an OOM'd node with no completed requests");
        assert_eq!(failed.attainment(), None);
        let report = generate(&result);
        assert!(report.text.contains("n/a"), "{}", report.text);
        let json = to_json_summary(&result, &report.monitor);
        assert!(json.contains("\"attainment\": null"), "{json}");
    }

    #[test]
    fn csv_has_row_per_request() {
        let result = run_config_text("Chat (chatbot):\n  num_requests: 3\n", None).unwrap();
        let csv = to_csv(&result);
        assert_eq!(csv.lines().count(), 4); // header + 3 requests
        assert!(csv.starts_with("node,app,request"));
    }

    #[test]
    fn truncate_handles_long_names() {
        assert_eq!(truncate("short", 28), "short");
        let long = "x".repeat(64);
        assert_eq!(truncate(&long, 28).chars().count(), 28);
    }

    #[test]
    fn json_summary_is_deterministic_and_complete() {
        let cfg = "Chat (chatbot):\n  num_requests: 2\n";
        let summarize = || {
            let result = run_config_text(cfg, None).unwrap();
            let report = generate(&result);
            to_json_summary(&result, &report.monitor)
        };
        let j1 = summarize();
        let j2 = summarize();
        assert_eq!(j1, j2, "run summary JSON must reproduce byte-for-byte");
        assert!(j1.contains("\"consumerbench_run\": 1"));
        assert!(j1.contains("\"Chat (chatbot)\""));
        assert!(j1.contains("\"mean_busy_smact\""));
        assert!(!j1.contains("inf"), "non-finite leaked into JSON");
    }
}
