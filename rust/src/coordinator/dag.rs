//! Workflow DAG construction and validation (§3.2, step ②).
//!
//! ConsumerBench builds a directed acyclic graph from the YAML
//! specification: each node is an application instance whose lifecycle is
//! `setup → exec × num_requests → cleanup`; edges are `depend_on`
//! relations. Validation rejects cycles and dangling references; scheduling
//! is ready-set based so independent branches run concurrently.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

use crate::coordinator::config::WorkflowNodeConfig;

/// Index of a node in the DAG.
pub type NodeId = usize;

/// A validated workflow DAG.
#[derive(Debug, Clone)]
pub struct Dag {
    ids: Vec<String>,
    uses: Vec<String>,
    background: Vec<bool>,
    deps: Vec<Vec<NodeId>>,
    dependents: Vec<Vec<NodeId>>,
}

impl Dag {
    /// Build and validate from config nodes.
    pub fn build(nodes: &[WorkflowNodeConfig]) -> Result<Dag> {
        let mut index: BTreeMap<&str, NodeId> = BTreeMap::new();
        for (i, n) in nodes.iter().enumerate() {
            if index.insert(n.id.as_str(), i).is_some() {
                bail!("duplicate node id `{}`", n.id);
            }
        }
        let mut deps = vec![Vec::new(); nodes.len()];
        let mut dependents = vec![Vec::new(); nodes.len()];
        for (i, n) in nodes.iter().enumerate() {
            for d in &n.depend_on {
                let Some(&j) = index.get(d.as_str()) else {
                    bail!("node `{}` depends on unknown node `{d}`", n.id);
                };
                if j == i {
                    bail!("node `{}` depends on itself", n.id);
                }
                // A repeated entry would double-count the edge in both
                // `deps` and `dependents`: inflated in-degrees for Kahn's
                // algorithm and a duplicated hop once edges carry timings
                // (the weighted critical path walks `deps`).
                if deps[i].contains(&j) {
                    bail!("node `{}` lists duplicate dependency `{d}`", n.id);
                }
                deps[i].push(j);
                dependents[j].push(i);
            }
        }
        let dag = Dag {
            ids: nodes.iter().map(|n| n.id.clone()).collect(),
            uses: nodes.iter().map(|n| n.uses.clone()).collect(),
            background: nodes.iter().map(|n| n.background).collect(),
            deps,
            dependents,
        };
        dag.toposort()?; // cycle check
        Ok(dag)
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn id(&self, n: NodeId) -> &str {
        &self.ids[n]
    }

    pub fn uses(&self, n: NodeId) -> &str {
        &self.uses[n]
    }

    pub fn is_background(&self, n: NodeId) -> bool {
        self.background[n]
    }

    pub fn deps(&self, n: NodeId) -> &[NodeId] {
        &self.deps[n]
    }

    pub fn dependents(&self, n: NodeId) -> &[NodeId] {
        &self.dependents[n]
    }

    pub fn node_by_id(&self, id: &str) -> Option<NodeId> {
        self.ids.iter().position(|i| i == id)
    }

    /// Kahn's algorithm; errors on cycles.
    pub fn toposort(&self) -> Result<Vec<NodeId>> {
        let n = self.len();
        let mut in_deg: Vec<usize> = (0..n).map(|i| self.deps[i].len()).collect();
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| in_deg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(node) = queue.pop() {
            order.push(node);
            for &dep in &self.dependents[node] {
                in_deg[dep] -= 1;
                if in_deg[dep] == 0 {
                    queue.push(dep);
                }
            }
        }
        if order.len() != n {
            let stuck: Vec<&str> = (0..n)
                .filter(|&i| in_deg[i] > 0)
                .map(|i| self.ids[i].as_str())
                .collect();
            bail!("workflow contains a cycle involving: {}", stuck.join(", "));
        }
        Ok(order)
    }

    /// Roots: nodes with no dependencies (runnable immediately).
    pub fn roots(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&i| self.deps[i].is_empty()).collect()
    }

    /// Nodes that become ready once `completed` holds all their deps.
    pub fn ready_after(&self, completed: &BTreeSet<NodeId>, node: NodeId) -> Vec<NodeId> {
        self.dependents[node]
            .iter()
            .copied()
            .filter(|&d| self.deps[d].iter().all(|x| completed.contains(x)))
            .collect()
    }

    /// Length of the longest dependency chain (diagnostics).
    pub fn depth(&self) -> usize {
        let order = self.toposort().expect("validated DAG");
        let mut depth = vec![1usize; self.len()];
        for &n in &order {
            for &d in &self.dependents[n] {
                depth[d] = depth[d].max(depth[n] + 1);
            }
        }
        depth.into_iter().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(id: &str, uses: &str, deps: &[&str]) -> WorkflowNodeConfig {
        WorkflowNodeConfig {
            id: id.into(),
            uses: uses.into(),
            depend_on: deps.iter().map(|s| s.to_string()).collect(),
            background: false,
        }
    }

    #[test]
    fn builds_fig23_shape() {
        // analysis + brainstorm → outline → {cover_art, captions}
        let nodes = vec![
            node("analysis", "Analysis", &[]),
            node("brainstorm", "Brainstorm", &[]),
            node("outline", "Outline", &["brainstorm", "analysis"]),
            node("cover_art", "CoverArt", &["outline"]),
            node("captions", "Captions", &["outline"]),
        ];
        let dag = Dag::build(&nodes).unwrap();
        assert_eq!(dag.len(), 5);
        assert_eq!(dag.roots(), vec![0, 1]);
        assert_eq!(dag.depth(), 3);
        let outline = dag.node_by_id("outline").unwrap();
        assert_eq!(dag.deps(outline).len(), 2);
        assert_eq!(dag.dependents(outline).len(), 2);
    }

    #[test]
    fn toposort_respects_deps() {
        let nodes = vec![
            node("a", "A", &[]),
            node("b", "B", &["a"]),
            node("c", "C", &["b"]),
        ];
        let dag = Dag::build(&nodes).unwrap();
        let order = dag.toposort().unwrap();
        let pos = |id: &str| order.iter().position(|&n| dag.id(n) == id).unwrap();
        assert!(pos("a") < pos("b"));
        assert!(pos("b") < pos("c"));
    }

    #[test]
    fn cycle_rejected() {
        let nodes = vec![node("a", "A", &["b"]), node("b", "B", &["a"])];
        let err = Dag::build(&nodes).unwrap_err();
        assert!(err.to_string().contains("cycle"));
    }

    #[test]
    fn self_dep_rejected() {
        let err = Dag::build(&[node("a", "A", &["a"])]).unwrap_err();
        assert!(err.to_string().contains("itself"));
    }

    #[test]
    fn unknown_dep_rejected() {
        let err = Dag::build(&[node("a", "A", &["ghost"])]).unwrap_err();
        assert!(err.to_string().contains("unknown"));
    }

    #[test]
    fn duplicate_dep_rejected() {
        // Regression: `depend_on: [a, a]` used to double-count the edge,
        // misreporting `deps(n).len()` and inflating the in-degree.
        let err = Dag::build(&[node("a", "A", &[]), node("b", "B", &["a", "a"])]).unwrap_err();
        assert!(err.to_string().contains("duplicate dependency"), "{err}");
    }

    #[test]
    fn duplicate_id_rejected() {
        let err = Dag::build(&[node("a", "A", &[]), node("a", "B", &[])]).unwrap_err();
        assert!(err.to_string().contains("duplicate"));
    }

    #[test]
    fn ready_after_gates_on_all_deps() {
        let nodes = vec![
            node("a", "A", &[]),
            node("b", "B", &[]),
            node("c", "C", &["a", "b"]),
        ];
        let dag = Dag::build(&nodes).unwrap();
        let mut completed = BTreeSet::new();
        completed.insert(0);
        assert!(dag.ready_after(&completed, 0).is_empty());
        completed.insert(1);
        assert_eq!(dag.ready_after(&completed, 1), vec![2]);
    }
}
