//! Synthetic workload generators standing in for the paper's datasets.
//!
//! The paper samples requests from LMSYS-Chat-1M (Chatbot), HotpotQA
//! (DeepResearch), COCO Captions (ImageGen), and Earnings-21 (LiveCaptions).
//! None of those corpora are available here, and the benchmark consumes only
//! the *request-shape* of each dataset — prompt/output token counts, image
//! prompt lengths, audio segment structure — not its semantics. Each
//! generator below reproduces the published length distributions with a
//! seeded PRNG so every experiment is bit-reproducible.

pub mod coco;
pub mod earnings21;
pub mod hotpotqa;
pub mod lmsys;

pub use coco::{CocoCaptions, ImagePrompt};
pub use earnings21::{AudioSegment, Earnings21};
pub use hotpotqa::{HotpotQa, ResearchTask};
pub use lmsys::{ChatRequest, LmsysChat};
