//! Earnings-21-shaped audio workload (LiveCaptions app).
//!
//! Earnings-21 is long-form real-world speech (earnings calls). The
//! LiveCaptions frontend chunks audio into fixed 2-second segments and sends
//! one every 2 seconds (§3.3). Each segment carries a speech-density factor
//! (pauses decode fewer tokens) and — reproducing the paper's footnote 2 —
//! a small seeded fraction of segments fail language identification and must
//! be re-encoded, which is what caused the 3/150 SLO violations in Fig. 3.

use crate::util::Rng;

/// One 2-second audio segment.
#[derive(Debug, Clone, PartialEq)]
pub struct AudioSegment {
    pub id: usize,
    /// Segment duration in seconds (the paper uses 2 s).
    pub duration: f64,
    /// Tokens the decoder will emit for this segment.
    pub transcript_tokens: usize,
    /// Language identification failed → segment is re-encoded (footnote 2).
    pub reencode: bool,
}

/// Seeded generator over a simulated earnings call.
#[derive(Debug, Clone)]
pub struct Earnings21 {
    rng: Rng,
    next_id: usize,
    segment_seconds: f64,
    reencode_prob: f64,
}

impl Earnings21 {
    const SEED_TAG: u64 = 0x4541_524E_2D32_3131; // "EARN-211"

    pub fn new(seed: u64) -> Self {
        Earnings21 {
            rng: Rng::new(seed ^ Self::SEED_TAG),
            next_id: 0,
            segment_seconds: 2.0,
            // Calibrated to the paper's 3-in-150 language-ID failures.
            reencode_prob: 0.02,
        }
    }

    pub fn with_segment_seconds(mut self, s: f64) -> Self {
        assert!(s > 0.0);
        self.segment_seconds = s;
        self
    }

    pub fn sample(&mut self) -> AudioSegment {
        // Speech density: earnings calls are mostly continuous speech with
        // occasional pauses. Whisper emits ~12 tokens/sec of dense speech
        // (subwords + timestamp/special tokens), down to ~2 when sparse.
        let density = if self.rng.chance(0.15) {
            self.rng.range_f64(0.1, 0.5) // pause-heavy segment
        } else {
            self.rng.range_f64(0.7, 1.0)
        };
        let tokens = (self.segment_seconds * 16.0 * density).round().max(1.0) as usize;
        let reencode = self.rng.chance(self.reencode_prob);
        let id = self.next_id;
        self.next_id += 1;
        AudioSegment {
            id,
            duration: self.segment_seconds,
            transcript_tokens: tokens,
            reencode,
        }
    }

    /// A stream of `n` segments (arrival period == segment duration; the
    /// app layer schedules arrivals).
    pub fn stream(&mut self, n: usize) -> Vec<AudioSegment> {
        (0..n).map(|_| self.sample()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(Earnings21::new(1).stream(20), Earnings21::new(1).stream(20));
    }

    #[test]
    fn segment_shape() {
        let mut g = Earnings21::new(5);
        for _ in 0..200 {
            let s = g.sample();
            assert_eq!(s.duration, 2.0);
            assert!((1..=32).contains(&s.transcript_tokens));
        }
    }

    #[test]
    fn reencode_rate_matches_paper() {
        // Paper: 3 of 150 segments hit language-ID failures (2%). Across a
        // large sample the rate should be near 2%.
        let mut g = Earnings21::new(42);
        let n = 10_000;
        let fails = g.stream(n).iter().filter(|s| s.reencode).count();
        let rate = fails as f64 / n as f64;
        assert!((0.01..0.03).contains(&rate), "rate = {rate}");
    }

    #[test]
    fn custom_segment_length() {
        // Apple Silicon config uses longer chunks (Appendix C).
        let mut g = Earnings21::new(3).with_segment_seconds(4.0);
        let s = g.sample();
        assert_eq!(s.duration, 4.0);
        assert!(s.transcript_tokens <= 64);
    }
}
