//! HotpotQA-shaped research workload (DeepResearch app).
//!
//! DeepResearch (smolagents' open-deep-research over HotpotQA) is an agentic
//! loop: each question triggers several tool-use iterations, each of which
//! prefills a long context (question + retrieved passages + scratchpad) and
//! decodes a reasoning step. We model a task as a sequence of iterations
//! with growing context — the property that motivates the 16 GB KV cache in
//! §4.2.1.

use crate::util::Rng;

/// One agent iteration: context to prefill, tokens to decode, and host-side
/// tool time (search/browse) before the model call.
#[derive(Debug, Clone, PartialEq)]
pub struct ResearchIteration {
    pub context_tokens: usize,
    pub decode_tokens: usize,
    pub tool_time: f64,
}

/// A full multi-hop research task.
#[derive(Debug, Clone, PartialEq)]
pub struct ResearchTask {
    pub id: usize,
    pub iterations: Vec<ResearchIteration>,
}

impl ResearchTask {
    pub fn total_prefill_tokens(&self) -> usize {
        self.iterations.iter().map(|i| i.context_tokens).sum()
    }

    pub fn total_decode_tokens(&self) -> usize {
        self.iterations.iter().map(|i| i.decode_tokens).sum()
    }

    /// Peak context length — drives KV-cache sizing.
    pub fn peak_context(&self) -> usize {
        self.iterations.iter().map(|i| i.context_tokens).max().unwrap_or(0)
    }
}

/// Seeded generator of HotpotQA-shaped tasks.
#[derive(Debug, Clone)]
pub struct HotpotQa {
    rng: Rng,
    next_id: usize,
    max_context: usize,
}

impl HotpotQa {
    const SEED_TAG: u64 = 0x484F_5450_4F54_5141; // "HOTPOTQA"

    pub fn new(seed: u64, max_context: usize) -> Self {
        assert!(max_context >= 1024);
        HotpotQa {
            rng: Rng::new(seed ^ Self::SEED_TAG),
            next_id: 0,
            max_context,
        }
    }

    pub fn sample(&mut self) -> ResearchTask {
        // Multi-hop questions need 4–10 agent iterations.
        let n_iters = self.rng.range_usize(4, 11);
        let mut context = self.rng.range_usize(512, 1536); // question + system prompt
        let mut iterations = Vec::with_capacity(n_iters);
        for _ in 0..n_iters {
            // Each hop retrieves passages: context grows 1–4k tokens.
            context = (context + self.rng.range_usize(1024, 4096)).min(self.max_context);
            iterations.push(ResearchIteration {
                context_tokens: context,
                decode_tokens: self.rng.range_usize(128, 768),
                tool_time: self.rng.range_f64(3.0, 10.0), // web search + page parsing
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        ResearchTask { id, iterations }
    }

    pub fn batch(&mut self, n: usize) -> Vec<ResearchTask> {
        (0..n).map(|_| self.sample()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(HotpotQa::new(1, 131_072).batch(5), HotpotQa::new(1, 131_072).batch(5));
    }

    #[test]
    fn context_grows_monotonically() {
        let mut g = HotpotQa::new(9, 131_072);
        for _ in 0..50 {
            let t = g.sample();
            for w in t.iterations.windows(2) {
                assert!(w[1].context_tokens >= w[0].context_tokens);
            }
        }
    }

    #[test]
    fn context_capped() {
        let mut g = HotpotQa::new(9, 8192);
        for _ in 0..100 {
            assert!(g.sample().peak_context() <= 8192);
        }
    }

    #[test]
    fn tasks_are_long_running() {
        let mut g = HotpotQa::new(3, 131_072);
        let t = g.sample();
        assert!(t.iterations.len() >= 4);
        assert!(t.total_prefill_tokens() > 4096);
        assert!(t.total_decode_tokens() > 512);
    }

    #[test]
    fn long_context_tasks_motivate_large_kv() {
        // With the model's 128K window, peak contexts should regularly get
        // into the tens of thousands of tokens.
        let mut g = HotpotQa::new(5, 131_072);
        let peak = g.batch(50).iter().map(|t| t.peak_context()).max().unwrap();
        assert!(peak > 16_384, "peak context {peak}");
    }
}
