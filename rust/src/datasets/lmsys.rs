//! LMSYS-Chat-1M-shaped chat workload (Chatbot app).
//!
//! The published dataset's single-turn statistics are heavy-tailed: median
//! prompt around 50–60 tokens with a long tail past 1k, median response
//! around 200 tokens. We model both as log-normal, clamped to the Chatbot's
//! context budget.

use crate::util::Rng;

/// One chat request: a prompt to prefill and a response length to decode.
#[derive(Debug, Clone, PartialEq)]
pub struct ChatRequest {
    pub id: usize,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
}

/// Seeded generator over LMSYS-shaped requests.
#[derive(Debug, Clone)]
pub struct LmsysChat {
    rng: Rng,
    next_id: usize,
    max_context: usize,
}

impl LmsysChat {
    /// Seed-tag mixed in so each dataset's stream decorrelates from others
    /// built from the same experiment seed.
    const SEED_TAG: u64 = 0x4C4D_5359_532D_3143; // "LMSYS-1C"

    pub fn new(seed: u64, max_context: usize) -> Self {
        assert!(max_context >= 64, "context budget too small");
        LmsysChat {
            rng: Rng::new(seed ^ Self::SEED_TAG),
            next_id: 0,
            max_context,
        }
    }

    /// Sample the next request.
    pub fn sample(&mut self) -> ChatRequest {
        // ln-normal: median ~60 prompt tokens, sigma 0.9 → tail to ~1k.
        let prompt = self.rng.lognormal(60f64.ln(), 0.9).round() as usize;
        // Median ~180 output tokens, sigma 0.7.
        let output = self.rng.lognormal(180f64.ln(), 0.7).round() as usize;
        let prompt = prompt.clamp(8, self.max_context / 2);
        let output = output.clamp(16, self.max_context - prompt);
        let id = self.next_id;
        self.next_id += 1;
        ChatRequest {
            id,
            prompt_tokens: prompt,
            output_tokens: output,
        }
    }

    /// Sample a batch of n requests.
    pub fn batch(&mut self, n: usize) -> Vec<ChatRequest> {
        (0..n).map(|_| self.sample()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn deterministic_for_seed() {
        let a = LmsysChat::new(7, 4096).batch(20);
        let b = LmsysChat::new(7, 4096).batch(20);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = LmsysChat::new(1, 4096).batch(20);
        let b = LmsysChat::new(2, 4096).batch(20);
        assert_ne!(a, b);
    }

    #[test]
    fn lengths_within_context() {
        let mut g = LmsysChat::new(3, 2048);
        for _ in 0..1000 {
            let r = g.sample();
            assert!(r.prompt_tokens + r.output_tokens <= 2048);
            assert!(r.prompt_tokens >= 8);
            assert!(r.output_tokens >= 16);
        }
    }

    #[test]
    fn distribution_is_heavy_tailed() {
        let reqs = LmsysChat::new(11, 8192).batch(5000);
        let prompts: Vec<f64> = reqs.iter().map(|r| r.prompt_tokens as f64).collect();
        let s = Summary::of(&prompts).unwrap();
        // Median near 60, mean pulled up by the tail.
        assert!(s.p50 > 35.0 && s.p50 < 100.0, "p50 = {}", s.p50);
        assert!(s.mean > s.p50, "mean {} should exceed median {}", s.mean, s.p50);
        assert!(s.p99 > 300.0, "p99 = {}", s.p99);
    }

    #[test]
    fn ids_are_sequential() {
        let reqs = LmsysChat::new(5, 4096).batch(5);
        let ids: Vec<usize> = reqs.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }
}
