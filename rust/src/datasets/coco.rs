//! COCO-Captions-shaped image-generation workload (ImageGen app).
//!
//! COCO captions are short scene descriptions (~10 words / ~12 tokens). The
//! ImageGen request shape is (prompt tokens, denoise steps, resolution);
//! SD-3.5-Medium-Turbo runs a small fixed step count, and the SLO is per
//! denoising step (1 s, §3.3).

use crate::util::Rng;

/// One text-to-image request.
#[derive(Debug, Clone, PartialEq)]
pub struct ImagePrompt {
    pub id: usize,
    pub prompt_tokens: usize,
    /// Denoising steps (turbo models: 4–10).
    pub steps: usize,
    /// Square output resolution in pixels.
    pub resolution: usize,
}

impl ImagePrompt {
    /// Latent tokens processed per step at this resolution (VAE factor 8,
    /// patch size 2 — the SD3 MMDiT token count).
    pub fn latent_tokens(&self) -> usize {
        let latent = self.resolution / 8;
        (latent / 2) * (latent / 2)
    }
}

/// Seeded generator of COCO-shaped prompts.
#[derive(Debug, Clone)]
pub struct CocoCaptions {
    rng: Rng,
    next_id: usize,
    default_steps: usize,
}

impl CocoCaptions {
    const SEED_TAG: u64 = 0x434F_434F_2D43_4150; // "COCO-CAP"

    pub fn new(seed: u64, default_steps: usize) -> Self {
        assert!(default_steps >= 1);
        CocoCaptions {
            rng: Rng::new(seed ^ Self::SEED_TAG),
            next_id: 0,
            default_steps,
        }
    }

    pub fn sample(&mut self) -> ImagePrompt {
        // Caption lengths: ~N(12, 3) tokens, clamped.
        let prompt = self.rng.normal(12.0, 3.0).round().max(4.0) as usize;
        let id = self.next_id;
        self.next_id += 1;
        ImagePrompt {
            id,
            prompt_tokens: prompt.min(64),
            steps: self.default_steps,
            resolution: 512,
        }
    }

    pub fn batch(&mut self, n: usize) -> Vec<ImagePrompt> {
        (0..n).map(|_| self.sample()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(CocoCaptions::new(4, 8).batch(10), CocoCaptions::new(4, 8).batch(10));
    }

    #[test]
    fn captions_are_short() {
        let mut g = CocoCaptions::new(2, 8);
        for _ in 0..500 {
            let p = g.sample();
            assert!((4..=64).contains(&p.prompt_tokens));
            assert_eq!(p.steps, 8);
        }
    }

    #[test]
    fn latent_tokens_at_512() {
        let p = ImagePrompt {
            id: 0,
            prompt_tokens: 10,
            steps: 8,
            resolution: 512,
        };
        // 512/8 = 64 latent → 32x32 = 1024 patch tokens.
        assert_eq!(p.latent_tokens(), 1024);
    }
}
