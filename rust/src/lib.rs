//! # ConsumerBench
//!
//! A ground-up reproduction of *ConsumerBench: Benchmarking Generative AI
//! Applications on End-User Devices* (Gu et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! * **Layer 3 (this crate)** — the benchmarking framework: YAML-configured
//!   workflows, a DAG scheduler, a resource orchestrator (greedy / MPS
//!   partition / fair-share), a system monitor, and the simulated consumer
//!   testbed it all runs on.
//! * **Layer 2** — JAX models (`python/compile/models/`) for the four
//!   applications, AOT-lowered to HLO text loaded by [`runtime`].
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) called by the
//!   L2 models; correctness is pinned against a pure-jnp oracle at build
//!   time.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod analysis;
pub mod apps;
pub mod cli;
pub mod datasets;
pub mod coordinator;
pub mod gpusim;
pub mod monitor;
pub mod runtime;
pub mod scenario;
pub mod server;
pub mod util;
