//! Discrete-event execution engine for the simulated testbed.
//!
//! All experiment timing in ConsumerBench is *virtual time* produced by this
//! engine: applications submit **jobs** (requests) consisting of **phases**
//! (prefill, per-token decode, denoise step, ...); a GPU phase bulk-enqueues
//! its kernels into the device stream (launch-ahead, the behaviour that
//! produces the paper's starvation result), a CPU phase occupies cores. The
//! engine advances a deterministic event heap, applies the configured
//! [`Policy`] on every state change, and records a piecewise-constant trace
//! of every counter the paper's system monitor collects (SMACT, SMOCC,
//! memory bandwidth, VRAM, power, CPU utilization).
//!
//! The engine is deliberately *reactive*: the coordinator drives it with
//! `submit` / `run_until` / `take_completed`, which is how workflow DAG
//! dependencies and inference-server batching decisions are made at virtual
//! time without the engine knowing about them.

use std::collections::VecDeque;

use crate::gpusim::kernel::{duration, occupancy, sms_wanted, Device, KernelDesc};
use crate::gpusim::policy::{Policy, ReadyKernel};
use crate::gpusim::power::{cpu_power, gpu_power};
use crate::gpusim::profiles::Testbed;
use crate::gpusim::queue::{Event, EventKind, EventQueue};
use crate::gpusim::vram::{AllocId, VramAllocator};

// The trace and queue live in their own modules; re-exported here so
// existing `gpusim::engine::{TraceSample, trace_digest, …}` imports keep
// working.
pub use crate::gpusim::queue::QueueBackend;
pub use crate::gpusim::trace::{
    trace_canonical_bytes, trace_digest, Fnv1a, StreamingTrace, Trace, TraceAggregates, TraceMode,
    TraceRow, TraceSample, TraceView,
};

/// Identifies a registered application/client.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClientId(pub usize);

/// Identifies a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

/// CPU-side work chunk (threads ≈ desired parallelism).
#[derive(Debug, Clone, PartialEq)]
pub struct CpuWork {
    pub flops: f64,
    pub bytes: f64,
    pub threads: usize,
}

/// Memory operation applied when a phase begins.
#[derive(Debug, Clone, PartialEq)]
pub enum MemOp {
    /// Allocate VRAM for the job's client.
    Alloc { label: String, bytes: u64 },
    /// Free the client's allocations carrying one label (e.g. just the
    /// `kv-cache` region during a GPU→CPU migration, weights staying put).
    Free { label: String },
    /// Free all VRAM held by the job's client (cleanup).
    FreeAll,
}

/// One phase of a job: optional host-side delay, then either a stream of GPU
/// kernels (bulk-enqueued) or a CPU work chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub tag: &'static str,
    pub device: Device,
    /// Host think/preprocess time before the phase's work enqueues.
    pub host_pre: f64,
    /// GPU kernels, stream-ordered (only for `Device::Gpu`).
    pub kernels: Vec<KernelDesc>,
    /// CPU work (only for `Device::Cpu`).
    pub cpu: Option<CpuWork>,
    pub mem_ops: Vec<MemOp>,
}

impl Phase {
    /// A GPU phase with the given kernels.
    pub fn gpu(tag: &'static str, host_pre: f64, kernels: Vec<KernelDesc>) -> Phase {
        Phase {
            tag,
            device: Device::Gpu,
            host_pre,
            kernels,
            cpu: None,
            mem_ops: Vec::new(),
        }
    }

    /// A CPU phase with one work chunk.
    pub fn cpu(tag: &'static str, host_pre: f64, work: CpuWork) -> Phase {
        Phase {
            tag,
            device: Device::Cpu,
            host_pre,
            kernels: Vec::new(),
            cpu: Some(work),
            mem_ops: Vec::new(),
        }
    }

    /// A host-only phase (setup sleeps, I/O waits, memory ops).
    pub fn host(tag: &'static str, host_pre: f64) -> Phase {
        Phase {
            tag,
            device: Device::Cpu,
            host_pre,
            kernels: Vec::new(),
            cpu: None,
            mem_ops: Vec::new(),
        }
    }

    pub fn with_mem_ops(mut self, ops: Vec<MemOp>) -> Phase {
        self.mem_ops = ops;
        self
    }
}

/// A job specification: a request (or setup/cleanup action) from a client.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub client: ClientId,
    pub label: String,
    pub phases: Vec<Phase>,
}

/// Statistics for one completed phase.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub tag: &'static str,
    pub start: f64,
    pub end: f64,
    /// Sum of kernel/cpu execution time inside the phase.
    pub exec_time: f64,
    /// Sum of time work items spent ready-but-not-launched (contention).
    pub queue_wait: f64,
}

/// Result of a finished job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: JobId,
    pub client: ClientId,
    pub label: String,
    pub submit: f64,
    pub end: f64,
    pub phases: Vec<PhaseStat>,
    /// Set if the job failed (e.g. VRAM OOM during a mem op).
    pub error: Option<String>,
}

impl JobResult {
    /// End-to-end virtual latency.
    pub fn latency(&self) -> f64 {
        self.end - self.submit
    }

    /// Sum of exec/wait across phases matching a tag prefix.
    pub fn phase_time(&self, tag_prefix: &str) -> f64 {
        self.phases
            .iter()
            .filter(|p| p.tag.starts_with(tag_prefix))
            .map(|p| p.end - p.start)
            .sum()
    }

    pub fn queue_wait(&self) -> f64 {
        self.phases.iter().map(|p| p.queue_wait).sum()
    }
}

#[derive(Debug)]
struct JobState {
    spec: JobSpec,
    submit: f64,
    cur_phase: usize,
    cur_kernel: usize,
    phase_start: f64,
    exec_time: f64,
    queue_wait: f64,
    stats: Vec<PhaseStat>,
}

/// Dense slab for in-flight jobs: `JobId = generation << 32 | slot`.
///
/// The hot loop indexes jobs on every event; a `HashMap` paid a hash +
/// probe per access. The slab is a direct `Vec` index. Freed slots are
/// recycled through a free list (bounded memory over long sweeps), and the
/// generation tag keeps every issued id unique, so external maps keyed by
/// `JobId` (the executor's routing table) can never alias a recycled slot.
/// First-generation ids equal the old sequential counter, and live ids are
/// always distinct, so JobId-sorted resident sets keep a fixed iteration
/// order — the property the trace's float sums depend on.
#[derive(Debug, Default)]
struct JobSlab {
    slots: Vec<Option<JobState>>,
    gens: Vec<u32>,
    free: Vec<u32>,
    live: usize,
}

impl JobSlab {
    fn with_capacity(n: usize) -> JobSlab {
        JobSlab {
            slots: Vec::with_capacity(n),
            gens: Vec::with_capacity(n),
            free: Vec::new(),
            live: 0,
        }
    }

    fn insert(&mut self, state: JobState) -> JobId {
        self.live += 1;
        match self.free.pop() {
            Some(idx) => {
                let i = idx as usize;
                debug_assert!(self.slots[i].is_none());
                self.slots[i] = Some(state);
                JobId(((self.gens[i] as u64) << 32) | idx as u64)
            }
            None => {
                let idx = self.slots.len() as u64;
                self.slots.push(Some(state));
                self.gens.push(0);
                JobId(idx)
            }
        }
    }

    #[inline]
    fn idx(&self, id: JobId) -> usize {
        let i = (id.0 & 0xffff_ffff) as usize;
        assert!(
            i < self.slots.len() && self.gens[i] as u64 == id.0 >> 32,
            "unknown job {id:?}"
        );
        i
    }

    #[inline]
    fn get(&self, id: JobId) -> &JobState {
        let i = self.idx(id);
        self.slots[i].as_ref().expect("unknown job")
    }

    #[inline]
    fn get_mut(&mut self, id: JobId) -> &mut JobState {
        let i = self.idx(id);
        self.slots[i].as_mut().expect("unknown job")
    }

    fn remove(&mut self, id: JobId) -> JobState {
        let i = self.idx(id);
        let state = self.slots[i].take().expect("unknown job");
        self.gens[i] = self.gens[i].wrapping_add(1);
        self.free.push(i as u32);
        self.live -= 1;
        state
    }

    fn len(&self) -> usize {
        self.live
    }
}

#[derive(Debug, Clone)]
struct GpuReady {
    /// Policy view with cached `sms_wanted` (computed once at enqueue).
    rk: ReadyKernel,
    job: JobId,
    ready_since: f64,
}

#[derive(Debug, Clone)]
struct GpuResident {
    /// Sort key of the resident set (ascending JobId).
    job: JobId,
    client: ClientId,
    sms: usize,
    occupancy: f64,
    bw_rate: f64, // bytes/sec while resident
}

#[derive(Debug, Clone, Copy)]
struct CpuReady {
    seq: u64,
    job: JobId,
    ready_since: f64,
}

#[derive(Debug, Clone)]
struct CpuResident {
    /// Sort key of the resident set (ascending JobId).
    job: JobId,
    cores: usize,
    bw_rate: f64,
}

/// The simulated testbed: one GPU + one CPU driven by an event queue.
pub struct Engine {
    testbed: Testbed,
    policy: Policy,
    now: f64,
    seq: u64,
    /// Pluggable event core ([`QueueBackend`]): binary heap or timer wheel,
    /// pinned to identical pop order by `tests/queue_equivalence.rs`.
    events: Box<dyn EventQueue + Send>,
    clients: Vec<String>,
    jobs: JobSlab,
    // GPU state
    gpu_free_sms: usize,
    /// Sorted by (enqueue_time, seq) by construction: event time is
    /// monotone, so every new entry appends at the tail. Ring buffer: the
    /// common grant pattern drains a prefix, which is O(grants) here.
    gpu_ready: VecDeque<GpuReady>,
    /// Reused policy-view buffer (no allocation on the hot path).
    gpu_ready_scratch: Vec<ReadyKernel>,
    /// Reused launch buffer for `schedule_gpu` (no allocation per pass).
    gpu_launch_scratch: Vec<(GpuReady, usize)>,
    /// Resident GPU kernels, kept sorted by JobId. `record()` sums f64
    /// rates over the resident sets and float addition is order-sensitive,
    /// so iteration order must be fixed for traces to be byte-identical
    /// across runs (golden-trace determinism). A sorted Vec reproduces the
    /// old BTreeMap's ascending-JobId order with dense cache-friendly
    /// iteration on the per-event sampling path.
    gpu_resident: Vec<GpuResident>,
    /// SMs held per client, dense by ClientId (clients are interned 0..n).
    gpu_held: Vec<usize>,
    /// Thermal clock-cap factor in (0, 1]: new launches run at this fraction
    /// of full clock (chaos `thermal_throttle`; 1.0 = no throttle).
    gpu_clock_scale: f64,
    /// While true, no new GPU kernels launch (chaos `suspend`); resident
    /// kernels drain normally.
    gpu_suspended: bool,
    vram: VramAllocator,
    // CPU state
    cpu_free_cores: usize,
    /// FIFO by construction (`now` and `seq` are monotone at push time), so
    /// no per-pass sort; launches always drain a prefix.
    cpu_ready: VecDeque<CpuReady>,
    /// Resident CPU work, sorted by JobId (same determinism argument as
    /// `gpu_resident`).
    cpu_resident: Vec<CpuResident>,
    // Outputs
    completed: Vec<JobResult>,
    trace: Trace,
    trace_enabled: bool,
    trace_mode: TraceMode,
    /// Bounded-memory recorder (`TraceMode::Streaming`); `None` under
    /// `Full`, where rows materialize into `trace` instead.
    streaming: Option<StreamingTrace>,
    /// Reused per-client sample buffer for the streaming record path.
    pc_scratch: Vec<(f32, f32)>,
    /// Events processed since construction (monotone; a pure function of the
    /// submitted workload, so it is deterministic across identical runs).
    events_processed: u64,
    /// Optional deterministic event budget enforced by
    /// [`Engine::run_until_budgeted`]. `None` = unbounded.
    event_budget: Option<u64>,
}

/// Typed error for a deterministic execution budget running dry.
///
/// Budgets are pure functions of the configuration (an event count or a
/// virtual-time horizon), so a budget-exhausted run fails at the *same*
/// virtual time with the *same* message on every host — the outcome can
/// land in golden digests, unlike a wall-clock timeout.
#[derive(Debug, Clone, PartialEq)]
pub enum BudgetExhausted {
    /// The engine processed `budget` events without draining the workload.
    Events { budget: u64, at: f64 },
    /// Virtual time advanced past `limit` without the workload completing.
    VirtualTime { limit: f64, at: f64 },
}

impl std::fmt::Display for BudgetExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BudgetExhausted::Events { budget, at } => {
                write!(f, "event budget exhausted: {budget} events processed, t={at:.3}")
            }
            BudgetExhausted::VirtualTime { limit, at } => {
                write!(f, "virtual-time budget exhausted: limit {limit:.3}s, t={at:.3}")
            }
        }
    }
}

impl std::error::Error for BudgetExhausted {}

/// Typed failure from a budgeted run.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    /// A deterministic execution budget ran dry.
    Budget(BudgetExhausted),
    /// The event queue popped an event earlier than the current clock — a
    /// broken [`EventQueue`] backend. The check is exact (no epsilon): the
    /// old `now - 1e-9` slack silently loosened at large virtual times,
    /// where 1e-9 is below one ulp and the comparison degenerated.
    ClockRegression { event_time: f64, now: f64 },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Budget(b) => b.fmt(f),
            EngineError::ClockRegression { event_time, now } => write!(
                f,
                "event queue went backwards: popped t={event_time} with clock at t={now}"
            ),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<BudgetExhausted> for EngineError {
    fn from(b: BudgetExhausted) -> Self {
        EngineError::Budget(b)
    }
}

/// Construction-time knobs for [`Engine::with_options`].
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Event-queue implementation (digest-neutral; see [`QueueBackend`]).
    pub queue: QueueBackend,
    /// Full materialized trace, or bounded-memory streaming digest.
    pub trace_mode: TraceMode,
    /// Expected number of jobs the scenario will submit (the executor
    /// derives it from the configured request counts). Sizes the event
    /// queue, the job slab, and the resident sets — a reservation, not a
    /// limit; any value is safe.
    pub capacity_hint: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            queue: QueueBackend::Heap,
            trace_mode: TraceMode::Full,
            capacity_hint: 256,
        }
    }
}

impl Engine {
    pub fn new(testbed: Testbed, policy: Policy) -> Self {
        Self::with_options(testbed, policy, EngineOptions::default())
    }

    pub fn with_options(testbed: Testbed, policy: Policy, opts: EngineOptions) -> Self {
        let gpu_sms = testbed.gpu.num_sms;
        let cpu_cores = testbed.cpu.num_cores;
        let vram = VramAllocator::new(testbed.gpu.vram_bytes);
        let hint = opts.capacity_hint.max(16);
        // A job in flight contributes at most a couple of pending events
        // (its next phase/kernel boundary), so 2× the expected job count
        // with sane bounds replaces the old hardcoded 1024.
        let event_cap = (hint * 2).clamp(64, 1 << 16);
        let resident_cap = hint.clamp(16, 64);
        let streaming = match opts.trace_mode {
            TraceMode::Streaming { window } => Some(StreamingTrace::new(window)),
            TraceMode::Full => None,
        };
        Engine {
            testbed,
            policy,
            now: 0.0,
            seq: 0,
            events: opts.queue.make(event_cap),
            clients: Vec::new(),
            jobs: JobSlab::with_capacity(hint.min(1 << 14)),
            gpu_free_sms: gpu_sms,
            gpu_ready: VecDeque::with_capacity(resident_cap),
            gpu_ready_scratch: Vec::new(),
            gpu_launch_scratch: Vec::new(),
            gpu_resident: Vec::with_capacity(resident_cap),
            gpu_held: Vec::new(),
            gpu_clock_scale: 1.0,
            gpu_suspended: false,
            vram,
            cpu_free_cores: cpu_cores,
            cpu_ready: VecDeque::with_capacity(16),
            cpu_resident: Vec::with_capacity(16),
            completed: Vec::new(),
            trace: Trace::new(),
            trace_enabled: true,
            trace_mode: opts.trace_mode,
            streaming,
            pc_scratch: Vec::new(),
            events_processed: 0,
            event_budget: None,
        }
    }

    pub fn testbed(&self) -> &Testbed {
        &self.testbed
    }

    pub fn policy(&self) -> &Policy {
        &self.policy
    }

    /// Swap the resource-sharing policy (takes effect on the next
    /// scheduling pass; resident kernels are never preempted).
    pub fn set_policy(&mut self, policy: Policy) {
        self.policy = policy;
    }

    /// Mutate the policy **at runtime** and apply it immediately: a
    /// scheduling pass runs under the updated policy and a trace row is
    /// recorded at the current virtual time, so the reconfiguration itself
    /// is an event in the trace (and therefore in the golden digest).
    /// Deterministic as long as the caller invokes it at deterministic
    /// virtual times — the adaptive controller's contract.
    pub fn update_policy<R>(&mut self, f: impl FnOnce(&mut Policy) -> R) -> R {
        let r = f(&mut self.policy);
        self.schedule_gpu();
        self.schedule_cpu();
        self.record();
        r
    }

    /// Current thermal clock-cap factor (1.0 = full clock).
    pub fn gpu_clock_scale(&self) -> f64 {
        self.gpu_clock_scale
    }

    /// Cap the GPU clock at `scale`× full speed (chaos `thermal_throttle`).
    /// Applies to kernels launched from now on; resident kernels keep their
    /// completion times — like a real DVFS step, which cannot retro-time
    /// in-flight work. Same contract as [`Engine::update_policy`]: a
    /// scheduling pass runs immediately and a trace row is recorded, so the
    /// fault transition is part of the golden digest.
    pub fn set_gpu_clock_scale(&mut self, scale: f64) {
        assert!(
            scale > 0.0 && scale <= 1.0,
            "clock scale must be in (0, 1]: {scale}"
        );
        self.gpu_clock_scale = scale;
        self.schedule_gpu();
        self.schedule_cpu();
        self.record();
    }

    /// Whether new GPU launches are currently frozen.
    pub fn gpu_suspended(&self) -> bool {
        self.gpu_suspended
    }

    /// Suspend/resume the GPU (chaos `suspend`): while suspended no new
    /// kernels launch; resident kernels drain and CPU work keeps running.
    /// Resume runs a scheduling pass immediately so queued launches go out
    /// at the resume timestamp. Trace-visible like `update_policy`.
    pub fn set_gpu_suspended(&mut self, suspended: bool) {
        self.gpu_suspended = suspended;
        self.schedule_gpu();
        self.schedule_cpu();
        self.record();
    }

    /// Disable trace recording (benchmarking the engine itself).
    pub fn set_trace_enabled(&mut self, enabled: bool) {
        self.trace_enabled = enabled;
    }

    pub fn register_client(&mut self, name: impl Into<String>) -> ClientId {
        self.clients.push(name.into());
        self.gpu_held.push(0);
        ClientId(self.clients.len() - 1)
    }

    pub fn client_name(&self, id: ClientId) -> &str {
        &self.clients[id.0]
    }

    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn vram(&self) -> &VramAllocator {
        &self.vram
    }

    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Drain the recorded trace. The returned buffer is shrunk to its
    /// length so long sweeps that hold many drained traces don't pin the
    /// engines' peak recording capacity. Under `TraceMode::Streaming` this
    /// materializes only the bounded tail window (the digest and running
    /// aggregates stay queryable afterwards).
    pub fn take_trace(&mut self) -> Trace {
        if let Some(st) = &mut self.streaming {
            return st.take_tail();
        }
        let mut t = std::mem::take(&mut self.trace);
        t.shrink_to_fit();
        t
    }

    /// The trace recording mode this engine was constructed with.
    pub fn trace_mode(&self) -> TraceMode {
        self.trace_mode
    }

    /// The event-queue backend this engine was constructed with.
    pub fn queue_backend(&self) -> QueueBackend {
        self.events.backend()
    }

    /// Mode-aware digest of every row recorded so far: under `Full` it
    /// hashes the materialized trace; under `Streaming` it is the
    /// incrementally folded digest. Identical runs produce identical
    /// values in either mode (pinned by `tests/queue_equivalence.rs`).
    pub fn current_trace_digest(&self) -> u64 {
        match &self.streaming {
            Some(st) => st.digest(),
            None => trace_digest(&self.trace),
        }
    }

    /// Streaming recorder state, when running under `TraceMode::Streaming`.
    pub fn streaming_trace(&self) -> Option<&StreamingTrace> {
        self.streaming.as_ref()
    }

    /// Running piecewise-constant aggregates (`TraceMode::Streaming` only;
    /// under `Full` compute them with [`TraceAggregates::from_trace`]).
    pub fn trace_aggregates(&self) -> Option<TraceAggregates> {
        self.streaming.as_ref().map(|s| *s.aggregates())
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Submit a job arriving at virtual time `at` (>= now).
    pub fn submit(&mut self, spec: JobSpec, at: f64) -> JobId {
        assert!(
            at >= self.now - 1e-12,
            "submit in the past: at={} now={}",
            at,
            self.now
        );
        assert!(!spec.phases.is_empty(), "job `{}` has no phases", spec.label);
        assert!(
            spec.client.0 < self.clients.len(),
            "unregistered client {:?}",
            spec.client
        );
        // Absorb the 1e-12 submit slack so the queue never sees an event
        // earlier than the clock (the pop-side check is exact, no epsilon).
        let at = at.max(self.now);
        let host_pre = spec.phases[0].host_pre;
        let id = self.jobs.insert(JobState {
            spec,
            submit: at,
            cur_phase: 0,
            cur_kernel: 0,
            phase_start: 0.0,
            exec_time: 0.0,
            queue_wait: 0.0,
            stats: Vec::new(),
        });
        let seq = self.next_seq();
        self.events.push(Event {
            time: at + host_pre,
            seq,
            kind: EventKind::PhaseBegin,
            job: id,
        });
        id
    }

    /// Time of the next pending event, if any.
    pub fn next_event_time(&self) -> Option<f64> {
        self.events.peek_time()
    }

    /// Install (or clear) the deterministic event budget enforced by
    /// [`Engine::run_until_budgeted`]. The count is cumulative over the
    /// engine's lifetime, so set the budget once at construction time.
    pub fn set_event_budget(&mut self, budget: Option<u64>) {
        self.event_budget = budget;
    }

    /// Events processed since construction.
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Process all events with time <= `t`; afterwards `now == max(now, t)`.
    ///
    /// Infallible wrapper for callers that never install an event budget;
    /// panics on any [`EngineError`] (budget-aware drivers must use
    /// [`Engine::run_until_budgeted`]).
    pub fn run_until(&mut self, t: f64) {
        self.run_until_budgeted(t)
            .unwrap_or_else(|e| panic!("engine failure inside unbudgeted run_until: {e}"));
    }

    /// Process all events with time <= `t`, charging each against the
    /// event budget (if one is installed). On exhaustion the engine stops
    /// at a deterministic virtual time — a pure function of workload and
    /// budget — and returns [`BudgetExhausted::Events`]; `now` is left at
    /// the last processed event, not advanced to `t`.
    ///
    /// Same-timestamp events are applied as one batch: every event still
    /// runs its state transition and scheduling pass individually (grant
    /// outcomes depend on them), but the trace records a single row when
    /// the batch ends. Zero-width intermediate states were invisible to
    /// the monitor's piecewise-constant resampling anyway (`dt > 0` guard),
    /// and a burst of N same-time events now costs one row instead of N.
    pub fn run_until_budgeted(&mut self, t: f64) -> Result<(), EngineError> {
        let mut dirty = false;
        while let Some(head_t) = self.events.peek_time() {
            if head_t > t {
                break;
            }
            if let Some(budget) = self.event_budget {
                if self.events_processed >= budget {
                    if dirty {
                        self.record();
                    }
                    return Err(BudgetExhausted::Events { budget, at: self.now }.into());
                }
            }
            let ev = self.events.pop().expect("peeked event vanished");
            if ev.time < self.now {
                debug_assert!(
                    false,
                    "event queue went backwards: {} < {}",
                    ev.time, self.now
                );
                if dirty {
                    self.record();
                }
                return Err(EngineError::ClockRegression {
                    event_time: ev.time,
                    now: self.now,
                });
            }
            self.now = ev.time;
            self.events_processed += 1;
            self.process(ev);
            dirty = true;
            // Batch boundary: flush the trace row unless the next pending
            // event shares this exact timestamp.
            if self.events.peek_time() != Some(self.now) {
                self.record();
                dirty = false;
            }
        }
        debug_assert!(!dirty, "batch left unflushed at loop exit");
        self.now = self.now.max(t);
        Ok(())
    }

    /// Run the queue dry. Counts events but does not enforce the budget —
    /// unit-scale helpers drain tiny workloads where a budget is noise.
    pub fn run_all(&mut self) {
        while let Some(ev) = self.events.pop() {
            assert!(
                ev.time >= self.now,
                "event queue went backwards: {} < {}",
                ev.time,
                self.now
            );
            self.now = ev.time;
            self.events_processed += 1;
            self.process(ev);
            if self.events.peek_time() != Some(self.now) {
                self.record();
            }
        }
    }

    /// Drain finished jobs since the last call.
    pub fn take_completed(&mut self) -> Vec<JobResult> {
        std::mem::take(&mut self.completed)
    }

    pub fn pending_jobs(&self) -> usize {
        self.jobs.len()
    }

    // ------------------------------------------------------------------
    // Event processing
    // ------------------------------------------------------------------

    fn process(&mut self, ev: Event) {
        match ev.kind {
            EventKind::PhaseBegin => self.on_phase_begin(ev.job),
            EventKind::KernelDone => self.on_kernel_done(ev.job),
            EventKind::CpuDone => self.on_cpu_done(ev.job),
        }
        self.schedule_gpu();
        self.schedule_cpu();
        // Trace recording happens at batch boundaries in the run loops, not
        // here — one row per distinct timestamp.
    }

    fn on_phase_begin(&mut self, job: JobId) {
        let (num_mem_ops, device, has_kernels, has_cpu, client) = {
            let js = self.jobs.get_mut(job);
            js.phase_start = self.now;
            js.cur_kernel = 0;
            js.exec_time = 0.0;
            js.queue_wait = 0.0;
            let ph = &js.spec.phases[js.cur_phase];
            (
                ph.mem_ops.len(),
                ph.device,
                !ph.kernels.is_empty(),
                ph.cpu.is_some(),
                js.spec.client,
            )
        };
        // Apply memory ops in place (no clone of the op list or the client
        // name); OOM fails the job, rolling back the Allocs this phase
        // already applied so a partially applied op list can never leak
        // VRAM for the rest of the run (Free/FreeAll are not undone — they
        // model releases that already happened).
        let mut applied: Vec<AllocId> = Vec::new();
        for i in 0..num_mem_ops {
            let js = self.jobs.get(job);
            let op = &js.spec.phases[js.cur_phase].mem_ops[i];
            let oom = match op {
                MemOp::Alloc { label, bytes } => {
                    match self.vram.alloc(&self.clients[client.0], label, *bytes) {
                        Ok(id) => {
                            applied.push(id);
                            None
                        }
                        Err(e) => Some(e),
                    }
                }
                MemOp::Free { label } => {
                    self.vram.free_labeled(&self.clients[client.0], label);
                    None
                }
                MemOp::FreeAll => {
                    self.vram.free_client(&self.clients[client.0]);
                    None
                }
            };
            if let Some(e) = oom {
                for id in applied.drain(..).rev() {
                    self.vram.free(id);
                }
                self.fail_job(job, format!("{e}"));
                return;
            }
        }
        match device {
            Device::Gpu if has_kernels => {
                self.push_gpu_ready(job);
            }
            Device::Cpu if has_cpu => {
                let seq = self.next_seq();
                self.cpu_ready.push_back(CpuReady {
                    seq,
                    job,
                    ready_since: self.now,
                });
            }
            // Host-only phase: completes immediately (host_pre already elapsed).
            _ => self.finish_phase(job),
        }
    }

    fn on_kernel_done(&mut self, job: JobId) {
        let idx = self
            .gpu_resident
            .binary_search_by_key(&job, |r| r.job)
            .expect("kernel done without residency");
        let res = self.gpu_resident.remove(idx);
        self.gpu_free_sms += res.sms;
        self.gpu_held[res.client.0] -= res.sms;

        let more_kernels = {
            let js = self.jobs.get_mut(job);
            js.cur_kernel += 1;
            let ph = &js.spec.phases[js.cur_phase];
            js.cur_kernel < ph.kernels.len()
        };
        if more_kernels {
            // The stream's next kernel becomes visible to the work
            // distributor *now* (when its predecessor completes). This is
            // what produces the paper's Fig. 5b stall pattern: a small
            // kernel that went ready while a device-filling kernel was
            // resident waits about one large-kernel duration, every time.
            self.push_gpu_ready(job);
        } else {
            self.finish_phase(job);
        }
    }

    fn on_cpu_done(&mut self, job: JobId) {
        let idx = self
            .cpu_resident
            .binary_search_by_key(&job, |r| r.job)
            .expect("cpu done without residency");
        let res = self.cpu_resident.remove(idx);
        self.cpu_free_cores += res.cores;
        self.finish_phase(job);
    }

    fn finish_phase(&mut self, job: JobId) {
        let (done, next_host_pre) = {
            let js = self.jobs.get_mut(job);
            let ph = &js.spec.phases[js.cur_phase];
            js.stats.push(PhaseStat {
                tag: ph.tag,
                start: js.phase_start - ph.host_pre,
                end: self.now,
                exec_time: js.exec_time,
                queue_wait: js.queue_wait,
            });
            js.cur_phase += 1;
            if js.cur_phase < js.spec.phases.len() {
                (false, js.spec.phases[js.cur_phase].host_pre)
            } else {
                (true, 0.0)
            }
        };
        if done {
            self.complete_job(job, None);
        } else {
            let seq = self.next_seq();
            self.events.push(Event {
                time: self.now + next_host_pre,
                seq,
                kind: EventKind::PhaseBegin,
                job,
            });
        }
    }

    fn fail_job(&mut self, job: JobId, error: String) {
        self.complete_job(job, Some(error));
    }

    fn complete_job(&mut self, job: JobId, error: Option<String>) {
        let js = self.jobs.remove(job);
        self.completed.push(JobResult {
            id: job,
            client: js.spec.client,
            label: js.spec.label,
            submit: js.submit,
            end: self.now,
            phases: js.stats,
            error,
        });
    }

    // ------------------------------------------------------------------
    // Scheduling
    // ------------------------------------------------------------------

    /// Append the job's current stream-head kernel to the ready set. The
    /// set stays sorted because `now` (and `seq`) are monotone.
    fn push_gpu_ready(&mut self, job: JobId) {
        let seq = self.next_seq();
        let (client, wanted) = {
            let js = self.jobs.get(job);
            let k = &js.spec.phases[js.cur_phase].kernels[js.cur_kernel];
            (js.spec.client, sms_wanted(k, &self.testbed.gpu).unwrap_or(1))
        };
        debug_assert!(self
            .gpu_ready
            .back()
            .map(|r| (r.rk.enqueue_time, r.rk.seq) <= (self.now, seq))
            .unwrap_or(true));
        self.gpu_ready.push_back(GpuReady {
            rk: ReadyKernel {
                client,
                enqueue_time: self.now,
                seq,
                sms_wanted: wanted,
            },
            job,
            ready_since: self.now,
        });
    }

    fn schedule_gpu(&mut self) {
        if self.gpu_suspended || self.gpu_ready.is_empty() || self.gpu_free_sms == 0 {
            return;
        }
        // Greedy fast path: grants are always a prefix of the FIFO ready
        // list, so skip the policy-view copy entirely (the dominant
        // configuration in the figure benches).
        let grants: Vec<crate::gpusim::policy::Grant> = if matches!(self.policy, Policy::Greedy) {
            let mut free = self.gpu_free_sms;
            let mut grants = Vec::new();
            for (i, r) in self.gpu_ready.iter().enumerate() {
                if free == 0 {
                    break;
                }
                let sms = r.rk.sms_wanted.min(free).max(1);
                grants.push(crate::gpusim::policy::Grant { ready_index: i, sms });
                free -= sms;
            }
            grants
        } else {
            // Reuse the scratch view buffer; entries are pre-sorted and
            // carry cached `sms_wanted`.
            self.gpu_ready_scratch.clear();
            self.gpu_ready_scratch.extend(self.gpu_ready.iter().map(|r| r.rk));
            self.policy.schedule(
                &self.gpu_ready_scratch,
                self.gpu_free_sms,
                &self.gpu_held,
                self.testbed.gpu.num_sms,
            )
        };
        if grants.is_empty() {
            return;
        }
        // Collect the granted entries into the reused launch buffer, then
        // remove them from the ready list — as a head advance when the grant
        // set is a prefix (the common case), otherwise by descending index.
        let is_prefix = grants.iter().enumerate().all(|(i, g)| g.ready_index == i);
        let mut launches = std::mem::take(&mut self.gpu_launch_scratch);
        launches.clear();
        for g in &grants {
            launches.push((self.gpu_ready[g.ready_index].clone(), g.sms));
        }
        if is_prefix {
            // Ring-buffer head advance: O(grants), not O(queue).
            for _ in 0..grants.len() {
                self.gpu_ready.pop_front();
            }
        } else {
            let mut idx: Vec<usize> = grants.iter().map(|g| g.ready_index).collect();
            idx.sort_unstable_by(|a, b| b.cmp(a));
            for i in idx {
                self.gpu_ready.remove(i);
            }
        }
        let gpu = self.testbed.gpu.clone();
        for (entry, sms) in launches.drain(..) {
            let (kernel, client) = {
                let js = self.jobs.get(entry.job);
                (
                    js.spec.phases[js.cur_phase].kernels[js.cur_kernel].clone(),
                    js.spec.client,
                )
            };
            // A thermal clock cap stretches everything downstream of the
            // clock — compute and memory alike — so the whole duration
            // scales by 1/gpu_clock_scale.
            let dur = match duration(&kernel, &gpu, sms) {
                Ok(d) => d / self.gpu_clock_scale,
                Err(e) => {
                    self.fail_job(entry.job, format!("launch failure: {e}"));
                    continue;
                }
            };
            let occ = occupancy(&kernel, &gpu).expect("occupancy checked in duration");
            {
                let js = self.jobs.get_mut(entry.job);
                js.queue_wait += self.now - entry.ready_since;
                js.exec_time += dur;
            }
            self.gpu_free_sms -= sms;
            self.gpu_held[client.0] += sms;
            // Insert keeping the resident set sorted by JobId (the fixed
            // iteration order the trace's float sums depend on).
            let pos = self
                .gpu_resident
                .binary_search_by_key(&entry.job, |r| r.job)
                .expect_err("job already resident");
            self.gpu_resident.insert(
                pos,
                GpuResident {
                    job: entry.job,
                    client,
                    sms,
                    occupancy: occ.occupancy,
                    bw_rate: kernel.bytes / dur.max(1e-12),
                },
            );
            let seq = self.next_seq();
            self.events.push(Event {
                time: self.now + dur,
                seq,
                kind: EventKind::KernelDone,
                job: entry.job,
            });
        }
        self.gpu_launch_scratch = launches;
    }

    fn schedule_cpu(&mut self) {
        if self.cpu_ready.is_empty() || self.cpu_free_cores == 0 {
            return;
        }
        // The ready queue is FIFO by construction: entries are pushed with
        // monotone (`now`, `seq`), so the old per-pass sort is a no-op.
        debug_assert!(self
            .cpu_ready
            .iter()
            .zip(self.cpu_ready.iter().skip(1))
            .all(|(a, b)| (a.ready_since, a.seq) <= (b.ready_since, b.seq)));
        let cpu = self.testbed.cpu.clone();
        // Every considered entry launches (cores = min(threads, free) >= 1),
        // so the launched set is always a queue prefix: pop from the head.
        while self.cpu_free_cores > 0 {
            let Some(&entry) = self.cpu_ready.front() else {
                break;
            };
            let work = {
                let js = self.jobs.get(entry.job);
                js.spec.phases[js.cur_phase].cpu.clone().expect("cpu phase without work")
            };
            let cores = work.threads.min(self.cpu_free_cores).max(1);
            // A few cores saturate DRAM bandwidth; beyond that only compute
            // scales.
            let bw_factor = (cores as f64 / 4.0).min(1.0);
            let compute = work.flops / (cpu.peak_flops * cores as f64 / cpu.num_cores as f64);
            let memory = work.bytes / (cpu.mem_bw * bw_factor);
            let dur = cpu.dispatch_overhead + compute.max(memory);
            {
                let js = self.jobs.get_mut(entry.job);
                js.queue_wait += self.now - entry.ready_since;
                js.exec_time += dur;
            }
            self.cpu_free_cores -= cores;
            let pos = self
                .cpu_resident
                .binary_search_by_key(&entry.job, |r| r.job)
                .expect_err("job already resident on cpu");
            self.cpu_resident.insert(
                pos,
                CpuResident {
                    job: entry.job,
                    cores,
                    bw_rate: work.bytes / dur.max(1e-12),
                },
            );
            let seq = self.next_seq();
            self.events.push(Event {
                time: self.now + dur,
                seq,
                kind: EventKind::CpuDone,
                job: entry.job,
            });
            self.cpu_ready.pop_front();
        }
    }

    // ------------------------------------------------------------------
    // Trace recording
    // ------------------------------------------------------------------

    fn record(&mut self) {
        if !self.trace_enabled {
            return;
        }
        let gpu = &self.testbed.gpu;
        let cpu = &self.testbed.cpu;
        let total_sms = gpu.num_sms as f64;
        let smact = (gpu.num_sms - self.gpu_free_sms) as f64 / total_sms;
        // Single pass over the (JobId-sorted) resident set: same summation
        // order as the old BTreeMap walk, one traversal instead of three.
        let mut smocc = 0.0f64;
        let mut gpu_bw = 0.0f64;
        for r in &self.gpu_resident {
            smocc += r.sms as f64 * r.occupancy;
            gpu_bw += r.bw_rate;
        }
        let smocc = smocc / total_sms;
        let bw_frac = (gpu_bw / gpu.mem_bw).min(1.0);
        let cpu_util = (cpu.num_cores - self.cpu_free_cores) as f64 / cpu.num_cores as f64;
        let dram_frac = (self
            .cpu_resident
            .iter()
            .map(|r| r.bw_rate)
            .sum::<f64>()
            / cpu.mem_bw)
            .min(1.0);
        let row = TraceRow {
            t: self.now,
            gpu_smact: smact as f32,
            gpu_smocc: smocc as f32,
            gpu_bw_frac: bw_frac as f32,
            gpu_power: gpu_power(gpu, smact, smocc, bw_frac) as f32,
            vram_used: self.vram.used(),
            cpu_util: cpu_util as f32,
            dram_bw_frac: dram_frac as f32,
            cpu_power: cpu_power(cpu, cpu_util, dram_frac) as f32,
        };
        if let Some(st) = &mut self.streaming {
            // Bounded-memory path: fill the reused scratch slice, fold the
            // row into the digest/aggregates, keep only the ring window.
            self.pc_scratch.clear();
            self.pc_scratch.resize(self.clients.len(), (0.0, 0.0));
            fill_per_client(&self.gpu_resident, total_sms, &mut self.pc_scratch);
            st.record(&row, &self.pc_scratch);
        } else {
            // Columnar append: the per-client slice is written in place —
            // no per-sample heap allocation.
            let per_client = self.trace.push_row(row, self.clients.len());
            fill_per_client(&self.gpu_resident, total_sms, per_client);
        }
    }

    /// Invariant check used by property tests: SM/core accounting balances.
    pub fn check_invariants(&self) {
        let gpu_held: usize = self.gpu_held.iter().sum();
        let resident: usize = self.gpu_resident.iter().map(|r| r.sms).sum();
        assert_eq!(gpu_held, resident, "held/resident mismatch");
        assert_eq!(
            self.gpu_free_sms + resident,
            self.testbed.gpu.num_sms,
            "SM conservation violated"
        );
        assert!(
            self.gpu_resident.windows(2).all(|w| w[0].job < w[1].job),
            "gpu resident set not sorted by JobId"
        );
        let cpu_busy: usize = self.cpu_resident.iter().map(|r| r.cores).sum();
        assert_eq!(
            self.cpu_free_cores + cpu_busy,
            self.testbed.cpu.num_cores,
            "core conservation violated"
        );
        assert!(
            self.cpu_resident.windows(2).all(|w| w[0].job < w[1].job),
            "cpu resident set not sorted by JobId"
        );
    }
}

/// Per-client (smact, smocc) contributions, summed in the fixed
/// JobId-sorted resident order (float addition is order-sensitive; this is
/// the golden-trace determinism contract).
fn fill_per_client(resident: &[GpuResident], total_sms: f64, out: &mut [(f32, f32)]) {
    for r in resident {
        let e = &mut out[r.client.0];
        e.0 += (r.sms as f64 / total_sms) as f32;
        e.1 += (r.sms as f64 * r.occupancy / total_sms) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::profiles::Testbed;

    fn kernel(tag: &'static str, blocks: usize, flops: f64) -> KernelDesc {
        KernelDesc::new(tag, blocks, 256, 64, 0, flops, flops / 10.0)
    }

    fn engine() -> Engine {
        Engine::new(Testbed::intel_server(), Policy::Greedy)
    }

    #[test]
    fn single_job_completes() {
        let mut e = engine();
        let c = e.register_client("chat");
        e.submit(
            JobSpec {
                client: c,
                label: "req0".into(),
                phases: vec![Phase::gpu("work", 0.0, vec![kernel("k", 288, 1e9)])],
            },
            0.0,
        );
        e.run_all();
        let done = e.take_completed();
        assert_eq!(done.len(), 1);
        let r = &done[0];
        assert!(r.error.is_none());
        assert!(r.end > 0.0);
        assert_eq!(r.phases.len(), 1);
        e.check_invariants();
    }

    #[test]
    fn event_budget_exhausts_deterministically() {
        let run = || {
            let mut e = engine();
            let c = e.register_client("chat");
            let k = kernel("k", 288, 1e9);
            e.submit(
                JobSpec {
                    client: c,
                    label: "many".into(),
                    phases: vec![Phase::gpu("p", 0.0, vec![k.clone(), k.clone(), k.clone()])],
                },
                0.0,
            );
            let r = e.run_until_budgeted(f64::MAX);
            (r, e.events_processed(), e.now())
        };
        let mut e = engine();
        e.set_event_budget(Some(2));
        let c = e.register_client("chat");
        let k = kernel("k", 288, 1e9);
        e.submit(
            JobSpec {
                client: c,
                label: "many".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![k.clone(), k.clone(), k.clone()])],
            },
            0.0,
        );
        let err = e.run_until_budgeted(f64::MAX).unwrap_err();
        let EngineError::Budget(BudgetExhausted::Events { budget, at }) = err.clone() else {
            panic!("expected Events variant, got {err:?}");
        };
        assert_eq!(budget, 2);
        assert_eq!(e.events_processed(), 2);
        // Identical workload + budget → identical stopping point (repeat).
        let mut e2 = engine();
        e2.set_event_budget(Some(2));
        let c2 = e2.register_client("chat");
        let k2 = kernel("k", 288, 1e9);
        e2.submit(
            JobSpec {
                client: c2,
                label: "many".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![k2.clone(), k2.clone(), k2])],
            },
            0.0,
        );
        let err2 = e2.run_until_budgeted(f64::MAX).unwrap_err();
        assert_eq!(err.to_string(), err2.to_string());
        let EngineError::Budget(BudgetExhausted::Events { at: at2, .. }) = err2 else {
            unreachable!()
        };
        assert_eq!(at.to_bits(), at2.to_bits(), "stop time must be bit-identical");
        // Without a budget the same workload drains fine.
        let (ok, processed, _) = run();
        assert!(ok.is_ok());
        assert!(processed > 2);
    }

    #[test]
    fn oversized_budget_is_inert() {
        let mut e = engine();
        e.set_event_budget(Some(1_000_000));
        let c = e.register_client("chat");
        e.submit(
            JobSpec {
                client: c,
                label: "req0".into(),
                phases: vec![Phase::gpu("work", 0.0, vec![kernel("k", 288, 1e9)])],
            },
            0.0,
        );
        e.run_until_budgeted(f64::MAX).unwrap();
        assert_eq!(e.take_completed().len(), 1);
        assert!(e.events_processed() > 0);
    }

    #[test]
    fn kernels_in_phase_run_sequentially() {
        let mut e = engine();
        let c = e.register_client("chat");
        let k = kernel("k", 288, 1e9);
        let solo_dur = {
            let mut e1 = engine();
            let c1 = e1.register_client("x");
            e1.submit(
                JobSpec {
                    client: c1,
                    label: "one".into(),
                    phases: vec![Phase::gpu("p", 0.0, vec![k.clone()])],
                },
                0.0,
            );
            e1.run_all();
            e1.take_completed()[0].latency()
        };
        e.submit(
            JobSpec {
                client: c,
                label: "three".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![k.clone(), k.clone(), k.clone()])],
            },
            0.0,
        );
        e.run_all();
        let lat = e.take_completed()[0].latency();
        assert!(
            (lat - 3.0 * solo_dur).abs() < 0.15 * solo_dur,
            "lat={lat} expected ~{}",
            3.0 * solo_dur
        );
    }

    #[test]
    fn host_pre_delays_phase() {
        let mut e = engine();
        let c = e.register_client("chat");
        e.submit(
            JobSpec {
                client: c,
                label: "delayed".into(),
                phases: vec![Phase::gpu("p", 0.5, vec![kernel("k", 72, 1e6)])],
            },
            1.0,
        );
        e.run_all();
        let r = &e.take_completed()[0];
        assert!(r.end >= 1.5);
        assert!((r.latency() - 0.5) < 0.1, "latency {}", r.latency());
    }

    #[test]
    fn greedy_small_kernel_stalls_behind_big_kernel() {
        // ImageGen-style device-filling stream vs a LiveCaptions-style tiny
        // kernel: under Greedy the tiny kernel waits about one large-kernel
        // duration (the paper's Fig. 5b stall), instead of its microsecond
        // exclusive latency.
        let mut e = engine();
        let big_client = e.register_client("imagegen");
        let small_client = e.register_client("livecaptions");
        let big = kernel("denoise", 10_000, 2e10);
        let big_dur = crate::gpusim::kernel::duration(&big, &e.testbed().gpu, 72).unwrap();
        e.submit(
            JobSpec {
                client: big_client,
                label: "step".into(),
                phases: vec![Phase::gpu("denoise", 0.0, vec![big; 10])],
            },
            0.0,
        );
        // Tiny kernel arrives while the first big kernel is resident.
        let tiny = kernel("decode", 2, 1e6);
        let tiny_solo = crate::gpusim::kernel::duration(&tiny, &e.testbed().gpu, 2).unwrap();
        e.submit(
            JobSpec {
                client: small_client,
                label: "tok".into(),
                phases: vec![Phase::gpu("decode", 0.0, vec![tiny])],
            },
            0.001,
        );
        e.run_all();
        let done = e.take_completed();
        let big_end = done.iter().find(|r| r.label == "step").unwrap().end;
        let tiny_r = done.iter().find(|r| r.label == "tok").unwrap();
        // Stalled by roughly one big-kernel duration — orders of magnitude
        // beyond its exclusive latency …
        assert!(
            tiny_r.queue_wait() > 0.5 * big_dur,
            "wait {} vs big kernel {}",
            tiny_r.queue_wait(),
            big_dur
        );
        assert!(tiny_r.latency() > 100.0 * tiny_solo);
        // … but not blocked behind the entire 10-kernel stream.
        assert!(
            tiny_r.end < big_end * 0.5,
            "tiny finished at {} but bulk at {}",
            tiny_r.end,
            big_end
        );
    }

    #[test]
    fn partition_protects_small_client() {
        let tb = Testbed::intel_server();
        let mut e = Engine::new(tb, Policy::Greedy);
        let big_client = e.register_client("imagegen");
        let small_client = e.register_client("livecaptions");
        e.set_policy(Policy::equal_partition(&[big_client, small_client], 72));
        let big = kernel("denoise", 10_000, 2e10);
        e.submit(
            JobSpec {
                client: big_client,
                label: "step".into(),
                phases: vec![Phase::gpu("denoise", 0.0, vec![big; 10])],
            },
            0.0,
        );
        let tiny = kernel("decode", 2, 1e6);
        e.submit(
            JobSpec {
                client: small_client,
                label: "tok".into(),
                phases: vec![Phase::gpu("decode", 0.0, vec![tiny])],
            },
            0.001,
        );
        e.run_all();
        let done = e.take_completed();
        let big_end = done.iter().find(|r| r.label == "step").unwrap().end;
        let tiny_r = done.iter().find(|r| r.label == "tok").unwrap();
        assert!(
            tiny_r.end < big_end * 0.2,
            "partitioned tiny kernel should not wait for the bulk: {} vs {}",
            tiny_r.end,
            big_end
        );
    }

    #[test]
    fn cpu_phase_occupies_cores() {
        let mut e = engine();
        let c = e.register_client("chat-cpu");
        e.submit(
            JobSpec {
                client: c,
                label: "cpu-req".into(),
                phases: vec![Phase::cpu(
                    "prefill",
                    0.0,
                    CpuWork {
                        flops: 1.6e10, // 10 ms at 100% of the Xeon
                        bytes: 1e8,
                        threads: 24,
                    },
                )],
            },
            0.0,
        );
        e.run_all();
        let r = &e.take_completed()[0];
        assert!(r.error.is_none());
        assert!(r.latency() > 5e-3 && r.latency() < 0.1, "lat {}", r.latency());
        // Trace should have seen full CPU utilization at some point.
        assert!(e.trace().iter().any(|s| s.cpu_util > 0.99));
        e.check_invariants();
    }

    #[test]
    fn oversized_smem_kernel_fails_job_not_engine() {
        // `KernelDesc::new` validates registers/threads but deliberately not
        // `smem_per_block` against any profile — the fit check is the
        // occupancy model's job. A kernel whose shared-memory footprint
        // exceeds the SM must surface as a typed launch error on the
        // JobResult, not a panic deep in the engine.
        let mut e = engine();
        let c = e.register_client("bad-backend");
        let hog = KernelDesc::new("smem-hog", 64, 64, 32, 128 * 1024, 1e6, 1e3);
        e.submit(
            JobSpec {
                client: c,
                label: "doesnt-fit".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![hog])],
            },
            0.0,
        );
        e.run_all();
        let done = e.take_completed();
        assert_eq!(done.len(), 1);
        let err = done[0].error.as_deref().expect("job must fail, not hang");
        assert!(err.contains("shared memory"), "{err}");
        // The engine stays consistent and can keep serving other clients.
        e.check_invariants();
        let ok = e.register_client("good");
        e.submit(
            JobSpec {
                client: ok,
                label: "fits".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 72, 1e6)])],
            },
            e.now(),
        );
        e.run_all();
        assert!(e.take_completed()[0].error.is_none());
    }

    #[test]
    fn oom_fails_job_with_error() {
        let mut e = engine();
        let c = e.register_client("big-model");
        e.submit(
            JobSpec {
                client: c,
                label: "setup".into(),
                phases: vec![Phase::host("load", 0.1).with_mem_ops(vec![MemOp::Alloc {
                    label: "weights".into(),
                    bytes: 30 * (1 << 30), // 30 GB > 24 GB
                }])],
            },
            0.0,
        );
        e.run_all();
        let r = &e.take_completed()[0];
        assert!(r.error.as_deref().unwrap().contains("OOM"));
    }

    #[test]
    fn partial_mem_op_failure_rolls_back_applied_allocs() {
        // An op list that partially applies before OOMing must not leak the
        // already-applied allocations (the chaos VRAM-ballast fault hits
        // this path whenever a ballast window overlaps a model load).
        let mut e = engine();
        let c = e.register_client("server");
        e.submit(
            JobSpec {
                client: c,
                label: "setup".into(),
                phases: vec![Phase::host("load", 0.0).with_mem_ops(vec![MemOp::Alloc {
                    label: "weights".into(),
                    bytes: 2 << 30,
                }])],
            },
            0.0,
        );
        e.run_all();
        let before = e.vram().used();
        assert_eq!(before, 2 << 30);
        e.submit(
            JobSpec {
                client: c,
                label: "grow".into(),
                phases: vec![Phase::host("grow", 0.0).with_mem_ops(vec![
                    MemOp::Alloc { label: "kv-a".into(), bytes: 1 << 30 },
                    MemOp::Alloc { label: "kv-b".into(), bytes: 2u64 << 30 },
                    MemOp::Alloc { label: "huge".into(), bytes: 40 * (1u64 << 30) },
                ])],
            },
            e.now(),
        );
        e.run_all();
        let done = e.take_completed();
        let grow = done.iter().find(|r| r.label == "grow").unwrap();
        assert!(grow.error.as_deref().unwrap().contains("OOM"));
        assert_eq!(
            e.vram().used(),
            before,
            "partially applied allocs must roll back on failure"
        );
        assert_eq!(e.vram().used_by("server"), before);
    }

    #[test]
    fn thermal_throttle_slows_new_launches_and_lands_in_the_trace() {
        let solo = |scale: f64| {
            let mut e = engine();
            let c = e.register_client("x");
            if scale < 1.0 {
                e.set_gpu_clock_scale(scale);
            }
            e.submit(
                JobSpec {
                    client: c,
                    label: "r".into(),
                    phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 288, 1e9)])],
                },
                0.0,
            );
            e.run_all();
            e.take_completed()[0].latency()
        };
        let full = solo(1.0);
        let capped = solo(0.5);
        assert!(
            (capped - 2.0 * full).abs() < 0.05 * full,
            "half clock must double the kernel: {capped} vs {full}"
        );
        // The transition itself records a trace row (golden-digest visible).
        let mut e = engine();
        e.register_client("x");
        let rows = e.trace().len();
        e.set_gpu_clock_scale(0.35);
        assert!(e.trace().len() > rows);
        assert_eq!(e.gpu_clock_scale(), 0.35);
    }

    #[test]
    fn suspend_freezes_gpu_launches_until_resume() {
        let mut e = engine();
        let c = e.register_client("x");
        e.set_gpu_suspended(true);
        e.submit(
            JobSpec {
                client: c,
                label: "r".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 288, 1e9)])],
            },
            0.0,
        );
        e.run_until(1.0);
        assert_eq!(
            e.take_completed().len(),
            0,
            "no kernel may launch while suspended"
        );
        assert!(e.gpu_suspended());
        e.set_gpu_suspended(false);
        e.run_all();
        let r = &e.take_completed()[0];
        assert!(r.error.is_none());
        assert!(r.end >= 1.0, "work completes only after resume: {}", r.end);
        e.check_invariants();
    }

    #[test]
    fn mem_ops_alloc_and_free() {
        let mut e = engine();
        let c = e.register_client("chat");
        e.submit(
            JobSpec {
                client: c,
                label: "setup".into(),
                phases: vec![Phase::host("load", 0.1).with_mem_ops(vec![MemOp::Alloc {
                    label: "weights".into(),
                    bytes: 2 << 30,
                }])],
            },
            0.0,
        );
        e.run_all();
        assert_eq!(e.vram().used(), 2 << 30);
        e.submit(
            JobSpec {
                client: c,
                label: "cleanup".into(),
                phases: vec![Phase::host("unload", 0.0).with_mem_ops(vec![MemOp::FreeAll])],
            },
            e.now(),
        );
        e.run_all();
        assert_eq!(e.vram().used(), 0);
    }

    #[test]
    fn mem_op_free_releases_only_the_label() {
        let mut e = engine();
        let c = e.register_client("server");
        e.submit(
            JobSpec {
                client: c,
                label: "setup".into(),
                phases: vec![Phase::host("load", 0.0).with_mem_ops(vec![
                    MemOp::Alloc { label: "weights".into(), bytes: 2 << 30 },
                    MemOp::Alloc { label: "kv-cache".into(), bytes: 1 << 30 },
                ])],
            },
            0.0,
        );
        e.run_all();
        assert_eq!(e.vram().used(), (2 << 30) + (1 << 30));
        e.submit(
            JobSpec {
                client: c,
                label: "offload".into(),
                phases: vec![Phase::host("kv.offload", 0.1)
                    .with_mem_ops(vec![MemOp::Free { label: "kv-cache".into() }])],
            },
            e.now(),
        );
        e.run_all();
        assert_eq!(e.vram().used(), 2 << 30, "weights must stay resident");
    }

    #[test]
    fn update_policy_reschedules_and_records() {
        let mut e = engine();
        let a = e.register_client("a");
        let b = e.register_client("b");
        e.set_policy(Policy::SloAware {
            priority: vec![b],
            reserve_sms: 8,
        });
        e.submit(
            JobSpec {
                client: a,
                label: "bulk".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 10_000, 2e10); 3])],
            },
            0.0,
        );
        e.run_until(0.001);
        let rows_before = e.trace().len();
        let changed = e.update_policy(|p| p.set_reserve_sms(16));
        assert!(changed, "SloAware must accept a reserve update");
        assert_eq!(e.policy().reserve_sms(), Some(16));
        assert!(
            e.trace().len() > rows_before,
            "a policy update must land in the trace"
        );
        e.run_all();
        e.check_invariants();
    }

    #[test]
    fn run_until_is_incremental() {
        let mut e = engine();
        let c = e.register_client("chat");
        e.submit(
            JobSpec {
                client: c,
                label: "late".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 72, 1e6)])],
            },
            5.0,
        );
        e.run_until(1.0);
        assert_eq!(e.take_completed().len(), 0);
        assert_eq!(e.now(), 1.0);
        e.run_until(10.0);
        assert_eq!(e.take_completed().len(), 1);
    }

    #[test]
    fn trace_records_utilization() {
        let mut e = engine();
        let c = e.register_client("chat");
        e.submit(
            JobSpec {
                client: c,
                label: "r".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 100_000, 1e11)])],
            },
            0.0,
        );
        e.run_all();
        // At some point the full GPU was reserved by client 0.
        assert!(e.trace().iter().any(|s| s.gpu_smact > 0.99));
        assert!(e
            .trace()
            .iter()
            .any(|s| s.per_client[c.0].0 > 0.99 && s.per_client[c.0].1 > 0.5));
        // Power rises above idle while running.
        let idle = e.testbed().gpu.idle_power as f32;
        assert!(e.trace().iter().any(|s| s.gpu_power > idle * 2.0));
    }

    #[test]
    fn trace_canonical_bytes_roundtrip_identity() {
        let run = || {
            let mut e = engine();
            let a = e.register_client("a");
            let b = e.register_client("b");
            for i in 0..10 {
                let cl = if i % 2 == 0 { a } else { b };
                e.submit(
                    JobSpec {
                        client: cl,
                        label: format!("r{i}"),
                        phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 300 + i, 1e8)])],
                    },
                    i as f64 * 0.002,
                );
            }
            e.run_all();
            e.take_trace()
        };
        let t1 = run();
        let t2 = run();
        // Byte-identical traces across two fresh engines in one process —
        // this is what the BTreeMap resident sets guarantee (HashMap
        // iteration order would perturb the f64 bandwidth sums).
        assert_eq!(trace_canonical_bytes(&t1), trace_canonical_bytes(&t2));
        assert_eq!(trace_digest(&t1), trace_digest(&t2));
        assert!(!t1.is_empty());
    }

    #[test]
    fn take_trace_returns_right_sized_buffer() {
        let mut e = engine();
        let c = e.register_client("chat");
        for i in 0..50 {
            e.submit(
                JobSpec {
                    client: c,
                    label: format!("r{i}"),
                    phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 100 + i, 1e7)])],
                },
                i as f64 * 0.001,
            );
        }
        e.run_all();
        let t = e.take_trace();
        assert!(!t.is_empty());
        assert!(
            t.row_capacity() <= t.len() + 16,
            "drained trace still holds peak capacity: cap {} len {}",
            t.row_capacity(),
            t.len()
        );
        assert!(e.trace().is_empty(), "take_trace must drain the engine");
    }

    #[test]
    fn trace_digest_distinguishes_workloads() {
        let run = |blocks: usize| {
            let mut e = engine();
            let c = e.register_client("a");
            e.submit(
                JobSpec {
                    client: c,
                    label: "r".into(),
                    phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", blocks, 1e8)])],
                },
                0.0,
            );
            e.run_all();
            trace_digest(e.trace())
        };
        assert_ne!(run(300), run(301));
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut e = engine();
            let a = e.register_client("a");
            let b = e.register_client("b");
            for i in 0..20 {
                let cl = if i % 2 == 0 { a } else { b };
                e.submit(
                    JobSpec {
                        client: cl,
                        label: format!("r{i}"),
                        phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 500 + i, 1e8)])],
                    },
                    i as f64 * 0.001,
                );
            }
            e.run_all();
            let mut ends: Vec<(String, f64)> =
                e.take_completed().into_iter().map(|r| (r.label, r.end)).collect();
            ends.sort_by(|x, y| x.0.cmp(&y.0));
            ends
        };
        assert_eq!(run(), run());
    }

    fn mixed_workload(e: &mut Engine) {
        let a = e.register_client("a");
        let b = e.register_client("b");
        for i in 0..20 {
            let cl = if i % 2 == 0 { a } else { b };
            e.submit(
                JobSpec {
                    client: cl,
                    label: format!("r{i}"),
                    phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 400 + i, 1e8)])],
                },
                // Duplicate arrival times on purpose: same-timestamp
                // batches must behave identically on both queue backends.
                (i / 2) as f64 * 0.002,
            );
        }
    }

    #[test]
    fn wheel_backend_is_digest_identical_to_heap() {
        let run = |queue: QueueBackend| {
            let mut e = Engine::with_options(
                Testbed::intel_server(),
                Policy::Greedy,
                EngineOptions { queue, ..EngineOptions::default() },
            );
            assert_eq!(e.queue_backend(), queue);
            mixed_workload(&mut e);
            e.run_all();
            let ends: Vec<u64> = e.take_completed().iter().map(|r| r.end.to_bits()).collect();
            (trace_digest(e.trace()), ends)
        };
        assert_eq!(run(QueueBackend::Heap), run(QueueBackend::Wheel));
    }

    #[test]
    fn streaming_mode_matches_full_trace_digest() {
        let full = {
            let mut e = engine();
            mixed_workload(&mut e);
            e.run_all();
            e
        };
        let mut st = Engine::with_options(
            Testbed::intel_server(),
            Policy::Greedy,
            EngineOptions {
                trace_mode: TraceMode::Streaming { window: 8 },
                ..EngineOptions::default()
            },
        );
        mixed_workload(&mut st);
        st.run_all();
        assert_eq!(full.current_trace_digest(), st.current_trace_digest());
        let rec = st.streaming_trace().unwrap();
        assert_eq!(rec.rows_recorded(), full.trace().len() as u64);
        assert!(rec.tail_len() <= 8, "ring exceeded window: {}", rec.tail_len());
        // The running aggregates equal a post-hoc pass over the full trace.
        let agg = st.trace_aggregates().unwrap();
        assert_eq!(agg, TraceAggregates::from_trace(full.trace()));
        // take_trace under streaming yields the bounded tail.
        let tail = st.take_trace();
        assert!(tail.len() <= 8);
        assert_eq!(
            tail.rows().last().map(|r| r.t.to_bits()),
            full.trace().rows().last().map(|r| r.t.to_bits())
        );
    }

    #[test]
    fn job_slab_recycles_slots_with_fresh_generations() {
        let mut e = engine();
        let c = e.register_client("chat");
        let first = e.submit(
            JobSpec {
                client: c,
                label: "one".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 72, 1e6)])],
            },
            0.0,
        );
        assert_eq!(first, JobId(0), "first-generation ids stay sequential");
        e.run_all();
        assert_eq!(e.pending_jobs(), 0);
        let second = e.submit(
            JobSpec {
                client: c,
                label: "two".into(),
                phases: vec![Phase::gpu("p", 0.0, vec![kernel("k", 72, 1e6)])],
            },
            e.now(),
        );
        // The slot is reused but the id is globally fresh.
        assert_ne!(second, first);
        assert_eq!(second.0 & 0xffff_ffff, 0, "slot 0 must be recycled");
        assert_eq!(second.0 >> 32, 1, "generation must bump on reuse");
        e.run_all();
        let done = e.take_completed();
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|r| r.error.is_none()));
    }

    #[test]
    fn capacity_hint_is_behavior_neutral() {
        let run = |hint: usize| {
            let mut e = Engine::with_options(
                Testbed::intel_server(),
                Policy::Greedy,
                EngineOptions { capacity_hint: hint, ..EngineOptions::default() },
            );
            mixed_workload(&mut e);
            e.run_all();
            trace_digest(e.trace())
        };
        assert_eq!(run(1), run(100_000));
    }
}
