//! Calibrated device profiles.
//!
//! The paper's testbeds are an NVIDIA RTX 6000 (Turing) + Intel Xeon Gold
//! 6126 server and an Apple MacBook M1 Pro. We model each as a set of
//! published architectural constants; the simulator derives occupancy and
//! kernel timing from these, so the profiles are the *only* place absolute
//! hardware numbers live.

/// GPU architectural profile (SM-granularity execution model).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuProfile {
    pub name: &'static str,
    /// Number of streaming multiprocessors (or SM-equivalents for Apple).
    pub num_sms: usize,
    /// Max resident threads per SM.
    pub max_threads_per_sm: usize,
    /// Max resident warps per SM (`max_threads / warp_size`).
    pub max_warps_per_sm: usize,
    /// Threads per warp.
    pub warp_size: usize,
    /// Register file size per SM (32-bit registers).
    pub regs_per_sm: usize,
    /// Shared memory per SM in bytes (VMEM-equivalent scratchpad).
    pub smem_per_sm: usize,
    /// Max resident thread blocks per SM.
    pub max_blocks_per_sm: usize,
    /// VRAM capacity in bytes.
    pub vram_bytes: u64,
    /// Peak memory bandwidth, bytes/second.
    pub mem_bw: f64,
    /// Peak fp32 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Fixed kernel-launch overhead in seconds (driver + dispatch).
    pub launch_overhead: f64,
    /// Idle board power (W).
    pub idle_power: f64,
    /// Board power limit / TDP (W).
    pub max_power: f64,
    /// Occupancy at which the SM's ALUs saturate: below this, effective
    /// throughput degrades proportionally (latency hiding breaks down).
    pub occ_saturation: f64,
    /// True for unified-memory devices (Apple Silicon): VRAM == DRAM and
    /// GPU/CPU share the bandwidth budget.
    pub unified_memory: bool,
}

/// CPU profile used for CPU-exclusive execution and hybrid (KV-cache-on-CPU)
/// scenarios.
#[derive(Debug, Clone, PartialEq)]
pub struct CpuProfile {
    pub name: &'static str,
    pub num_cores: usize,
    /// Peak fp32 throughput across all cores, FLOP/s (SIMD included).
    pub peak_flops: f64,
    /// DRAM bandwidth, bytes/second.
    pub mem_bw: f64,
    /// DRAM capacity in bytes.
    pub dram_bytes: u64,
    /// Package idle power (W), RAPL-style.
    pub idle_power: f64,
    /// Package TDP (W).
    pub max_power: f64,
    /// Per-dispatch overhead on the CPU path (thread-pool wake etc.).
    pub dispatch_overhead: f64,
}

/// The paper's primary testbed GPU: NVIDIA Quadro RTX 6000 (Turing TU102),
/// 72 SMs, 24 GB GDDR6, 672 GB/s, 16.3 TFLOP/s fp32, 260 W.
pub fn rtx6000() -> GpuProfile {
    GpuProfile {
        name: "RTX6000",
        num_sms: 72,
        max_threads_per_sm: 1024,
        max_warps_per_sm: 32,
        warp_size: 32,
        regs_per_sm: 65_536,
        smem_per_sm: 65_536,
        max_blocks_per_sm: 16,
        vram_bytes: 24 * (1 << 30),
        mem_bw: 672e9,
        peak_flops: 16.3e12,
        launch_overhead: 5e-6,
        idle_power: 55.0,
        max_power: 260.0,
        occ_saturation: 0.40,
        unified_memory: false,
    }
}

/// Apple M1 Pro 16-core GPU modeled as 16 SM-equivalents. 32 GB unified
/// memory at 200 GB/s shared with the CPU; ~5.2 TFLOP/s fp32; low power.
/// Apple's scheduler is modeled as `Policy::FairShare` by the orchestrator.
pub fn m1_pro_gpu() -> GpuProfile {
    GpuProfile {
        name: "M1ProGPU",
        num_sms: 16,
        max_threads_per_sm: 1024,
        max_warps_per_sm: 32,
        warp_size: 32,
        regs_per_sm: 65_536,
        // Apple threadgroup memory: 32 KB per threadgroup; model 64 KB/core.
        smem_per_sm: 65_536,
        max_blocks_per_sm: 16,
        vram_bytes: 32 * (1 << 30), // unified: capacity == DRAM
        mem_bw: 200e9,
        peak_flops: 5.2e12,
        launch_overhead: 8e-6,
        idle_power: 4.0,
        max_power: 30.0,
        occ_saturation: 0.40,
        unified_memory: true,
    }
}

/// Intel Xeon Gold 6126 as configured in the paper's server (24 cores
/// visible, 2.6 GHz, AVX-512): ~1.6 TFLOP/s fp32 aggregate, 32 GB DRAM,
/// ~119 GB/s (6-channel DDR4-2666), 125 W TDP.
pub fn xeon6126() -> CpuProfile {
    CpuProfile {
        name: "Xeon6126",
        num_cores: 24,
        peak_flops: 1.6e12,
        mem_bw: 119e9,
        dram_bytes: 32 * (1 << 30),
        idle_power: 25.0,
        max_power: 125.0,
        dispatch_overhead: 2e-6,
    }
}

/// M1 Pro CPU complex (6 performance + 2 efficiency cores, paper's config).
/// The package advertises 200 GB/s, but a CPU-cluster-only workload reaches
/// roughly half of it — the GPU shares the same fabric.
pub fn m1_pro_cpu() -> CpuProfile {
    CpuProfile {
        name: "M1ProCPU",
        num_cores: 8,
        peak_flops: 0.8e12,
        mem_bw: 100e9,
        dram_bytes: 32 * (1 << 30),
        idle_power: 1.0,
        max_power: 30.0,
        dispatch_overhead: 2e-6,
    }
}

/// A full testbed: one GPU + one CPU, as the orchestrator sees it.
#[derive(Debug, Clone)]
pub struct Testbed {
    pub gpu: GpuProfile,
    pub cpu: CpuProfile,
}

impl Testbed {
    /// The paper's primary Intel + RTX 6000 server (§4, "Experimental Setup").
    pub fn intel_server() -> Self {
        Testbed {
            gpu: rtx6000(),
            cpu: xeon6126(),
        }
    }

    /// The paper's MacBook M1 Pro laptop (§4.4, Appendix C).
    pub fn macbook_m1_pro() -> Self {
        Testbed {
            gpu: m1_pro_gpu(),
            cpu: m1_pro_cpu(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtx6000_matches_published_specs() {
        let g = rtx6000();
        assert_eq!(g.num_sms, 72);
        assert_eq!(g.vram_bytes, 24 * (1 << 30));
        assert_eq!(g.max_warps_per_sm * g.warp_size, g.max_threads_per_sm);
        assert!(g.peak_flops > 16e12 && g.peak_flops < 17e12);
    }

    #[test]
    fn m1_is_unified_and_low_power() {
        let g = m1_pro_gpu();
        assert!(g.unified_memory);
        assert!(g.max_power < rtx6000().max_power / 5.0);
        assert_eq!(g.vram_bytes, m1_pro_cpu().dram_bytes);
    }

    #[test]
    fn cpu_profiles_sane() {
        let c = xeon6126();
        assert_eq!(c.num_cores, 24);
        assert!(c.peak_flops < rtx6000().peak_flops / 5.0);
        assert!(c.mem_bw < rtx6000().mem_bw);
    }

    #[test]
    fn testbeds_compose() {
        let t = Testbed::intel_server();
        assert_eq!(t.gpu.name, "RTX6000");
        assert_eq!(t.cpu.name, "Xeon6126");
        let m = Testbed::macbook_m1_pro();
        assert!(m.gpu.unified_memory);
    }
}
