//! GPU resource-sharing policies (§3.2 "resource orchestrator").
//!
//! The paper evaluates three regimes:
//!
//! * **Greedy** — the CUDA default: kernels occupy SMs first-come-first-serve
//!   and a launched kernel takes every free SM its grid can use. Reproduces
//!   the starvation finding (§4.2): bulk-enqueued large kernels monopolize
//!   the device and small latency-sensitive kernels queue behind them.
//! * **Partition** — NVIDIA MPS-style static caps: each client may hold at
//!   most a fixed number of SMs, idle partitions stay idle (the stairstep
//!   under-utilization of Fig. 5).
//! * **FairShare** — the Apple-Silicon-like scheduler (§4.4): per-client cap
//!   is recomputed as `total / active_clients`, with leftover SMs granted to
//!   whoever is waiting; non-preemptive, so fairness is still imperfect.

use std::collections::BTreeMap;

use crate::gpusim::engine::ClientId;

/// A ready kernel as the policy sees it.
#[derive(Debug, Clone, Copy)]
pub struct ReadyKernel {
    pub client: ClientId,
    /// FIFO key: time the kernel's phase was enqueued (stream order).
    pub enqueue_time: f64,
    /// Tie-break sequence for determinism.
    pub seq: u64,
    /// SMs the kernel wants (grid fully spread).
    pub sms_wanted: usize,
}

/// A grant decision: which ready kernel launches on how many SMs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grant {
    /// Index into the ready list passed to `schedule`.
    pub ready_index: usize,
    pub sms: usize,
}

/// Resource-sharing policy.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// First-come-first-serve over all free SMs.
    Greedy,
    /// Static per-client SM caps (MPS analogue). Clients absent from the map
    /// are uncapped.
    Partition(BTreeMap<ClientId, usize>),
    /// Dynamic equal share across active clients, leftover redistributed.
    FairShare,
    /// The paper's §5.2 proposal, implemented as an extension: clients with
    /// tight SLOs are *priority* clients whose ready kernels are served
    /// before best-effort work, and a small SM reservation is withheld from
    /// best-effort kernels so a latency-sensitive kernel never waits a full
    /// device-filling kernel to drain. Work-conserving: if no priority
    /// client is active, best-effort work gets the whole device.
    SloAware {
        /// Latency-sensitive clients (tight SLOs).
        priority: Vec<ClientId>,
        /// SMs withheld from best-effort kernels while any priority client
        /// has ready or resident work.
        reserve_sms: usize,
    },
}

impl Policy {
    /// Static MPS partition giving each of `clients` an equal share of
    /// `total_sms` (the paper's 33%-each configuration).
    pub fn equal_partition(clients: &[ClientId], total_sms: usize) -> Policy {
        assert!(!clients.is_empty());
        let share = total_sms / clients.len();
        Policy::Partition(clients.iter().map(|&c| (c, share)).collect())
    }

    /// The `SloAware` SM reservation, if this policy carries one. The
    /// adaptive controller reads this to decide grow/shrink actions.
    pub fn reserve_sms(&self) -> Option<usize> {
        match self {
            Policy::SloAware { reserve_sms, .. } => Some(*reserve_sms),
            _ => None,
        }
    }

    /// Set the `SloAware` reservation (runtime reconfiguration via
    /// [`crate::gpusim::engine::Engine::update_policy`]). Returns `false`
    /// — and changes nothing — for policies without a reservation.
    pub fn set_reserve_sms(&mut self, n: usize) -> bool {
        match self {
            Policy::SloAware { reserve_sms, .. } => {
                *reserve_sms = n;
                true
            }
            _ => false,
        }
    }

    /// Decide launches given the ready set, free SMs, and current per-client
    /// holdings (`held_by` is dense, indexed by `ClientId`; clients past its
    /// end hold nothing). Returns grants in launch order. `ready` MUST be
    /// sorted by (enqueue_time, seq) — the engine guarantees this.
    ///
    /// Policies are non-preemptive and work-conserving within their caps: a
    /// kernel launches with `min(wanted, allowed)` SMs as long as at least
    /// one SM is allowed, matching how the hardware work distributor drains
    /// grids onto whatever SMs are available.
    pub fn schedule(
        &self,
        ready: &[ReadyKernel],
        mut free_sms: usize,
        held_by: &[usize],
        total_sms: usize,
    ) -> Vec<Grant> {
        debug_assert!(ready.windows(2).all(|w| {
            (w[0].enqueue_time, w[0].seq) <= (w[1].enqueue_time, w[1].seq)
        }));
        let mut grants = Vec::new();
        // Dense working copy of the holdings, sized to cover every client
        // appearing in the ready set (a handful of machine words — cheap
        // compared to the BTreeMap clone this replaces).
        let need = held_by
            .len()
            .max(ready.iter().map(|r| r.client.0 + 1).max().unwrap_or(0));
        let mut held: Vec<usize> = Vec::with_capacity(need);
        held.extend_from_slice(held_by);
        held.resize(need, 0);

        match self {
            Policy::Greedy => {
                for (i, rk) in ready.iter().enumerate() {
                    if free_sms == 0 {
                        break;
                    }
                    let sms = rk.sms_wanted.min(free_sms).max(1);
                    grants.push(Grant { ready_index: i, sms });
                    free_sms -= sms;
                }
            }
            Policy::Partition(caps) => {
                for (i, rk) in ready.iter().enumerate() {
                    if free_sms == 0 {
                        break;
                    }
                    let cap = caps.get(&rk.client).copied().unwrap_or(total_sms);
                    let used = held[rk.client.0];
                    let allowed = cap.saturating_sub(used).min(free_sms);
                    if allowed == 0 {
                        continue; // this client's partition is full; others may go
                    }
                    let sms = rk.sms_wanted.min(allowed).max(1);
                    grants.push(Grant { ready_index: i, sms });
                    held[rk.client.0] += sms;
                    free_sms -= sms;
                }
            }
            Policy::SloAware { priority, reserve_sms } => {
                let priority_active = ready.iter().any(|rk| priority.contains(&rk.client))
                    || held
                        .iter()
                        .enumerate()
                        .any(|(c, &n)| n > 0 && priority.contains(&ClientId(c)));
                // Pass 1: priority clients in FIFO order, full device.
                let mut launched = vec![false; ready.len()];
                for (i, rk) in ready.iter().enumerate() {
                    if free_sms == 0 {
                        break;
                    }
                    if !priority.contains(&rk.client) {
                        continue;
                    }
                    let sms = rk.sms_wanted.min(free_sms).max(1);
                    grants.push(Grant { ready_index: i, sms });
                    launched[i] = true;
                    free_sms -= sms;
                }
                // Pass 2: best-effort clients, leaving the reservation free
                // whenever a priority client is active.
                let floor = if priority_active { *reserve_sms } else { 0 };
                for (i, rk) in ready.iter().enumerate() {
                    if free_sms <= floor {
                        break;
                    }
                    if launched[i] || priority.contains(&rk.client) {
                        continue;
                    }
                    let sms = rk.sms_wanted.min(free_sms - floor).max(1);
                    grants.push(Grant { ready_index: i, sms });
                    free_sms -= sms;
                }
            }
            Policy::FairShare => {
                // Active clients: anyone holding SMs or with ready work.
                // Ascending-ClientId enumeration reproduces the old
                // BTreeMap's iteration order exactly.
                let mut active: Vec<ClientId> = held
                    .iter()
                    .enumerate()
                    .filter(|(_, &n)| n > 0)
                    .map(|(c, _)| ClientId(c))
                    .collect();
                for rk in ready {
                    if !active.contains(&rk.client) {
                        active.push(rk.client);
                    }
                }
                let fair_cap = (total_sms / active.len().max(1)).max(1);
                // Pass 1: grant up to the fair cap, FIFO order.
                let mut launched = vec![false; ready.len()];
                for (i, rk) in ready.iter().enumerate() {
                    if free_sms == 0 {
                        break;
                    }
                    let used = held[rk.client.0];
                    let allowed = fair_cap.saturating_sub(used).min(free_sms);
                    if allowed == 0 {
                        continue;
                    }
                    let sms = rk.sms_wanted.min(allowed).max(1);
                    grants.push(Grant { ready_index: i, sms });
                    launched[i] = true;
                    held[rk.client.0] += sms;
                    free_sms -= sms;
                }
                // Pass 2: leftover SMs go to still-waiting kernels FIFO —
                // work conservation (unlike static MPS partitions).
                for (i, rk) in ready.iter().enumerate() {
                    if free_sms == 0 {
                        break;
                    }
                    if launched[i] {
                        continue;
                    }
                    let sms = rk.sms_wanted.min(free_sms).max(1);
                    grants.push(Grant { ready_index: i, sms });
                    held[rk.client.0] += sms;
                    free_sms -= sms;
                }
            }
        }
        grants
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Policy::Greedy => write!(f, "greedy"),
            Policy::Partition(caps) => {
                write!(f, "partition(")?;
                for (i, (c, n)) in caps.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "c{}={}", c.0, n)?;
                }
                write!(f, ")")
            }
            Policy::FairShare => write!(f, "fair-share"),
            Policy::SloAware { priority, reserve_sms } => {
                write!(f, "slo-aware(prio={}, reserve={reserve_sms})", priority.len())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rk(client: usize, t: f64, seq: u64, want: usize) -> ReadyKernel {
        ReadyKernel {
            client: ClientId(client),
            enqueue_time: t,
            seq,
            sms_wanted: want,
        }
    }

    /// Dense holdings vector from (client, sms) pairs.
    fn held(pairs: &[(usize, usize)]) -> Vec<usize> {
        let n = pairs.iter().map(|&(c, _)| c + 1).max().unwrap_or(0);
        let mut v = vec![0; n];
        for &(c, h) in pairs {
            v[c] = h;
        }
        v
    }

    #[test]
    fn greedy_big_kernel_takes_everything() {
        let p = Policy::Greedy;
        let ready = [rk(0, 0.0, 0, 72), rk(1, 1.0, 1, 2)];
        let grants = p.schedule(&ready, 72, &[], 72);
        assert_eq!(grants, vec![Grant { ready_index: 0, sms: 72 }]);
    }

    #[test]
    fn greedy_fifo_order_respected() {
        let p = Policy::Greedy;
        // Small kernel enqueued first gets served first.
        let ready = [rk(1, 0.0, 0, 2), rk(0, 1.0, 1, 72)];
        let grants = p.schedule(&ready, 72, &[], 72);
        assert_eq!(grants.len(), 2);
        assert_eq!(grants[0], Grant { ready_index: 0, sms: 2 });
        assert_eq!(grants[1], Grant { ready_index: 1, sms: 70 });
    }

    #[test]
    fn greedy_no_free_no_grant() {
        let p = Policy::Greedy;
        let ready = [rk(0, 0.0, 0, 1)];
        assert!(p.schedule(&ready, 0, &[], 72).is_empty());
    }

    #[test]
    fn partition_caps_each_client() {
        let p = Policy::equal_partition(&[ClientId(0), ClientId(1), ClientId(2)], 72);
        let ready = [rk(0, 0.0, 0, 72)];
        let grants = p.schedule(&ready, 72, &[], 72);
        assert_eq!(grants, vec![Grant { ready_index: 0, sms: 24 }]);
    }

    #[test]
    fn partition_full_client_does_not_block_others() {
        let p = Policy::equal_partition(&[ClientId(0), ClientId(1), ClientId(2)], 72);
        let held = held(&[(0, 24)]); // client 0 partition full
        let ready = [rk(0, 0.0, 0, 10), rk(1, 1.0, 1, 10)];
        let grants = p.schedule(&ready, 48, &held, 72);
        assert_eq!(grants, vec![Grant { ready_index: 1, sms: 10 }]);
    }

    #[test]
    fn partition_idle_share_stays_idle() {
        // Client 1 and 2 idle; client 0 still capped at 24 — the paper's
        // under-utilization finding.
        let p = Policy::equal_partition(&[ClientId(0), ClientId(1), ClientId(2)], 72);
        let ready = [rk(0, 0.0, 0, 72)];
        let grants = p.schedule(&ready, 72, &[], 72);
        assert_eq!(grants[0].sms, 24);
    }

    #[test]
    fn fair_share_splits_between_active() {
        let p = Policy::FairShare;
        let ready = [rk(0, 0.0, 0, 72), rk(1, 0.5, 1, 72)];
        let grants = p.schedule(&ready, 72, &[], 72);
        // Both get their fair cap of 36.
        assert_eq!(grants.len(), 2);
        assert_eq!(grants[0].sms, 36);
        assert_eq!(grants[1].sms, 36);
    }

    #[test]
    fn fair_share_is_work_conserving() {
        // One active client → it gets everything (unlike static partition).
        let p = Policy::FairShare;
        let ready = [rk(0, 0.0, 0, 72)];
        let grants = p.schedule(&ready, 72, &[], 72);
        assert_eq!(grants[0].sms, 72);
    }

    #[test]
    fn fair_share_leftover_redistributed() {
        let p = Policy::FairShare;
        // Client 0 wants tiny, client 1 wants everything.
        let ready = [rk(0, 0.0, 0, 2), rk(1, 0.5, 1, 72)];
        let grants = p.schedule(&ready, 72, &[], 72);
        // Client 0 takes 2 (under its cap of 36), client 1 takes its cap 36,
        // then leftover 34 goes back to client 1? No — non-launched kernels
        // only; both launched, so grants are [2, 36].
        assert_eq!(grants.len(), 2);
        assert_eq!(grants[0].sms, 2);
        assert_eq!(grants[1].sms, 36);
    }

    #[test]
    fn slo_aware_serves_priority_first() {
        let p = Policy::SloAware {
            priority: vec![ClientId(1)],
            reserve_sms: 8,
        };
        // Best-effort device-filler arrived first; priority tiny kernel second.
        let ready = [rk(0, 0.0, 0, 72), rk(1, 1.0, 1, 4)];
        let grants = p.schedule(&ready, 72, &[], 72);
        // Priority kernel launches first with its full want …
        assert_eq!(grants[0], Grant { ready_index: 1, sms: 4 });
        // … and the best-effort kernel is capped so the reservation stays free.
        assert_eq!(grants[1], Grant { ready_index: 0, sms: 60 });
    }

    #[test]
    fn slo_aware_work_conserving_when_priority_idle() {
        let p = Policy::SloAware {
            priority: vec![ClientId(1)],
            reserve_sms: 8,
        };
        let ready = [rk(0, 0.0, 0, 72)];
        let grants = p.schedule(&ready, 72, &[], 72);
        // No priority work anywhere → no reservation withheld.
        assert_eq!(grants, vec![Grant { ready_index: 0, sms: 72 }]);
    }

    #[test]
    fn slo_aware_reserves_while_priority_resident() {
        let p = Policy::SloAware {
            priority: vec![ClientId(1)],
            reserve_sms: 8,
        };
        let held = held(&[(1, 4)]); // priority kernel resident
        let ready = [rk(0, 0.0, 0, 72)];
        let grants = p.schedule(&ready, 68, &held, 72);
        assert_eq!(grants, vec![Grant { ready_index: 0, sms: 60 }]);
    }

    #[test]
    fn reserve_accessors_only_touch_slo_aware() {
        let mut p = Policy::SloAware {
            priority: vec![ClientId(1)],
            reserve_sms: 8,
        };
        assert_eq!(p.reserve_sms(), Some(8));
        assert!(p.set_reserve_sms(24));
        assert_eq!(p.reserve_sms(), Some(24));
        for mut other in [Policy::Greedy, Policy::FairShare] {
            assert_eq!(other.reserve_sms(), None);
            assert!(!other.set_reserve_sms(12));
            assert_eq!(other.reserve_sms(), None);
        }
    }

    #[test]
    fn grants_never_exceed_free() {
        for policy in [
            Policy::Greedy,
            Policy::equal_partition(&[ClientId(0), ClientId(1)], 72),
            Policy::FairShare,
            Policy::SloAware { priority: vec![ClientId(1)], reserve_sms: 8 },
        ] {
            let ready = [rk(0, 0.0, 0, 50), rk(1, 0.1, 1, 50), rk(0, 0.2, 2, 50)];
            let grants = policy.schedule(&ready, 30, &[], 72);
            let total: usize = grants.iter().map(|g| g.sms).sum();
            assert!(total <= 30, "{policy}: granted {total} > 30 free");
        }
    }
}
