//! Kernel descriptors and the occupancy model.
//!
//! A kernel is described by its launch geometry and per-thread resource
//! footprint — the same quantities the paper extracts with Nsight to explain
//! SMOCC differences (§4.1): llama.cpp's tuned kernels vs. PyTorch's generic
//! attention needing >150 registers/thread, and Whisper's decoder kernels
//! with high register + shared-memory pressure.
//!
//! The occupancy calculation mirrors the CUDA occupancy calculator: resident
//! blocks per SM are bounded by the register file, shared memory, thread
//! count, and the hardware block limit.

use crate::gpusim::profiles::GpuProfile;

/// Where a phase of work executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Device {
    Gpu,
    Cpu,
}

/// Interned kernel tag.
///
/// Kernel names used to be bare `&'static str`, which meant every tag had
/// to be a compile-time literal; the pluggable kernel backends
/// ([`crate::gpusim::backend`]) synthesize names like `decode.attn@torch`
/// at table-construction time, so tags are now interned: [`Tag::intern`]
/// deduplicates through a global pool (each distinct name is leaked exactly
/// once) and the hot path stays a `Copy` of a `&'static str`. Equality is
/// by content, so two tags with the same text compare equal regardless of
/// how they were created — interning is an allocation strategy, not an
/// identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Tag(&'static str);

impl Tag {
    /// Wrap a compile-time literal (no pool access; content equality makes
    /// this indistinguishable from the interned path).
    pub const fn from_static(s: &'static str) -> Tag {
        Tag(s)
    }

    /// Intern a runtime-synthesized name. Repeated calls with the same text
    /// return the same leaked allocation, so the pool growth is bounded by
    /// the number of distinct tags (a few dozen across all backends).
    pub fn intern(s: &str) -> Tag {
        use std::collections::BTreeSet;
        use std::sync::{Mutex, OnceLock};
        static POOL: OnceLock<Mutex<BTreeSet<&'static str>>> = OnceLock::new();
        let pool = POOL.get_or_init(|| Mutex::new(BTreeSet::new()));
        // A panicking holder can only have left the set missing its
        // newest entry; the pool is insert-only, so recovering the guard
        // is always safe (at worst the tag is re-leaked once).
        let mut pool = pool.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(&hit) = pool.get(s) {
            return Tag(hit);
        }
        let leaked: &'static str = Box::leak(s.to_owned().into_boxed_str());
        pool.insert(leaked);
        Tag(leaked)
    }

    pub fn as_str(&self) -> &'static str {
        self.0
    }
}

impl From<&'static str> for Tag {
    fn from(s: &'static str) -> Tag {
        Tag(s)
    }
}

impl<'a> PartialEq<&'a str> for Tag {
    fn eq(&self, other: &&'a str) -> bool {
        self.0 == *other
    }
}

impl PartialEq<str> for Tag {
    fn eq(&self, other: &str) -> bool {
        self.0 == other
    }
}

impl std::fmt::Display for Tag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0)
    }
}

/// Descriptor for one GPU kernel launch.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDesc {
    /// Human-readable tag, e.g. "decode.attn" — used in per-request traces.
    pub tag: Tag,
    /// Number of thread blocks in the grid.
    pub blocks: usize,
    /// Threads per block.
    pub threads_per_block: usize,
    /// 32-bit registers per thread.
    pub regs_per_thread: usize,
    /// Shared memory per block, bytes.
    pub smem_per_block: usize,
    /// Total floating-point work, FLOPs.
    pub flops: f64,
    /// Total DRAM traffic, bytes.
    pub bytes: f64,
}

impl KernelDesc {
    /// Convenience constructor with footprint validation.
    ///
    /// Only profile-independent footprints are asserted here (block count,
    /// thread range, register encoding range). Whether the kernel *fits* a
    /// particular GPU — registers per block, shared memory per block,
    /// threads per SM — depends on the profile and is surfaced as a typed
    /// [`LaunchError`] by [`occupancy`] at launch time, never as a panic
    /// deep in the engine.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        tag: impl Into<Tag>,
        blocks: usize,
        threads_per_block: usize,
        regs_per_thread: usize,
        smem_per_block: usize,
        flops: f64,
        bytes: f64,
    ) -> Self {
        let tag = tag.into();
        assert!(blocks > 0, "{tag}: kernel must have at least one block");
        assert!(
            (1..=1024).contains(&threads_per_block),
            "{tag}: threads_per_block {threads_per_block} out of range"
        );
        assert!(regs_per_thread > 0 && regs_per_thread <= 255, "{tag}: regs out of range");
        assert!(flops >= 0.0 && bytes >= 0.0, "{tag}: negative work");
        KernelDesc {
            tag,
            blocks,
            threads_per_block,
            regs_per_thread,
            smem_per_block,
            flops,
            bytes,
        }
    }
}

/// Result of the occupancy calculation for a kernel on a device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Occupancy {
    /// Resident blocks per SM (>= 1; a kernel that fits no SM is a launch
    /// failure, surfaced as an error by `occupancy()`).
    pub blocks_per_sm: usize,
    /// Resident warps per SM for this kernel.
    pub warps_per_sm: usize,
    /// Fraction of the SM's warp slots occupied: the SMOCC contribution of
    /// each SM this kernel runs on.
    pub occupancy: f64,
    /// Which resource bounds residency (diagnostic, shows up in reports).
    pub limiter: Limiter,
}

/// The resource that limits occupancy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Limiter {
    Registers,
    SharedMemory,
    Threads,
    BlockSlots,
}

impl std::fmt::Display for Limiter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Limiter::Registers => write!(f, "registers"),
            Limiter::SharedMemory => write!(f, "shared-memory"),
            Limiter::Threads => write!(f, "threads"),
            Limiter::BlockSlots => write!(f, "block-slots"),
        }
    }
}

/// Kernel launch failure (resources exceed a single SM).
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum LaunchError {
    #[error("kernel `{0}` needs {1} registers/block, SM has {2}")]
    TooManyRegisters(Tag, usize, usize),
    #[error("kernel `{0}` needs {1} B shared memory/block, SM has {2}")]
    TooMuchSharedMemory(Tag, usize, usize),
    #[error("kernel `{0}` needs {1} threads/block, SM runs at most {2}")]
    TooManyThreads(Tag, usize, usize),
}

/// Compute CUDA-style occupancy of `k` on `gpu`.
///
/// Every kernel-doesn't-fit condition — register file, shared memory, or a
/// block wider than the SM's thread capacity — is a typed [`LaunchError`]
/// here, which the engine turns into a failed job (never a panic or a
/// division by a zero block limit).
pub fn occupancy(k: &KernelDesc, gpu: &GpuProfile) -> Result<Occupancy, LaunchError> {
    let regs_per_block = k.regs_per_thread * k.threads_per_block;
    if regs_per_block > gpu.regs_per_sm {
        return Err(LaunchError::TooManyRegisters(k.tag, regs_per_block, gpu.regs_per_sm));
    }
    if k.smem_per_block > gpu.smem_per_sm {
        return Err(LaunchError::TooMuchSharedMemory(k.tag, k.smem_per_block, gpu.smem_per_sm));
    }
    if k.threads_per_block > gpu.max_threads_per_sm {
        // Without this check `limit_threads` would truncate to zero and the
        // grid math below (and `sms_wanted`'s div_ceil) would divide by it.
        return Err(LaunchError::TooManyThreads(
            k.tag,
            k.threads_per_block,
            gpu.max_threads_per_sm,
        ));
    }

    let limit_regs = gpu.regs_per_sm / regs_per_block;
    let limit_smem = if k.smem_per_block == 0 {
        usize::MAX
    } else {
        gpu.smem_per_sm / k.smem_per_block
    };
    let limit_threads = gpu.max_threads_per_sm / k.threads_per_block;
    let limit_slots = gpu.max_blocks_per_sm;

    let (blocks_per_sm, limiter) = [
        (limit_regs, Limiter::Registers),
        (limit_smem, Limiter::SharedMemory),
        (limit_threads, Limiter::Threads),
        (limit_slots, Limiter::BlockSlots),
    ]
    .into_iter()
    .min_by_key(|(v, _)| *v)
    .unwrap();

    // Checked above: regs and smem fit at least one block; threads_per_block
    // <= 1024 <= max_threads_per_sm; so blocks_per_sm >= 1.
    debug_assert!(blocks_per_sm >= 1);

    let warps_per_block = k.threads_per_block.div_ceil(gpu.warp_size);
    // A kernel cannot keep more blocks resident than its grid has.
    let resident_blocks = blocks_per_sm.min(k.blocks.max(1));
    let warps_per_sm = (resident_blocks * warps_per_block).min(gpu.max_warps_per_sm);
    Ok(Occupancy {
        blocks_per_sm,
        warps_per_sm,
        occupancy: warps_per_sm as f64 / gpu.max_warps_per_sm as f64,
        limiter,
    })
}

/// How many SMs the kernel *wants* to fully spread its grid.
pub fn sms_wanted(k: &KernelDesc, gpu: &GpuProfile) -> Result<usize, LaunchError> {
    let occ = occupancy(k, gpu)?;
    Ok(k.blocks.div_ceil(occ.blocks_per_sm).min(gpu.num_sms).max(1))
}

/// Execution time of the kernel when granted `granted_sms` SMs.
///
/// The roofline is evaluated on the granted slice of the device: compute
/// capability scales with SM share and degrades below the occupancy
/// saturation point (latency hiding breaks down — the paper's low-SMOCC
/// pathology); memory bandwidth scales with SM share.
pub fn duration(k: &KernelDesc, gpu: &GpuProfile, granted_sms: usize) -> Result<f64, LaunchError> {
    assert!(granted_sms >= 1, "duration: granted_sms must be >= 1");
    let occ = occupancy(k, gpu)?;
    let share = (granted_sms as f64 / gpu.num_sms as f64).min(1.0);
    let eff = (occ.occupancy / gpu.occ_saturation).min(1.0);
    let compute = k.flops / (gpu.peak_flops * share * eff.max(1e-3));
    let memory = k.bytes / (gpu.mem_bw * share);
    Ok(gpu.launch_overhead + compute.max(memory))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::profiles::rtx6000;

    fn tuned_kernel() -> KernelDesc {
        // llama.cpp-style: modest registers, no heavy smem.
        KernelDesc::new("decode.matmul", 288, 256, 64, 8 * 1024, 1e9, 5e7)
    }

    fn register_hog() -> KernelDesc {
        // PyTorch generic attention per §4.1: >150 regs/thread.
        KernelDesc::new("denoise.attn", 288, 256, 168, 16 * 1024, 1e9, 5e7)
    }

    #[test]
    fn tuned_kernel_has_high_occupancy() {
        let occ = occupancy(&tuned_kernel(), &rtx6000()).unwrap();
        assert!(occ.occupancy >= 0.9, "occ = {}", occ.occupancy);
        // 64 regs × 256 threads ties the register and thread limits at 4
        // blocks/SM; either limiter is a valid report.
        assert!(matches!(occ.limiter, Limiter::Threads | Limiter::Registers));
    }

    #[test]
    fn register_pressure_kills_occupancy() {
        let occ = occupancy(&register_hog(), &rtx6000()).unwrap();
        // 168 regs * 256 threads = 43008 regs/block → 1 block/SM → 8 warps.
        assert_eq!(occ.blocks_per_sm, 1);
        assert_eq!(occ.limiter, Limiter::Registers);
        assert!(occ.occupancy <= 0.3, "occ = {}", occ.occupancy);
    }

    #[test]
    fn smem_limits_occupancy() {
        let k = KernelDesc::new("dec.small", 72, 128, 48, 48 * 1024, 1e6, 1e5);
        let occ = occupancy(&k, &rtx6000()).unwrap();
        assert_eq!(occ.blocks_per_sm, 1); // 64KB / 48KB = 1
        assert_eq!(occ.limiter, Limiter::SharedMemory);
    }

    #[test]
    fn occupancy_monotone_in_registers() {
        let gpu = rtx6000();
        let mut prev = f64::INFINITY;
        for regs in [32, 64, 96, 128, 168, 200, 255] {
            let k = KernelDesc::new("t", 1000, 256, regs, 0, 1e9, 1e6);
            let occ = occupancy(&k, &gpu).unwrap().occupancy;
            assert!(occ <= prev + 1e-12, "occupancy rose with more registers");
            prev = occ;
        }
    }

    #[test]
    fn oversized_block_is_launch_error() {
        let k = KernelDesc::new("huge", 1, 1024, 255, 0, 1.0, 1.0);
        assert!(matches!(
            occupancy(&k, &rtx6000()),
            Err(LaunchError::TooManyRegisters(..))
        ));
        let k2 = KernelDesc::new("smem", 1, 64, 32, 128 * 1024, 1.0, 1.0);
        assert!(matches!(
            occupancy(&k2, &rtx6000()),
            Err(LaunchError::TooMuchSharedMemory(..))
        ));
    }

    #[test]
    fn sms_wanted_caps_at_device() {
        let gpu = rtx6000();
        let big = KernelDesc::new("big", 100_000, 256, 64, 0, 1e9, 1e6);
        assert_eq!(sms_wanted(&big, &gpu).unwrap(), gpu.num_sms);
        let small = KernelDesc::new("small", 3, 256, 64, 0, 1e6, 1e3);
        assert!(sms_wanted(&small, &gpu).unwrap() <= 3);
    }

    #[test]
    fn duration_scales_with_granted_sms() {
        let gpu = rtx6000();
        let k = tuned_kernel();
        let full = duration(&k, &gpu, gpu.num_sms).unwrap();
        let third = duration(&k, &gpu, gpu.num_sms / 3).unwrap();
        let ratio = third / full;
        assert!(ratio > 2.0 && ratio < 4.0, "ratio = {ratio}");
    }

    #[test]
    fn low_occupancy_kernel_is_slower_at_same_work() {
        let gpu = rtx6000();
        // Same FLOPs/bytes; only the register footprint differs. Make it
        // compute-bound so occupancy matters.
        let fast = KernelDesc::new("f", 1000, 256, 64, 0, 1e11, 1e6);
        let slow = KernelDesc::new("s", 1000, 256, 168, 0, 1e11, 1e6);
        let df = duration(&fast, &gpu, gpu.num_sms).unwrap();
        let ds = duration(&slow, &gpu, gpu.num_sms).unwrap();
        assert!(ds > df * 1.2, "df={df} ds={ds}");
    }

    #[test]
    fn memory_bound_kernel_ignores_occupancy() {
        let gpu = rtx6000();
        // Pure streaming: tiny FLOPs, big bytes.
        let a = KernelDesc::new("a", 1000, 256, 64, 0, 1e3, 1e9);
        let b = KernelDesc::new("b", 1000, 256, 168, 0, 1e3, 1e9);
        let da = duration(&a, &gpu, gpu.num_sms).unwrap();
        let db = duration(&b, &gpu, gpu.num_sms).unwrap();
        assert!((da - db).abs() / da < 0.01);
    }

    #[test]
    fn launch_overhead_floors_tiny_kernels() {
        let gpu = rtx6000();
        let tiny = KernelDesc::new("tiny", 1, 32, 32, 0, 1.0, 1.0);
        let d = duration(&tiny, &gpu, 1).unwrap();
        assert!(d >= gpu.launch_overhead);
        assert!(d < gpu.launch_overhead * 3.0);
    }

    #[test]
    fn small_grid_cannot_exceed_its_blocks() {
        let gpu = rtx6000();
        let k = KernelDesc::new("one-block", 1, 256, 32, 0, 1e6, 1e3);
        let occ = occupancy(&k, &gpu).unwrap();
        // One block resident → 8 warps of 32 → low SMOCC even though the
        // limiter would allow more.
        assert_eq!(occ.warps_per_sm, 8);
    }

    // ------------------------------------------------------------------
    // Occupancy-model boundaries: one test per limiter, pinned explicitly.
    // ------------------------------------------------------------------

    #[test]
    fn register_file_bound_kernel() {
        let gpu = rtx6000();
        // 128 regs × 256 threads = 32768 regs/block → 2 blocks by registers;
        // threads would allow 4, smem ∞, slots 16.
        let k = KernelDesc::new("regbound", 1000, 256, 128, 0, 1e9, 1e6);
        let occ = occupancy(&k, &gpu).unwrap();
        assert_eq!(occ.limiter, Limiter::Registers);
        assert_eq!(occ.blocks_per_sm, 2);
        assert!((occ.occupancy - 0.5).abs() < 1e-12);
    }

    #[test]
    fn smem_bound_kernel() {
        let gpu = rtx6000();
        // 24 KiB smem → 2 blocks by shared memory; registers would allow 8,
        // threads 8, slots 16.
        let k = KernelDesc::new("smembound", 1000, 128, 32, 24 * 1024, 1e9, 1e6);
        let occ = occupancy(&k, &gpu).unwrap();
        assert_eq!(occ.limiter, Limiter::SharedMemory);
        assert_eq!(occ.blocks_per_sm, 2);
    }

    #[test]
    fn block_slot_bound_kernel() {
        let gpu = rtx6000();
        // Tiny blocks: registers allow 128, threads 32, smem ∞ — the
        // hardware block-slot limit (16) binds first.
        let k = KernelDesc::new("slotbound", 1000, 32, 16, 0, 1e9, 1e6);
        let occ = occupancy(&k, &gpu).unwrap();
        assert_eq!(occ.limiter, Limiter::BlockSlots);
        assert_eq!(occ.blocks_per_sm, gpu.max_blocks_per_sm);
        // 16 blocks × 1 warp = 16 of 32 warp slots.
        assert!((occ.occupancy - 0.5).abs() < 1e-12);
    }

    #[test]
    fn occ_saturation_curve_flat_above_knee_proportional_below() {
        let gpu = rtx6000(); // occ_saturation = 0.40
        let time_at = |k: &KernelDesc| duration(k, &gpu, gpu.num_sms).unwrap();
        // Compute-bound so the saturation term dominates; launch overhead is
        // ~5 µs against ~60 ms of compute.
        let occ100 = KernelDesc::new("sat100", 10_000, 256, 64, 0, 1e12, 1.0);
        let occ75 = KernelDesc::new("sat75", 10_000, 256, 80, 0, 1e12, 1.0);
        let occ25 = KernelDesc::new("sat25", 10_000, 256, 168, 0, 1e12, 1.0);
        assert!((occupancy(&occ100, &gpu).unwrap().occupancy - 1.0).abs() < 1e-12);
        assert!((occupancy(&occ25, &gpu).unwrap().occupancy - 0.25).abs() < 1e-12);
        // Above the knee latency hiding is complete: 100% and 75% occupancy
        // run at identical speed.
        assert!((time_at(&occ100) - time_at(&occ75)).abs() < 1e-12);
        // Below the knee throughput degrades by occ / occ_saturation:
        // 0.25 / 0.40 → 1.6× slower.
        let ratio = time_at(&occ25) / time_at(&occ100);
        assert!((ratio - 1.6).abs() < 0.01, "ratio {ratio}");
        // Far below the knee (1 block of 2 warps per SM = 0.0625) the
        // degradation stays proportional: 0.0625 / 0.40 = 6.4×.
        let occ6 = KernelDesc::new("sat6", 10_000, 64, 64, 64 * 1024, 1e12, 1.0);
        assert!((occupancy(&occ6, &gpu).unwrap().occupancy - 0.0625).abs() < 1e-12);
        let deep = time_at(&occ6) / time_at(&occ100);
        assert!((deep - 6.4).abs() < 0.05, "deep ratio {deep}");
    }

    #[test]
    fn oversized_threads_are_a_typed_launch_error() {
        // A profile whose SM runs fewer threads than one block asks for
        // must yield `TooManyThreads`, not a zero block limit (which would
        // panic in `sms_wanted`'s div_ceil).
        let mut gpu = rtx6000();
        gpu.max_threads_per_sm = 512;
        let k = KernelDesc::new("wide", 16, 1024, 32, 0, 1e6, 1e3);
        assert!(matches!(
            occupancy(&k, &gpu),
            Err(LaunchError::TooManyThreads(..))
        ));
        assert!(sms_wanted(&k, &gpu).is_err());
        assert!(duration(&k, &gpu, gpu.num_sms).is_err());
    }

    #[test]
    fn tags_intern_by_content() {
        let a = Tag::intern("synth.decode@torch");
        let b = Tag::intern(&format!("synth.decode@{}", "torch"));
        assert_eq!(a, b);
        // Interned and static tags with the same text are equal, and the
        // interned pointer is stable (content-keyed pool).
        assert_eq!(a, Tag::from_static("synth.decode@torch"));
        assert!(std::ptr::eq(a.as_str(), b.as_str()));
        assert_eq!(a, "synth.decode@torch");
        assert_ne!(a, Tag::intern("synth.decode@tuned"));
        assert_eq!(format!("{a}"), "synth.decode@torch");
    }
}
