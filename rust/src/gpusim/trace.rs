//! Columnar monitor-trace storage and the canonical trace encoding.
//!
//! The engine records one sample per processed event, so the trace is the
//! hottest output buffer in the simulator. Storing each sample as an owned
//! struct with its own `Vec` of per-client counters (the pre-overhaul
//! layout) paid one heap allocation per event; [`Trace`] instead keeps a
//! flat row array plus one shared per-client column buffer, so recording a
//! sample is two amortized appends and no per-sample allocation.
//!
//! The canonical byte encoding (and its FNV-1a digest): exact little-endian
//! bit patterns per field, a `u64` per-client count per row, and a `u64`
//! row-count suffix. (The count moved from prefix to suffix when streaming
//! digesting landed — an incremental hasher cannot know the row count up
//! front, and every digest consumer compares run-vs-run, never against
//! bytes pinned across versions.) Two traces are byte-identical iff every
//! recorded float is bit-identical — the golden-trace determinism contract.
//! [`trace_digest`] streams rows through the hasher and never materializes
//! the canonical byte vector; [`trace_canonical_bytes`] still builds it for
//! tests, and the two are pinned equivalent by a unit test below.
//!
//! [`StreamingTrace`] is the bounded-memory recorder behind
//! [`TraceMode::Streaming`]: rows fold into the digest and into running
//! piecewise-constant aggregates ([`TraceAggregates`]) as they are
//! recorded, and only a fixed tail window stays materialized — fleet-sized
//! sweeps hold O(window) trace memory per scenario instead of O(events).

use std::ops::Deref;

/// The scalar (non-per-client) counters of one sample.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TraceRow {
    pub t: f64,
    pub gpu_smact: f32,
    pub gpu_smocc: f32,
    pub gpu_bw_frac: f32,
    pub gpu_power: f32,
    pub vram_used: u64,
    pub cpu_util: f32,
    pub dram_bw_frac: f32,
    pub cpu_power: f32,
}

/// One owned sampled point of the monitor trace (piecewise-constant until
/// the next). Construction-friendly form used by tests and external
/// producers; the engine's storage is the columnar [`Trace`].
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSample {
    pub t: f64,
    pub gpu_smact: f32,
    pub gpu_smocc: f32,
    pub gpu_bw_frac: f32,
    pub gpu_power: f32,
    pub vram_used: u64,
    pub cpu_util: f32,
    pub dram_bw_frac: f32,
    pub cpu_power: f32,
    /// Per-client (smact, smocc), indexed by ClientId.
    pub per_client: Vec<(f32, f32)>,
}

impl TraceSample {
    fn row(&self) -> TraceRow {
        TraceRow {
            t: self.t,
            gpu_smact: self.gpu_smact,
            gpu_smocc: self.gpu_smocc,
            gpu_bw_frac: self.gpu_bw_frac,
            gpu_power: self.gpu_power,
            vram_used: self.vram_used,
            cpu_util: self.cpu_util,
            dram_bw_frac: self.dram_bw_frac,
            cpu_power: self.cpu_power,
        }
    }

    /// Append this sample's canonical byte encoding to `out`.
    pub fn canonical_bytes(&self, out: &mut Vec<u8>) {
        sink_row(&self.row(), &self.per_client, out);
    }
}

/// A borrowed view of one trace row plus its per-client slice. Derefs to
/// [`TraceRow`], so scalar counters read exactly like the old owned sample
/// (`view.gpu_smact`, `view.per_client[c]`).
#[derive(Debug, Clone, Copy)]
pub struct TraceView<'a> {
    row: &'a TraceRow,
    pub per_client: &'a [(f32, f32)],
}

impl Deref for TraceView<'_> {
    type Target = TraceRow;
    fn deref(&self) -> &TraceRow {
        self.row
    }
}

impl TraceView<'_> {
    /// Materialize an owned sample (cold paths / tests).
    pub fn to_sample(&self) -> TraceSample {
        TraceSample {
            t: self.row.t,
            gpu_smact: self.row.gpu_smact,
            gpu_smocc: self.row.gpu_smocc,
            gpu_bw_frac: self.row.gpu_bw_frac,
            gpu_power: self.row.gpu_power,
            vram_used: self.row.vram_used,
            cpu_util: self.row.cpu_util,
            dram_bw_frac: self.row.dram_bw_frac,
            cpu_power: self.row.cpu_power,
            per_client: self.per_client.to_vec(),
        }
    }
}

/// Columnar trace storage: a flat row array plus one shared per-client
/// column buffer (rows index into it via end offsets, so a mid-run client
/// registration keeps every historical row's slice intact).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    rows: Vec<TraceRow>,
    per_client: Vec<(f32, f32)>,
    /// End offset of row `i`'s slice in `per_client` (start = end of `i-1`).
    pc_end: Vec<u32>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    /// Preallocate for `rows` samples of `clients` clients each.
    pub fn with_capacity(rows: usize, clients: usize) -> Trace {
        Trace {
            rows: Vec::with_capacity(rows),
            per_client: Vec::with_capacity(rows * clients),
            pc_end: Vec::with_capacity(rows),
        }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The scalar rows, contiguous (use for `windows`, `last`, etc.).
    pub fn rows(&self) -> &[TraceRow] {
        &self.rows
    }

    fn pc_range(&self, i: usize) -> (usize, usize) {
        let end = self.pc_end[i] as usize;
        let start = if i == 0 { 0 } else { self.pc_end[i - 1] as usize };
        (start, end)
    }

    /// Per-client (smact, smocc) slice of row `i`.
    pub fn per_client(&self, i: usize) -> &[(f32, f32)] {
        let (start, end) = self.pc_range(i);
        &self.per_client[start..end]
    }

    pub fn get(&self, i: usize) -> TraceView<'_> {
        TraceView {
            row: &self.rows[i],
            per_client: self.per_client(i),
        }
    }

    pub fn last(&self) -> Option<TraceView<'_>> {
        if self.is_empty() {
            None
        } else {
            Some(self.get(self.len() - 1))
        }
    }

    pub fn iter(&self) -> impl ExactSizeIterator<Item = TraceView<'_>> + '_ {
        (0..self.len()).map(move |i| self.get(i))
    }

    /// Hot-path append: push the scalar row, then fill the returned
    /// zero-initialized per-client slice in place. Amortized O(clients),
    /// no per-sample allocation.
    pub fn push_row(&mut self, row: TraceRow, clients: usize) -> &mut [(f32, f32)] {
        let start = self.per_client.len();
        let end = start + clients;
        assert!(end <= u32::MAX as usize, "trace per-client buffer overflow");
        self.rows.push(row);
        self.per_client.resize(end, (0.0, 0.0));
        self.pc_end.push(end as u32);
        &mut self.per_client[start..end]
    }

    /// Append an owned sample (test/compat path).
    pub fn push(&mut self, sample: TraceSample) {
        let slot = self.push_row(sample.row(), sample.per_client.len());
        slot.copy_from_slice(&sample.per_client);
    }

    /// Build a trace from owned samples (test/compat path).
    pub fn from_samples(samples: &[TraceSample]) -> Trace {
        let clients = samples.first().map(|s| s.per_client.len()).unwrap_or(0);
        let mut t = Trace::with_capacity(samples.len(), clients);
        for s in samples {
            t.push(s.clone());
        }
        t
    }

    /// Drop excess capacity so a drained engine doesn't pin peak memory
    /// for the rest of a long sweep.
    pub fn shrink_to_fit(&mut self) {
        self.rows.shrink_to_fit();
        self.per_client.shrink_to_fit();
        self.pc_end.shrink_to_fit();
    }

    /// Total reserved capacity in rows (diagnostics/tests).
    pub fn row_capacity(&self) -> usize {
        self.rows.capacity()
    }
}

/// Byte consumer shared by the canonical encoder and the streaming digest.
trait ByteSink {
    fn put(&mut self, bytes: &[u8]);
}

impl ByteSink for Vec<u8> {
    fn put(&mut self, bytes: &[u8]) {
        self.extend_from_slice(bytes);
    }
}

/// Incremental 64-bit FNV-1a hasher.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Fnv1a {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Fnv1a {
        Fnv1a(Self::OFFSET)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut hash = self.0;
        for &b in bytes {
            hash ^= b as u64;
            hash = hash.wrapping_mul(Self::PRIME);
        }
        self.0 = hash;
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl ByteSink for Fnv1a {
    fn put(&mut self, bytes: &[u8]) {
        self.update(bytes);
    }
}

/// Canonical encoding of one row: exact little-endian bit patterns, then a
/// `u64` per-client count and the per-client pairs.
fn sink_row(row: &TraceRow, per_client: &[(f32, f32)], out: &mut impl ByteSink) {
    out.put(&row.t.to_bits().to_le_bytes());
    out.put(&row.gpu_smact.to_bits().to_le_bytes());
    out.put(&row.gpu_smocc.to_bits().to_le_bytes());
    out.put(&row.gpu_bw_frac.to_bits().to_le_bytes());
    out.put(&row.gpu_power.to_bits().to_le_bytes());
    out.put(&row.vram_used.to_le_bytes());
    out.put(&row.cpu_util.to_bits().to_le_bytes());
    out.put(&row.dram_bw_frac.to_bits().to_le_bytes());
    out.put(&row.cpu_power.to_bits().to_le_bytes());
    out.put(&(per_client.len() as u64).to_le_bytes());
    for (act, occ) in per_client {
        out.put(&act.to_bits().to_le_bytes());
        out.put(&occ.to_bits().to_le_bytes());
    }
}

/// Canonical byte encoding of a whole trace: every row, then the `u64`
/// row-count suffix. Kept for tests and external tooling; the digest below
/// streams the same bytes without materializing this vector.
pub fn trace_canonical_bytes(trace: &Trace) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + trace.len() * 64);
    for i in 0..trace.len() {
        sink_row(&trace.rows[i], trace.per_client(i), &mut out);
    }
    out.put(&(trace.len() as u64).to_le_bytes());
    out
}

/// FNV-1a 64-bit digest over the canonical trace encoding — a compact
/// fingerprint for golden-trace tests and scenario reports. Streaming: the
/// canonical byte vector is never built.
pub fn trace_digest(trace: &Trace) -> u64 {
    let mut h = Fnv1a::new();
    for i in 0..trace.len() {
        sink_row(&trace.rows[i], trace.per_client(i), &mut h);
    }
    h.update(&(trace.len() as u64).to_le_bytes());
    h.finish()
}

/// Trace recording mode, selected at engine construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceMode {
    /// Materialize every row (the classic mode; memory grows with events).
    #[default]
    Full,
    /// Fold rows into the digest + running aggregates, keep only the last
    /// `window` rows materialized. Peak trace memory is O(window).
    Streaming { window: usize },
}

/// Default tail window for `trace_mode: streaming` when no explicit
/// `trace_window:` is configured.
pub const DEFAULT_STREAM_WINDOW: usize = 512;

/// Running piecewise-constant aggregates over a trace, accumulated row by
/// row in recording order. Folding order matches a sequential pass over a
/// full trace exactly, so for identical runs the streaming aggregates are
/// **bit-identical** to [`TraceAggregates::from_trace`] on the materialized
/// trace (asserted by engine and equivalence tests).
///
/// Semantics: the trace is piecewise-constant — row `i`'s values hold from
/// `t[i]` until `t[i+1]`. Energies are exact rectangle integrals of power
/// over that step function; busy-weighted SM means use the same
/// `gpu_smact > 1e-6 && dt > 0` gate as the monitor's busy statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TraceAggregates {
    pub rows: u64,
    pub t_start: f64,
    pub t_end: f64,
    /// Total time with the GPU busy (`gpu_smact > 1e-6`).
    pub busy_time: f64,
    busy_smact_int: f64,
    busy_smocc_int: f64,
    /// ∫ gpu_power dt over the whole trace span (joules).
    pub gpu_energy_j: f64,
    /// ∫ cpu_power dt over the whole trace span (joules).
    pub cpu_energy_j: f64,
    pub peak_vram: u64,
    pub peak_gpu_power: f32,
    pub peak_cpu_power: f32,
}

impl TraceAggregates {
    /// Fold one row, given the previously recorded row (None for the
    /// first). The `prev` row's values held over `[prev.t, row.t)`.
    pub fn observe(&mut self, prev: Option<&TraceRow>, row: &TraceRow) {
        if self.rows == 0 {
            self.t_start = row.t;
        }
        self.rows += 1;
        self.t_end = row.t;
        if let Some(p) = prev {
            let dt = row.t - p.t;
            if dt > 0.0 {
                self.gpu_energy_j += p.gpu_power as f64 * dt;
                self.cpu_energy_j += p.cpu_power as f64 * dt;
                if p.gpu_smact > 1e-6 {
                    self.busy_time += dt;
                    self.busy_smact_int += p.gpu_smact as f64 * dt;
                    self.busy_smocc_int += p.gpu_smocc as f64 * dt;
                }
            }
        }
        self.peak_vram = self.peak_vram.max(row.vram_used);
        self.peak_gpu_power = self.peak_gpu_power.max(row.gpu_power);
        self.peak_cpu_power = self.peak_cpu_power.max(row.cpu_power);
    }

    /// Aggregates of a fully materialized trace (one sequential pass, same
    /// fold order as streaming recording).
    pub fn from_trace(trace: &Trace) -> TraceAggregates {
        let mut agg = TraceAggregates::default();
        let rows = trace.rows();
        for i in 0..rows.len() {
            let prev = if i == 0 { None } else { Some(&rows[i - 1]) };
            agg.observe(prev, &rows[i]);
        }
        agg
    }

    /// Recorded span in virtual seconds.
    pub fn span(&self) -> f64 {
        (self.t_end - self.t_start).max(0.0)
    }

    /// Time-weighted mean SMACT over busy time (0 if never busy).
    pub fn mean_busy_smact(&self) -> f64 {
        if self.busy_time > 0.0 {
            self.busy_smact_int / self.busy_time
        } else {
            0.0
        }
    }

    /// Time-weighted mean SMOCC over busy time (0 if never busy).
    pub fn mean_busy_smocc(&self) -> f64 {
        if self.busy_time > 0.0 {
            self.busy_smocc_int / self.busy_time
        } else {
            0.0
        }
    }
}

/// Bounded-memory trace recorder ([`TraceMode::Streaming`]).
///
/// Every recorded row is folded into the FNV digest (identical to
/// [`trace_digest`] over the equivalent full trace) and into
/// [`TraceAggregates`]; only the last `window` rows stay materialized, in
/// a ring. Peak memory is O(window × clients), independent of run length —
/// verified by the bounded-allocation test in `tests/queue_equivalence.rs`.
#[derive(Debug, Clone)]
pub struct StreamingTrace {
    window: usize,
    hasher: Fnv1a,
    rows_recorded: u64,
    agg: TraceAggregates,
    prev: Option<TraceRow>,
    // Tail ring: rows + per-client pairs, evicted front-first at `window`.
    ring_rows: std::collections::VecDeque<TraceRow>,
    ring_counts: std::collections::VecDeque<u32>,
    ring_pc: std::collections::VecDeque<(f32, f32)>,
}

impl StreamingTrace {
    pub fn new(window: usize) -> StreamingTrace {
        assert!(window >= 1, "streaming window must be >= 1");
        StreamingTrace {
            window,
            hasher: Fnv1a::new(),
            rows_recorded: 0,
            agg: TraceAggregates::default(),
            prev: None,
            ring_rows: std::collections::VecDeque::with_capacity(window),
            ring_counts: std::collections::VecDeque::with_capacity(window),
            ring_pc: std::collections::VecDeque::new(),
        }
    }

    /// Fold one row: digest, aggregates, tail ring.
    pub fn record(&mut self, row: &TraceRow, per_client: &[(f32, f32)]) {
        sink_row(row, per_client, &mut self.hasher);
        self.agg.observe(self.prev.as_ref(), row);
        self.prev = Some(*row);
        self.rows_recorded += 1;
        if self.ring_rows.len() == self.window {
            self.ring_rows.pop_front();
            let n = self.ring_counts.pop_front().expect("ring count underflow");
            self.ring_pc.drain(..n as usize);
        }
        self.ring_rows.push_back(*row);
        self.ring_counts.push_back(per_client.len() as u32);
        self.ring_pc.extend(per_client.iter().copied());
    }

    /// Digest of everything recorded so far — equal to [`trace_digest`] of
    /// the full trace an identical `TraceMode::Full` run would have
    /// materialized.
    pub fn digest(&self) -> u64 {
        let mut h = self.hasher.clone();
        h.update(&self.rows_recorded.to_le_bytes());
        h.finish()
    }

    pub fn rows_recorded(&self) -> u64 {
        self.rows_recorded
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Rows currently materialized in the tail ring (≤ window).
    pub fn tail_len(&self) -> usize {
        self.ring_rows.len()
    }

    /// Reserved ring capacity in rows — bounded by O(window) regardless of
    /// how many rows were recorded (the bounded-allocation test's probe).
    pub fn ring_row_capacity(&self) -> usize {
        self.ring_rows.capacity().max(self.ring_counts.capacity())
    }

    pub fn aggregates(&self) -> &TraceAggregates {
        &self.agg
    }

    /// Materialize the tail window as a [`Trace`], draining the ring (the
    /// digest, row count, and aggregates remain queryable). Cold path.
    pub fn take_tail(&mut self) -> Trace {
        let mut t = Trace::with_capacity(self.ring_rows.len(), 0);
        for (row, n) in self.ring_rows.drain(..).zip(self.ring_counts.drain(..)) {
            let slot = t.push_row(row, n as usize);
            for e in slot.iter_mut() {
                *e = self.ring_pc.pop_front().expect("ring pc underflow");
            }
        }
        debug_assert!(self.ring_pc.is_empty());
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: f64, clients: usize) -> TraceSample {
        TraceSample {
            t,
            gpu_smact: 0.5,
            gpu_smocc: 0.25,
            gpu_bw_frac: 0.1,
            gpu_power: 120.0,
            vram_used: 1 << 30,
            cpu_util: 0.3,
            dram_bw_frac: 0.05,
            cpu_power: 40.0,
            per_client: (0..clients).map(|i| (i as f32 * 0.1, i as f32 * 0.05)).collect(),
        }
    }

    #[test]
    fn push_row_and_push_sample_agree() {
        let s0 = sample(0.0, 3);
        let s1 = sample(1.0, 3);
        let mut a = Trace::new();
        a.push(s0.clone());
        a.push(s1.clone());
        let mut b = Trace::new();
        for s in [&s0, &s1] {
            let slot = b.push_row(s.row(), s.per_client.len());
            slot.copy_from_slice(&s.per_client);
        }
        assert_eq!(trace_canonical_bytes(&a), trace_canonical_bytes(&b));
        assert_eq!(a.get(1).to_sample(), s1);
    }

    #[test]
    fn streaming_digest_matches_canonical_bytes() {
        let trace = Trace::from_samples(&[sample(0.0, 2), sample(0.5, 2), sample(1.0, 2)]);
        let mut h = Fnv1a::new();
        h.update(&trace_canonical_bytes(&trace));
        assert_eq!(
            trace_digest(&trace),
            h.finish(),
            "streaming digest must equal FNV-1a over the canonical byte vector"
        );
    }

    #[test]
    fn digest_sensitive_to_every_field() {
        let base = Trace::from_samples(&[sample(0.0, 2)]);
        let d0 = trace_digest(&base);
        let mut s = sample(0.0, 2);
        s.per_client[1].1 += 1e-6;
        assert_ne!(d0, trace_digest(&Trace::from_samples(&[s])));
        let mut s = sample(0.0, 2);
        s.vram_used += 1;
        assert_ne!(d0, trace_digest(&Trace::from_samples(&[s])));
    }

    #[test]
    fn variable_client_counts_keep_slices_intact() {
        let mut t = Trace::new();
        t.push(sample(0.0, 1));
        t.push(sample(1.0, 3));
        assert_eq!(t.per_client(0).len(), 1);
        assert_eq!(t.per_client(1).len(), 3);
        assert_eq!(t.get(1).per_client[2], (0.2, 0.1));
    }

    #[test]
    fn views_deref_to_scalar_counters() {
        let t = Trace::from_samples(&[sample(2.5, 0)]);
        let v = t.last().unwrap();
        assert_eq!(v.t, 2.5);
        assert!(v.gpu_smact > 0.49);
        assert!(t.iter().any(|s| s.cpu_util > 0.2));
    }

    #[test]
    fn shrink_to_fit_right_sizes() {
        let mut t = Trace::with_capacity(1024, 4);
        t.push(sample(0.0, 4));
        assert!(t.row_capacity() >= 1024);
        t.shrink_to_fit();
        assert!(t.row_capacity() < 1024);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn empty_trace_encodes_as_count_suffix() {
        let t = Trace::new();
        assert_eq!(trace_canonical_bytes(&t), 0u64.to_le_bytes().to_vec());
        let mut h = Fnv1a::new();
        h.update(&0u64.to_le_bytes());
        assert_eq!(trace_digest(&t), h.finish());
    }

    #[test]
    fn streaming_recorder_matches_full_trace_digest_and_keeps_window() {
        let samples: Vec<TraceSample> =
            (0..50).map(|i| sample(i as f64 * 0.1, 2)).collect();
        let full = Trace::from_samples(&samples);
        let mut st = StreamingTrace::new(4);
        for s in &samples {
            st.record(&s.row(), &s.per_client);
        }
        assert_eq!(st.digest(), trace_digest(&full));
        assert_eq!(st.rows_recorded(), 50);
        assert_eq!(st.tail_len(), 4);
        assert!(st.ring_row_capacity() <= 16, "ring must stay O(window)");
        // Aggregates are bit-identical to a post-hoc pass.
        assert_eq!(*st.aggregates(), TraceAggregates::from_trace(&full));
        // The tail materializes the last `window` rows verbatim.
        let tail = st.take_tail();
        assert_eq!(tail.len(), 4);
        assert_eq!(tail.rows()[0].t.to_bits(), full.rows()[46].t.to_bits());
        assert_eq!(tail.per_client(3), full.per_client(49));
        // Digest/aggregates survive draining the tail.
        assert_eq!(st.digest(), trace_digest(&full));
    }

    #[test]
    fn aggregates_integrate_piecewise_constant_power() {
        // Two steps: 100 W for 1 s, then 50 W for 2 s, final row closes the
        // span (its own values hold zero width).
        let mk = |t: f64, gpu_w: f32, smact: f32| TraceSample {
            t,
            gpu_smact: smact,
            gpu_smocc: smact * 0.5,
            gpu_bw_frac: 0.0,
            gpu_power: gpu_w,
            vram_used: (t * 1e9) as u64,
            cpu_util: 0.0,
            dram_bw_frac: 0.0,
            cpu_power: 10.0,
            per_client: Vec::new(),
        };
        let trace = Trace::from_samples(&[
            mk(0.0, 100.0, 0.8),
            mk(1.0, 50.0, 0.4),
            mk(3.0, 0.0, 0.0),
        ]);
        let a = TraceAggregates::from_trace(&trace);
        assert_eq!(a.rows, 3);
        assert!((a.span() - 3.0).abs() < 1e-12);
        assert!((a.gpu_energy_j - (100.0 + 2.0 * 50.0)).abs() < 1e-9);
        assert!((a.cpu_energy_j - 30.0).abs() < 1e-9);
        assert!((a.busy_time - 3.0).abs() < 1e-12);
        // Busy-weighted mean SMACT: (0.8·1 + 0.4·2) / 3.
        assert!((a.mean_busy_smact() - 1.6 / 3.0).abs() < 1e-9);
        assert_eq!(a.peak_vram, 3_000_000_000);
        assert_eq!(a.peak_gpu_power, 100.0);
        // Duplicate-timestamp rows are zero-width: they change nothing but
        // peaks.
        let mut dup = TraceAggregates::default();
        let r0 = mk(0.0, 100.0, 0.8).row();
        let r0b = mk(0.0, 500.0, 0.1).row();
        dup.observe(None, &r0);
        dup.observe(Some(&r0), &r0b);
        assert_eq!(dup.gpu_energy_j, 0.0);
        assert_eq!(dup.peak_gpu_power, 500.0);
    }
}
