//! Board/package power models (NVML and RAPL analogues).
//!
//! The paper observes (Fig. 8) that applications with very different SMOCC
//! still reach similar *peak* board power, because reserving SMs (SMACT)
//! already gates most of the dynamic power (clock/issue activity), while
//! occupancy and DRAM traffic contribute smaller shares. The weights below
//! encode that: SMACT-dominant, with SMOCC and bandwidth terms.

use crate::gpusim::profiles::{CpuProfile, GpuProfile};

/// Weight of SM reservation (SMACT) in GPU dynamic power.
pub const W_SMACT: f64 = 0.50;
/// Weight of SM occupancy (SMOCC) in GPU dynamic power.
pub const W_SMOCC: f64 = 0.35;
/// Weight of memory bandwidth utilization in GPU dynamic power.
pub const W_BW: f64 = 0.15;

/// Instantaneous GPU board power given utilization fractions in [0, 1].
pub fn gpu_power(gpu: &GpuProfile, smact: f64, smocc: f64, bw_frac: f64) -> f64 {
    let activity = (W_SMACT * smact + W_SMOCC * smocc + W_BW * bw_frac).clamp(0.0, 1.0);
    gpu.idle_power + (gpu.max_power - gpu.idle_power) * activity
}

/// Instantaneous CPU package power (RAPL analogue) given core utilization
/// and DRAM bandwidth fraction.
pub fn cpu_power(cpu: &CpuProfile, core_util: f64, dram_frac: f64) -> f64 {
    let activity = (0.85 * core_util + 0.15 * dram_frac).clamp(0.0, 1.0);
    cpu.idle_power + (cpu.max_power - cpu.idle_power) * activity
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::profiles::{rtx6000, xeon6126};

    #[test]
    fn idle_at_zero_activity() {
        let g = rtx6000();
        assert_eq!(gpu_power(&g, 0.0, 0.0, 0.0), g.idle_power);
        let c = xeon6126();
        assert_eq!(cpu_power(&c, 0.0, 0.0), c.idle_power);
    }

    #[test]
    fn max_at_full_activity() {
        let g = rtx6000();
        assert!((gpu_power(&g, 1.0, 1.0, 1.0) - g.max_power).abs() < 1e-9);
    }

    #[test]
    fn smact_dominates_smocc() {
        // The paper's observation: full SMACT at low SMOCC is already most
        // of peak power; two apps with SMOCC 0.7 vs 0.15 at SMACT 1.0 differ
        // by well under 2x.
        let g = rtx6000();
        let hi = gpu_power(&g, 1.0, 0.7, 0.5);
        let lo = gpu_power(&g, 1.0, 0.15, 0.3);
        assert!(hi / lo < 1.5, "hi={hi} lo={lo}");
        assert!(lo > 0.5 * g.max_power);
    }

    #[test]
    fn power_monotone_in_each_term() {
        let g = rtx6000();
        assert!(gpu_power(&g, 0.5, 0.2, 0.2) < gpu_power(&g, 0.9, 0.2, 0.2));
        assert!(gpu_power(&g, 0.5, 0.2, 0.2) < gpu_power(&g, 0.5, 0.6, 0.2));
        assert!(gpu_power(&g, 0.5, 0.2, 0.2) < gpu_power(&g, 0.5, 0.2, 0.9));
    }

    #[test]
    fn cpu_cheaper_than_gpu_at_full_load() {
        // Appendix B.2: CPU execution draws significantly less power.
        assert!(cpu_power(&xeon6126(), 1.0, 1.0) < gpu_power(&rtx6000(), 1.0, 1.0, 1.0));
    }
}
