//! Deterministic chaos injection: seed-derived fault schedules in virtual
//! time (the ROADMAP's "deterministic chaos" item).
//!
//! The paper observes its findings under *clean* conditions; real end-user
//! devices additionally throttle, suspend, spike VRAM, and crash their
//! model servers. The engine is a deterministic discrete-event simulator,
//! so faults can be injected FoundationDB-style: a [`FaultSchedule`] is a
//! pure function of `(ChaosConfig, seed)` — xorshift64* off the scenario
//! seed, every timestamp in virtual time — and each fault is applied
//! through a host job under a dedicated `chaos` client, so faults are
//! engine events like any other: they land in the trace and therefore in
//! the golden digest, and the same seed replays byte-identically across
//! `--jobs 1/N` and repeats.
//!
//! Fault vocabulary ([`ChaosKind`]):
//! * `thermal_throttle` — a clock-cap factor applied to newly launched GPU
//!   kernels for a window (resident kernels keep their completion times,
//!   like a real DVFS step that doesn't retro-time in-flight work).
//! * `vram_ballast` — a transient allocation pinning a fraction of VRAM,
//!   forcing OOM pressure on `VramAllocator` for a window.
//! * `suspend` — a virtual-time freeze of new GPU launches (device
//!   suspend/resume); CPU work keeps running, as on a discrete GPU that
//!   drops off the bus.
//! * `server_crash` — the shared inference server drops its in-flight
//!   unified batch, re-enqueues occupied slots' requests, frees its VRAM,
//!   and re-runs `start()` (weights reload on restart).
//! * `pcie_degrade` — scales the KV-migration DMA bandwidth for a window
//!   (link retraining / contention).

use crate::util::rng::Rng;

/// Minimum virtual-time gap enforced between consecutive fault episodes so
/// jittered windows can never overlap (overlap would tear start/end pairing).
const MIN_GAP: f64 = 1e-6;

/// Which fault class a chaos schedule injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChaosKind {
    /// Clock-cap windows: new GPU launches run at `intensity`× clock.
    ThermalThrottle,
    /// Transient VRAM pin of `intensity` × capacity for a window.
    VramBallast,
    /// Device suspend/resume: no new GPU launches inside the window.
    Suspend,
    /// Shared-server crash + restart mid-batch (point event).
    ServerCrash,
    /// KV-migration DMA bandwidth scaled by `intensity` for a window.
    PcieDegrade,
}

/// Stable key for a chaos kind in YAML configs, scenario names, and reports.
pub fn chaos_key(k: ChaosKind) -> &'static str {
    k.key()
}

impl ChaosKind {
    pub const ALL: [ChaosKind; 5] = [
        ChaosKind::ThermalThrottle,
        ChaosKind::VramBallast,
        ChaosKind::Suspend,
        ChaosKind::ServerCrash,
        ChaosKind::PcieDegrade,
    ];

    pub fn key(self) -> &'static str {
        match self {
            ChaosKind::ThermalThrottle => "thermal_throttle",
            ChaosKind::VramBallast => "vram_ballast",
            ChaosKind::Suspend => "suspend",
            ChaosKind::ServerCrash => "server_crash",
            ChaosKind::PcieDegrade => "pcie_degrade",
        }
    }

    /// Parse a YAML / CLI spelling.
    pub fn parse(s: &str) -> Option<ChaosKind> {
        match s.to_ascii_lowercase().replace(['-', ' ', '.'], "_").as_str() {
            "thermal_throttle" | "throttle" | "thermal" => Some(ChaosKind::ThermalThrottle),
            "vram_ballast" | "ballast" | "vram" => Some(ChaosKind::VramBallast),
            "suspend" | "suspend_resume" | "sleep" => Some(ChaosKind::Suspend),
            "server_crash" | "crash" => Some(ChaosKind::ServerCrash),
            "pcie_degrade" | "pcie" => Some(ChaosKind::PcieDegrade),
            _ => None,
        }
    }

    /// Windowed faults emit start/end pairs; `server_crash` is a point event.
    pub fn windowed(self) -> bool {
        !matches!(self, ChaosKind::ServerCrash)
    }

    /// Whether `intensity` means anything for this kind.
    pub fn uses_intensity(self) -> bool {
        matches!(
            self,
            ChaosKind::ThermalThrottle | ChaosKind::VramBallast | ChaosKind::PcieDegrade
        )
    }
}

impl std::fmt::Display for ChaosKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

/// Parameters of a chaos schedule. All times are virtual seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    pub kind: ChaosKind,
    /// Nominal time of the first episode.
    pub start: f64,
    /// Nominal spacing between episodes.
    pub period: f64,
    /// Number of episodes.
    pub count: usize,
    /// Window length of each episode (windowed kinds only).
    pub duration: f64,
    /// Kind-specific strength: clock-cap factor (throttle), fraction of
    /// VRAM capacity (ballast), DMA bandwidth scale (pcie). In (0, 1].
    pub intensity: f64,
    /// Uniform jitter on each episode's start, as a fraction of `period`
    /// (an episode lands in `base ± jitter·period`). In [0, 1).
    pub jitter: f64,
}

impl ChaosConfig {
    /// The curated per-kind defaults used by the scenario matrix: episodes
    /// land inside the first ~25 virtual seconds, where every default-mix
    /// scenario still has work in flight.
    pub fn curated(kind: ChaosKind) -> ChaosConfig {
        let (start, period, count, duration, intensity) = match kind {
            ChaosKind::ThermalThrottle => (1.0, 6.0, 4, 5.0, 0.35),
            ChaosKind::VramBallast => (1.0, 5.0, 4, 3.0, 0.35),
            ChaosKind::Suspend => (1.5, 6.0, 3, 1.0, 0.0),
            ChaosKind::ServerCrash => (2.0, 8.0, 3, 0.0, 0.0),
            ChaosKind::PcieDegrade => (1.0, 6.0, 3, 4.0, 0.1),
        };
        ChaosConfig {
            kind,
            start,
            period,
            count,
            duration,
            intensity,
            jitter: 0.25,
        }
    }

    /// Validate parameter ranges; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if !self.start.is_finite() || self.start < 0.0 {
            return Err(format!("chaos start must be >= 0, got {}", self.start));
        }
        if self.count == 0 {
            return Err("chaos count must be >= 1".into());
        }
        if !self.period.is_finite() || self.period <= 0.0 {
            return Err(format!("chaos period must be > 0, got {}", self.period));
        }
        if !(0.0..1.0).contains(&self.jitter) {
            return Err(format!("chaos jitter must be in [0, 1), got {}", self.jitter));
        }
        if self.kind.windowed() {
            if !self.duration.is_finite() || self.duration <= 0.0 {
                return Err(format!(
                    "chaos duration must be > 0 for {}, got {}",
                    self.kind, self.duration
                ));
            }
            if self.count > 1 && self.duration >= self.period {
                return Err(format!(
                    "chaos duration ({}) must be < period ({}) for repeated {} windows",
                    self.duration, self.period, self.kind
                ));
            }
        }
        if self.kind.uses_intensity() && !(self.intensity > 0.0 && self.intensity <= 1.0) {
            return Err(format!(
                "chaos intensity must be in (0, 1] for {}, got {}",
                self.kind, self.intensity
            ));
        }
        Ok(())
    }

    /// Render the YAML `chaos:` block this config corresponds to, so dumped
    /// scenario configs are self-describing and re-runnable.
    pub fn to_yaml(&self) -> String {
        let mut out = String::new();
        out.push_str("chaos:\n");
        out.push_str(&format!("  kind: {}\n", self.kind.key()));
        out.push_str(&format!("  start: {}\n", self.start));
        out.push_str(&format!("  period: {}\n", self.period));
        out.push_str(&format!("  count: {}\n", self.count));
        if self.kind.windowed() {
            out.push_str(&format!("  duration: {}\n", self.duration));
        }
        if self.kind.uses_intensity() {
            out.push_str(&format!("  intensity: {}\n", self.intensity));
        }
        out.push_str(&format!("  jitter: {}\n", self.jitter));
        out
    }
}

/// One fault transition to apply at a virtual timestamp.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// Cap the GPU clock: new launches run at `factor`× speed.
    ThrottleStart { factor: f64 },
    ThrottleEnd,
    /// Pin `frac` of VRAM capacity under the chaos client.
    BallastStart { frac: f64 },
    BallastEnd,
    SuspendStart,
    SuspendEnd,
    ServerCrash,
    /// Scale KV-migration DMA bandwidth by `scale`.
    PcieDegradeStart { scale: f64 },
    PcieDegradeEnd,
}

impl FaultAction {
    /// Trace-visible phase tag for the fault's host job.
    pub fn tag(&self) -> &'static str {
        match self {
            FaultAction::ThrottleStart { .. } => "chaos.throttle.start",
            FaultAction::ThrottleEnd => "chaos.throttle.end",
            FaultAction::BallastStart { .. } => "chaos.ballast.start",
            FaultAction::BallastEnd => "chaos.ballast.end",
            FaultAction::SuspendStart => "chaos.suspend",
            FaultAction::SuspendEnd => "chaos.resume",
            FaultAction::ServerCrash => "chaos.server_crash",
            FaultAction::PcieDegradeStart { .. } => "chaos.pcie.start",
            FaultAction::PcieDegradeEnd => "chaos.pcie.end",
        }
    }
}

/// A fault transition at a virtual time; `episode` indexes the originating
/// episode (ballast allocations are labelled per-episode with it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: f64,
    pub episode: usize,
    pub action: FaultAction,
}

/// The expanded, time-ordered fault schedule for one scenario run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    pub events: Vec<FaultEvent>,
}

impl FaultSchedule {
    /// Expand a config into concrete fault events. Pure function of
    /// `(cfg, seed)`: the jitter stream is a dedicated xorshift64*
    /// generator keyed off the scenario seed and the fault kind, so the
    /// schedule never perturbs (or is perturbed by) workload synthesis.
    /// Episodes are clamped to be non-overlapping and strictly ordered, so
    /// windowed start/end pairs can never interleave.
    pub fn generate(cfg: &ChaosConfig, seed: u64) -> FaultSchedule {
        let mix = (cfg.kind as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed ^ 0xC7A0_5EED_D15E_A5E5 ^ mix);
        let mut events = Vec::with_capacity(cfg.count * 2);
        let mut cursor = 0.0_f64;
        for episode in 0..cfg.count {
            let base = cfg.start + episode as f64 * cfg.period;
            let offset = (rng.next_f64() * 2.0 - 1.0) * cfg.jitter * cfg.period;
            let at = (base + offset).max(0.0).max(cursor);
            match cfg.kind {
                ChaosKind::ServerCrash => {
                    events.push(FaultEvent {
                        at,
                        episode,
                        action: FaultAction::ServerCrash,
                    });
                    cursor = at + MIN_GAP;
                }
                kind => {
                    let (start, end) = match kind {
                        ChaosKind::ThermalThrottle => (
                            FaultAction::ThrottleStart {
                                factor: cfg.intensity,
                            },
                            FaultAction::ThrottleEnd,
                        ),
                        ChaosKind::VramBallast => (
                            FaultAction::BallastStart {
                                frac: cfg.intensity,
                            },
                            FaultAction::BallastEnd,
                        ),
                        ChaosKind::Suspend => (FaultAction::SuspendStart, FaultAction::SuspendEnd),
                        ChaosKind::PcieDegrade => (
                            FaultAction::PcieDegradeStart {
                                scale: cfg.intensity,
                            },
                            FaultAction::PcieDegradeEnd,
                        ),
                        ChaosKind::ServerCrash => unreachable!(),
                    };
                    events.push(FaultEvent {
                        at,
                        episode,
                        action: start,
                    });
                    events.push(FaultEvent {
                        at: at + cfg.duration,
                        episode,
                        action: end,
                    });
                    cursor = at + cfg.duration + MIN_GAP;
                }
            }
        }
        debug_assert!(events.windows(2).all(|w| w[0].at <= w[1].at));
        FaultSchedule { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_parse_roundtrip() {
        for &k in &ChaosKind::ALL {
            assert_eq!(ChaosKind::parse(k.key()), Some(k));
            assert_eq!(format!("{k}"), k.key());
        }
        assert_eq!(ChaosKind::parse("Thermal-Throttle"), Some(ChaosKind::ThermalThrottle));
        assert_eq!(ChaosKind::parse("nonsense"), None);
    }

    #[test]
    fn curated_configs_validate() {
        for &k in &ChaosKind::ALL {
            let cfg = ChaosConfig::curated(k);
            cfg.validate().unwrap();
            assert!(!cfg.to_yaml().is_empty());
        }
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        let base = ChaosConfig::curated(ChaosKind::ThermalThrottle);
        for bad in [
            ChaosConfig { start: -1.0, ..base.clone() },
            ChaosConfig { count: 0, ..base.clone() },
            ChaosConfig { period: 0.0, ..base.clone() },
            ChaosConfig { jitter: 1.0, ..base.clone() },
            ChaosConfig { duration: 0.0, ..base.clone() },
            ChaosConfig { duration: base.period, ..base.clone() },
            ChaosConfig { intensity: 0.0, ..base.clone() },
            ChaosConfig { intensity: 1.5, ..base.clone() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn schedule_is_a_pure_function_of_config_and_seed() {
        for &k in &ChaosKind::ALL {
            let cfg = ChaosConfig::curated(k);
            let a = FaultSchedule::generate(&cfg, 42);
            let b = FaultSchedule::generate(&cfg, 42);
            assert_eq!(a, b, "{k}: same seed must reproduce the schedule");
            let c = FaultSchedule::generate(&cfg, 43);
            assert_ne!(a, c, "{k}: a different seed must move the jittered episodes");
        }
    }

    #[test]
    fn windowed_schedules_pair_and_never_overlap() {
        for &k in &ChaosKind::ALL {
            let cfg = ChaosConfig::curated(k);
            let s = FaultSchedule::generate(&cfg, 7);
            assert!(s.events.windows(2).all(|w| w[0].at <= w[1].at), "{k}: unordered");
            if k.windowed() {
                assert_eq!(s.events.len(), cfg.count * 2);
                for pair in s.events.chunks(2) {
                    assert_eq!(pair[0].episode, pair[1].episode);
                    assert!(
                        (pair[1].at - pair[0].at - cfg.duration).abs() < 1e-9,
                        "{k}: window length"
                    );
                }
                // Strict ordering between episodes: end_i < start_{i+1}.
                for w in s.events.chunks(2).collect::<Vec<_>>().windows(2) {
                    assert!(w[0][1].at < w[1][0].at, "{k}: windows overlap");
                }
            } else {
                assert_eq!(s.events.len(), cfg.count);
            }
            assert!(s.events.iter().all(|e| e.at >= 0.0));
        }
    }

    #[test]
    fn jitter_zero_lands_on_the_nominal_grid() {
        let cfg = ChaosConfig {
            jitter: 0.0,
            ..ChaosConfig::curated(ChaosKind::ServerCrash)
        };
        let s = FaultSchedule::generate(&cfg, 99);
        for (i, e) in s.events.iter().enumerate() {
            assert!((e.at - (cfg.start + i as f64 * cfg.period)).abs() < 1e-9);
        }
    }
}
