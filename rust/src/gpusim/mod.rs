//! The simulated end-user testbed (substrate).
//!
//! The paper runs on physical hardware (RTX 6000 + Xeon server, MacBook M1
//! Pro). This environment has neither, so — per the substitution rule in
//! DESIGN.md §2 — the device is rebuilt as a deterministic discrete-event
//! simulator that models exactly the mechanisms the paper's findings rest
//! on: SM occupancy limited by per-thread resources, FIFO kernel arbitration
//! with launch-ahead streams, static MPS-style partitions, VRAM capacity
//! pressure, and NVML/RAPL-style power.
//!
//! Layout:
//! * [`profiles`] — calibrated architectural constants per device.
//! * [`kernel`]   — kernel descriptors + CUDA-style occupancy model.
//! * [`backend`]  — pluggable kernel implementations (launch-shape tables).
//! * [`policy`]   — greedy / partition / fair-share SM arbitration.
//! * [`engine`]   — the event-driven executor.
//! * [`queue`]    — pluggable event-queue backends (heap / timer wheel).
//! * [`trace`]    — columnar monitor-trace storage + canonical encoding.
//! * [`vram`]     — capacity-enforcing device-memory allocator.
//! * [`power`]    — board/package power models.
//! * [`chaos`]    — seed-derived fault schedules (deterministic chaos).

pub mod backend;
pub mod chaos;
pub mod engine;
pub mod kernel;
pub mod policy;
pub mod power;
pub mod profiles;
pub mod queue;
pub mod trace;
pub mod vram;

pub use backend::KernelBackend;
pub use chaos::{chaos_key, ChaosConfig, ChaosKind, FaultAction, FaultEvent, FaultSchedule};
pub use engine::{
    BudgetExhausted, ClientId, CpuWork, Engine, EngineError, EngineOptions, JobId, JobResult,
    JobSpec, MemOp, Phase,
};
pub use kernel::{Device, KernelDesc, Tag};
pub use policy::Policy;
pub use profiles::Testbed;
pub use queue::{EventQueue, QueueBackend};
pub use trace::{
    StreamingTrace, Trace, TraceAggregates, TraceMode, TraceRow, TraceSample, TraceView,
};
