//! Event-queue backends for the engine's hot loop.
//!
//! The engine drains one [`Event`] at a time in (time, insertion-seq)
//! order. That order *is* the determinism contract: every trace row, every
//! golden digest, and every budget-exhaustion stopping point is a pure
//! function of it. This module makes the queue pluggable behind
//! [`EventQueue`] so the classic binary heap ([`HeapQueue`]) and a
//! hierarchical timer wheel ([`TimerWheelQueue`]) are interchangeable at
//! construction time — and pins them byte-identical to each other with the
//! property tests in `tests/queue_equivalence.rs`.
//!
//! Why a wheel: the heap pays `O(log n)` pointer-chasing sifts per push and
//! pop. The wheel buckets events by a fixed time quantum into a hierarchy
//! of 64-slot levels (a calendar queue with power-of-two cascading), so
//! push and pop are `O(1)` amortized, with an unbounded `overflow` list as
//! the calendar-queue fallback for events beyond the wheel horizon
//! (~`2^48` ticks ≈ 3×10⁷ virtual seconds — far past the engine's default
//! virtual-time budget).
//!
//! The wheel keeps an **eager-advance invariant**: whenever the queue is
//! non-empty, the earliest batch of events has already been cascaded down
//! into a sorted `current` buffer. That makes `peek_time` a shared-borrow
//! `O(1)` accessor (the engine's `next_event_time(&self)` signature never
//! changed), and it concentrates all cascade work at batch boundaries.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::gpusim::engine::JobId;

/// What a pending event does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    PhaseBegin,
    KernelDone,
    CpuDone,
}

/// One pending engine event. Ordered by `(time, seq)`: earlier virtual time
/// first, ties broken by insertion order — the tie-break every backend must
/// reproduce exactly.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time: f64,
    pub seq: u64,
    pub kind: EventKind,
    pub job: JobId,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reverse: earlier time first, then insertion order.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then(other.seq.cmp(&self.seq))
    }
}

/// `a` strictly precedes `b` in pop order.
#[inline]
fn precedes(a: &Event, b: &Event) -> bool {
    match a.time.partial_cmp(&b.time).expect("NaN event time") {
        Ordering::Less => true,
        Ordering::Greater => false,
        Ordering::Equal => a.seq < b.seq,
    }
}

/// Selects the [`EventQueue`] implementation at engine construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueBackend {
    /// `BinaryHeap<Event>` — the reference implementation.
    #[default]
    Heap,
    /// Hierarchical timer wheel with calendar-queue overflow.
    Wheel,
}

impl QueueBackend {
    pub const ALL: [QueueBackend; 2] = [QueueBackend::Heap, QueueBackend::Wheel];

    /// Canonical config/CLI key.
    pub fn key(&self) -> &'static str {
        match self {
            QueueBackend::Heap => "heap",
            QueueBackend::Wheel => "wheel",
        }
    }

    /// Parse a config/CLI key (`heap` | `wheel`).
    pub fn parse(s: &str) -> Option<QueueBackend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "heap" | "binary_heap" => Some(QueueBackend::Heap),
            "wheel" | "timer_wheel" => Some(QueueBackend::Wheel),
            _ => None,
        }
    }

    /// Construct the backend, pre-sized for roughly `capacity` pending
    /// events.
    pub fn make(self, capacity: usize) -> Box<dyn EventQueue + Send> {
        match self {
            QueueBackend::Heap => Box::new(HeapQueue::with_capacity(capacity)),
            QueueBackend::Wheel => Box::new(TimerWheelQueue::with_capacity(capacity)),
        }
    }
}

/// A priority queue of engine events, popped in exact `(time, seq)` order.
///
/// Contract (checked by `tests/queue_equivalence.rs`): for any interleaving
/// of pushes and pops, the pop sequence is identical across all backends —
/// including same-timestamp ties, which must come out in insertion order.
pub trait EventQueue {
    fn push(&mut self, ev: Event);
    fn pop(&mut self) -> Option<Event>;
    /// Time of the earliest pending event. `O(1)` on every backend.
    fn peek_time(&self) -> Option<f64>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn backend(&self) -> QueueBackend;
}

/// Reference backend: `BinaryHeap` with the reversed [`Ord`] above.
#[derive(Debug, Default)]
pub struct HeapQueue {
    heap: BinaryHeap<Event>,
}

impl HeapQueue {
    pub fn with_capacity(capacity: usize) -> HeapQueue {
        HeapQueue {
            heap: BinaryHeap::with_capacity(capacity),
        }
    }
}

impl EventQueue for HeapQueue {
    fn push(&mut self, ev: Event) {
        debug_assert!(!ev.time.is_nan(), "NaN event time");
        self.heap.push(ev);
    }

    fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn backend(&self) -> QueueBackend {
        QueueBackend::Heap
    }
}

/// Wheel geometry: 8 levels × 64 slots covers `2^48` ticks of horizon.
const LEVELS: usize = 8;
const SLOT_BITS: u32 = 6;
const SLOTS: usize = 1 << SLOT_BITS;
const SLOT_MASK: u64 = (SLOTS as u64) - 1;

/// Tick quantum in virtual seconds. 100 ns resolves every distinct kernel
/// boundary the cost models produce while keeping a 1 M-second horizon
/// inside the wheel; sub-quantum time differences still order correctly
/// because same-tick events are sorted by exact `(time, seq)`.
const TICK_SECONDS: f64 = 1e-7;

/// Hierarchical timer wheel with a calendar-queue overflow list.
///
/// Determinism argument, in three parts:
/// 1. `tick(t) = floor(t / quantum)` is weakly monotone, so
///    `tick(a) < tick(b)` implies `a < b`, and equal times share a tick.
///    Ordering whole ticks first therefore never reorders distinct times.
/// 2. `advance` always moves the cursor to the *smallest* occupied tick
///    (bottom-up level scan over occupancy bitmaps, strictly-above-cursor
///    masks), so `current` holds exactly the globally earliest events.
/// 3. Within `current`, events sort by exact `(time, seq)` — the heap's
///    tie-break, reproduced bit-for-bit.
#[derive(Debug)]
pub struct TimerWheelQueue {
    /// The earliest pending events, sorted by `(time, seq)`; `head..` are
    /// live. Non-empty whenever the queue is non-empty (eager advance).
    current: Vec<Event>,
    head: usize,
    /// Tick of the last batch cascaded into `current`. All events still in
    /// the wheel have a strictly greater tick.
    cursor: u64,
    /// `LEVELS × SLOTS` buckets, flattened.
    slots: Vec<Vec<Event>>,
    /// Per-level occupancy bitmap (bit = slot non-empty).
    occ: [u64; LEVELS],
    /// Calendar-queue fallback for events beyond the wheel horizon.
    overflow: Vec<Event>,
    len: usize,
}

impl TimerWheelQueue {
    pub fn with_capacity(capacity: usize) -> TimerWheelQueue {
        TimerWheelQueue {
            current: Vec::with_capacity(capacity.min(1 << 12)),
            head: 0,
            cursor: 0,
            slots: (0..LEVELS * SLOTS).map(|_| Vec::new()).collect(),
            occ: [0; LEVELS],
            overflow: Vec::new(),
            len: 0,
        }
    }

    #[inline]
    fn tick(time: f64) -> u64 {
        debug_assert!(!time.is_nan(), "NaN event time");
        // `as u64` saturates: negatives clamp to tick 0 (still ordered by
        // exact time inside `current`), +inf clamps to u64::MAX (overflow
        // list).
        (time / TICK_SECONDS) as u64
    }

    /// Sorted insert into the live tail of `current`.
    fn insert_current(&mut self, ev: Event) {
        let pos = self.current[self.head..].partition_point(|e| precedes(e, &ev));
        self.current.insert(self.head + pos, ev);
    }

    /// Route an event to `current`, a wheel slot, or the overflow list,
    /// relative to the current cursor. Does not touch `len`.
    fn push_inner(&mut self, ev: Event) {
        let tick = Self::tick(ev.time);
        if tick <= self.cursor {
            // The cursor already advanced to (or past) this tick, so the
            // event belongs to the batch being drained. `current` stays
            // sorted by exact (time, seq), which is the true global order
            // here: everything still in the wheel has a greater tick.
            self.insert_current(ev);
            return;
        }
        let diff = tick ^ self.cursor;
        let level = ((63 - diff.leading_zeros()) / SLOT_BITS) as usize;
        if level >= LEVELS {
            self.overflow.push(ev);
            return;
        }
        let slot = ((tick >> (SLOT_BITS * level as u32)) & SLOT_MASK) as usize;
        self.slots[level * SLOTS + slot].push(ev);
        self.occ[level] |= 1u64 << slot;
    }

    /// Refill `current` with the globally earliest pending events. No-op
    /// when `current` still has live entries; returns with `current`
    /// non-empty and sorted unless the whole queue is empty.
    fn advance(&mut self) {
        if self.head < self.current.len() {
            return;
        }
        self.current.clear();
        self.head = 0;
        loop {
            if !self.current.is_empty() {
                self.current.sort_unstable_by(|a, b| {
                    a.time
                        .partial_cmp(&b.time)
                        .expect("NaN event time")
                        .then(a.seq.cmp(&b.seq))
                });
                return;
            }
            // Bottom-up scan for the lowest occupied slot strictly above
            // the cursor's own slot at each level. Every occupied slot
            // satisfies that (events always land above the cursor), so the
            // first hit is the minimal pending tick group.
            let mut progressed = false;
            for level in 0..LEVELS {
                let shift = SLOT_BITS * level as u32;
                let group = ((self.cursor >> shift) & SLOT_MASK) as u32;
                // Guard the shift: group == 63 would need `<< 64` (UB).
                let candidates = if group >= 63 {
                    0
                } else {
                    self.occ[level] & (!0u64 << (group + 1))
                };
                if candidates == 0 {
                    continue;
                }
                let slot = candidates.trailing_zeros() as u64;
                let idx = level * SLOTS + slot as usize;
                self.occ[level] &= !(1u64 << slot);
                if level == 0 {
                    // A level-0 slot holds exactly one tick's events (the
                    // cursor's upper bits can only change once level 0 is
                    // fully drained, so the slot never mixes windows).
                    self.cursor = (self.cursor & !SLOT_MASK) | slot;
                    std::mem::swap(&mut self.current, &mut self.slots[idx]);
                } else {
                    // Cascade: jump the cursor to the start of this slot's
                    // window and redistribute. Events on the window's first
                    // tick land in `current` (they are provably minimal);
                    // the rest fall to strictly lower levels.
                    let window = SLOT_BITS * level as u32;
                    self.cursor = ((self.cursor >> (window + SLOT_BITS)) << (window + SLOT_BITS))
                        | (slot << window);
                    let mut events = std::mem::take(&mut self.slots[idx]);
                    for ev in events.drain(..) {
                        self.push_inner(ev);
                    }
                    // Hand the (now empty) buffer back to recycle capacity;
                    // redistribution can never target the slot it came from.
                    self.slots[idx] = events;
                }
                progressed = true;
                break;
            }
            if progressed {
                continue;
            }
            // Wheel fully empty: reseed from the overflow list, if any.
            if self.overflow.is_empty() {
                return; // queue truly empty
            }
            let min_tick = self
                .overflow
                .iter()
                .map(|e| Self::tick(e.time))
                .min()
                .expect("non-empty overflow");
            self.cursor = min_tick;
            let events = std::mem::take(&mut self.overflow);
            for ev in events {
                // Min-tick events go straight to `current`; later ones
                // re-bucket against the new cursor (possibly back into a
                // fresh overflow list if still beyond the horizon).
                self.push_inner(ev);
            }
        }
    }
}

impl EventQueue for TimerWheelQueue {
    fn push(&mut self, ev: Event) {
        self.push_inner(ev);
        self.len += 1;
        // Eager advance: only needed when the queue was empty and the new
        // event landed in the wheel rather than `current`.
        if self.head == self.current.len() {
            self.advance();
        }
    }

    fn pop(&mut self) -> Option<Event> {
        if self.head == self.current.len() {
            debug_assert_eq!(self.len, 0, "eager-advance invariant violated");
            return None;
        }
        let ev = self.current[self.head];
        self.head += 1;
        self.len -= 1;
        if self.head == self.current.len() {
            self.current.clear();
            self.head = 0;
            self.advance();
        }
        Some(ev)
    }

    fn peek_time(&self) -> Option<f64> {
        self.current.get(self.head).map(|e| e.time)
    }

    fn len(&self) -> usize {
        self.len
    }

    fn backend(&self) -> QueueBackend {
        QueueBackend::Wheel
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(time: f64, seq: u64) -> Event {
        Event {
            time,
            seq,
            kind: EventKind::PhaseBegin,
            job: JobId(seq),
        }
    }

    fn drain(q: &mut dyn EventQueue) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push((e.time.to_bits(), e.seq));
        }
        out
    }

    #[test]
    fn backend_keys_roundtrip() {
        for b in QueueBackend::ALL {
            assert_eq!(QueueBackend::parse(b.key()), Some(b));
        }
        assert_eq!(QueueBackend::parse("Wheel"), Some(QueueBackend::Wheel));
        assert_eq!(QueueBackend::parse("fifo"), None);
    }

    #[test]
    fn both_backends_order_a_static_schedule() {
        // Times chosen to hit same-tick ties (sub-quantum deltas), exact
        // duplicates, cross-level spreads, and a far-future overflow event.
        let times = [
            0.0,
            0.0,
            3.2e-8, // same tick as 0.0 (quantum 1e-7), later exact time
            1e-7,
            5e-4,
            5e-4,
            0.013,
            0.013 + 1e-9,
            2.5,
            2.5,
            7_200.0,
            4.0e7, // beyond the 2^48-tick horizon → overflow list
        ];
        for backend in QueueBackend::ALL {
            let mut q = backend.make(16);
            for (seq, &t) in times.iter().enumerate() {
                q.push(ev(t, seq as u64));
            }
            assert_eq!(q.len(), times.len());
            let got = drain(q.as_mut());
            let mut want: Vec<(u64, u64)> = times
                .iter()
                .enumerate()
                .map(|(s, &t)| (t.to_bits(), s as u64))
                .collect();
            want.sort_by(|a, b| {
                f64::from_bits(a.0)
                    .partial_cmp(&f64::from_bits(b.0))
                    .unwrap()
                    .then(a.1.cmp(&b.1))
            });
            assert_eq!(got, want, "backend {:?}", backend);
        }
    }

    #[test]
    fn wheel_matches_heap_under_interleaved_push_pop() {
        // Deterministic LCG; times are generated non-decreasing relative to
        // the last pop (the engine's usage pattern), with frequent exact
        // ties and occasional far-future jumps.
        let mut state = 0x2545_f491_4f6c_dd1du64;
        let mut rng = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        let mut heap = HeapQueue::with_capacity(64);
        let mut wheel = TimerWheelQueue::with_capacity(64);
        let mut seq = 0u64;
        let mut now = 0.0f64;
        for _ in 0..2_000 {
            let op = rng() % 4;
            if op == 0 {
                let a = heap.pop();
                let b = wheel.pop();
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        assert_eq!(x.time.to_bits(), y.time.to_bits());
                        assert_eq!(x.seq, y.seq);
                        assert_eq!(x.kind, y.kind);
                        assert_eq!(x.job, y.job);
                        now = x.time;
                    }
                    other => panic!("pop mismatch: {other:?}"),
                }
            } else {
                let dt = match rng() % 5 {
                    0 => 0.0, // exact tie with `now`
                    1 => (rng() % 50) as f64 * 1e-9,
                    2 => (rng() % 1_000) as f64 * 1e-6,
                    3 => (rng() % 1_000) as f64 * 1e-2,
                    _ => 1e6 + (rng() % 100) as f64 * 1e6, // deep future
                };
                let e = ev(now + dt, seq);
                seq += 1;
                heap.push(e);
                wheel.push(e);
            }
            assert_eq!(heap.len(), wheel.len());
            assert_eq!(
                heap.peek_time().map(f64::to_bits),
                wheel.peek_time().map(f64::to_bits)
            );
        }
        assert_eq!(drain(&mut heap), drain(&mut wheel));
    }

    #[test]
    fn wheel_handles_push_below_cursor() {
        let mut q = TimerWheelQueue::with_capacity(8);
        q.push(ev(1.0, 0));
        q.push(ev(2.0, 1));
        assert_eq!(q.pop().unwrap().seq, 0);
        // The cursor has advanced past tick(1.5); the event must still come
        // out before the 2.0 one, in exact time order.
        q.push(ev(1.5, 2));
        assert_eq!(q.pop().unwrap().seq, 2);
        assert_eq!(q.pop().unwrap().seq, 1);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn wheel_overflow_reseeds_in_order() {
        let horizon = (1u64 << 48) as f64 * TICK_SECONDS;
        let mut q = TimerWheelQueue::with_capacity(8);
        q.push(ev(horizon * 3.0, 0));
        q.push(ev(horizon * 2.0, 1));
        q.push(ev(0.5, 2));
        q.push(ev(horizon * 2.0, 3)); // tie in the overflow list
        assert_eq!(q.peek_time(), Some(0.5));
        let got = drain(&mut q);
        assert_eq!(
            got,
            vec![
                (0.5f64.to_bits(), 2),
                ((horizon * 2.0).to_bits(), 1),
                ((horizon * 2.0).to_bits(), 3),
                ((horizon * 3.0).to_bits(), 0),
            ]
        );
    }

    #[test]
    fn peek_is_stable_and_cheap() {
        let mut q = TimerWheelQueue::with_capacity(8);
        assert_eq!(q.peek_time(), None);
        q.push(ev(0.25, 0));
        q.push(ev(0.125, 1));
        assert_eq!(q.peek_time(), Some(0.125));
        assert_eq!(q.peek_time(), Some(0.125)); // idempotent, &self
        assert_eq!(q.pop().unwrap().seq, 1);
        assert_eq!(q.peek_time(), Some(0.25));
    }
}
