//! Pluggable kernel-backend implementations (the §6 ablation made a
//! first-class execution dimension).
//!
//! The paper's headline system insight is that *which kernel implementation
//! serves a model* dominates end-to-end behaviour on consumer GPUs:
//! llama.cpp's launch shapes are tuned to the architecture (fused per-layer
//! kernels, modest registers → high SMOCC), while generic PyTorch attention
//! needs >150 registers/thread → ≤1 resident block/SM → occupancy collapse,
//! and eager execution splinters each token into hundreds of small launches.
//! Previously those shapes were hardcoded inside `apps/models.rs`, so the
//! tuned-vs-generic ablation could not be expressed, swept, or reported.
//!
//! [`KernelBackend`] owns the launch-shape tables — grid geometry,
//! registers/thread, shared memory, launch counts, DRAM-traffic factors —
//! and the CPU-backend work multipliers for all three model families. The
//! model profiles in `apps::models` keep the *magnitudes* (parameter
//! counts, weight bytes, FLOP budgets); the backend decides how that work
//! is cut into kernels. Three implementations ship:
//!
//! * [`KernelBackend::TunedNative`] — today's llama.cpp / whisper-online /
//!   stable-diffusion-webui shapes: the same logical work, launch counts,
//!   and aggregate timing as the pre-backend behaviour (llama decode now
//!   splits its 30 launches into 22 weight matmuls + 8 KV-reading
//!   attention kernels instead of 30 uniform ones, so per-kernel byte
//!   splits — and therefore trace digests — shift while totals match).
//!   Configs that name no `backend:` get this one.
//! * [`KernelBackend::GenericTorch`] — unfused eager execution: attention
//!   at 168 registers/thread with materialized intermediates (extra DRAM
//!   traffic), several times more launches per unit of work.
//! * [`KernelBackend::FusedCustom`] — an idealized hand-tuned variant:
//!   flash-attention-style fused kernels, fewest launches, no intermediate
//!   traffic. The upper bound a kernel engineer could reach.
//!
//! Tables are built once per backend (interned [`Tag`]s, `OnceLock`) so the
//! per-token kernel-generation hot path never touches the tag pool.

use std::sync::OnceLock;

use crate::gpusim::kernel::{KernelDesc, Tag};

/// Which kernel implementation executes a model family's work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KernelBackend {
    /// llama.cpp / whisper-online / webui shapes tuned to the GPU
    /// architecture (the measured defaults; §4.1).
    #[default]
    TunedNative,
    /// Generic PyTorch eager execution: unfused ops, register-hungry
    /// attention, many small launches (§4.1's occupancy pathology).
    GenericTorch,
    /// Idealized hand-fused kernels (flash-attention-style): the tuned
    /// backend's logical work in the fewest, highest-occupancy launches.
    FusedCustom,
}

/// Stable key for a backend in YAML configs, scenario names, and reports.
pub fn backend_key(b: KernelBackend) -> &'static str {
    b.key()
}

impl KernelBackend {
    pub const ALL: [KernelBackend; 3] = [
        KernelBackend::TunedNative,
        KernelBackend::GenericTorch,
        KernelBackend::FusedCustom,
    ];

    pub fn key(self) -> &'static str {
        match self {
            KernelBackend::TunedNative => "tuned_native",
            KernelBackend::GenericTorch => "generic_torch",
            KernelBackend::FusedCustom => "fused_custom",
        }
    }

    /// Parse a YAML / CLI spelling.
    pub fn parse(s: &str) -> Option<KernelBackend> {
        match s.to_ascii_lowercase().replace(['-', ' ', '.'], "_").as_str() {
            "tuned_native" | "tuned" | "native" | "llama_cpp" | "llamacpp" => {
                Some(KernelBackend::TunedNative)
            }
            "generic_torch" | "generic" | "torch" | "pytorch" => {
                Some(KernelBackend::GenericTorch)
            }
            "fused_custom" | "fused" | "custom" | "ideal" => Some(KernelBackend::FusedCustom),
            _ => None,
        }
    }

    /// Fixed-latency multiplier on a server's KV-placement migration: the
    /// generic framework tears down and rebuilds its allocator state around
    /// a placement change, where the tuned/fused runtimes remap in place.
    pub fn kv_migration_latency_mult(self) -> f64 {
        match self {
            KernelBackend::TunedNative | KernelBackend::FusedCustom => 1.0,
            KernelBackend::GenericTorch => 4.0,
        }
    }

    /// The llama-family launch-shape table.
    pub fn llama(self) -> &'static LlamaShapes {
        static TUNED: OnceLock<LlamaShapes> = OnceLock::new();
        static GENERIC: OnceLock<LlamaShapes> = OnceLock::new();
        static FUSED: OnceLock<LlamaShapes> = OnceLock::new();
        match self {
            KernelBackend::TunedNative => TUNED.get_or_init(LlamaShapes::tuned),
            KernelBackend::GenericTorch => GENERIC.get_or_init(LlamaShapes::generic_torch),
            KernelBackend::FusedCustom => FUSED.get_or_init(LlamaShapes::fused_custom),
        }
    }

    /// The diffusion-family launch-shape table.
    pub fn diffusion(self) -> &'static DiffusionShapes {
        static TUNED: OnceLock<DiffusionShapes> = OnceLock::new();
        static GENERIC: OnceLock<DiffusionShapes> = OnceLock::new();
        static FUSED: OnceLock<DiffusionShapes> = OnceLock::new();
        match self {
            KernelBackend::TunedNative => TUNED.get_or_init(DiffusionShapes::tuned),
            KernelBackend::GenericTorch => GENERIC.get_or_init(DiffusionShapes::generic_torch),
            KernelBackend::FusedCustom => FUSED.get_or_init(DiffusionShapes::fused_custom),
        }
    }

    /// The whisper-family launch-shape table.
    pub fn whisper(self) -> &'static WhisperShapes {
        static TUNED: OnceLock<WhisperShapes> = OnceLock::new();
        static GENERIC: OnceLock<WhisperShapes> = OnceLock::new();
        static FUSED: OnceLock<WhisperShapes> = OnceLock::new();
        match self {
            KernelBackend::TunedNative => TUNED.get_or_init(WhisperShapes::tuned),
            KernelBackend::GenericTorch => GENERIC.get_or_init(WhisperShapes::generic_torch),
            KernelBackend::FusedCustom => FUSED.get_or_init(WhisperShapes::fused_custom),
        }
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

/// One launch geometry in a backend's shape table: everything about a
/// kernel except the work it carries.
#[derive(Debug, Clone, Copy)]
pub struct LaunchShape {
    pub tag: Tag,
    pub blocks: usize,
    pub threads_per_block: usize,
    pub regs_per_thread: usize,
    pub smem_per_block: usize,
}

impl LaunchShape {
    fn new(tag: Tag, blocks: usize, threads: usize, regs: usize, smem: usize) -> LaunchShape {
        LaunchShape {
            tag,
            blocks,
            threads_per_block: threads,
            regs_per_thread: regs,
            smem_per_block: smem,
        }
    }

    /// Instantiate the shape with a work payload.
    pub fn kernel(&self, flops: f64, bytes: f64) -> KernelDesc {
        self.kernel_with_blocks(self.blocks, flops, bytes)
    }

    /// Instantiate with a dynamic grid size (prefill scales with tokens).
    pub fn kernel_with_blocks(&self, blocks: usize, flops: f64, bytes: f64) -> KernelDesc {
        KernelDesc::new(
            self.tag,
            blocks,
            self.threads_per_block,
            self.regs_per_thread,
            self.smem_per_block,
            flops,
            bytes,
        )
    }
}

/// Synthesize a backend-qualified tag (`decode.attn@torch`). The tuned
/// backend keeps the bare historical names so traces and tests that match
/// on them stay meaningful.
fn tag(base: &'static str, suffix: Option<&str>) -> Tag {
    match suffix {
        None => Tag::from_static(base),
        Some(s) => Tag::intern(&format!("{base}@{s}")),
    }
}

// ---------------------------------------------------------------------
// Llama family
// ---------------------------------------------------------------------

/// Launch-shape table for decoder-only LLMs. The single source of truth for
/// the per-token decode launch count (the old `LLAMA_KERNELS_PER_TOKEN`),
/// the 288-block decode geometry, and the attention/matmul split — shared
/// by `decode_kernels`, `decode_kernels_no_attn`, and the inference
/// server's batched iterations, so the shapes cannot drift between
/// variants.
#[derive(Debug, Clone)]
pub struct LlamaShapes {
    /// Fused prefill launch, one (or two, with `prefill_attn`) per layer.
    pub prefill_matmul: LaunchShape,
    /// Present when the backend launches attention separately at prefill.
    pub prefill_attn: Option<LaunchShape>,
    /// Weight-matmul launches per decoded token.
    pub decode_matmul_launches: usize,
    /// Attention launches per decoded token (the KV-reading subset — the
    /// launches that drop out in `--no-kv-offload` mode).
    pub decode_attn_launches: usize,
    pub decode_matmul: LaunchShape,
    pub decode_attn: LaunchShape,
    /// DRAM-traffic multiplier on the KV bytes attention reads (unfused
    /// backends materialize QKᵀ/softmax intermediates).
    pub attn_bytes_factor: f64,
    /// Fraction of the per-token FLOPs spent in attention launches.
    pub attn_flops_frac: f64,
    /// CPU-backend effectiveness multipliers (no AVX-friendly layout, no
    /// operator fusion) applied on top of the model's own CPU factors.
    pub cpu_flops_mult: f64,
    pub cpu_bytes_mult: f64,
}

impl LlamaShapes {
    /// Total kernel launches per decoded token.
    pub fn decode_launches(&self) -> usize {
        self.decode_matmul_launches + self.decode_attn_launches
    }

    /// llama.cpp: one fused launch per layer at prefill; 30 launches per
    /// decoded token at the tuned 288-block / 3-blocks-per-SM shape
    /// (SMACT 100% at SMOCC 75% on Turing).
    fn tuned() -> LlamaShapes {
        LlamaShapes {
            prefill_matmul: LaunchShape::new(tag("prefill.layer", None), 2048, 256, 64, 16 * 1024),
            prefill_attn: None,
            decode_matmul_launches: 22,
            decode_attn_launches: 8,
            decode_matmul: LaunchShape::new(tag("decode.layer", None), 288, 256, 80, 8 * 1024),
            decode_attn: LaunchShape::new(tag("decode.attn", None), 288, 256, 80, 8 * 1024),
            attn_bytes_factor: 1.0,
            attn_flops_frac: 0.15,
            cpu_flops_mult: 1.0,
            cpu_bytes_mult: 1.0,
        }
    }

    /// PyTorch eager: unfused sublayers → 120 launches per token, attention
    /// at the §4.1 register footprint (168/thread → 1 block/SM) reading 3×
    /// the nominal KV bytes through materialized intermediates.
    fn generic_torch() -> LlamaShapes {
        let s = Some("torch");
        LlamaShapes {
            prefill_matmul: LaunchShape::new(tag("prefill.matmul", s), 2048, 256, 96, 8 * 1024),
            prefill_attn: Some(LaunchShape::new(tag("prefill.attn", s), 2048, 256, 168, 16 * 1024)),
            decode_matmul_launches: 96,
            decode_attn_launches: 24,
            decode_matmul: LaunchShape::new(tag("decode.matmul", s), 288, 256, 96, 8 * 1024),
            decode_attn: LaunchShape::new(tag("decode.attn", s), 256, 256, 168, 16 * 1024),
            attn_bytes_factor: 3.0,
            attn_flops_frac: 0.15,
            cpu_flops_mult: 1.5,
            cpu_bytes_mult: 1.25,
        }
    }

    /// Idealized hand-fused variant: two layers per decode launch, full
    /// occupancy (64 regs × 256 threads → 4 blocks/SM → 100%).
    fn fused_custom() -> LlamaShapes {
        let s = Some("custom");
        LlamaShapes {
            prefill_matmul: LaunchShape::new(tag("prefill.fused", s), 2048, 256, 64, 8 * 1024),
            prefill_attn: None,
            decode_matmul_launches: 14,
            decode_attn_launches: 4,
            decode_matmul: LaunchShape::new(tag("decode.fused", s), 288, 256, 64, 8 * 1024),
            decode_attn: LaunchShape::new(tag("decode.attn", s), 288, 256, 64, 16 * 1024),
            attn_bytes_factor: 1.0,
            attn_flops_frac: 0.15,
            cpu_flops_mult: 0.8,
            cpu_bytes_mult: 0.9,
        }
    }
}

// ---------------------------------------------------------------------
// Diffusion family
// ---------------------------------------------------------------------

/// Launch-shape table for diffusion models: the denoise-step attention /
/// matmul shapes plus the (backend-invariant) CLIP-encoder and VAE-decoder
/// geometries, single-sourced so the preamble/denoise/VAE variants cannot
/// drift apart.
#[derive(Debug, Clone)]
pub struct DiffusionShapes {
    /// Launches per logical attention op (eager backends split qkᵀ /
    /// softmax / pv into separate kernels).
    pub attn_split: usize,
    pub attn: LaunchShape,
    pub other: LaunchShape,
    /// DRAM bytes per logical attention op (across all splits).
    pub attn_bytes_per_op: f64,
    /// DRAM bytes per matmul/conv/norm launch.
    pub other_bytes_per_op: f64,
    pub clip: LaunchShape,
    pub clip_launches: usize,
    pub clip_flops: f64,
    pub clip_bytes: f64,
    pub vae: LaunchShape,
    pub vae_launches: usize,
    pub vae_flops: f64,
    pub vae_bytes: f64,
    pub cpu_flops_mult: f64,
}

impl DiffusionShapes {
    /// CLIP/VAE bracketing geometry — identical across backends (webui and
    /// eager PyTorch share the encoder/decoder implementations).
    fn with_preamble(mut base: DiffusionShapes) -> DiffusionShapes {
        base.clip = LaunchShape::new(tag("clip.encode", None), 512, 256, 64, 8 * 1024);
        base.clip_launches = 8;
        base.clip_flops = 2e10;
        base.clip_bytes = 32e6;
        base.vae = LaunchShape::new(tag("vae.decode", None), 4096, 256, 96, 8 * 1024);
        base.vae_launches = 12;
        base.vae_flops = 4e10;
        base.vae_bytes = 256e6;
        base
    }

    fn skeleton(attn: LaunchShape, other: LaunchShape) -> DiffusionShapes {
        // clip/vae filled by `with_preamble`; placeholders here.
        DiffusionShapes {
            attn_split: 1,
            attn,
            other,
            attn_bytes_per_op: 64.0 * 1024.0 * 1024.0,
            other_bytes_per_op: 128.0 * 1024.0 * 1024.0,
            clip: other,
            clip_launches: 0,
            clip_flops: 0.0,
            clip_bytes: 0.0,
            vae: other,
            vae_launches: 0,
            vae_flops: 0.0,
            vae_bytes: 0.0,
            cpu_flops_mult: 1.0,
        }
    }

    /// The webui/PyTorch default the paper measured: fused-enough matmuls
    /// but generic attention at 168 regs/thread (SMOCC ≈ 0.25, §4.1).
    fn tuned() -> DiffusionShapes {
        Self::with_preamble(Self::skeleton(
            LaunchShape::new(tag("denoise.attn", None), 2048, 256, 168, 16 * 1024),
            LaunchShape::new(tag("denoise.matmul", None), 2048, 256, 96, 8 * 1024),
        ))
    }

    /// Fully eager: each attention op splits into three launches and
    /// materializes intermediates (1.5× the attention DRAM traffic).
    fn generic_torch() -> DiffusionShapes {
        let s = Some("torch");
        let mut t = Self::with_preamble(Self::skeleton(
            LaunchShape::new(tag("denoise.attn", s), 2048, 256, 168, 16 * 1024),
            LaunchShape::new(tag("denoise.matmul", s), 2048, 256, 96, 8 * 1024),
        ));
        t.attn_split = 3;
        t.attn_bytes_per_op = 96.0 * 1024.0 * 1024.0;
        t.cpu_flops_mult = 1.5;
        t
    }

    /// Flash-attention-style fused step: attention at 64 regs / 32 KiB smem
    /// (2 blocks/SM → SMOCC 0.5, above the saturation knee) with no
    /// intermediate traffic.
    fn fused_custom() -> DiffusionShapes {
        let s = Some("custom");
        let mut t = Self::with_preamble(Self::skeleton(
            LaunchShape::new(tag("denoise.attn", s), 2048, 256, 64, 32 * 1024),
            LaunchShape::new(tag("denoise.matmul", s), 2048, 256, 96, 8 * 1024),
        ));
        t.attn_bytes_per_op = 32.0 * 1024.0 * 1024.0;
        t.cpu_flops_mult = 0.8;
        t
    }
}

// ---------------------------------------------------------------------
// Whisper family
// ---------------------------------------------------------------------

/// Launch-shape table for encoder-decoder speech models: the encoder
/// matmul geometry and the decoder's tiny-kernel burst, with per-backend
/// launch counts (the whisper profile keeps the FLOP/byte magnitudes).
#[derive(Debug, Clone)]
pub struct WhisperShapes {
    pub encode_launches: usize,
    pub encode: LaunchShape,
    /// Launches per decoded transcript token.
    pub decode_launches: usize,
    pub decode: LaunchShape,
    pub cpu_flops_mult: f64,
}

impl WhisperShapes {
    /// whisper-online: 16 healthy encoder matmuls; 40 tiny register/smem-
    /// hungry decoder kernels per token (SMOCC ≈ 0.06, Fig. 4c).
    fn tuned() -> WhisperShapes {
        WhisperShapes {
            encode_launches: 16,
            encode: LaunchShape::new(tag("encode.matmul", None), 1500, 256, 64, 32 * 1024),
            decode_launches: 40,
            decode: LaunchShape::new(tag("decode.small", None), 72, 64, 200, 40 * 1024),
            cpu_flops_mult: 1.0,
        }
    }

    /// Eager PyTorch: every op its own launch — twice the kernels at the
    /// same shapes, so the decoder becomes even more launch-bound.
    fn generic_torch() -> WhisperShapes {
        let s = Some("torch");
        WhisperShapes {
            encode_launches: 32,
            encode: LaunchShape::new(tag("encode.matmul", s), 1500, 256, 96, 32 * 1024),
            decode_launches: 80,
            decode: LaunchShape::new(tag("decode.small", s), 72, 64, 200, 40 * 1024),
            cpu_flops_mult: 1.5,
        }
    }

    /// Hand-fused decoder: the 40-kernel burst collapses to 10 launches at
    /// a healthy footprint (96 regs, 16 KiB smem).
    fn fused_custom() -> WhisperShapes {
        let s = Some("custom");
        WhisperShapes {
            encode_launches: 12,
            encode: LaunchShape::new(tag("encode.matmul", s), 1500, 256, 64, 32 * 1024),
            decode_launches: 10,
            decode: LaunchShape::new(tag("decode.fused", s), 72, 128, 96, 16 * 1024),
            cpu_flops_mult: 0.8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::kernel::occupancy;
    use crate::gpusim::profiles::{m1_pro_gpu, rtx6000};

    #[test]
    fn keys_and_parse_roundtrip() {
        for b in KernelBackend::ALL {
            assert_eq!(KernelBackend::parse(b.key()), Some(b));
            assert_eq!(format!("{b}"), b.key());
        }
        assert_eq!(KernelBackend::parse("tuned"), Some(KernelBackend::TunedNative));
        assert_eq!(KernelBackend::parse("llama.cpp"), Some(KernelBackend::TunedNative));
        assert_eq!(KernelBackend::parse("PyTorch"), Some(KernelBackend::GenericTorch));
        assert_eq!(KernelBackend::parse("fused-custom"), Some(KernelBackend::FusedCustom));
        assert_eq!(KernelBackend::parse("npu"), None);
        assert_eq!(KernelBackend::default(), KernelBackend::TunedNative);
    }

    #[test]
    fn tables_are_cached_and_stable() {
        let a = KernelBackend::GenericTorch.llama() as *const LlamaShapes;
        let b = KernelBackend::GenericTorch.llama() as *const LlamaShapes;
        assert!(std::ptr::eq(a, b), "tables must be built once");
        assert_eq!(KernelBackend::TunedNative.llama().decode_launches(), 30);
        assert_eq!(KernelBackend::GenericTorch.llama().decode_launches(), 120);
        assert_eq!(KernelBackend::FusedCustom.llama().decode_launches(), 18);
    }

    #[test]
    fn every_table_shape_fits_both_testbeds() {
        // Backends synthesize shapes; none may be a guaranteed launch
        // failure on a supported profile.
        for gpu in [rtx6000(), m1_pro_gpu()] {
            for b in KernelBackend::ALL {
                let l = b.llama();
                let mut shapes = vec![l.prefill_matmul, l.decode_matmul, l.decode_attn];
                if let Some(a) = l.prefill_attn {
                    shapes.push(a);
                }
                let d = b.diffusion();
                shapes.extend([d.attn, d.other, d.clip, d.vae]);
                let w = b.whisper();
                shapes.extend([w.encode, w.decode]);
                for s in shapes {
                    let k = s.kernel(1e6, 1e3);
                    let occ = occupancy(&k, &gpu).unwrap_or_else(|e| {
                        panic!("{b}: shape `{}` does not fit {}: {e}", s.tag, gpu.name)
                    });
                    assert!(occ.blocks_per_sm >= 1);
                }
            }
        }
    }

    #[test]
    fn backend_tags_are_distinguishable() {
        // Non-tuned backends qualify their tags so per-request traces show
        // which implementation ran; tuned keeps the historical names.
        assert_eq!(KernelBackend::TunedNative.llama().decode_matmul.tag, "decode.layer");
        assert_eq!(
            KernelBackend::GenericTorch.llama().decode_attn.tag,
            "decode.attn@torch"
        );
        assert_eq!(
            KernelBackend::FusedCustom.whisper().decode.tag,
            "decode.fused@custom"
        );
        assert_eq!(KernelBackend::TunedNative.diffusion().attn.tag, "denoise.attn");
    }

    #[test]
    fn generic_attention_has_the_register_pathology() {
        let gpu = rtx6000();
        let g = KernelBackend::GenericTorch;
        let attn = g.llama().decode_attn.kernel(1e8, 1e7);
        let occ = occupancy(&attn, &gpu).unwrap();
        assert_eq!(occ.blocks_per_sm, 1, "168 regs/thread → 1 block/SM");
        assert!(occ.occupancy <= 0.3);
        // The tuned decode shape keeps llama.cpp's 75% occupancy.
        let tuned = KernelBackend::TunedNative.llama().decode_matmul.kernel(1e8, 1e7);
        assert!(occupancy(&tuned, &gpu).unwrap().occupancy >= 0.7);
        // The fused variant reaches full occupancy.
        let fused = KernelBackend::FusedCustom.llama().decode_matmul.kernel(1e8, 1e7);
        assert!((occupancy(&fused, &gpu).unwrap().occupancy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn migration_cost_multiplier_only_penalizes_generic() {
        assert_eq!(KernelBackend::TunedNative.kv_migration_latency_mult(), 1.0);
        assert!(KernelBackend::GenericTorch.kv_migration_latency_mult() > 1.0);
        assert_eq!(KernelBackend::FusedCustom.kv_migration_latency_mult(), 1.0);
    }
}
