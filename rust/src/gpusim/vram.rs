//! VRAM allocator with per-client accounting.
//!
//! The paper's scenarios hinge on GPU memory pressure: 24 GB forces the
//! Llama-8B Chatbot onto the CPU (§B.4) and forces DeepResearch's 16 GB KV
//! cache into CPU DRAM (§4.2.1). The allocator is a simple bump-accounted
//! pool — placement *decisions* live in the orchestrator / server; this
//! module only enforces capacity and tracks per-client usage and peaks.

use std::collections::BTreeMap;

/// Opaque allocation handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AllocId(u64);

/// Out-of-memory error, carrying context for diagnostics.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("VRAM OOM: client `{client}` requested {requested} B (`{label}`), {used} of {capacity} B in use")]
pub struct OomError {
    pub client: String,
    pub label: String,
    pub requested: u64,
    pub used: u64,
    pub capacity: u64,
}

#[derive(Debug, Clone)]
struct Allocation {
    /// Index into the allocator's interned client-name table.
    client: u32,
    label: String,
    bytes: u64,
}

/// A capacity-enforcing allocator over device memory.
///
/// Client names are interned on first use: the engine's per-phase memory
/// ops and per-client accounting queries (`used_by`, `free_client`) compare
/// dense indices instead of walking every allocation with string equality.
#[derive(Debug, Clone)]
pub struct VramAllocator {
    capacity: u64,
    used: u64,
    peak: u64,
    next_id: u64,
    allocs: BTreeMap<AllocId, Allocation>,
    /// Interned client names; `client_used[i]` tracks live bytes of
    /// `client_names[i]`.
    client_names: Vec<String>,
    client_used: Vec<u64>,
}

impl VramAllocator {
    pub fn new(capacity: u64) -> Self {
        VramAllocator {
            capacity,
            used: 0,
            peak: 0,
            next_id: 0,
            allocs: BTreeMap::new(),
            client_names: Vec::new(),
            client_used: Vec::new(),
        }
    }

    fn intern(&mut self, client: &str) -> u32 {
        match self.client_names.iter().position(|n| n == client) {
            Some(i) => i as u32,
            None => {
                self.client_names.push(client.to_string());
                self.client_used.push(0);
                (self.client_names.len() - 1) as u32
            }
        }
    }

    fn lookup(&self, client: &str) -> Option<u32> {
        self.client_names.iter().position(|n| n == client).map(|i| i as u32)
    }

    /// Allocate `bytes` on behalf of `client`. `label` names the buffer
    /// ("weights", "kv-cache", "activations") for reports and errors.
    pub fn alloc(&mut self, client: &str, label: &str, bytes: u64) -> Result<AllocId, OomError> {
        // checked_add: an absurd request (chaos ballast, corrupt config)
        // must OOM, not wrap around u64 and falsely fit.
        if !self.used.checked_add(bytes).is_some_and(|t| t <= self.capacity) {
            return Err(OomError {
                client: client.to_string(),
                label: label.to_string(),
                requested: bytes,
                used: self.used,
                capacity: self.capacity,
            });
        }
        let cidx = self.intern(client);
        let id = AllocId(self.next_id);
        self.next_id += 1;
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.client_used[cidx as usize] += bytes;
        self.allocs.insert(
            id,
            Allocation {
                client: cidx,
                label: label.to_string(),
                bytes,
            },
        );
        Ok(id)
    }

    /// Check whether an allocation would fit without performing it.
    /// Overflowing `used + bytes` counts as not fitting.
    pub fn would_fit(&self, bytes: u64) -> bool {
        self.used.checked_add(bytes).is_some_and(|t| t <= self.capacity)
    }

    /// Free an allocation; panics on double-free (a framework bug).
    pub fn free(&mut self, id: AllocId) {
        let a = self.allocs.remove(&id).expect("double free / unknown AllocId");
        self.used -= a.bytes;
        self.client_used[a.client as usize] -= a.bytes;
    }

    /// Free every allocation of `client` carrying `label` (e.g. the KV
    /// region during a GPU→CPU migration, leaving weights resident).
    pub fn free_labeled(&mut self, client: &str, label: &str) -> u64 {
        let Some(cidx) = self.lookup(client) else {
            return 0;
        };
        let mut freed = 0;
        self.allocs.retain(|_, a| {
            if a.client == cidx && a.label == label {
                freed += a.bytes;
                false
            } else {
                true
            }
        });
        self.used -= freed;
        self.client_used[cidx as usize] -= freed;
        freed
    }

    /// Free everything owned by a client (cleanup path).
    pub fn free_client(&mut self, client: &str) -> u64 {
        let Some(cidx) = self.lookup(client) else {
            return 0;
        };
        let mut freed = 0;
        self.allocs.retain(|_, a| {
            if a.client == cidx {
                freed += a.bytes;
                false
            } else {
                true
            }
        });
        self.used -= freed;
        self.client_used[cidx as usize] = 0;
        freed
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.used
    }

    /// Bytes currently held by a client. O(1) per-client counter.
    pub fn used_by(&self, client: &str) -> u64 {
        self.lookup(client)
            .map(|i| self.client_used[i as usize])
            .unwrap_or(0)
    }

    /// (client, label, bytes) inventory, for the report's memory section.
    pub fn inventory(&self) -> Vec<(String, String, u64)> {
        self.allocs
            .values()
            .map(|a| {
                (
                    self.client_names[a.client as usize].clone(),
                    a.label.clone(),
                    a.bytes,
                )
            })
            .collect()
    }
}

/// Gibibytes → bytes, used throughout app model sizing.
pub const fn gib(n: u64) -> u64 {
    n * (1 << 30)
}

/// Mebibytes → bytes.
pub const fn mib(n: u64) -> u64 {
    n * (1 << 20)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_balances() {
        let mut v = VramAllocator::new(gib(24));
        let a = v.alloc("chat", "weights", gib(2)).unwrap();
        let b = v.alloc("img", "weights", gib(5)).unwrap();
        assert_eq!(v.used(), gib(7));
        assert_eq!(v.used_by("chat"), gib(2));
        v.free(a);
        assert_eq!(v.used(), gib(5));
        v.free(b);
        assert_eq!(v.used(), 0);
        assert_eq!(v.peak(), gib(7));
    }

    #[test]
    fn oom_when_over_capacity() {
        let mut v = VramAllocator::new(gib(24));
        v.alloc("research", "kv-cache", gib(16)).unwrap();
        v.alloc("chat", "weights", gib(2)).unwrap();
        let err = v.alloc("img", "weights", gib(10)).unwrap_err();
        assert_eq!(err.requested, gib(10));
        assert_eq!(err.used, gib(18));
        assert!(err.to_string().contains("img"));
    }

    #[test]
    fn would_fit_is_consistent() {
        let mut v = VramAllocator::new(gib(8));
        assert!(v.would_fit(gib(8)));
        v.alloc("a", "w", gib(5)).unwrap();
        assert!(v.would_fit(gib(3)));
        assert!(!v.would_fit(gib(4)));
    }

    #[test]
    fn free_labeled_releases_only_matching_buffers() {
        let mut v = VramAllocator::new(gib(24));
        v.alloc("server", "weights", gib(2)).unwrap();
        v.alloc("server", "kv-cache", gib(14)).unwrap();
        v.alloc("img", "kv-cache", gib(1)).unwrap();
        let freed = v.free_labeled("server", "kv-cache");
        assert_eq!(freed, gib(14));
        assert_eq!(v.used_by("server"), gib(2));
        assert_eq!(v.used_by("img"), gib(1));
        assert_eq!(v.free_labeled("server", "kv-cache"), 0);
        assert_eq!(v.free_labeled("ghost", "kv-cache"), 0);
    }

    #[test]
    fn free_client_releases_all() {
        let mut v = VramAllocator::new(gib(24));
        v.alloc("chat", "weights", gib(2)).unwrap();
        v.alloc("chat", "kv-cache", gib(1)).unwrap();
        v.alloc("img", "weights", gib(5)).unwrap();
        let freed = v.free_client("chat");
        assert_eq!(freed, gib(3));
        assert_eq!(v.used(), gib(5));
        assert_eq!(v.used_by("chat"), 0);
    }

    #[test]
    fn absurd_request_ooms_instead_of_wrapping() {
        // u64::MAX + anything used to wrap and "fit"; it must OOM.
        let mut v = VramAllocator::new(gib(24));
        v.alloc("server", "weights", gib(2)).unwrap();
        assert!(!v.would_fit(u64::MAX));
        let err = v.alloc("chaos", "ballast", u64::MAX).unwrap_err();
        assert_eq!(err.requested, u64::MAX);
        assert_eq!(v.used(), gib(2), "failed alloc must not change accounting");
        assert!(v.would_fit(gib(22)));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut v = VramAllocator::new(gib(1));
        let a = v.alloc("x", "w", 100).unwrap();
        v.free(a);
        v.free(a);
    }

    #[test]
    fn inventory_lists_buffers() {
        let mut v = VramAllocator::new(gib(24));
        v.alloc("chat", "weights", gib(2)).unwrap();
        v.alloc("chat", "kv-cache", gib(1)).unwrap();
        let inv = v.inventory();
        assert_eq!(inv.len(), 2);
        assert!(inv.iter().any(|(c, l, b)| c == "chat" && l == "kv-cache" && *b == gib(1)));
    }
}
