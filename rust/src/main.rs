//! ConsumerBench CLI — run YAML-defined GenAI workflows on the simulated
//! end-user testbed and report SLO attainment + system metrics.

fn main() -> anyhow::Result<()> {
    consumerbench::cli::main()
}
