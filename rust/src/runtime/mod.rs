//! Model runtime: the bridge between Layer 3 (this crate) and Layers 1/2
//! (the JAX models + Pallas kernels in `python/`).
//!
//! Two interchangeable implementations sit behind the same `Runtime` API:
//!
//! * **`pjrt`** (cargo feature `pjrt`) — compiles the AOT HLO-text
//!   artifacts on a PJRT CPU client via the `xla` bindings and executes
//!   real numerics per simulated request. The bindings are not on
//!   crates.io, so the feature ships without a registered dependency; see
//!   `Cargo.toml` for how to wire them in.
//! * **`sim`** (default) — a stub that parses the same manifest and
//!   produces deterministic per-tensor checksums, keeping the executor's
//!   real-compute hook (call counts, seeding, error paths) exercised
//!   without any native dependency.
//!
//! All reported latencies come from the virtual-time simulator in both
//! builds; the PJRT path adds numerics validation only.

pub mod manifest;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{make_literal, LoadedModel, Runtime};

#[cfg(not(feature = "pjrt"))]
mod sim;
#[cfg(not(feature = "pjrt"))]
pub use sim::Runtime;
