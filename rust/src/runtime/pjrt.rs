//! Real-numerics PJRT runtime (`--features pjrt`).
//!
//! Loads AOT-compiled HLO-text artifacts and executes them through the
//! `xla` bindings. `python/compile/aot.py` lowers each model entry point
//! once to **HLO text** (not a serialized proto — jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids) and writes a manifest describing input shapes. At
//! startup this side compiles every artifact on the PJRT CPU client; the
//! executor then runs real numerics for each simulated request.
//!
//! Python never runs on the request path: once `artifacts/` exists, the
//! binary is self-contained.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest, TensorSpec};
use crate::util::Rng;

/// A compiled model entry point.
pub struct LoadedModel {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime holding one compiled executable per model entry point.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    models: BTreeMap<String, LoadedModel>,
    dir: PathBuf,
}

impl Runtime {
    /// Load every artifact listed in `<dir>/manifest.txt`.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        let mut models = BTreeMap::new();
        for spec in manifest.artifacts {
            let hlo_path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path.to_str().context("non-utf8 path")?,
            )
            .map_err(anyhow_xla)
            .with_context(|| format!("parsing {}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(anyhow_xla)?;
            models.insert(spec.name.clone(), LoadedModel { spec, exe });
        }
        Ok(Runtime { client, models, dir })
    }

    /// Whether an artifact directory looks usable (manifest present).
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.txt").is_file()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn model_names(&self) -> Vec<&str> {
        // BTreeMap keys iterate sorted, so the listing is already stable.
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.models.get(name).map(|m| &m.spec)
    }

    /// Execute a model with explicit input literals. Outputs are the
    /// elements of the result tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let model = self
            .models
            .get(name)
            .with_context(|| format!("unknown model `{name}`"))?;
        if inputs.len() != model.spec.inputs.len() {
            bail!(
                "model `{name}` expects {} inputs, got {}",
                model.spec.inputs.len(),
                inputs.len()
            );
        }
        let result = model.exe.execute::<xla::Literal>(inputs).map_err(anyhow_xla)?;
        let tuple = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
        tuple.to_tuple().map_err(anyhow_xla)
    }

    /// Execute with deterministic pseudo-random inputs of the declared
    /// shapes — the executor's per-request "real compute" path, where the
    /// semantic content of the tensors is irrelevant but the computation
    /// must actually run.
    pub fn execute_seeded(&self, name: &str, seed: u64) -> Result<Vec<xla::Literal>> {
        let model = self
            .models
            .get(name)
            .with_context(|| format!("unknown model `{name}`"))?;
        let mut rng = Rng::new(seed ^ 0x504A_5254); // "PJRT"
        let inputs: Result<Vec<xla::Literal>> = model
            .spec
            .inputs
            .iter()
            .map(|t| make_literal(t, &mut rng))
            .collect();
        self.execute(name, &inputs?)
    }
}

/// Build a literal of the given spec filled with small random values.
pub fn make_literal(spec: &TensorSpec, rng: &mut Rng) -> Result<xla::Literal> {
    let n: usize = spec.dims.iter().product::<usize>().max(1);
    match spec.dtype.as_str() {
        "f32" => {
            let data: Vec<f32> = (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 0.2).collect();
            let lit = xla::Literal::vec1(&data);
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            lit.reshape(&dims).map_err(anyhow_xla)
        }
        other => bail!("unsupported dtype `{other}` (manifest v1 supports f32)"),
    }
}

fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path used by `make artifacts`.
    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn availability_check_without_dir() {
        assert!(!Runtime::available("/nonexistent/dir"));
    }

    #[test]
    fn load_and_execute_artifacts_if_present() {
        // Full round-trip over the real AOT artifacts. Skipped (not failed)
        // when artifacts haven't been built; `make test` builds them first.
        let dir = artifacts_dir();
        if !Runtime::available(&dir) {
            eprintln!("artifacts not built; skipping PJRT round-trip test");
            return;
        }
        let rt = Runtime::load_dir(&dir).expect("artifacts must load");
        assert!(!rt.model_names().is_empty());
        for name in rt.model_names() {
            let outs = rt.execute_seeded(name, 42).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!outs.is_empty(), "{name} returned no outputs");
            // Outputs must be finite (the L2 models are normalized).
            let first = outs[0].to_vec::<f32>();
            if let Ok(v) = first {
                assert!(
                    v.iter().all(|x| x.is_finite()),
                    "{name} produced non-finite outputs"
                );
            }
        }
    }

    #[test]
    fn execute_seeded_is_deterministic() {
        let dir = artifacts_dir();
        if !Runtime::available(&dir) {
            eprintln!("artifacts not built; skipping determinism test");
            return;
        }
        let rt = Runtime::load_dir(&dir).unwrap();
        let name = rt.model_names()[0].to_string();
        let a = rt.execute_seeded(&name, 7).unwrap();
        let b = rt.execute_seeded(&name, 7).unwrap();
        assert_eq!(a[0].to_vec::<f32>().unwrap(), b[0].to_vec::<f32>().unwrap());
    }

    #[test]
    fn wrong_input_count_rejected() {
        let dir = artifacts_dir();
        if !Runtime::available(&dir) {
            return;
        }
        let rt = Runtime::load_dir(&dir).unwrap();
        let name = rt.model_names()[0].to_string();
        match rt.execute(&name, &[]) {
            Ok(_) => panic!("expected input-count error"),
            Err(err) => assert!(err.to_string().contains("inputs")),
        }
    }
}
