//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! Format (one artifact per line, `#` comments allowed):
//!
//! ```text
//! name|file.hlo.txt|dtype:d0xd1x...;dtype:...|n_outputs
//! chatbot_decode|chatbot_decode.hlo.txt|f32:1x64;f32:4x2x128x4x16|2
//! ```
//!
//! Kept deliberately line-oriented so both sides can parse it without a
//! serialization library (the offline crate set has none).

use anyhow::{bail, Context, Result};

/// Shape + dtype of one model input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let (dtype, dims_str) = s
            .split_once(':')
            .with_context(|| format!("tensor spec `{s}` missing `:`"))?;
        if dtype.is_empty() {
            bail!("tensor spec `{s}` has empty dtype");
        }
        let dims: Result<Vec<usize>> = if dims_str.is_empty() {
            Ok(Vec::new()) // scalar
        } else {
            dims_str
                .split('x')
                .map(|d| d.parse::<usize>().with_context(|| format!("bad dim `{d}` in `{s}`")))
                .collect()
        };
        Ok(TensorSpec {
            dtype: dtype.to_string(),
            dims: dims?,
        })
    }

    pub fn num_elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn render(&self) -> String {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        format!("{}:{}", self.dtype, dims.join("x"))
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub n_outputs: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 4 {
                bail!("manifest line {}: expected 4 `|`-separated fields, got {}", i + 1, parts.len());
            }
            let inputs: Result<Vec<TensorSpec>> = if parts[2].is_empty() {
                Ok(Vec::new())
            } else {
                parts[2].split(';').map(TensorSpec::parse).collect()
            };
            let spec = ArtifactSpec {
                name: parts[0].to_string(),
                file: parts[1].to_string(),
                inputs: inputs?,
                n_outputs: parts[3]
                    .parse()
                    .with_context(|| format!("manifest line {}: bad n_outputs", i + 1))?,
            };
            if artifacts.iter().any(|a: &ArtifactSpec| a.name == spec.name) {
                bail!("manifest line {}: duplicate artifact `{}`", i + 1, spec.name);
            }
            artifacts.push(spec);
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        let text = "\
# artifacts built by aot.py
chatbot_decode|chatbot_decode.hlo.txt|f32:1x64;f32:4x2x128x4x16|2
imagegen_step|imagegen_step.hlo.txt|f32:1x256x128|1
";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("chatbot_decode").unwrap();
        assert_eq!(a.file, "chatbot_decode.hlo.txt");
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![1, 64]);
        assert_eq!(a.inputs[1].dims, vec![4, 2, 128, 4, 16]);
        assert_eq!(a.n_outputs, 2);
        assert_eq!(a.inputs[0].render(), "f32:1x64");
    }

    #[test]
    fn scalar_spec() {
        let t = TensorSpec::parse("f32:").unwrap();
        assert!(t.dims.is_empty());
        assert_eq!(t.num_elements(), 1);
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Manifest::parse("too|few|fields\n").is_err());
        assert!(Manifest::parse("a|f.hlo|f32:2x2|notanum\n").is_err());
        assert!(Manifest::parse("a|f.hlo|badspec|1\n").is_err());
        assert!(Manifest::parse("a|f|f32:2|1\na|g|f32:2|1\n").is_err()); // dup
    }

    #[test]
    fn empty_inputs_allowed() {
        let m = Manifest::parse("nullary|f.hlo.txt||1\n").unwrap();
        assert!(m.get("nullary").unwrap().inputs.is_empty());
    }

    #[test]
    fn num_elements() {
        let t = TensorSpec::parse("f32:4x8x2").unwrap();
        assert_eq!(t.num_elements(), 64);
    }
}
