//! Stub runtime used when the `pjrt` feature is off (the default).
//!
//! The offline toolchain has no `xla` bindings, so the default build gates
//! the real-numerics path out entirely and substitutes a deterministic
//! stand-in: the manifest still parses, the same artifacts are addressable,
//! and `execute_seeded` produces a seed-stable checksum per declared input
//! tensor (drawn through the same RNG discipline as the PJRT path), so the
//! executor's per-request "real compute" hook keeps its call counts and
//! determinism properties without the native dependency.
//!
//! All experiment timing is virtual and comes from the simulator either
//! way — the PJRT path only validates numerics, so simulation results are
//! identical across the two builds.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest, TensorSpec};
use crate::util::Rng;

/// Manifest-backed runtime without compiled executables.
pub struct Runtime {
    specs: BTreeMap<String, ArtifactSpec>,
    dir: PathBuf,
}

impl Runtime {
    /// Load (parse) every artifact listed in `<dir>/manifest.txt`.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&text)?;
        let mut specs = BTreeMap::new();
        for spec in manifest.artifacts {
            specs.insert(spec.name.clone(), spec);
        }
        Ok(Runtime { specs, dir })
    }

    /// Whether an artifact directory looks usable (manifest present).
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.txt").is_file()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn model_names(&self) -> Vec<&str> {
        // BTreeMap keys iterate sorted, so the listing is already stable.
        self.specs.keys().map(|s| s.as_str()).collect()
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Stand-in for PJRT execution: deterministically synthesize the
    /// declared input tensors and reduce each to a checksum. One f32 per
    /// input, mirroring "some computation ran over tensors of the declared
    /// shapes".
    pub fn execute_seeded(&self, name: &str, seed: u64) -> Result<Vec<f32>> {
        let spec = self
            .specs
            .get(name)
            .with_context(|| format!("unknown model `{name}`"))?;
        let mut rng = Rng::new(seed ^ 0x504A_5254); // same discipline as PJRT
        spec.inputs.iter().map(|t| checksum(t, &mut rng)).collect()
    }
}

fn checksum(spec: &TensorSpec, rng: &mut Rng) -> Result<f32> {
    match spec.dtype.as_str() {
        "f32" => {
            let mut acc = 0.0f32;
            for _ in 0..spec.num_elements() {
                acc += (rng.next_f64() as f32 - 0.5) * 0.2;
            }
            Ok(acc)
        }
        other => bail!("unsupported dtype `{other}` (manifest v1 supports f32)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "tiny_llama_decode|tiny_llama_decode.hlo.txt|f32:1x64;f32:8x16|2\n",
        )
        .unwrap();
    }

    #[test]
    fn availability_check_without_dir() {
        assert!(!Runtime::available("/nonexistent/dir"));
    }

    #[test]
    fn loads_manifest_and_lists_models() {
        let dir = std::env::temp_dir().join("cb_sim_runtime");
        write_manifest(&dir);
        let rt = Runtime::load_dir(&dir).unwrap();
        assert_eq!(rt.model_names(), vec!["tiny_llama_decode"]);
        assert_eq!(rt.spec("tiny_llama_decode").unwrap().inputs.len(), 2);
        assert!(rt.spec("missing").is_none());
        assert_eq!(rt.dir(), dir.as_path());
    }

    #[test]
    fn execute_seeded_is_deterministic_and_seed_sensitive() {
        let dir = std::env::temp_dir().join("cb_sim_runtime_det");
        write_manifest(&dir);
        let rt = Runtime::load_dir(&dir).unwrap();
        let a = rt.execute_seeded("tiny_llama_decode", 7).unwrap();
        let b = rt.execute_seeded("tiny_llama_decode", 7).unwrap();
        let c = rt.execute_seeded("tiny_llama_decode", 8).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 2);
        assert!(rt.execute_seeded("missing", 1).is_err());
    }
}
