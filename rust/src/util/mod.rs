//! Self-contained utility layer.
//!
//! The build environment is fully offline with a small vendored crate set,
//! so ConsumerBench carries its own minimal implementations of the pieces a
//! benchmark framework needs: a YAML-subset parser for workflow configs, a
//! deterministic PRNG for workload synthesis, descriptive statistics for
//! report generation, time-series storage for the system monitor, canonical
//! JSON rendering primitives shared by every machine-readable report, and a
//! tiny property-based testing kit used across the test suite.

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timeseries;
pub mod yaml;

pub use rng::Rng;
pub use stats::Summary;
pub use timeseries::TimeSeries;
pub use yaml::Value;
