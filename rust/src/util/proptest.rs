//! Minimal property-based testing kit.
//!
//! The vendored crate set has no `proptest`/`quickcheck`, so the test suite
//! uses this seeded mini-framework: a property is a closure over a `Gen`
//! (a thin wrapper around [`crate::util::Rng`] with sizing helpers); the
//! runner executes it for `cases` seeds and reports the failing seed so a
//! failure is reproducible with `check_seeded`.
//!
//! There is no shrinking — cases are kept small by construction instead
//! (generators take explicit size bounds).

use crate::util::rng::Rng;

/// Generator context handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Seed of this case, for failure reporting.
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            seed,
        }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector with length in [0, max_len] of generated elements.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize(0, max_len + 1);
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one of the provided items.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        self.rng.choice(items)
    }
}

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `prop` for `cases` deterministic seeds derived from `base_seed`;
/// panics with the failing seed and message on the first failure.
pub fn check(name: &str, base_seed: u64, cases: usize, mut prop: impl FnMut(&mut Gen) -> CaseResult) {
    for i in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i as u64 + 1);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property `{name}` failed at case {i}/{cases} (seed {seed:#x}): {msg}\n\
                 reproduce with util::proptest::check_seeded(\"{name}\", {seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_seeded(name: &str, seed: u64, mut prop: impl FnMut(&mut Gen) -> CaseResult) {
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("property `{name}` failed (seed {seed:#x}): {msg}");
    }
}

/// Assert helper that formats a property failure.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count", 1, 50, |_g| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 2, 10, |g| {
            let x = g.u64(0, 100);
            if x < 1000 {
                Err(format!("x = {x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Gen::new(99);
        let mut b = Gen::new(99);
        for _ in 0..20 {
            assert_eq!(a.u64(0, 1000), b.u64(0, 1000));
        }
    }

    #[test]
    fn vec_respects_max_len() {
        let mut g = Gen::new(3);
        for _ in 0..100 {
            let v = g.vec(7, |g| g.u64(0, 10));
            assert!(v.len() <= 7);
        }
    }

    #[test]
    fn prop_assert_macro_returns_err() {
        fn inner(x: u64) -> CaseResult {
            prop_assert!(x < 5, "x too big: {x}");
            Ok(())
        }
        assert!(inner(3).is_ok());
        assert_eq!(inner(9).unwrap_err(), "x too big: 9");
    }
}
