//! Descriptive statistics for benchmark reports.
//!
//! The report generator summarizes per-request latencies, SLO attainment, and
//! sampled system counters; everything here is allocation-light and exact
//! (percentiles by sorting, not sketches — request counts are small).

/// Summary statistics over a set of samples.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary. Returns `None` for an empty slice.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / count as f64;
        Some(Summary {
            count,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[count - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Linear-interpolated percentile of pre-sorted data. `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of unsorted data (sorts a copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    percentile_sorted(&sorted, p)
}

/// Fraction of samples that are <= the threshold. Used for SLO attainment:
/// attainment = fraction of request latencies within the SLO bound.
///
/// Returns `None` for an empty sample slice: a node whose requests never
/// ran (e.g. an OOM'd setup) has *no* attainment, not a perfect one —
/// report layers render it as `n/a` rather than 100%.
pub fn fraction_within(samples: &[f64], threshold: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    Some(samples.iter().filter(|&&x| x <= threshold).count() as f64 / samples.len() as f64)
}

/// Streaming mean/variance (Welford). Used by the monitor where sample
/// streams are long-lived and we do not want to retain every point.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [10.0, 20.0];
        assert!((percentile_sorted(&sorted, 50.0) - 15.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 10.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 20.0);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile_sorted(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn fraction_within_basics() {
        let xs = [0.5, 1.0, 1.5, 2.0];
        assert!((fraction_within(&xs, 1.0).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(fraction_within(&xs, 10.0), Some(1.0));
        assert_eq!(fraction_within(&xs, 0.1), Some(0.0));
    }

    #[test]
    fn fraction_within_empty_is_none_not_perfect() {
        // Regression: an empty sample set used to report 1.0 — a node whose
        // requests all failed would show 100% SLO attainment.
        assert_eq!(fraction_within(&[], 1.0), None);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 0.37).sin() * 5.0 + 2.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
        assert_eq!(w.count(), 1000);
    }

    #[test]
    fn welford_empty() {
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.std(), 0.0);
        assert_eq!(w.count(), 0);
    }
}
