//! Descriptive statistics for benchmark reports.
//!
//! The report generator summarizes per-request latencies, SLO attainment, and
//! sampled system counters; everything here is allocation-light and exact
//! (percentiles by sorting, not sketches — request counts are small).
//!
//! The fleet subsystem is the exception: a device-population sweep cannot
//! retain every sample, so it folds metrics into *mergeable* fixed-bin
//! sketches — [`FixedHistogram`] (exact `u64` bin counts, so merging is
//! associative, commutative, and shard-partition-invariant) and [`Moments`]
//! (Welford/Chan streaming mean/variance, merged in canonical shard order
//! so report bytes stay identical at any `--jobs`).

/// Summary statistics over a set of samples.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary. Returns `None` for an empty slice — and for a
    /// slice containing any NaN: a NaN sample means an upstream metric is
    /// broken, and the old `partial_cmp(..).expect("NaN in samples")` turned
    /// that into a panic deep inside report generation. Rejecting the whole
    /// set keeps the report pipeline alive and renders the field as `n/a`.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() || samples.iter().any(|x| x.is_nan()) {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / count as f64;
        Some(Summary {
            count,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[count - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Linear-interpolated percentile of pre-sorted data. `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of unsorted data (sorts a copy). `None` for an empty slice or
/// one containing NaN — the same rejection contract as [`Summary::of`], and
/// for the same reason: this used to panic via `partial_cmp(..).expect(..)`.
pub fn percentile(samples: &[f64], p: f64) -> Option<f64> {
    if samples.is_empty() || samples.iter().any(|x| x.is_nan()) {
        return None;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    Some(percentile_sorted(&sorted, p))
}

/// Fraction of samples that are <= the threshold. Used for SLO attainment:
/// attainment = fraction of request latencies within the SLO bound.
///
/// Returns `None` for an empty sample slice: a node whose requests never
/// ran (e.g. an OOM'd setup) has *no* attainment, not a perfect one —
/// report layers render it as `n/a` rather than 100%.
pub fn fraction_within(samples: &[f64], threshold: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    Some(samples.iter().filter(|&&x| x <= threshold).count() as f64 / samples.len() as f64)
}

/// Streaming mean/variance (Welford). Used by the monitor where sample
/// streams are long-lived and we do not want to retain every point.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Mergeable fixed-bin histogram over `[lo, hi)` with either log-scale or
/// linear bin edges, plus explicit underflow/overflow bins. The bin layout
/// is fixed at construction, and counts are exact `u64`s, so
/// [`FixedHistogram::merge`] is plain integer addition: **associative,
/// commutative, and shard-partition-invariant** — folding a population
/// through any sharding yields bit-identical counts, which is what lets the
/// fleet runner promise byte-identical reports at any `--jobs`.
///
/// Quantiles use the nearest-rank convention (the `k`-th smallest sample
/// with `k = round(q·(n−1))`) and answer with the bin's representative
/// value: the geometric midpoint for log bins, the arithmetic midpoint for
/// linear bins. The error versus the exact nearest-rank sample is therefore
/// at most half a bin: relative error `≤ (hi/lo)^(1/(2·bins)) − 1` for log
/// scale, absolute error `≤ (hi − lo)/(2·bins)` for linear. Samples landing
/// in the underflow/overflow bins answer exactly `lo`/`hi`.
///
/// NaN samples count into the underflow bin (the `!(x >= lo)` branch) so a
/// broken metric can never panic the fold path; the fleet runner filters
/// them out before folding anyway.
#[derive(Debug, Clone, PartialEq)]
pub struct FixedHistogram {
    log: bool,
    lo: f64,
    hi: f64,
    /// ln(lo) (log scale) or lo (linear) — the fold transform's offset.
    t_lo: f64,
    /// bins / (t(hi) − t(lo)) — the fold transform's scale.
    t_scale: f64,
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl FixedHistogram {
    /// Log-scale layout: `bins` geometric bins spanning `[lo, hi)`, `lo > 0`.
    pub fn log_scale(lo: f64, hi: f64, bins: usize) -> FixedHistogram {
        assert!(lo > 0.0 && hi > lo && bins > 0, "bad log layout");
        FixedHistogram {
            log: true,
            lo,
            hi,
            t_lo: lo.ln(),
            t_scale: bins as f64 / (hi.ln() - lo.ln()),
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    /// Linear layout: `bins` equal-width bins spanning `[lo, hi)`.
    pub fn linear(lo: f64, hi: f64, bins: usize) -> FixedHistogram {
        assert!(hi > lo && bins > 0, "bad linear layout");
        FixedHistogram {
            log: false,
            lo,
            hi,
            t_lo: lo,
            t_scale: bins as f64 / (hi - lo),
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    /// Half-bin quantile error bound of this layout (relative for log
    /// scale, absolute for linear) — the documented accuracy contract.
    pub fn error_bound(&self) -> f64 {
        let bins = self.counts.len() as f64;
        if self.log {
            (self.hi / self.lo).powf(1.0 / (2.0 * bins)) - 1.0
        } else {
            (self.hi - self.lo) / (2.0 * bins)
        }
    }

    /// Fold one sample. Total work is one transform + one increment.
    pub fn fold(&mut self, x: f64) {
        if !(x >= self.lo) {
            // Below range — and NaN, which fails every comparison.
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let t = if self.log { x.ln() } else { x };
            let idx = ((t - self.t_lo) * self.t_scale) as usize;
            // Float rounding at the top edge can land one past the end.
            let idx = idx.min(self.counts.len() - 1);
            self.counts[idx] += 1;
        }
    }

    /// Merge another histogram of the identical layout into this one.
    /// Exact integer addition — see the type docs for why this makes shard
    /// folds order-independent.
    pub fn merge(&mut self, other: &FixedHistogram) {
        assert!(
            self.log == other.log
                && self.lo == other.lo
                && self.hi == other.hi
                && self.counts.len() == other.counts.len(),
            "merging histograms with different layouts"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
    }

    /// Total folded samples (underflow and overflow included).
    pub fn count(&self) -> u64 {
        self.underflow + self.overflow + self.counts.iter().sum::<u64>()
    }

    /// Nearest-rank quantile, `q` in `[0, 1]`. `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        let k = ((q.clamp(0.0, 1.0) * (n - 1) as f64).round() as u64).min(n - 1);
        if k < self.underflow {
            return Some(self.lo);
        }
        let mut seen = self.underflow;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if k < seen {
                return Some(self.representative(i));
            }
        }
        Some(self.hi)
    }

    /// The representative (midpoint) value of interior bin `i`.
    fn representative(&self, i: usize) -> f64 {
        let frac = (i as f64 + 0.5) / self.t_scale;
        if self.log {
            (self.t_lo + frac).exp()
        } else {
            self.t_lo + frac
        }
    }

    /// Resident aggregation cells of this sketch (interior bins plus the
    /// two boundary bins) — the unit the fleet memory-bound accounting and
    /// its pinned test are expressed in.
    pub fn cells(&self) -> usize {
        self.counts.len() + 2
    }
}

/// Mergeable streaming moments: count, mean, M2 (for variance), min, max.
/// [`Moments::push`] is Welford's update; [`Moments::merge`] is Chan's
/// parallel combination. Counts and extrema merge exactly; mean/M2 are
/// floating point, so merging is associative only up to rounding — callers
/// that need byte-identical output (the fleet runner) must merge in a
/// canonical order, which is independent of `--jobs` by construction there.
#[derive(Debug, Clone, PartialEq)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Moments {
    fn default() -> Self {
        Moments::new()
    }
}

impl Moments {
    pub fn new() -> Moments {
        Moments {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Chan's parallel merge. Empty operands are identity elements, so a
    /// fold over empty shards is a no-op.
    pub fn merge(&mut self, other: &Moments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        self.mean += delta * other.n as f64 / n as f64;
        self.m2 += other.m2 + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Resident aggregation cells (one per scalar field).
    pub fn cells(&self) -> usize {
        5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [10.0, 20.0];
        assert!((percentile_sorted(&sorted, 50.0) - 15.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 10.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 20.0);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile_sorted(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn fraction_within_basics() {
        let xs = [0.5, 1.0, 1.5, 2.0];
        assert!((fraction_within(&xs, 1.0).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(fraction_within(&xs, 10.0), Some(1.0));
        assert_eq!(fraction_within(&xs, 0.1), Some(0.0));
    }

    #[test]
    fn fraction_within_empty_is_none_not_perfect() {
        // Regression: an empty sample set used to report 1.0 — a node whose
        // requests all failed would show 100% SLO attainment.
        assert_eq!(fraction_within(&[], 1.0), None);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 0.37).sin() * 5.0 + 2.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
        assert_eq!(w.count(), 1000);
    }

    #[test]
    fn welford_empty() {
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.std(), 0.0);
        assert_eq!(w.count(), 0);
    }

    #[test]
    fn summary_of_nan_is_none_not_panic() {
        // Regression: `Summary::of` used to panic via
        // `partial_cmp(..).expect("NaN in samples")` deep inside report
        // generation. A NaN sample now rejects the whole set.
        assert!(Summary::of(&[1.0, f64::NAN, 3.0]).is_none());
        assert!(Summary::of(&[f64::NAN]).is_none());
        // Infinities are orderable and stay summarizable.
        let s = Summary::of(&[1.0, f64::INFINITY]).unwrap();
        assert_eq!(s.max, f64::INFINITY);
    }

    #[test]
    fn percentile_nan_is_none_not_panic() {
        assert!(percentile(&[2.0, f64::NAN], 50.0).is_none());
        assert!(percentile(&[], 50.0).is_none());
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), Some(2.0));
    }

    /// Deterministic pseudo-samples without pulling in util::rng (cross-mod
    /// dev-dependency keeps this file self-contained): xorshift64*.
    fn samples(seed: u64, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                let u = (s.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f64
                    / (1u64 << 53) as f64;
                // Log-uniform spread across the range.
                lo * (hi / lo).powf(u)
            })
            .collect()
    }

    #[test]
    fn histogram_merge_is_associative_and_commutative() {
        let xs = samples(7, 600, 1e-3, 1e2);
        let mk = |slice: &[f64]| {
            let mut h = FixedHistogram::log_scale(1e-2, 1e1, 24);
            for &x in slice {
                h.fold(x);
            }
            h
        };
        let (a, b, c) = (mk(&xs[..200]), mk(&xs[200..350]), mk(&xs[350..]));
        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc);
        // a ⊕ b == b ⊕ a
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab_c.count(), 600);
    }

    #[test]
    fn histogram_merge_is_shard_count_invariant() {
        let xs = samples(11, 500, 1e-4, 1e3);
        let whole = {
            let mut h = FixedHistogram::log_scale(1e-3, 1e2, 60);
            for &x in &xs {
                h.fold(x);
            }
            h
        };
        for shard in [1usize, 7, 50, 499] {
            let mut merged = FixedHistogram::log_scale(1e-3, 1e2, 60);
            for chunk in xs.chunks(shard) {
                let mut h = FixedHistogram::log_scale(1e-3, 1e2, 60);
                for &x in chunk {
                    h.fold(x);
                }
                merged.merge(&h);
            }
            assert_eq!(merged, whole, "shard size {shard}");
        }
    }

    #[test]
    fn histogram_quantile_within_documented_error_bound() {
        let xs = samples(13, 400, 2e-3, 5e1);
        let mut h = FixedHistogram::log_scale(1e-4, 1e3, 96);
        for &x in &xs {
            h.fold(x);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(f64::total_cmp);
        let bound = h.error_bound();
        assert!((bound - 0.087).abs() < 0.01, "bound {bound}");
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let est = h.quantile(q).unwrap();
            let k = ((q * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
            let exact = sorted[k];
            assert!(
                (est - exact).abs() / exact <= bound,
                "q={q}: est {est} vs exact {exact}, bound {bound}"
            );
        }
    }

    #[test]
    fn histogram_boundary_bins_and_nan() {
        let mut h = FixedHistogram::log_scale(1.0, 100.0, 10);
        h.fold(0.5); // underflow
        h.fold(f64::NAN); // underflow, never a panic
        h.fold(150.0); // overflow
        h.fold(1.0); // first interior bin (lo is inclusive)
        assert_eq!(h.count(), 4);
        assert_eq!(h.quantile(0.0), Some(1.0)); // underflow answers lo
        assert_eq!(h.quantile(1.0), Some(100.0)); // overflow answers hi
        assert_eq!(h.cells(), 12);
    }

    #[test]
    fn linear_histogram_covers_attainment_range() {
        let mut h = FixedHistogram::linear(0.0, 1.0, 100);
        for i in 0..=100 {
            h.fold(i as f64 / 100.0);
        }
        // 1.0 lands in the overflow bin and answers exactly 1.0.
        assert_eq!(h.quantile(1.0), Some(1.0));
        assert_eq!(h.count(), 101);
        assert!((h.error_bound() - 0.005).abs() < 1e-12);
        let p50 = h.quantile(0.5).unwrap();
        assert!((p50 - 0.5).abs() <= h.error_bound() + 1e-12, "p50 {p50}");
    }

    #[test]
    fn moments_merge_matches_sequential_fold() {
        let xs = samples(17, 300, 1e-2, 1e2);
        let mut whole = Moments::new();
        for &x in &xs {
            whole.push(x);
        }
        for shard in [1usize, 9, 64] {
            let mut merged = Moments::new();
            for chunk in xs.chunks(shard) {
                let mut m = Moments::new();
                for &x in chunk {
                    m.push(x);
                }
                merged.merge(&m);
            }
            assert_eq!(merged.count(), whole.count());
            assert_eq!(merged.min(), whole.min());
            assert_eq!(merged.max(), whole.max());
            assert!((merged.mean() - whole.mean()).abs() / whole.mean() < 1e-12);
            assert!((merged.std() - whole.std()).abs() / whole.std() < 1e-9);
        }
    }

    #[test]
    fn moments_merge_commutes_and_empty_is_identity() {
        let xs = samples(19, 100, 0.1, 10.0);
        let mut a = Moments::new();
        let mut b = Moments::new();
        for &x in &xs[..40] {
            a.push(x);
        }
        for &x in &xs[40..] {
            b.push(x);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.count(), ba.count());
        assert!((ab.mean() - ba.mean()).abs() < 1e-12);
        assert!((ab.std() - ba.std()).abs() < 1e-12);
        let mut with_empty = a.clone();
        with_empty.merge(&Moments::new());
        assert_eq!(with_empty, a);
        let mut from_empty = Moments::new();
        from_empty.merge(&a);
        assert_eq!(from_empty, a);
    }
}
