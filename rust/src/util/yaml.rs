//! Minimal YAML-subset parser for ConsumerBench workflow configurations.
//!
//! The paper's input format (Fig. 2 / Fig. 23) uses a small, regular subset
//! of YAML: nested mappings by indentation, block sequences (`- item`),
//! inline sequences (`["a", "b"]`), scalars (strings, ints, floats, bools),
//! quoted strings, and `#` comments. This module parses exactly that subset
//! into a `Value` tree; the config schema layer (`coordinator::config`)
//! interprets the tree.
//!
//! Deliberately unsupported: anchors/aliases, multi-document streams, block
//! scalars, flow mappings, tabs for indentation (rejected with an error).


use std::fmt;

/// A parsed YAML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Seq(Vec<Value>),
    /// Insertion order is preserved separately because workflow semantics
    /// (e.g. display order of tasks) follow the file order.
    Map(Vec<(String, Value)>),
}

/// Parse error with 1-based line information.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("yaml parse error at line {line}: {msg}")]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_seq(&self) -> Option<&[Value]> {
        match self {
            Value::Seq(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Map(m) => Some(m),
            _ => None,
        }
    }

    /// Look up a key in a mapping.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Map(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Mapping keys in file order.
    pub fn keys(&self) -> Vec<&str> {
        match self {
            Value::Map(m) => m.iter().map(|(k, _)| k.as_str()).collect(),
            _ => Vec::new(),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s}"),
            Value::Seq(s) => {
                write!(f, "[")?;
                for (i, v) in s.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Map(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{k}: {v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Line {
    number: usize,
    indent: usize,
    content: String,
}

/// Parse a YAML document into a `Value`.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let lines = preprocess(text)?;
    if lines.is_empty() {
        return Ok(Value::Map(Vec::new()));
    }
    let mut pos = 0;
    let root_indent = lines[0].indent;
    let value = parse_block(&lines, &mut pos, root_indent)?;
    if pos < lines.len() {
        return Err(ParseError {
            line: lines[pos].number,
            msg: format!(
                "unexpected content at indent {} (expected <= {})",
                lines[pos].indent, root_indent
            ),
        });
    }
    Ok(value)
}

/// Strip comments and blank lines; reject tabs in indentation.
fn preprocess(text: &str) -> Result<Vec<Line>, ParseError> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let number = i + 1;
        let stripped = strip_comment(raw);
        if stripped.trim().is_empty() {
            continue;
        }
        let indent_str: String = stripped.chars().take_while(|c| c.is_whitespace()).collect();
        if indent_str.contains('\t') {
            return Err(ParseError {
                line: number,
                msg: "tabs are not allowed in indentation".into(),
            });
        }
        out.push(Line {
            number,
            indent: indent_str.len(),
            content: stripped.trim().to_string(),
        });
    }
    Ok(out)
}

/// Remove a trailing `#` comment that is not inside quotes.
fn strip_comment(line: &str) -> &str {
    let mut in_single = false;
    let mut in_double = false;
    for (idx, c) in line.char_indices() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '#' if !in_single && !in_double => {
                // YAML requires a preceding space (or line start) for comments.
                if idx == 0 || line[..idx].ends_with(' ') {
                    return &line[..idx];
                }
            }
            _ => {}
        }
    }
    line
}

/// Parse a block (mapping or sequence) whose items sit at `indent`.
fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, ParseError> {
    let first = &lines[*pos];
    if first.content.starts_with("- ") || first.content == "-" {
        parse_sequence(lines, pos, indent)
    } else {
        parse_mapping(lines, pos, indent)
    }
}

fn parse_sequence(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, ParseError> {
    let mut items = Vec::new();
    while *pos < lines.len() && lines[*pos].indent == indent {
        let line = &lines[*pos];
        if !(line.content.starts_with("- ") || line.content == "-") {
            break;
        }
        let rest = line.content[1..].trim().to_string();
        *pos += 1;
        if rest.is_empty() {
            // Nested block under the dash.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Value::Null);
            }
        } else if let Some(colon) = find_mapping_colon(&rest) {
            // `- key: value` starts an inline mapping item; subsequent keys
            // of the same item are indented deeper than the dash.
            let mut map = Vec::new();
            let (k, v) = split_key_value(&rest, colon, lines, pos, indent + 2)?;
            map.push((k, v));
            while *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                let Value::Map(more) = parse_mapping(lines, pos, child_indent)? else {
                    unreachable!("parse_mapping returns Map")
                };
                map.extend(more);
            }
            items.push(Value::Map(map));
        } else {
            items.push(parse_scalar(&rest));
        }
    }
    Ok(Value::Seq(items))
}

fn parse_mapping(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, ParseError> {
    let mut map: Vec<(String, Value)> = Vec::new();
    while *pos < lines.len() && lines[*pos].indent == indent {
        let line = &lines[*pos];
        if line.content.starts_with("- ") || line.content == "-" {
            break;
        }
        let colon = find_mapping_colon(&line.content).ok_or_else(|| ParseError {
            line: line.number,
            msg: format!("expected `key: value`, got `{}`", line.content),
        })?;
        let line_no = line.number;
        *pos += 1;
        let (key, value) = split_key_value(&line.content.clone(), colon, lines, pos, indent)?;
        if map.iter().any(|(k, _)| *k == key) {
            return Err(ParseError {
                line: line_no,
                msg: format!("duplicate key `{key}`"),
            });
        }
        map.push((key, value));
    }
    if map.is_empty() {
        return Err(ParseError {
            line: lines.get(*pos).map(|l| l.number).unwrap_or(0),
            msg: "expected a mapping".into(),
        });
    }
    Ok(Value::Map(map))
}

/// Split `key: value` at the given colon; if the value part is empty, parse
/// the following deeper-indented block as the value.
fn split_key_value(
    content: &str,
    colon: usize,
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
) -> Result<(String, Value), ParseError> {
    let key = unquote(content[..colon].trim());
    let rest = content[colon + 1..].trim();
    if rest.is_empty() {
        if *pos < lines.len() && lines[*pos].indent > indent {
            let child_indent = lines[*pos].indent;
            let v = parse_block(lines, pos, child_indent)?;
            Ok((key, v))
        } else {
            Ok((key, Value::Null))
        }
    } else {
        Ok((key, parse_scalar(rest)))
    }
}

/// Find the colon that separates key from value (not inside quotes or
/// brackets). Returns byte index.
fn find_mapping_colon(s: &str) -> Option<usize> {
    let mut in_single = false;
    let mut in_double = false;
    let mut depth = 0usize;
    for (idx, c) in s.char_indices() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '[' | '{' if !in_single && !in_double => depth += 1,
            ']' | '}' if !in_single && !in_double => depth = depth.saturating_sub(1),
            ':' if !in_single && !in_double && depth == 0 => {
                // Must be followed by space or end of line to be a mapping colon.
                let next = s[idx + 1..].chars().next();
                if next.is_none() || next == Some(' ') {
                    return Some(idx);
                }
            }
            _ => {}
        }
    }
    None
}

fn unquote(s: &str) -> String {
    let s = s.trim();
    if (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
        || (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

/// Parse a scalar or inline sequence.
fn parse_scalar(s: &str) -> Value {
    let s = s.trim();
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let items = split_inline_items(inner);
        return Value::Seq(items.iter().map(|i| parse_scalar(i)).collect());
    }
    if s.starts_with('"') || s.starts_with('\'') {
        return Value::Str(unquote(s));
    }
    match s {
        "null" | "~" | "" => return Value::Null,
        "true" | "True" => return Value::Bool(true),
        "false" | "False" => return Value::Bool(false),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Value::Int(i);
    }
    if let Ok(f) = s.parse::<f64>() {
        return Value::Float(f);
    }
    Value::Str(s.to_string())
}

/// Split `a, b, c` at top-level commas (respecting quotes and brackets).
fn split_inline_items(s: &str) -> Vec<String> {
    let mut items = Vec::new();
    let mut current = String::new();
    let mut in_single = false;
    let mut in_double = false;
    let mut depth = 0usize;
    for c in s.chars() {
        match c {
            '\'' if !in_double => {
                in_single = !in_single;
                current.push(c);
            }
            '"' if !in_single => {
                in_double = !in_double;
                current.push(c);
            }
            '[' | '{' if !in_single && !in_double => {
                depth += 1;
                current.push(c);
            }
            ']' | '}' if !in_single && !in_double => {
                depth = depth.saturating_sub(1);
                current.push(c);
            }
            ',' if !in_single && !in_double && depth == 0 => {
                items.push(current.trim().to_string());
                current.clear();
            }
            _ => current.push(c),
        }
    }
    if !current.trim().is_empty() {
        items.push(current.trim().to_string());
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_mapping() {
        let v = parse("model: Llama-3.2-3B\nnum_requests: 5\nslo: 1.5\nbackground: true\n").unwrap();
        assert_eq!(v.get("model").unwrap().as_str(), Some("Llama-3.2-3B"));
        assert_eq!(v.get("num_requests").unwrap().as_i64(), Some(5));
        assert_eq!(v.get("slo").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("background").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn nested_mapping() {
        let text = "\
tasks:
  chat:
    model: llama
    device: gpu
  img:
    model: sd
";
        let v = parse(text).unwrap();
        let tasks = v.get("tasks").unwrap();
        assert_eq!(tasks.keys(), vec!["chat", "img"]);
        assert_eq!(
            tasks.get("chat").unwrap().get("device").unwrap().as_str(),
            Some("gpu")
        );
    }

    #[test]
    fn inline_sequence() {
        let v = parse("depend_on: [\"analysis_1\", brainstorm]\n").unwrap();
        let deps = v.get("depend_on").unwrap().as_seq().unwrap();
        assert_eq!(deps.len(), 2);
        assert_eq!(deps[0].as_str(), Some("analysis_1"));
        assert_eq!(deps[1].as_str(), Some("brainstorm"));
    }

    #[test]
    fn block_sequence() {
        let text = "\
items:
  - alpha
  - 42
  - true
";
        let v = parse(text).unwrap();
        let items = v.get("items").unwrap().as_seq().unwrap();
        assert_eq!(items[0].as_str(), Some("alpha"));
        assert_eq!(items[1].as_i64(), Some(42));
        assert_eq!(items[2].as_bool(), Some(true));
    }

    #[test]
    fn sequence_of_mappings() {
        let text = "\
apps:
  - name: chat
    slo: 1
  - name: img
    slo: 2
";
        let v = parse(text).unwrap();
        let apps = v.get("apps").unwrap().as_seq().unwrap();
        assert_eq!(apps.len(), 2);
        assert_eq!(apps[0].get("name").unwrap().as_str(), Some("chat"));
        assert_eq!(apps[1].get("slo").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn comments_and_blank_lines() {
        let text = "\
# header comment
a: 1

b: 2  # trailing
";
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let v = parse("name: \"seg #4\"\n").unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("seg #4"));
    }

    #[test]
    fn paper_fig2_style_config() {
        let text = "\
Analysis (DeepResearch):
  model: Llama-3.2-3B
  num_requests: 1
  device: cpu
Creating Cover Art (ImageGen):
  model: SD-3.5-Medium-Turbo
  num_requests: 5
  device: gpu
  slo: 1s
Generating Captions (LiveCaptions):
  model: Whisper-Large-V3-Turbo
  num_requests: 1
  device: gpu
workflows:
  analysis_1:
    uses: Analysis (DeepResearch)
  cover_art:
    uses: Creating Cover Art (ImageGen)
    depend_on: [\"analysis_1\"]
";
        let v = parse(text).unwrap();
        assert_eq!(v.keys().len(), 4);
        assert_eq!(
            v.get("Creating Cover Art (ImageGen)")
                .unwrap()
                .get("slo")
                .unwrap()
                .as_str(),
            Some("1s")
        );
        let wf = v.get("workflows").unwrap();
        assert_eq!(
            wf.get("cover_art").unwrap().get("depend_on").unwrap().as_seq().unwrap()[0].as_str(),
            Some("analysis_1")
        );
    }

    #[test]
    fn duplicate_key_rejected() {
        let err = parse("a: 1\na: 2\n").unwrap_err();
        assert!(err.msg.contains("duplicate key"));
        assert_eq!(err.line, 2);
    }

    #[test]
    fn tabs_rejected() {
        let err = parse("a:\n\tb: 1\n").unwrap_err();
        assert!(err.msg.contains("tabs"));
    }

    #[test]
    fn missing_colon_rejected() {
        let err = parse("just a string line\n").unwrap_err();
        assert!(err.msg.contains("key: value"));
    }

    #[test]
    fn empty_document() {
        assert_eq!(parse("").unwrap(), Value::Map(Vec::new()));
        assert_eq!(parse("# only comments\n").unwrap(), Value::Map(Vec::new()));
    }

    #[test]
    fn null_value_for_empty() {
        let v = parse("key:\n").unwrap();
        assert_eq!(v.get("key"), Some(&Value::Null));
    }

    #[test]
    fn quoted_keys() {
        let v = parse("\"weird key: yes\": 1\n").unwrap();
        // The colon inside quotes must not split the key.
        assert_eq!(v.get("weird key: yes").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn float_and_negative() {
        let v = parse("a: -3\nb: 2.5\nc: -0.5\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(-3));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("c").unwrap().as_f64(), Some(-0.5));
    }

    #[test]
    fn urls_stay_strings() {
        // `http://x` has a colon not followed by space → not a mapping colon.
        let v = parse("url: http://example.com/a\n").unwrap();
        assert_eq!(v.get("url").unwrap().as_str(), Some("http://example.com/a"));
    }

    #[test]
    fn display_round_trip_flavour() {
        let v = parse("a: 1\nb: [x, y]\n").unwrap();
        let s = format!("{v}");
        assert!(s.contains("a: 1"));
        assert!(s.contains("[x, y]"));
    }
}
