//! Deterministic PRNG for workload synthesis and property tests.
//!
//! xorshift64* — small, fast, and good enough for workload sampling. Every
//! experiment seeds its generators explicitly so runs are bit-reproducible.

/// A seeded xorshift64* generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a non-zero seed (zero is mapped to a fixed
    /// constant — xorshift has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform float in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "range_u64: empty range [{lo}, {hi})");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform float in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "exponential: rate must be positive");
        -self.next_f64().max(1e-12).ln() / rate
    }

    /// Log-normal: exp(N(mu, sigma)). Used for prompt/output length models,
    /// which are heavy-tailed in the real traces the paper samples.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gaussian()).exp()
    }

    /// Pick an element uniformly from a non-empty slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "choice: empty slice");
        &items[self.range_usize(0, items.len())]
    }

    /// Sample an index according to the given non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: weights sum to zero");
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range_usize(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Fork a child generator with decorrelated state (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA24BAED4963EE407)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn zero_seed_is_not_stuck() {
        let mut r = Rng::new(0);
        let first = r.next_u64();
        assert_ne!(first, 0);
        assert_ne!(first, r.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x), "{x} out of [0,1)");
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range_u64(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn weighted_index_prefers_heavy_weight() {
        let mut r = Rng::new(17);
        let w = [1.0, 8.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert!(counts[1] > counts[0] * 4);
        assert!(counts[1] > counts[2] * 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(23);
        let mut child = parent.fork();
        let same = (0..64)
            .filter(|_| parent.next_u64() == child.next_u64())
            .count();
        assert!(same < 4);
    }
}
