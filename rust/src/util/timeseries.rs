//! Time-series storage for the system monitor.
//!
//! The simulator's counters (SMACT, SMOCC, bandwidth, power, ...) are sampled
//! on a fixed virtual-time grid, mirroring how the paper samples DCGM /
//! pcm-memory / NVML at a fixed wall-clock interval. A `TimeSeries` is a
//! named sequence of (t_seconds, value) points plus helpers to aggregate,
//! window, and render sparkline-style summaries for reports.

use crate::util::stats::Summary;

/// A named series of timestamped samples. Timestamps are virtual seconds and
/// must be pushed in non-decreasing order.
#[derive(Debug, Clone)]
pub struct TimeSeries {
    pub name: String,
    pub unit: String,
    times: Vec<f64>,
    values: Vec<f64>,
}

impl TimeSeries {
    pub fn new(name: impl Into<String>, unit: impl Into<String>) -> Self {
        TimeSeries {
            name: name.into(),
            unit: unit.into(),
            times: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Append a sample; panics if time goes backwards (monitor bug).
    pub fn push(&mut self, t: f64, v: f64) {
        if let Some(&last) = self.times.last() {
            assert!(
                t >= last,
                "time went backwards in series {}: {} < {}",
                self.name,
                t,
                last
            );
        }
        self.times.push(t);
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    pub fn times(&self) -> &[f64] {
        &self.times
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn iter(&self) -> impl Iterator<Item = (f64, f64)> + '_ {
        self.times.iter().copied().zip(self.values.iter().copied())
    }

    /// Samples within the half-open window [t0, t1).
    pub fn window(&self, t0: f64, t1: f64) -> Vec<f64> {
        self.iter()
            .filter(|(t, _)| *t >= t0 && *t < t1)
            .map(|(_, v)| v)
            .collect()
    }

    /// Mean over the whole series (0 for an empty series).
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            0.0
        } else {
            self.values.iter().sum::<f64>() / self.values.len() as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Time-weighted integral (e.g. power [W] → energy [J]) by trapezoid rule.
    pub fn integral(&self) -> f64 {
        let mut acc = 0.0;
        for i in 1..self.times.len() {
            let dt = self.times[i] - self.times[i - 1];
            acc += 0.5 * (self.values[i] + self.values[i - 1]) * dt;
        }
        acc
    }

    /// Summary statistics of the values.
    pub fn summary(&self) -> Option<Summary> {
        Summary::of(&self.values)
    }

    /// Downsample onto a fixed grid of `buckets` means — used when rendering
    /// long traces as compact rows in the text report.
    pub fn rebucket(&self, buckets: usize) -> Vec<f64> {
        assert!(buckets > 0);
        if self.is_empty() {
            return vec![0.0; buckets];
        }
        let t0 = self.times[0];
        let t1 = *self.times.last().unwrap();
        let span = (t1 - t0).max(1e-9);
        let mut sums = vec![0.0; buckets];
        let mut counts = vec![0usize; buckets];
        for (t, v) in self.iter() {
            let idx = (((t - t0) / span) * buckets as f64).min(buckets as f64 - 1.0) as usize;
            sums[idx] += v;
            counts[idx] += 1;
        }
        sums.iter()
            .zip(&counts)
            .map(|(s, &c)| if c == 0 { 0.0 } else { s / c as f64 })
            .collect()
    }

    /// Unicode sparkline of the series, normalized to [0, scale_max].
    pub fn sparkline(&self, buckets: usize, scale_max: f64) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let vals = self.rebucket(buckets);
        vals.iter()
            .map(|&v| {
                let frac = (v / scale_max.max(1e-9)).clamp(0.0, 1.0);
                BARS[((frac * 7.0).round()) as usize]
            })
            .collect()
    }

    /// Render as CSV lines (`t,value`).
    pub fn to_csv(&self) -> String {
        let mut out = format!("t_seconds,{} ({})\n", self.name, self.unit);
        for (t, v) in self.iter() {
            out.push_str(&format!("{t:.4},{v:.6}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series(points: &[(f64, f64)]) -> TimeSeries {
        let mut s = TimeSeries::new("test", "u");
        for &(t, v) in points {
            s.push(t, v);
        }
        s
    }

    #[test]
    fn push_and_iterate() {
        let s = series(&[(0.0, 1.0), (1.0, 2.0), (2.0, 3.0)]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.values(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "time went backwards")]
    fn non_monotone_time_panics() {
        let mut s = TimeSeries::new("t", "u");
        s.push(1.0, 0.0);
        s.push(0.5, 0.0);
    }

    #[test]
    fn window_half_open() {
        let s = series(&[(0.0, 1.0), (1.0, 2.0), (2.0, 3.0)]);
        assert_eq!(s.window(0.5, 2.0), vec![2.0]);
        assert_eq!(s.window(0.0, 3.0), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn integral_trapezoid() {
        // Constant 100 W over 10 s → 1000 J.
        let s = series(&[(0.0, 100.0), (5.0, 100.0), (10.0, 100.0)]);
        assert!((s.integral() - 1000.0).abs() < 1e-9);
        // Ramp 0→10 over 1 s → 5 J.
        let r = series(&[(0.0, 0.0), (1.0, 10.0)]);
        assert!((r.integral() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn rebucket_means() {
        let s = series(&[(0.0, 2.0), (0.4, 4.0), (0.6, 6.0), (1.0, 8.0)]);
        let b = s.rebucket(2);
        assert_eq!(b.len(), 2);
        assert!((b[0] - 3.0).abs() < 1e-9); // samples at 0.0, 0.4
        assert!((b[1] - 7.0).abs() < 1e-9); // samples at 0.6, 1.0
    }

    #[test]
    fn rebucket_empty() {
        let s = TimeSeries::new("e", "u");
        assert_eq!(s.rebucket(4), vec![0.0; 4]);
    }

    #[test]
    fn sparkline_shape() {
        let s = series(&[(0.0, 0.0), (1.0, 50.0), (2.0, 100.0)]);
        let spark = s.sparkline(3, 100.0);
        assert_eq!(spark.chars().count(), 3);
        let chars: Vec<char> = spark.chars().collect();
        assert!(chars[0] < chars[2], "sparkline should increase: {spark}");
    }

    #[test]
    fn csv_round_numbers() {
        let s = series(&[(0.0, 1.0)]);
        let csv = s.to_csv();
        assert!(csv.starts_with("t_seconds,test (u)\n"));
        assert!(csv.contains("0.0000,1.000000"));
    }

    #[test]
    fn mean_and_max() {
        let s = series(&[(0.0, 1.0), (1.0, 3.0)]);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.max(), 3.0);
    }
}
