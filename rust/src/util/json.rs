//! Minimal deterministic JSON rendering helpers.
//!
//! The offline crate set has no serde, and the scenario/bench reports need
//! *canonical* output anyway (byte-identical across runs — the golden-trace
//! contract), so the emitters hand-roll their JSON from two primitives
//! shared here: escaped string literals and shortest-roundtrip numbers.
//! Used by the scenario-matrix report (`scenario::runner`), the workflow
//! report (`coordinator::report`), and the micro-benchmark suite
//! (`benches/microbench.rs`).

/// JSON string literal with escaping.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number: shortest-roundtrip rendering; non-finite values (a failed
/// request's ∞ normalized latency) become `null`.
pub fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Optional JSON number: `None` renders as `null` (e.g. the attainment of a
/// node with no completed requests).
pub fn json_opt_num(x: Option<f64>) -> String {
    x.map(json_num).unwrap_or_else(|| "null".to_string())
}

/// Optional JSON boolean: `None` renders as `null` (e.g. an SLO verdict
/// with no configured bound).
pub fn json_opt_bool(x: Option<bool>) -> &'static str {
    match x {
        Some(true) => "true",
        Some(false) => "false",
        None => "null",
    }
}

/// Parsed JSON value — the read side of the journal/checkpoint layer.
///
/// Numbers are `f64`: the emitters above render shortest-roundtrip, so a
/// parse → re-render cycle is byte-exact for every value this crate writes
/// (the checkpoint/resume byte-identity contract rests on this).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object member by key (objects preserve insertion order; keys written
    /// by this crate are unique).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, JsonValue::Null)
    }
}

/// Parse one JSON document. Rejects trailing non-whitespace — a truncated
/// journal line therefore fails cleanly instead of yielding a prefix value.
pub fn parse(text: &str) -> Result<JsonValue, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing characters at byte {pos}"));
    }
    Ok(value)
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => {
            *pos += 1;
            let mut members = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(JsonValue::Obj(members));
            }
            loop {
                skip_ws(bytes, pos);
                let key = match parse_value(bytes, pos)? {
                    JsonValue::Str(s) => s,
                    other => return Err(format!("object key must be a string, got {other:?}")),
                };
                skip_ws(bytes, pos);
                if bytes.get(*pos) != Some(&b':') {
                    return Err(format!("expected `:` at byte {pos}", pos = *pos));
                }
                *pos += 1;
                let value = parse_value(bytes, pos)?;
                members.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(JsonValue::Obj(members));
                    }
                    _ => return Err(format!("expected `,` or `}}` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(JsonValue::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(JsonValue::Arr(items));
                    }
                    _ => return Err(format!("expected `,` or `]` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'"') => parse_string(bytes, pos).map(JsonValue::Str),
        Some(b't') => parse_literal(bytes, pos, "true", JsonValue::Bool(true)),
        Some(b'f') => parse_literal(bytes, pos, "false", JsonValue::Bool(false)),
        Some(b'n') => parse_literal(bytes, pos, "null", JsonValue::Null),
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_literal(
    bytes: &[u8],
    pos: &mut usize,
    lit: &str,
    value: JsonValue,
) -> Result<JsonValue, String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("invalid literal at byte {pos}", pos = *pos))
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(bytes[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&b) = bytes.get(*pos) else {
            return Err("unterminated string".into());
        };
        match b {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                let Some(&esc) = bytes.get(*pos) else {
                    return Err("unterminated escape".into());
                };
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hex = bytes
                            .get(*pos..*pos + 4)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or("truncated \\u escape")?;
                        let cp =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape".to_string())?;
                        *pos += 4;
                        // The emitters only write \u for C0 controls; other
                        // code points (incl. surrogates, which this crate
                        // never writes) fall back to the replacement char.
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("unknown escape \\{}", other as char)),
                }
            }
            _ => {
                // Consume one UTF-8 scalar (input is &str, so boundaries
                // are valid; find the char starting here).
                let rest = &bytes[*pos..];
                let s = std::str::from_utf8(rest).map_err(|_| "invalid utf-8".to_string())?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&bytes[start..*pos]).map_err(|_| "invalid utf-8".to_string())?;
    s.parse::<f64>()
        .map(JsonValue::Num)
        .map_err(|_| format!("invalid number `{s}` at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping_and_numbers() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
        assert_eq!(json_num(1.5), "1.5");
        assert_eq!(json_num(f64::INFINITY), "null");
        assert_eq!(json_num(f64::NAN), "null");
    }

    #[test]
    fn numbers_roundtrip_shortest() {
        assert_eq!(json_num(0.1), "0.1");
        assert_eq!(json_num(3.0), "3");
        assert_eq!(json_num(-2.25), "-2.25");
    }

    #[test]
    fn optional_values_render_null() {
        assert_eq!(json_opt_num(Some(0.5)), "0.5");
        assert_eq!(json_opt_num(None), "null");
        assert_eq!(json_opt_bool(Some(true)), "true");
        assert_eq!(json_opt_bool(Some(false)), "false");
        assert_eq!(json_opt_bool(None), "null");
    }

    #[test]
    fn parse_roundtrips_document() {
        let doc = r#"{"name":"a/b=c","n":3,"x":0.1,"neg":-2.25,"ok":true,"none":null,"arr":[1,2.5,"s"],"nested":{"k":"v"}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("a/b=c"));
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("x").unwrap().as_f64(), Some(0.1));
        assert_eq!(v.get("neg").unwrap().as_f64(), Some(-2.25));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert!(v.get("none").unwrap().is_null());
        let arr = v.get("arr").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_str(), Some("s"));
        assert_eq!(v.get("nested").unwrap().get("k").unwrap().as_str(), Some("v"));
    }

    #[test]
    fn parse_rejects_truncation_and_trailing() {
        assert!(parse(r#"{"a":1"#).is_err());
        assert!(parse(r#"{"a":1} extra"#).is_err());
        assert!(parse("").is_err());
        assert!(parse(r#"{"a":}"#).is_err());
    }

    #[test]
    fn parse_inverts_emitters_byte_exactly() {
        // The resume contract: every number the emitters write re-renders
        // to the same bytes after a parse cycle.
        for x in [0.1, 3.0, -2.25, 1e-9, 123456.789, f64::MAX, 5e-324] {
            let rendered = json_num(x);
            let back = parse(&rendered).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
            assert_eq!(json_num(back), rendered);
        }
        for s in ["plain", "quo\"te", "back\\slash", "new\nline", "\u{1}ctl", "héllo"] {
            let rendered = json_str(s);
            let back = parse(&rendered).unwrap();
            assert_eq!(back.as_str(), Some(s));
            assert_eq!(json_str(back.as_str().unwrap()), rendered);
        }
    }

    #[test]
    fn parse_handles_escapes() {
        let v = parse(r#""aA\n\t\\\"/""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\n\t\\\"/"));
    }
}
