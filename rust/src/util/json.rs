//! Minimal deterministic JSON rendering helpers.
//!
//! The offline crate set has no serde, and the scenario/bench reports need
//! *canonical* output anyway (byte-identical across runs — the golden-trace
//! contract), so the emitters hand-roll their JSON from two primitives
//! shared here: escaped string literals and shortest-roundtrip numbers.
//! Used by the scenario-matrix report (`scenario::runner`), the workflow
//! report (`coordinator::report`), and the micro-benchmark suite
//! (`benches/microbench.rs`).

/// JSON string literal with escaping.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number: shortest-roundtrip rendering; non-finite values (a failed
/// request's ∞ normalized latency) become `null`.
pub fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Optional JSON number: `None` renders as `null` (e.g. the attainment of a
/// node with no completed requests).
pub fn json_opt_num(x: Option<f64>) -> String {
    x.map(json_num).unwrap_or_else(|| "null".to_string())
}

/// Optional JSON boolean: `None` renders as `null` (e.g. an SLO verdict
/// with no configured bound).
pub fn json_opt_bool(x: Option<bool>) -> &'static str {
    match x {
        Some(true) => "true",
        Some(false) => "false",
        None => "null",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping_and_numbers() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
        assert_eq!(json_num(1.5), "1.5");
        assert_eq!(json_num(f64::INFINITY), "null");
        assert_eq!(json_num(f64::NAN), "null");
    }

    #[test]
    fn numbers_roundtrip_shortest() {
        assert_eq!(json_num(0.1), "0.1");
        assert_eq!(json_num(3.0), "3");
        assert_eq!(json_num(-2.25), "-2.25");
    }

    #[test]
    fn optional_values_render_null() {
        assert_eq!(json_opt_num(Some(0.5)), "0.5");
        assert_eq!(json_opt_num(None), "null");
        assert_eq!(json_opt_bool(Some(true)), "true");
        assert_eq!(json_opt_bool(Some(false)), "false");
        assert_eq!(json_opt_bool(None), "null");
    }
}
