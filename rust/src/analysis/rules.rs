//! The per-file determinism rules.
//!
//! Every rule here operates on the *masked* code view produced by
//! [`super::lexer`]: comments, string bodies, and `#[cfg(test)] mod` bodies
//! are already blanked, so a match is always real code on a real line.
//! Offsets in the masked view are byte-identical to the original file, so
//! diagnostics resolve to true `file:line` positions.

use super::lexer::{is_ident, LineIndex};
use super::Diagnostic;

/// Modules whose output feeds the golden trace digest or the report bytes.
/// Iteration order anywhere in these paths can leak into artifacts.
const DIGEST_SCOPES: &[&str] = &[
    "src/gpusim/",
    "src/scenario/",
    "src/coordinator/",
    "src/server/",
    "src/apps/",
];

/// Identifiers whose mere construction pulls in ambient (non-seed) entropy.
const ENTROPY_TOKENS: &[(&str, &str)] = &[
    ("thread_rng", "OS-seeded RNG"),
    ("OsRng", "OS entropy source"),
    ("from_entropy", "OS-seeded RNG constructor"),
    ("getrandom", "raw OS entropy"),
    ("RandomState", "randomly keyed hasher state"),
    ("DefaultHasher", "randomly keyed hasher state"),
];

/// Run every per-file rule over one masked source file.
pub fn run_rules(rel: &str, code: &str, lines: &LineIndex) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    unordered_iteration(rel, code, lines, &mut diags);
    wall_clock(rel, code, lines, &mut diags);
    poisonable_unwrap(rel, code, lines, &mut diags);
    float_order(rel, code, lines, &mut diags);
    ambient_entropy(rel, code, lines, &mut diags);
    diags
}

/// Boundary-aware occurrences of `token` in `code`: the match may not be
/// preceded or followed by an identifier character, so `HashMap` never
/// matches inside `NoHashMapHere` and `68` never matches inside `168`.
pub fn find_token(code: &str, token: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = code[from..].find(token) {
        let at = from + rel;
        from = at + 1;
        let end = at + token.len();
        let before_ok = at == 0 || !is_ident(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if before_ok && after_ok {
            out.push(at);
        }
    }
    out
}

fn unordered_iteration(rel: &str, code: &str, lines: &LineIndex, diags: &mut Vec<Diagnostic>) {
    if !DIGEST_SCOPES.iter().any(|scope| rel.contains(scope)) {
        return;
    }
    for token in ["HashMap", "HashSet"] {
        for at in find_token(code, token) {
            diags.push(Diagnostic {
                rule: "no-unordered-iteration",
                file: rel.to_string(),
                line: lines.line_of(at),
                message: format!(
                    "`{token}` in a digest-affecting module: std hash iteration order is \
                     seeded per-process and can leak into report bytes; use \
                     BTreeMap/BTreeSet or a sorted Vec"
                ),
            });
        }
    }
}

fn wall_clock(rel: &str, code: &str, lines: &LineIndex, diags: &mut Vec<Diagnostic>) {
    for (token, what) in [
        ("Instant::now", "`Instant::now()`"),
        ("SystemTime", "`SystemTime`"),
    ] {
        for at in find_token(code, token) {
            diags.push(Diagnostic {
                rule: "no-wall-clock",
                file: rel.to_string(),
                line: lines.line_of(at),
                message: format!(
                    "{what} reads the host clock: results must be a pure function of the \
                     scenario seed, so all timing flows from virtual engine time"
                ),
            });
        }
    }
}

fn poisonable_unwrap(rel: &str, code: &str, lines: &LineIndex, diags: &mut Vec<Diagnostic>) {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(rel_at) = code[from..].find(".lock") {
        let at = from + rel_at;
        from = at + 1;
        let mut j = at + ".lock".len();
        skip_ws(bytes, &mut j);
        if bytes.get(j) != Some(&b'(') {
            continue;
        }
        j += 1;
        skip_ws(bytes, &mut j);
        if bytes.get(j) != Some(&b')') {
            continue;
        }
        j += 1;
        skip_ws(bytes, &mut j);
        if bytes.get(j) != Some(&b'.') {
            continue;
        }
        j += 1;
        skip_ws(bytes, &mut j);
        let method_at = j;
        let method = read_ident(code, &mut j);
        if method == "unwrap" || method == "expect" {
            diags.push(Diagnostic {
                rule: "no-poisonable-unwrap",
                file: rel.to_string(),
                line: lines.line_of(method_at),
                message: format!(
                    "`.lock().{method}(…)` double-panics when a holder already panicked; \
                     recover the guard with `.unwrap_or_else(|e| e.into_inner())` and \
                     state why the protected data stays consistent"
                ),
            });
        }
    }
}

fn float_order(rel: &str, code: &str, lines: &LineIndex, diags: &mut Vec<Diagnostic>) {
    for fty in ["f32", "f64"] {
        let pat = format!(".sum::<{fty}>()");
        let mut from = 0;
        while let Some(rel_at) = code[from..].find(&pat) {
            let at = from + rel_at;
            from = at + 1;
            let Some(root) = chain_root(code, at) else {
                continue;
            };
            if hash_associated(code, &root) {
                diags.push(Diagnostic {
                    rule: "no-float-order-hazard",
                    file: rel.to_string(),
                    line: lines.line_of(at),
                    message: format!(
                        "`.sum::<{fty}>()` over hash-backed `{root}`: float addition is \
                         order-sensitive and hash iteration order is not deterministic; \
                         sum from a BTree/sorted source"
                    ),
                });
            }
        }
    }
}

fn ambient_entropy(rel: &str, code: &str, lines: &LineIndex, diags: &mut Vec<Diagnostic>) {
    // util/rng.rs is the one sanctioned RNG implementation.
    if rel.ends_with("util/rng.rs") {
        return;
    }
    for (token, what) in ENTROPY_TOKENS {
        for at in find_token(code, token) {
            diags.push(Diagnostic {
                rule: "no-ambient-entropy",
                file: rel.to_string(),
                line: lines.line_of(at),
                message: format!(
                    "`{token}` is {what}: all randomness must derive from the scenario \
                     seed via util::rng"
                ),
            });
        }
    }
    // A literal-seeded `Rng::new(…)` severs the stream from the scenario
    // seed just as surely as OS entropy randomizes it.
    let bytes = code.as_bytes();
    for at in find_token(code, "Rng::new") {
        let mut j = at + "Rng::new".len();
        skip_ws(bytes, &mut j);
        if bytes.get(j) != Some(&b'(') {
            continue;
        }
        let open = j;
        let mut depth = 0usize;
        let mut close = None;
        while j < bytes.len() {
            match bytes[j] {
                b'(' => depth += 1,
                b')' => {
                    depth -= 1;
                    if depth == 0 {
                        close = Some(j);
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let Some(close) = close else {
            continue;
        };
        if !contains_identifier(&code[open + 1..close]) {
            diags.push(Diagnostic {
                rule: "no-ambient-entropy",
                file: rel.to_string(),
                line: lines.line_of(at),
                message: "`Rng::new(…)` seeded from a bare literal: derive every seed \
                          from the scenario seed so streams stay reproducible and \
                          decorrelated"
                    .to_string(),
            });
        }
    }
}

fn skip_ws(bytes: &[u8], j: &mut usize) {
    while bytes.get(*j).copied().is_some_and(|b| b.is_ascii_whitespace()) {
        *j += 1;
    }
}

fn read_ident<'a>(code: &'a str, j: &mut usize) -> &'a str {
    let bytes = code.as_bytes();
    let start = *j;
    while bytes.get(*j).copied().is_some_and(is_ident) {
        *j += 1;
    }
    &code[start..*j]
}

/// Walk a method chain backwards from the `.` at `dot` to its root
/// identifier: over whitespace, `?`, balanced `(…)`/`[…]`, and `.method`
/// segments. Returns the root local/field name, or `None` when the
/// receiver is an expression we cannot name (conservatively not flagged).
fn chain_root(code: &str, dot: usize) -> Option<String> {
    let bytes = code.as_bytes();
    let mut j = dot;
    loop {
        let mut k = j;
        while k > 0 && bytes[k - 1].is_ascii_whitespace() {
            k -= 1;
        }
        if k == 0 {
            return None;
        }
        match bytes[k - 1] {
            b'?' => {
                j = k - 1;
            }
            b')' | b']' => {
                let close = bytes[k - 1];
                let open = if close == b')' { b'(' } else { b'[' };
                let mut depth = 0usize;
                let mut m = k;
                loop {
                    if m == 0 {
                        return None;
                    }
                    m -= 1;
                    if bytes[m] == close {
                        depth += 1;
                    } else if bytes[m] == open {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                }
                let mut k2 = m;
                while k2 > 0 && bytes[k2 - 1].is_ascii_whitespace() {
                    k2 -= 1;
                }
                if k2 > 0 && is_ident(bytes[k2 - 1]) {
                    // `name(…)`: a method segment if a `.` precedes the
                    // name, otherwise a free function call (unnamed root).
                    let mut s = k2;
                    while s > 0 && is_ident(bytes[s - 1]) {
                        s -= 1;
                    }
                    let mut k3 = s;
                    while k3 > 0 && bytes[k3 - 1].is_ascii_whitespace() {
                        k3 -= 1;
                    }
                    if k3 > 0 && bytes[k3 - 1] == b'.' {
                        j = k3 - 1;
                        continue;
                    }
                    return None;
                }
                if close == b']' {
                    // Indexing: keep walking toward the indexed receiver.
                    j = m;
                    continue;
                }
                return None;
            }
            c if is_ident(c) => {
                let end = k;
                let mut s = k;
                while s > 0 && is_ident(bytes[s - 1]) {
                    s -= 1;
                }
                let name = &code[s..end];
                let mut k3 = s;
                while k3 > 0 && bytes[k3 - 1].is_ascii_whitespace() {
                    k3 -= 1;
                }
                if k3 > 0 && bytes[k3 - 1] == b'.' {
                    // Field access: `self.field` roots at the field; deeper
                    // chains (`a.b.c`) are unnamed.
                    let mut k4 = k3 - 1;
                    while k4 > 0 && bytes[k4 - 1].is_ascii_whitespace() {
                        k4 -= 1;
                    }
                    let e2 = k4;
                    let mut s2 = k4;
                    while s2 > 0 && is_ident(bytes[s2 - 1]) {
                        s2 -= 1;
                    }
                    if &code[s2..e2] == "self" {
                        let mut k5 = s2;
                        while k5 > 0 && bytes[k5 - 1].is_ascii_whitespace() {
                            k5 -= 1;
                        }
                        if k5 == 0 || bytes[k5 - 1] != b'.' {
                            return Some(name.to_string());
                        }
                    }
                    return None;
                }
                return Some(name.to_string());
            }
            _ => return None,
        }
    }
}

/// Does any binding of `name` in this file look hash-backed? Matches
/// `name: …HashMap…` / `name = …HashSet…` within the same statement.
fn hash_associated(code: &str, name: &str) -> bool {
    for at in find_token(code, name) {
        let rest = code[at + name.len()..].trim_start();
        let after = match rest.as_bytes().first() {
            Some(b':') if rest.as_bytes().get(1) != Some(&b':') => &rest[1..],
            Some(b'=') if rest.as_bytes().get(1) != Some(&b'=') => &rest[1..],
            _ => continue,
        };
        let window = after.as_bytes();
        let window = &window[..window.len().min(64)];
        let window = window.split(|&b| b == b';').next().unwrap_or(window);
        if contains_bytes(window, b"HashMap") || contains_bytes(window, b"HashSet") {
            return true;
        }
    }
    false
}

fn contains_bytes(haystack: &[u8], needle: &[u8]) -> bool {
    haystack.windows(needle.len()).any(|w| w == needle)
}

/// Does the (masked) argument text reference any identifier? Numeric
/// literals — including hex, underscores, and type suffixes like `42u64`
/// — do not count.
fn contains_identifier(arg: &str) -> bool {
    let bytes = arg.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        if b.is_ascii_digit() {
            i += 1;
            while i < bytes.len() && is_ident(bytes[i]) {
                i += 1;
            }
        } else if b == b'_' || b.is_ascii_alphabetic() {
            return true;
        } else {
            i += 1;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::super::lexer::{mask, mask_cfg_test, LineIndex};
    use super::*;

    fn lint_src(rel: &str, src: &str) -> Vec<Diagnostic> {
        let masked = mask(src);
        let (code, _) = mask_cfg_test(&masked.code);
        run_rules(rel, &code, &LineIndex::new(src))
    }

    #[test]
    fn hashmap_flagged_only_in_digest_scope() {
        let src = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n";
        let hits = lint_src("rust/src/gpusim/x.rs", src);
        assert_eq!(hits.len(), 3);
        assert!(hits.iter().all(|d| d.rule == "no-unordered-iteration"));
        assert_eq!(hits[0].line, 1);
        assert!(lint_src("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_and_masking() {
        let src = "let t = std::time::Instant::now();\n// Instant::now in a comment\nlet s = \"SystemTime\";\n";
        let hits = lint_src("rust/src/util/x.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "no-wall-clock");
        assert_eq!(hits[0].line, 1);
    }

    #[test]
    fn poisonable_unwrap_but_not_recovery_pattern() {
        let src = "let a = m.lock().unwrap();\nlet b = m.lock().expect(\"poisoned\");\nlet c = m.lock().unwrap_or_else(|e| e.into_inner());\nlet d = m\n    .lock()\n    .unwrap();\n";
        let hits = lint_src("rust/src/util/x.rs", src);
        assert_eq!(hits.len(), 3);
        assert!(hits.iter().all(|d| d.rule == "no-poisonable-unwrap"));
        assert_eq!(hits[0].line, 1);
        assert_eq!(hits[1].line, 2);
        assert_eq!(hits[2].line, 6);
    }

    #[test]
    fn float_sum_over_hash_backed_source() {
        let src = "let m: HashMap<u32, f64> = source();\nlet t = m.values().sum::<f64>();\nlet v: Vec<f64> = rows();\nlet u = v.iter().sum::<f64>();\n";
        let hits = lint_src("rust/src/util/x.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "no-float-order-hazard");
        assert_eq!(hits[0].line, 2);
    }

    #[test]
    fn float_sum_roots_through_self_fields_and_filters() {
        let src = "let total = self\n    .weights\n    .iter()\n    .map(|r| r.rate)\n    .sum::<f64>();\nweights = HashMap::new();\n";
        let hits = lint_src("rust/src/util/x.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 5);
    }

    #[test]
    fn ambient_entropy_tokens_and_literal_seeds() {
        let src = "let h = RandomState::new();\nlet r = Rng::new(0x9E37_79B9);\nlet ok = Rng::new(seed ^ 7);\nlet ok2 = Rng::new(42u64.wrapping_add(seed));\n";
        let hits = lint_src("rust/src/util/x.rs", src);
        assert_eq!(hits.len(), 2);
        assert!(hits.iter().all(|d| d.rule == "no-ambient-entropy"));
        assert_eq!(hits[0].line, 1);
        assert_eq!(hits[1].line, 2);
    }

    #[test]
    fn rng_module_itself_is_exempt() {
        let src = "impl Rng { fn reseed() { let s = DefaultHasher::new(); } }\n";
        assert!(lint_src("rust/src/util/rng.rs", src).is_empty());
        assert_eq!(lint_src("rust/src/util/other.rs", src).len(), 1);
    }

    #[test]
    fn cfg_test_bodies_are_exempt() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let m = std::collections::HashMap::<u8, u8>::new(); m.lock().unwrap(); }\n}\n";
        assert!(lint_src("rust/src/gpusim/x.rs", src).is_empty());
    }
}
